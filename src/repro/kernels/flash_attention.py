"""Flash-attention forward Pallas kernel (tiled online-softmax).

Used for the 32k-prefill shapes: O(s^2) compute with O(s) memory — the
(sq, sk) logit matrix never materializes in HBM.  Supports causal masking
and an optional sliding window (gemma3 local layers).

Tiling: grid (b*h, sq/bq, sk/bk); (acc, m, l) online-softmax state lives in
VMEM scratch persisted across the sequential k-block dimension.  Causal
blocks strictly above the diagonal are skipped (no MXU work issued).
VMEM working set per step: bq*d + 2*bk*d + bq*bk floats — with the default
bq=bk=256, d<=256 that is ~1 MiB, MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, sq: int, sk: int, out_dtype):
    i = pl.program_id(1)
    kk = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # global row/col coordinates of this tile (last q row aligns to last k)
    q_off = i * bq + (sk - sq)
    k_off = kk * bk

    # causal block skip: the whole k-tile is strictly in the future
    live = True
    if causal:
        live = k_off <= q_off + bq - 1
    if window is not None:
        # block entirely outside the window (too far in the past)
        live = jnp.logical_and(live, k_off + bk - 1 > q_off - window) \
            if causal else (k_off + bk - 1 > q_off - window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = k_off + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= ki > qi - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kk == n_k - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / l).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 256,
                    bk: int = 256, interpret: bool = False) -> jax.Array:
    """q,k,v: (b, h, s, d) with kv heads pre-broadcast.  Returns (b,h,sq,d).

    sq and sk must be divisible by bq/bk (ops.py pads otherwise).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    # ValueError, not assert: `python -O` strips asserts and a ragged
    # sq/sk would silently truncate the attention grid
    if sq % bq or sk % bk:
        raise ValueError(
            f"sequence lengths must tile evenly: (sq={sq}, sk={sk}) vs "
            f"blocks (bq={bq}, bk={bk}); pad the operands (ops.py does) "
            f"or pick divisible block sizes")
    scale_ = float(scale) if scale is not None else float(d) ** -0.5
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, sk, d)
    vf = v.reshape(bh, sk, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale_, causal=causal, window=window,
            bq=bq, bk=bk, sq=sq, sk=sk, out_dtype=q.dtype),
        grid=(bh, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh_, i, kk: (bh_, i, 0)),
            pl.BlockSpec((1, bk, d), lambda bh_, i, kk: (bh_, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda bh_, i, kk: (bh_, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh_, i, kk: (bh_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
