"""W8A8 integer GEMM Pallas kernel — the LightPE-2 analogue on TPU.

int8 activations x int8 weights -> int32 accumulation on the MXU, with a
fused per-output-channel dequantization epilogue in VMEM (no HBM round trip
for the int32 accumulator).

Tiling: grid (m/bm, n/bn, k/bk); the int32 accumulator lives in a VMEM
scratch tile that persists across the (sequential) k dimension of the grid;
the epilogue fires on the last k step.  Block shapes default to MXU-aligned
(128, 128, 256): VMEM working set = bm*bk + bk*bn (int8) + bm*bn (int32)
= 32 KiB + 32 KiB + 64 KiB per step, comfortably double-bufferable in the
~128 MiB v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _w8a8_kernel(x_ref, w_ref, xs_ref, ws_ref, out_ref, acc_ref, *,
                 n_k: int, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out_ref[...] = (acc * xs_ref[0, 0] * ws_ref[...]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def w8a8_matmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 256, out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """(m,k) int8 @ (k,n) int8 with dequant epilogue.  m,n,k must be
    divisible by the block sizes (ops.py pads otherwise)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    # ValueError, not assert: `python -O` strips asserts and a
    # non-multiple m/n/k would silently truncate the grid
    if k != k2:
        raise ValueError(
            f"contraction mismatch: x_q {x_q.shape} has k={k} but w_q "
            f"{w_q.shape} has k={k2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shapes must tile evenly: (m={m}, n={n}, k={k}) vs blocks "
            f"(bm={bm}, bn={bn}, bk={bk}); pad the operands or pick "
            f"divisible block sizes")
    n_k = k // bk
    x_scale = jnp.asarray(x_scale, jnp.float32).reshape(1, 1)
    w_scale = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, n), (1, n))

    return pl.pallas_call(
        functools.partial(_w8a8_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
