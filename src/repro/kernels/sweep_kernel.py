"""Pallas sweep kernel — the hot (N x L) mapping + segment reduction.

The streamed DSE pipeline spends its device time in one place: the
row-stationary mapping + energy model over an ``(N configs, L layers)``
grid followed by a per-workload-segment reduction down to the
:data:`repro.core.dse_batch.AGGREGATE_OUTPUTS` columns.  The generic jax
path jits that as unfused XLA ops; this module writes it as a real Pallas
kernel with explicit tiling, following the tiling / ``pl.when``-epilogue /
scratch-accumulator idiom of :mod:`repro.kernels.w4a8_matmul`:

* grid ``(N/block_n, L/block_l)`` with the **layer axis innermost**, so
  each config tile revisits its output block while four ``(block_n, W)``
  VMEM scratch accumulators carry the running per-segment Kahan sums
  (cycles + energy, value + compensation) across layer tiles;
* the per-tile body *reuses* the shared array-namespace kernel
  (:func:`repro.core.dse_batch._sweep_kernel` with ``exact=False,
  outputs="layer_totals"``) on the tile's refs — one source of truth for
  the PPA math, so Pallas results track the jitted XLA path op-for-op;
* a ``(W, block_l)`` segment mask gates the sequential Kahan update per
  layer column, reproducing :func:`repro.core.dse_batch._kahan_sum_rows`
  over each ``[start, end)`` workload segment exactly (padded layer
  columns carry an all-zero mask and never touch the accumulators);
* the ``pl.when(l == n_l - 1)`` epilogue converts the accumulated sums to
  the six aggregate columns (latency, energy_j, throughput, perf/area)
  with the same formulas as ``_segment_aggregates``, writing one
  ``(block_n, 6 * W)`` output block per config tile.

``interpret=True`` (auto-selected when no accelerator platform is
attached) runs the same kernel through the Pallas interpreter on CPU —
bit-comparable to the jitted XLA path at the usual f32 tolerance, which
CI asserts at ≤1e-6 relative against the exact numpy kernel.  On an
accelerator the per-chunk config operands are donated
(``donate_argnums``) so steady-state streaming stops double-buffering
device memory.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.dse_batch import (AGGREGATE_OUTPUTS, _jax_has_accelerator,
                                  _sweep_kernel, _to_jax_inputs)

# operand order of the pallas_call — every cfg/lay field the mapping +
# energy model reads, one ref each (dicts don't cross the pallas boundary)
CFG_FIELDS = ("pe_rows", "pe_cols", "num_pes", "act_bits", "weight_bits",
              "glb_kb", "glb_bits", "filter_spad", "psum_spad",
              "spad_bits", "dram_bw_gbps", "mac_energy_pj", "clock_ghz",
              "area_mm2", "leak_mw")
LAY_FIELDS = ("r", "s", "e", "f", "c", "k", "h", "w", "batch", "macs")
# the per-layer precision columns that may be (N, L) instead of (N, 1)
MIXED_CFG_FIELDS = ("act_bits", "weight_bits", "mac_energy_pj")


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def resolve_pallas_interpret(interpret: bool | None = None) -> bool:
    """``None`` -> interpreter mode exactly when no accelerator platform
    is attached (the CPU-CI path); an explicit bool wins."""
    if interpret is None:
        return not _jax_has_accelerator()
    return bool(interpret)


def resolve_pallas_donate(donate: bool | None = None) -> bool:
    """``None`` -> donate per-chunk config operands only on a real
    accelerator (CPU jax can't consume donations and would warn)."""
    if donate is None:
        return _jax_has_accelerator()
    return bool(donate)


def _sweep_block_body(*refs, n_l: int, block_l: int, w: int):
    """One ``(block_n, block_l)`` tile: mapping + masked segment Kahan
    accumulation, epilogue on the last layer tile."""
    n_cfg, n_lay = len(CFG_FIELDS), len(LAY_FIELDS)
    cfg_refs = refs[:n_cfg]
    lay_refs = refs[n_cfg:n_cfg + n_lay]
    mask_ref, macs_ref, out_ref = refs[n_cfg + n_lay:n_cfg + n_lay + 3]
    acc_c, cmp_c, acc_e, cmp_e = refs[n_cfg + n_lay + 3:]

    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        acc_c[...] = jnp.zeros_like(acc_c)
        cmp_c[...] = jnp.zeros_like(cmp_c)
        acc_e[...] = jnp.zeros_like(acc_e)
        cmp_e[...] = jnp.zeros_like(cmp_e)

    cfg = {k: r[...] for k, r in zip(CFG_FIELDS, cfg_refs)}
    lay = {k: r[...] for k, r in zip(LAY_FIELDS, lay_refs)}
    totals = _sweep_kernel(jnp, cfg, lay, exact=False,
                           outputs="layer_totals")
    tc = totals["total_cycles"]            # (block_n, block_l) f32
    ep = totals["energy_pj"]
    mask = mask_ref[...]                   # (w, block_l) f32

    # Sequential compensated accumulation, one layer column at a time,
    # gated per segment: a segment's accumulator advances only on its own
    # columns, so each (config, segment) cell sees exactly the Kahan
    # update sequence of _kahan_sum_rows over that segment's slice.
    for j in range(block_l):
        sel = mask[:, j][None, :] > 0.5    # (1, w): layer j's segment(s)
        for acc_ref, cmp_ref, x in ((acc_c, cmp_c, tc),
                                    (acc_e, cmp_e, ep)):
            acc = acc_ref[...]
            comp = cmp_ref[...]
            y = x[:, j][:, None] - comp    # (block_n, w)
            t = acc + y
            c2 = (t - acc) - y
            acc_ref[...] = jnp.where(sel, t, acc)
            cmp_ref[...] = jnp.where(sel, c2, comp)

    @pl.when(l_idx == n_l - 1)
    def _epilogue():
        cycles = acc_c[...]                          # (block_n, w)
        energy = acc_e[...]
        clk = cfg["clock_ghz"]                       # (block_n, 1)
        latency_s = cycles / (clk * 1e9)
        energy_j = energy / 1e12
        throughput = macs_ref[...] / latency_s / 1e9  # (1, w) / (bn, w)
        perf_per_area = throughput / cfg["area_mm2"]
        out_ref[...] = jnp.concatenate(
            [cycles, energy, latency_s, energy_j, throughput,
             perf_per_area], axis=1)


@functools.lru_cache(maxsize=64)
def _build_sweep_call(n_pad: int, l_pad: int, w: int, block_n: int,
                      block_l: int, mixed_wide: tuple[bool, ...],
                      interpret: bool, donate: bool):
    """Compiled pallas_call for one (shape, tiling, mode) signature —
    cached so a steady-state chunk stream traces exactly once."""
    n_l = l_pad // block_l
    wide = dict(zip(MIXED_CFG_FIELDS, mixed_wide))

    cfg_block = pl.BlockSpec((block_n, 1), lambda i, l: (i, 0))
    cfg_block_wide = pl.BlockSpec((block_n, block_l), lambda i, l: (i, l))
    lay_block = pl.BlockSpec((1, block_l), lambda i, l: (0, l))
    in_specs = [cfg_block_wide if wide.get(name, False) else cfg_block
                for name in CFG_FIELDS]
    in_specs += [lay_block for _ in LAY_FIELDS]
    in_specs.append(pl.BlockSpec((w, block_l), lambda i, l: (0, l)))
    in_specs.append(pl.BlockSpec((1, w), lambda i, l: (0, 0)))

    call = pl.pallas_call(
        functools.partial(_sweep_block_body, n_l=n_l, block_l=block_l,
                          w=w),
        grid=(n_pad // block_n, n_l),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, 6 * w), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 6 * w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, w), jnp.float32)
                        for _ in range(4)],
        interpret=interpret,
    )
    # donating the per-chunk (N, ...) config operands lets steady-state
    # streaming reuse their device buffers instead of double-buffering
    donate_argnums = tuple(range(len(CFG_FIELDS))) if donate else ()
    return jax.jit(call, donate_argnums=donate_argnums)


def _pad_cfg(a: np.ndarray, n_pad: int, l_pad: int) -> np.ndarray:
    n, width = a.shape
    if n_pad > n:       # repeat the last row: valid throwaway work
        a = np.concatenate([a, np.repeat(a[-1:], n_pad - n, axis=0)])
    if width > 1 and l_pad > width:
        a = np.concatenate(
            [a, np.repeat(a[:, :1], l_pad - width, axis=1)], axis=1)
    return a


def _pad_lay(a: np.ndarray, l_pad: int) -> np.ndarray:
    width = a.shape[1]
    if l_pad > width:   # repeat layer 0: masked out of every segment
        a = np.concatenate(
            [a, np.repeat(a[:, :1], l_pad - width, axis=1)], axis=1)
    return a


def sweep_aggregates_pallas(cfg: dict, lay: dict, *,
                            bounds: tuple[tuple[int, int], ...] | None = None,
                            block_n: int | None = None,
                            block_l: int | None = None,
                            interpret: bool | None = None,
                            donate: bool | None = None) -> dict:
    """Aggregate sweep columns via the Pallas kernel.

    ``cfg`` / ``lay`` are the float64/int64 arrays of
    :func:`repro.core.dse_batch._make_cfg_lay` (the x64-free conversion
    happens here).  ``bounds=None`` treats the whole layer axis as one
    workload and returns ``{column: (N,)}`` like
    ``_run_kernel(..., outputs="aggregates")``; explicit ``bounds``
    returns ``{column: (W, N)}`` like ``_sweep_mixed_many``.  Results are
    jax arrays (dispatch is async under jit) — ``np.asarray`` to
    materialize.
    """
    missing = [k for k in CFG_FIELDS if k not in cfg]
    if missing:
        raise ValueError(
            f"sweep_aggregates_pallas: cfg is missing field(s) {missing}; "
            f"build it with repro.core.dse_batch._make_cfg_lay")
    missing = [k for k in LAY_FIELDS if k not in lay]
    if missing:
        raise ValueError(
            f"sweep_aggregates_pallas: lay is missing field(s) {missing}; "
            f"build it with repro.core.dse_batch._make_cfg_lay")
    n = int(np.shape(cfg["pe_rows"])[0])
    l = int(np.shape(lay["r"])[1])
    if n < 1 or l < 1:
        raise ValueError(
            f"sweep_aggregates_pallas: need at least one config and one "
            f"layer, got N={n}, L={l}")
    for name in CFG_FIELDS:
        shp = np.shape(cfg[name])
        want_widths = (1, l) if name in MIXED_CFG_FIELDS else (1,)
        if len(shp) != 2 or shp[0] != n or shp[1] not in want_widths:
            raise ValueError(
                f"sweep_aggregates_pallas: cfg[{name!r}] has shape {shp}; "
                f"expected ({n}, w) with w in {want_widths} — pass the "
                f"(N, 1) column form (or (N, L) for per-layer precision "
                f"fields)")
    for name in LAY_FIELDS:
        shp = np.shape(lay[name])
        if shp != (1, l):
            raise ValueError(
                f"sweep_aggregates_pallas: lay[{name!r}] has shape {shp}; "
                f"expected (1, {l})")
    squeeze = bounds is None
    if bounds is None:
        bounds = ((0, l),)
    bounds = tuple((int(s), int(e)) for s, e in bounds)
    for s, e in bounds:
        if not (0 <= s < e <= l):
            raise ValueError(
                f"sweep_aggregates_pallas: segment bounds ({s}, {e}) are "
                f"not a non-empty slice of the {l}-layer axis")
    w = len(bounds)

    interpret = resolve_pallas_interpret(interpret)
    donate = resolve_pallas_donate(donate)
    if block_n is None:
        block_n = min(512, _ceil_to(n, 8))
    if block_l is None:
        block_l = min(32, l)
    if block_n < 1 or block_l < 1:
        raise ValueError(
            f"sweep_aggregates_pallas: block sizes must be >= 1, got "
            f"block_n={block_n}, block_l={block_l}")

    jcfg, jlay = _to_jax_inputs(cfg, lay, exact=False)
    n_pad = _ceil_to(n, block_n)
    l_pad = _ceil_to(l, block_l)

    operands = [_pad_cfg(np.asarray(jcfg[name]), n_pad, l_pad)
                for name in CFG_FIELDS]
    operands += [_pad_lay(np.asarray(jlay[name]), l_pad)
                 for name in LAY_FIELDS]
    seg_mask = np.zeros((w, l_pad), dtype=np.float32)
    for wi, (s, e) in enumerate(bounds):
        seg_mask[wi, s:e] = 1.0
    seg_macs = np.array(
        [[jlay["macs"][0, s:e].sum(dtype=np.float32) for s, e in bounds]],
        dtype=np.float32)
    operands += [seg_mask, seg_macs]

    mixed_wide = tuple(np.shape(cfg[name])[1] == l and l > 1
                       for name in MIXED_CFG_FIELDS)
    fn = _build_sweep_call(n_pad, l_pad, w, block_n, block_l, mixed_wide,
                           interpret, donate)
    out = fn(*operands)                    # (n_pad, 6 * w), async

    result = {}
    for idx, name in enumerate(AGGREGATE_OUTPUTS):
        block = out[:n, idx * w:(idx + 1) * w]     # (N, W)
        result[name] = block[:, 0] if squeeze else block.T
    return result
