"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics the kernels are tested against (allclose sweeps
in tests/test_kernels_*.py).  No Pallas, no tiling — just math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import quantizers as qz


def w8a8_matmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """(m,k) int8 x (k,n) int8 -> int32 -> dequant(out = acc*sx*sw)."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def w4a8_matmul_ref(x_q: jax.Array, w_packed: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """int8 acts x nibble-packed pow2-int4 weights.

    ``w_packed``: (k//2, n) int8, two 4-bit codes per byte packed along k
    (see quantizers.pack_int4 applied along d_in).  Decode:
    value = sign * 2**(exp-7) * w_scale[n].
    """
    codes = qz.unpack_int4(w_packed.T).T              # (k, n) 4-bit codes
    w = qz.pow2_decode(codes, w_scale, jnp.float32)   # (k, n) float
    x = x_q.astype(jnp.float32) * x_scale
    return (x @ w).astype(out_dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Reference attention.  q,k,v: (b, h, s, d) — kv heads already
    broadcast to q heads.  Optional causal mask and sliding window."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (sk - sq)   # align last q with last k
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > (qi - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_partial_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                                 *, scale: float | None = None):
    """One-token decode attention against a KV shard, returning the partial
    softmax statistics used by the sharded flash-decode combine:

    q: (b, h, d); k,v: (b, s, h, d)  ->  (out, m, l) with
    out: (b, h, d) un-normalized partial sum, m: (b, h) row max,
    l: (b, h) sum of exp(logit - m).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out, m, l


def decode_attention_combine_ref(parts):
    """Merge partial (out, m, l) triples across KV shards (logsumexp)."""
    outs = jnp.stack([p[0] for p in parts])   # (n, b, h, d)
    ms = jnp.stack([p[1] for p in parts])     # (n, b, h)
    ls = jnp.stack([p[2] for p in parts])
    m_star = jnp.max(ms, axis=0)              # (b, h)
    alpha = jnp.exp(ms - m_star[None])        # (n, b, h)
    l_star = jnp.sum(alpha * ls, axis=0)
    out = jnp.sum(outs * alpha[..., None], axis=0) / l_star[..., None]
    return out


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         *, scale: float | None = None) -> jax.Array:
    """Full (unsharded) one-token decode attention oracle."""
    out, m, l = decode_attention_partial_ref(q, k, v, scale=scale)
    return (out / l[..., None]).astype(q.dtype)


def w8a8_decode_attention_ref(q, k_q, v_q, k_scale, v_scale, pos, *,
                              bs: int = 512) -> jax.Array:
    """Oracle for the W8A8 flash-decode kernel (block-wise semantics).

    q: (b, kvh, rep, hd) float; k_q/v_q: (b, S, kvh, hd) int8;
    k_scale/v_scale: (b, S, kvh) f32.  Matches the kernel's math exactly:
    q quantized per (row); probs quantized per (row, block) after folding
    the v-scales; both dots in int8->int32.
    """
    b, kvh, rep, hd = q.shape
    S = k_q.shape[1]
    scale = float(hd) ** -0.5
    qf = q.astype(jnp.float32)
    q_s = jnp.max(jnp.abs(qf), axis=-1, keepdims=True) / 127.0
    q_qq = jnp.round(qf / jnp.maximum(q_s, 1e-8)).astype(jnp.int8)
    li = jnp.einsum("bgrd,bsgd->bgrs", q_qq, k_q,
                    preferred_element_type=jnp.int32)
    logits = li.astype(jnp.float32) * (q_s * scale) \
        * k_scale.transpose(0, 2, 1)[:, :, None, :]
    ki = jnp.arange(S)[None, None, None, :]
    logits = jnp.where(ki <= pos, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pf = p * v_scale.transpose(0, 2, 1)[:, :, None, :]
    # block-wise prob quantization (the kernel's online form)
    pb = pf.reshape(b, kvh, rep, S // bs, bs)
    p_s = jnp.max(jnp.abs(pb), axis=-1, keepdims=True) / 127.0
    p_qq = jnp.round(pb / jnp.maximum(p_s, 1e-12)).astype(jnp.int8)
    vb = v_q.transpose(0, 2, 1, 3).reshape(b, kvh, S // bs, bs, hd)
    oi = jnp.einsum("bgrcs,bgcsd->bgrcd", p_qq, vb,
                    preferred_element_type=jnp.int32)
    out = jnp.sum(oi.astype(jnp.float32) * p_s, axis=3)   # (b,g,rep,hd)
    return (out / l[..., 0][..., None]).astype(q.dtype)
