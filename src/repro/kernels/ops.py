"""Jit'd public wrappers around the Pallas kernels.

Each op handles padding to MXU-aligned block multiples, backend selection
(``impl="auto"`` uses the Pallas kernel on TPU and the pure-jnp oracle on
CPU — interpret mode is for validation, not production), and shape
restoration.  Semantics are defined by :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import w8a8_matmul as _w8a8
from repro.kernels import w4a8_matmul as _w4a8
from repro.kernels import flash_attention as _flash


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def w8a8_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32,
                impl: str = "auto", bm: int = 128, bn: int = 128,
                bk: int = 256):
    """See ref.w8a8_matmul_ref.  x_q (m,k) int8, w_q (k,n) int8."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.w8a8_matmul_ref(x_q, w_q, x_scale, w_scale, out_dtype)
    interpret = impl == "interpret"
    m0, k0 = x_q.shape
    n0 = w_q.shape[1]
    x_q, _ = _pad_to(x_q, 0, bm)
    x_q, _ = _pad_to(x_q, 1, bk)
    w_q, _ = _pad_to(w_q, 0, bk)
    w_q, _ = _pad_to(w_q, 1, bn)
    ws = jnp.pad(jnp.asarray(w_scale, jnp.float32).reshape(-1),
                 (0, w_q.shape[1] - n0), constant_values=1.0)
    out = _w8a8.w8a8_matmul(x_q, w_q, x_scale, ws, bm=bm, bn=bn, bk=bk,
                            out_dtype=out_dtype, interpret=interpret)
    return out[:m0, :n0]


def w4a8_matmul(x_q, w_packed, x_scale, w_scale, *, out_dtype=jnp.float32,
                impl: str = "auto", bm: int = 128, bn: int = 128,
                bk: int = 256):
    """See ref.w4a8_matmul_ref.  x_q (m,k) int8, w_packed (k//2,n) int8."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.w4a8_matmul_ref(x_q, w_packed, x_scale, w_scale,
                                    out_dtype)
    interpret = impl == "interpret"
    m0, k0 = x_q.shape
    n0 = w_packed.shape[1]
    assert k0 % 2 == 0
    x_q, _ = _pad_to(x_q, 0, bm)
    x_q, _ = _pad_to(x_q, 1, bk)
    w_packed, _ = _pad_to(w_packed, 0, bk // 2)
    w_packed, _ = _pad_to(w_packed, 1, bn)
    ws = jnp.pad(jnp.asarray(w_scale, jnp.float32).reshape(-1),
                 (0, w_packed.shape[1] - n0), constant_values=1.0)
    out = _w4a8.w4a8_matmul(x_q, w_packed, x_scale, ws, bm=bm, bn=bn,
                            bk=bk, out_dtype=out_dtype, interpret=interpret)
    return out[:m0, :n0]


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, impl: str = "auto", bq: int = 256,
                    bk: int = 256):
    """See ref.flash_attention_ref.  q,k,v: (b, h, s, d)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, scale=scale)
    interpret = impl == "interpret"
    sq0, sk0 = q.shape[2], k.shape[2]
    bq_ = min(bq, sq0) if sq0 % min(bq, sq0) == 0 else bq
    bk_ = min(bk, sk0) if sk0 % min(bk, sk0) == 0 else bk
    # pad sequence dims; padded k positions are masked out by +q/-k offsets
    # only when causal; for safety we pad k with zeros and rely on causal /
    # window masks, so non-causal unpadded use requires divisible shapes.
    assert sq0 % bq_ == 0 and sk0 % bk_ == 0, (
        "pad seq lens to block multiples for the pallas path")
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, bq=bq_, bk=bk_,
                                  interpret=interpret)


def w8a8_decode_attention(q, k_q, v_q, k_scale, v_scale, pos, *,
                          bs: int = 512, impl: str = "auto"):
    """int8-KV grouped decode attention (see ref.w8a8_decode_attention_ref).

    The Pallas kernel streams int8 K/V blocks and runs both contractions
    on the MXU in int8 — the serving hot loop of the quantized decode
    path (§Perf cells A/C)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.w8a8_decode_attention_ref(q, k_q, v_q, k_scale,
                                              v_scale, pos, bs=bs)
    from repro.kernels import w8a8_decode as _dec
    return _dec.w8a8_decode_attention(q, k_q, v_q, k_scale, v_scale, pos,
                                      bs=bs, interpret=impl == "interpret")


# Decode attention (sharded flash-decode building blocks) is pure jnp —
# it is bandwidth-bound gather work, not MXU work; see kernels/ref.py.
decode_attention_partial = _ref.decode_attention_partial_ref
decode_attention_combine = _ref.decode_attention_combine_ref
decode_attention = _ref.decode_attention_ref
