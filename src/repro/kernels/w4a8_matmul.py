"""W4A8 power-of-two GEMM Pallas kernel — the LightPE-1 analogue on TPU.

int8 activations x nibble-packed 4-bit power-of-two weight codes
(sign | 3-bit exponent, the LightNN format).  TPU adaptation (DESIGN.md §4):
the ASIC's shift-only multiplier has no MXU meaning, but the 4-bit storage
is a 4x HBM->VMEM bandwidth win, so the kernel streams *packed* weights and
unpacks + decodes them in VMEM right before the MXU contraction:

    HBM:  (k/2, n) int8 packed        <- half the bytes of int8 weights
    VMEM: unpack -> (k, n) codes -> decode sign*2^(e-7) -> f32 tile
    MXU:  f32(acts) @ f32(weights) accumulated in f32
    epilogue: * x_scale * w_scale[n]

The decode is exact (powers of two are exactly representable), so the
kernel matches ref.w4a8_matmul_ref bit-for-bit in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.quant.quantizers import POW2_EXP_BIAS


def _decode_pow2_block(packed: jax.Array) -> jax.Array:
    """(bk//2, bn) packed int8 -> (bk, bn) f32 decoded weights (unscaled)."""
    p = packed.astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.int32)           # codes of even k
    hi = ((p >> 4) & 0xF).astype(jnp.int32)    # codes of odd k
    def decode(c):
        e = (c & 7) - POW2_EXP_BIAS
        sign = 1.0 - 2.0 * ((c >> 3) & 1).astype(jnp.float32)
        return sign * jnp.exp2(e.astype(jnp.float32))
    wlo = decode(lo)                           # (bk//2, bn)
    whi = decode(hi)
    # interleave rows: out[2i] = wlo[i], out[2i+1] = whi[i]
    bk2, bn = wlo.shape
    return jnp.stack([wlo, whi], axis=1).reshape(2 * bk2, bn)


def _w4a8_kernel(x_ref, wp_ref, xs_ref, ws_ref, out_ref, acc_ref, *,
                 n_k: int, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_pow2_block(wp_ref[...])                  # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] * xs_ref[0, 0]
                        * ws_ref[...]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def w4a8_matmul(x_q: jax.Array, w_packed: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 256, out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """(m,k) int8 @ packed (k//2,n) pow2-int4 with dequant epilogue."""
    m, k = x_q.shape
    kp, n = w_packed.shape
    # real ValueErrors, not asserts: under `python -O` an assert vanishes
    # and a non-multiple m/n/k silently truncates the grid into garbage
    if k != 2 * kp:
        raise ValueError(
            f"activation k={k} must be twice the packed weight rows "
            f"kp={kp} (two int4 values per int8 byte); got x_q "
            f"{x_q.shape} vs w_packed {w_packed.shape}")
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shapes must tile evenly: (m={m}, n={n}, k={k}) vs blocks "
            f"(bm={bm}, bn={bn}, bk={bk}); pad the operands or pick "
            f"divisible block sizes")
    if bk % 2:
        raise ValueError(
            f"bk={bk} must be even so each k-block unpacks whole int4 "
            f"pairs")
    n_k = k // bk
    x_scale = jnp.asarray(x_scale, jnp.float32).reshape(1, 1)
    w_scale = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, n), (1, n))

    return pl.pallas_call(
        functools.partial(_w4a8_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_q, w_packed, x_scale, w_scale)
