"""W8A8 flash-decode Pallas kernel — int8-KV grouped-query attention for
one decode step (the §Perf serving hot loop, LightPE-2 arithmetic).

For each (batch, kv-head) the kernel streams int8 K/V blocks from HBM with
their per-(position, head) scales, runs both contractions in int8 on the
MXU (QK^T with the query pre-quantized; PV with the block's probabilities
quantized per row after folding in the v-scales), and maintains online-
softmax state in VMEM.  HBM traffic per step ~= S * hd bytes per K and V
(int8) + S * 4 * 2 scale bytes — half the bf16 cache read, with int8 MACs.

Grid: (b * kvh, S / bs); scratch: acc (rep, hd) f32, m/l (rep, 1) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, out_ref,
            acc_ref, m_ref, l_ref, *, bs: int, scale: float, rep: int,
            hd: int, out_dtype):
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    pos = pos_ref[0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- quantize q once per block (cheap: (rep, hd)) --------------------
    q = q_ref[0].astype(jnp.float32)                       # (rep, hd)
    q_s = jnp.max(jnp.abs(q), axis=-1, keepdims=True) / 127.0
    q_q = jnp.round(q / jnp.maximum(q_s, 1e-8)).astype(jnp.int8)

    # ---- int8 QK^T ------------------------------------------------------
    k = k_ref[0]                                           # (bs, hd) int8
    li = jax.lax.dot_general(q_q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.int32)
    ks = ks_ref[0].reshape(1, bs)                          # (1, bs) f32
    logits = li.astype(jnp.float32) * (q_s * scale) * ks   # (rep, bs)
    ki = j * bs + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
    logits = jnp.where(ki <= pos, logits, NEG_INF)

    # ---- online softmax ---------------------------------------------------
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)                            # (rep, bs)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new

    # ---- int8 PV: fold v-scales into probs, quantize per row -------------
    vs = vs_ref[0].reshape(1, bs)                          # (1, bs)
    pf = p * vs
    p_s = jnp.max(jnp.abs(pf), axis=-1, keepdims=True) / 127.0
    p_q = jnp.round(pf / jnp.maximum(p_s, 1e-12)).astype(jnp.int8)
    v = v_ref[0]                                           # (bs, hd) int8
    oi = jax.lax.dot_general(p_q, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)
    acc_ref[...] = acc_ref[...] * alpha + oi.astype(jnp.float32) * p_s

    @pl.when(j == n_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / l).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def w8a8_decode_attention(q, k_q, v_q, k_scale, v_scale, pos, *,
                          bs: int = 512, interpret: bool = False):
    """q: (b, kvh, rep, hd) float; k_q/v_q: (b, S, kvh, hd) int8;
    k_scale/v_scale: (b, S, kvh) f32; pos: () int32.
    Returns (b, kvh, rep, hd) in q.dtype."""
    b, kvh, rep, hd = q.shape
    S = k_q.shape[1]
    # ValueError, not assert: `python -O` strips asserts and a ragged S
    # would silently truncate the sequence grid
    if S % bs:
        raise ValueError(
            f"kv sequence length S={S} must be divisible by the block "
            f"size bs={bs}; pad the cache or pick a divisible bs")
    scale = float(hd) ** -0.5
    bh = b * kvh
    qf = q.reshape(bh, rep, hd)
    kf = k_q.transpose(0, 2, 1, 3).reshape(bh, S, hd)
    vf = v_q.transpose(0, 2, 1, 3).reshape(bh, S, hd)
    ksf = k_scale.transpose(0, 2, 1).reshape(bh, S)
    vsf = v_scale.transpose(0, 2, 1).reshape(bh, S)
    posv = jnp.asarray(pos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale, rep=rep, hd=hd,
                          out_dtype=q.dtype),
        grid=(bh, S // bs),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # pos
            pl.BlockSpec((1, rep, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, rep, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
        ],
        interpret=interpret,
    )(posv, qf, kf, vf, ksf, vsf)
    return out.reshape(b, kvh, rep, hd)
