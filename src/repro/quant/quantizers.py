"""Quantizers mirroring the paper's PE types, in JAX.

* symmetric int8/int4 (per-tensor or per-channel) — the LightPE-2 / W8A8
  storage format;
* power-of-two ("one shift", LightNN) 4-bit weights — LightPE-1;
* two-term power-of-two ("two shifts + add") 8-bit weights — the LightPE-2
  datapath's exact arithmetic, used by the paper-faithful accuracy model;
* fake-quantization with straight-through estimators for QAT;
* int4 nibble packing for the Pallas W4A8 kernel.

All functions are pure and jit/vmap/grad-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _absmax(x: jax.Array, axis=None) -> jax.Array:
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-8)


# ---------------------------------------------------------------------------
# Symmetric integer quantization
# ---------------------------------------------------------------------------

def int_scale(x: jax.Array, bits: int, axis=None) -> jax.Array:
    """Symmetric scale so that absmax maps to the max quantized level."""
    qmax = 2 ** (bits - 1) - 1
    return _absmax(x, axis) / qmax


def quantize_int(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32)


def dequantize_int(q: jax.Array, scale: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_dequantize_int(x: jax.Array, bits: int, axis=None) -> jax.Array:
    # stay in x.dtype (int8 levels are exact in bf16): a f32 scale would
    # promote the whole fake-quant chain to f32 and double its HBM traffic
    scale = int_scale(x, bits, axis).astype(x.dtype)
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Power-of-two quantization (LightNN / LightPE)
# ---------------------------------------------------------------------------
# 4-bit code: [sign(1) | exp(3)]; value = sign * scale * 2**(exp - 7)
# exp in [0, 7] -> magnitudes scale * {2^-7 .. 2^0}.  No exact zero (the
# smallest level is scale/128), matching a shift-only datapath.

POW2_EXP_BIAS = 7


def pow2_encode(w: jax.Array, scale: jax.Array) -> jax.Array:
    """Encode weights to 4-bit pow2 codes (stored in int8, low nibble)."""
    mag = jnp.abs(w) / scale                       # (0, 1]-ish
    e = jnp.round(jnp.log2(jnp.maximum(mag, 2.0 ** (-POW2_EXP_BIAS))))
    e = jnp.clip(e + POW2_EXP_BIAS, 0, 7).astype(jnp.int8)
    sign = (w < 0).astype(jnp.int8)
    return (sign << 3) | e


def pow2_decode(code: jax.Array, scale: jax.Array,
                dtype=jnp.float32) -> jax.Array:
    e = (code & 7).astype(jnp.int32) - POW2_EXP_BIAS
    sign = 1.0 - 2.0 * ((code >> 3) & 1).astype(jnp.float32)
    return (sign * jnp.exp2(e.astype(jnp.float32)) * scale).astype(dtype)


def pow2_scale(w: jax.Array, axis=None) -> jax.Array:
    """Scale chosen so absmax lands on the top pow2 level (2^0 * scale)."""
    return _absmax(w, axis)


def quantize_dequantize_pow2(w: jax.Array, axis=None) -> jax.Array:
    scale = pow2_scale(w, axis)
    return pow2_decode(pow2_encode(w, scale), scale, w.dtype)


def quantize_dequantize_pow2_2term(w: jax.Array, axis=None) -> jax.Array:
    """Two-term pow2 ("two shifts + add", LightPE-2 datapath).

    Greedy residual: v1 = pow2(w); v2 = pow2(w - v1); result = v1 + v2.
    """
    scale = pow2_scale(w, axis)
    v1 = pow2_decode(pow2_encode(w, scale), scale, w.dtype)
    r = w - v1
    v2 = pow2_decode(pow2_encode(r, scale), scale, w.dtype)
    # only add the second term where it reduces error
    better = jnp.abs(w - (v1 + v2)) < jnp.abs(w - v1)
    return jnp.where(better, v1 + v2, v1)


# ---------------------------------------------------------------------------
# Straight-through estimators (QAT)
# ---------------------------------------------------------------------------

def ste(x: jax.Array, qdq: jax.Array) -> jax.Array:
    """Straight-through: forward = qdq(x), gradient = identity."""
    return x + jax.lax.stop_gradient(qdq - x)


def fake_quant_int(x: jax.Array, bits: int, axis=None) -> jax.Array:
    return ste(x, quantize_dequantize_int(x, bits, axis))


def fake_quant_pow2(x: jax.Array, axis=None) -> jax.Array:
    return ste(x, quantize_dequantize_pow2(x, axis))


def fake_quant_pow2_2term(x: jax.Array, axis=None) -> jax.Array:
    return ste(x, quantize_dequantize_pow2_2term(x, axis))


# ---------------------------------------------------------------------------
# int4 nibble packing (for the W4A8 Pallas kernel)
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack 4-bit codes pairwise along the last dim: (..., K) -> (..., K//2).

    Element 2i goes to the low nibble, 2i+1 to the high nibble.
    """
    assert codes.shape[-1] % 2 == 0, "last dim must be even to pack"
    lo = codes[..., 0::2].astype(jnp.uint8) & 0xF
    hi = codes[..., 1::2].astype(jnp.uint8) & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: (..., K//2) -> (..., K) uint4 codes."""
    p = packed.astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
