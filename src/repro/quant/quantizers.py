"""Quantizers mirroring the paper's PE types, in JAX.

* symmetric int8/int4 (per-tensor or per-channel) — the LightPE-2 / W8A8
  storage format;
* power-of-two ("one shift", LightNN) 4-bit weights — LightPE-1;
* two-term power-of-two ("two shifts + add") 8-bit weights — the LightPE-2
  datapath's exact arithmetic, used by the paper-faithful accuracy model;
* fake-quantization with straight-through estimators for QAT;
* int4 nibble packing for the Pallas W4A8 kernel.

Every fake-quant entry point is driven by one :class:`FakeQuantSpec`
config: :func:`quantize_dequantize` / :func:`fake_quant` dispatch on the
spec, and the historical per-kind functions are thin wrappers that build
the equivalent spec.  All functions are pure and jit/vmap/grad-safe.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def _absmax(x: jax.Array, axis=None) -> jax.Array:
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-8)


# ---------------------------------------------------------------------------
# Symmetric integer quantization
# ---------------------------------------------------------------------------

def int_scale(x: jax.Array, bits: int, axis=None) -> jax.Array:
    """Symmetric scale so that absmax maps to the max quantized level."""
    qmax = 2 ** (bits - 1) - 1
    return _absmax(x, axis) / qmax


def quantize_int(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32)


def dequantize_int(q: jax.Array, scale: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _qdq_int(x: jax.Array, bits: int, axis=None) -> jax.Array:
    # stay in x.dtype (int8 levels are exact in bf16): a f32 scale would
    # promote the whole fake-quant chain to f32 and double its HBM traffic
    scale = int_scale(x, bits, axis).astype(x.dtype)
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Power-of-two quantization (LightNN / LightPE)
# ---------------------------------------------------------------------------
# 4-bit code: [sign(1) | exp(3)]; value = sign * scale * 2**(exp - 7)
# exp in [0, 7] -> magnitudes scale * {2^-7 .. 2^0}.  No exact zero (the
# smallest level is scale/128), matching a shift-only datapath.

POW2_EXP_BIAS = 7


def pow2_encode(w: jax.Array, scale: jax.Array) -> jax.Array:
    """Encode weights to 4-bit pow2 codes (stored in int8, low nibble)."""
    mag = jnp.abs(w) / scale                       # (0, 1]-ish
    e = jnp.round(jnp.log2(jnp.maximum(mag, 2.0 ** (-POW2_EXP_BIAS))))
    e = jnp.clip(e + POW2_EXP_BIAS, 0, 7).astype(jnp.int8)
    sign = (w < 0).astype(jnp.int8)
    return (sign << 3) | e


def pow2_decode(code: jax.Array, scale: jax.Array,
                dtype=jnp.float32) -> jax.Array:
    e = (code & 7).astype(jnp.int32) - POW2_EXP_BIAS
    sign = 1.0 - 2.0 * ((code >> 3) & 1).astype(jnp.float32)
    return (sign * jnp.exp2(e.astype(jnp.float32)) * scale).astype(dtype)


def pow2_scale(w: jax.Array, axis=None) -> jax.Array:
    """Scale chosen so absmax lands on the top pow2 level (2^0 * scale)."""
    return _absmax(w, axis)


def _qdq_pow2(w: jax.Array, axis=None) -> jax.Array:
    scale = pow2_scale(w, axis)
    return pow2_decode(pow2_encode(w, scale), scale, w.dtype)


def _qdq_pow2_2term(w: jax.Array, axis=None) -> jax.Array:
    """Two-term pow2 ("two shifts + add", LightPE-2 datapath).

    Greedy residual: v1 = pow2(w); v2 = pow2(w - v1); result = v1 + v2.
    """
    scale = pow2_scale(w, axis)
    v1 = pow2_decode(pow2_encode(w, scale), scale, w.dtype)
    r = w - v1
    v2 = pow2_decode(pow2_encode(r, scale), scale, w.dtype)
    # only add the second term where it reduces error
    better = jnp.abs(w - (v1 + v2)) < jnp.abs(w - v1)
    return jnp.where(better, v1 + v2, v1)


# ---------------------------------------------------------------------------
# Unified fake-quant config
# ---------------------------------------------------------------------------

FAKE_QUANT_KINDS = ("none", "int", "pow2", "pow2_2term")

# code width is fixed by the datapath for the shift-based kinds
_KIND_BITS = {"none": 0, "int": 8, "pow2": 4, "pow2_2term": 8}


@dataclasses.dataclass(frozen=True)
class FakeQuantSpec:
    """One config describing any fake-quant transform in this module.

    ``kind`` picks the quantizer family ("none" is the fp passthrough),
    ``bits`` the code width (fixed per datapath for the pow2 kinds, so it
    defaults per kind and only "int" accepts other widths), ``axis`` the
    reduction axis of the scale.  ``per_channel`` without an explicit
    ``axis`` resolves to axis 0 — the (d_in, d_out) weight convention
    used across qlinear / the QAT loop / the calibrator.
    """

    kind: str = "int"
    bits: int | None = None
    axis: int | None = None
    per_channel: bool = False

    def __post_init__(self):
        if self.kind not in FAKE_QUANT_KINDS:
            raise ValueError(
                f"unknown fake-quant kind {self.kind!r}; "
                f"expected one of {FAKE_QUANT_KINDS}")
        if self.bits is None:
            object.__setattr__(self, "bits", _KIND_BITS[self.kind])
        elif self.kind in ("pow2", "pow2_2term", "none"):
            if self.bits != _KIND_BITS[self.kind]:
                raise ValueError(
                    f"kind {self.kind!r} has a fixed {_KIND_BITS[self.kind]}"
                    f"-bit code; got bits={self.bits}")
        elif not 2 <= self.bits <= 32:
            raise ValueError(f"int bits must be in [2, 32]; got {self.bits}")
        if self.axis is not None and not self.per_channel:
            object.__setattr__(self, "per_channel", True)

    @property
    def resolved_axis(self) -> int | None:
        """Scale axis after applying the per_channel default (axis 0)."""
        if self.axis is not None:
            return self.axis
        return 0 if self.per_channel else None


def quantize_dequantize(x: jax.Array, spec: FakeQuantSpec) -> jax.Array:
    """Quantize-dequantize ``x`` per ``spec`` (no STE; use for PTQ/eval)."""
    if spec.kind == "none":
        return x
    axis = spec.resolved_axis
    if spec.kind == "int":
        return _qdq_int(x, spec.bits, axis)
    if spec.kind == "pow2":
        return _qdq_pow2(x, axis)
    return _qdq_pow2_2term(x, axis)


# ---------------------------------------------------------------------------
# Straight-through estimators (QAT)
# ---------------------------------------------------------------------------

def ste(x: jax.Array, qdq: jax.Array) -> jax.Array:
    """Straight-through: forward = qdq(x), gradient = identity."""
    return x + jax.lax.stop_gradient(qdq - x)


def fake_quant(x: jax.Array, spec: FakeQuantSpec) -> jax.Array:
    """Fake-quantize ``x`` per ``spec``: forward = qdq, gradient = id."""
    if spec.kind == "none":
        return x
    return ste(x, quantize_dequantize(x, spec))


# -- historical per-kind entry points: thin wrappers over the spec form --

def quantize_dequantize_int(x: jax.Array, bits: int, axis=None) -> jax.Array:
    return quantize_dequantize(x, FakeQuantSpec("int", bits, axis))


def quantize_dequantize_pow2(w: jax.Array, axis=None) -> jax.Array:
    return quantize_dequantize(w, FakeQuantSpec("pow2", axis=axis))


def quantize_dequantize_pow2_2term(w: jax.Array, axis=None) -> jax.Array:
    return quantize_dequantize(w, FakeQuantSpec("pow2_2term", axis=axis))


def fake_quant_int(x: jax.Array, bits: int, axis=None) -> jax.Array:
    return fake_quant(x, FakeQuantSpec("int", bits, axis))


def fake_quant_pow2(x: jax.Array, axis=None) -> jax.Array:
    return fake_quant(x, FakeQuantSpec("pow2", axis=axis))


def fake_quant_pow2_2term(x: jax.Array, axis=None) -> jax.Array:
    return fake_quant(x, FakeQuantSpec("pow2_2term", axis=axis))


# ---------------------------------------------------------------------------
# int4 nibble packing (for the W4A8 Pallas kernel)
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack 4-bit codes pairwise along the last dim: (..., K) -> (..., K//2).

    Element 2i goes to the low nibble, 2i+1 to the high nibble.
    """
    assert codes.shape[-1] % 2 == 0, "last dim must be even to pack"
    lo = codes[..., 0::2].astype(jnp.uint8) & 0xF
    hi = codes[..., 1::2].astype(jnp.uint8) & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: (..., K//2) -> (..., K) uint4 codes."""
    p = packed.astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
