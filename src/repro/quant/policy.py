"""Execution-mode policy: maps the paper's PE types to TPU execution modes.

| QAPPA PE   | mode      | train (QAT)                 | serve              |
|------------|-----------|-----------------------------|--------------------|
| FP32       | fp32      | fp32 everywhere             | fp32               |
| INT16      | bf16      | bf16 compute (TPU 16b MAC)  | bf16               |
| LightPE-2  | w8a8      | fake-quant int8 acts+wts    | int8 MXU kernel    |
| LightPE-1  | w4a8_pow2 | fake-quant pow2 wts, int8 a | packed-int4 kernel |
"""

from __future__ import annotations

import dataclasses
import enum

import jax.numpy as jnp

from repro.core.pe import PEType


class ExecMode(str, enum.Enum):
    FP32 = "fp32"
    BF16 = "bf16"
    W8A8 = "w8a8"               # LightPE-2 analogue
    W4A8_POW2 = "w4a8_pow2"     # LightPE-1 analogue


PE_TO_MODE = {
    PEType.FP32: ExecMode.FP32,
    PEType.INT16: ExecMode.BF16,
    PEType.LIGHTPE2: ExecMode.W8A8,
    PEType.LIGHTPE1: ExecMode.W4A8_POW2,
}

MODE_TO_PE = {v: k for k, v in PE_TO_MODE.items()}


def mode_for_pe(pe_type) -> ExecMode:
    """The TPU execution mode for a QAPPA PE type.

    Raises a descriptive ``ValueError`` (never a bare ``KeyError``) when
    the type has no mapping — a PE type added for mixed-precision
    co-exploration must be wired into ``PE_TO_MODE`` before models can
    train/serve with it.
    """
    try:
        return PE_TO_MODE[PEType(pe_type)]
    except (KeyError, ValueError):
        raise ValueError(
            f"PE type {pe_type!r} has no execution-mode mapping; add it to "
            f"repro.quant.policy.PE_TO_MODE (known: "
            f"{sorted(t.value for t in PE_TO_MODE)})") from None


def pe_for_mode(mode) -> PEType:
    """Inverse of :func:`mode_for_pe`, with the same loud-failure contract."""
    try:
        return MODE_TO_PE[ExecMode(mode)]
    except (KeyError, ValueError):
        raise ValueError(
            f"execution mode {mode!r} has no PE-type mapping; add it to "
            f"repro.quant.policy.PE_TO_MODE (known: "
            f"{sorted(m.value for m in MODE_TO_PE)})") from None


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Resolved numerics policy for a model instance."""

    mode: ExecMode = ExecMode.BF16
    # weight-quantization axis convention: per-output-channel
    per_channel: bool = True
    # QAT: fake-quantize activations too (False = weight-only QAT with
    # dynamic act quantization at serve time; §Perf cell B iteration)
    qat_acts: bool = True
    # keep precision-sensitive ops (norms, softmax, SSM recurrence, router)
    # in this dtype regardless of mode
    stable_dtype: object = jnp.float32

    @property
    def compute_dtype(self):
        return jnp.float32 if self.mode == ExecMode.FP32 else jnp.bfloat16

    @property
    def quantized(self) -> bool:
        return self.mode in (ExecMode.W8A8, ExecMode.W4A8_POW2)

    @property
    def weight_bits(self) -> int:
        return {ExecMode.FP32: 32, ExecMode.BF16: 16,
                ExecMode.W8A8: 8, ExecMode.W4A8_POW2: 4}[self.mode]

    @property
    def act_bits(self) -> int:
        return {ExecMode.FP32: 32, ExecMode.BF16: 16,
                ExecMode.W8A8: 8, ExecMode.W4A8_POW2: 8}[self.mode]

    @property
    def pe_type(self) -> PEType:
        return pe_for_mode(self.mode)


def policy_for(mode: ExecMode | str | None) -> QuantPolicy:
    if mode is None:
        return QuantPolicy()
    return QuantPolicy(mode=ExecMode(mode))
