"""Quantization-aware linear algebra used by every model layer.

Two regimes, one entry point (:func:`qdot`):

* **train (QAT)** — weights/activations are fake-quantized with STE per the
  policy, contraction runs in the compute dtype.  Gradients flow.
* **serve** — weights are stored quantized (:class:`QuantizedTensor`:
  int8, or nibble-packed pow2-int4), activations are dynamically quantized
  to int8, and the contraction runs in integer arithmetic with a fused
  dequant epilogue (Pallas kernel on TPU; pure-jnp reference elsewhere).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.quant.policy import ExecMode, QuantPolicy
from repro.quant import quantizers as qz


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Serving-time quantized weight: data + per-output-channel scales.

    ``data`` layout:
      * w8a8: int8, logical shape (d_in, d_out)
      * w4a8_pow2: int8 nibble-packed pow2 codes, shape (d_in//2, d_out)
        packed along d_in (two input-channel codes per byte)
    """

    data: jax.Array
    scale: jax.Array          # (1, d_out) or scalar
    mode: str                 # static aux: ExecMode value
    orig_shape: tuple         # logical (d_in, d_out)

    def tree_flatten(self):
        return (self.data, self.scale), (self.mode, self.orig_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        mode, orig_shape = aux
        return cls(data=data, scale=scale, mode=mode, orig_shape=orig_shape)


def quantize_weight(w: jax.Array, policy: QuantPolicy) -> QuantizedTensor:
    """Quantize a (d_in, d_out) weight for serving."""
    assert w.ndim == 2, "quantize_weight expects (d_in, d_out)"
    if policy.mode == ExecMode.W8A8:
        scale = qz.int_scale(w, 8, axis=0)              # (1, d_out)
        q = qz.quantize_int(w, scale, 8)
        return QuantizedTensor(q, scale, policy.mode.value, tuple(w.shape))
    if policy.mode == ExecMode.W4A8_POW2:
        scale = qz.pow2_scale(w, axis=0)                # (1, d_out)
        codes = qz.pow2_encode(w, scale)                # (d_in, d_out) 4-bit
        packed = qz.pack_int4(codes.T).T                # pack along d_in
        return QuantizedTensor(packed, scale, policy.mode.value,
                               tuple(w.shape))
    raise ValueError(f"mode {policy.mode} is not a quantized mode")


def dequantize_weight(qw: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    if qw.mode == ExecMode.W8A8.value:
        return qz.dequantize_int(qw.data, qw.scale, dtype)
    if qw.mode == ExecMode.W4A8_POW2.value:
        codes = qz.unpack_int4(qw.data.T).T
        return qz.pow2_decode(codes, qw.scale, dtype)
    raise ValueError(qw.mode)


# ---------------------------------------------------------------------------
# QAT fake-quant contraction (training path)
# ---------------------------------------------------------------------------

def weight_quant_spec(policy: QuantPolicy, axis=0) -> qz.FakeQuantSpec:
    """FakeQuantSpec for a (d_in, d_out) weight under ``policy``."""
    if policy.mode == ExecMode.W8A8:
        return qz.FakeQuantSpec("int", 8, axis)
    if policy.mode == ExecMode.W4A8_POW2:
        return qz.FakeQuantSpec("pow2", axis=axis)
    return qz.FakeQuantSpec("none")


def act_quant_spec(policy: QuantPolicy) -> qz.FakeQuantSpec:
    """FakeQuantSpec for activations (dynamic per-tensor int8, or none)."""
    if policy.quantized and policy.qat_acts:
        return qz.FakeQuantSpec("int", 8)
    return qz.FakeQuantSpec("none")


def qat_weight(w: jax.Array, policy: QuantPolicy, axis=0) -> jax.Array:
    """Fake-quantized weight view for training; STE gradients."""
    return qz.fake_quant(w, weight_quant_spec(policy, axis=axis))


def qat_act(x: jax.Array, policy: QuantPolicy) -> jax.Array:
    """Fake-quantized activation (dynamic per-tensor int8)."""
    return qz.fake_quant(x, act_quant_spec(policy))


# ---------------------------------------------------------------------------
# Integer serving contraction (pure-jnp reference; kernels/ops.py provides
# the Pallas-accelerated variant with identical semantics)
# ---------------------------------------------------------------------------

def int8_dot(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
             w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """(m, k) int8 x (k, n) int8 -> int32 accumulate -> dequant."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def serve_dot(x: jax.Array, qw: QuantizedTensor,
              out_dtype=None) -> jax.Array:
    """Quantized serving matmul on the last dim of ``x``."""
    out_dtype = out_dtype or x.dtype
    d_in, d_out = qw.orig_shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d_in)
    x_scale = qz.int_scale(x2.astype(jnp.float32), 8, axis=None)
    x_q = qz.quantize_int(x2.astype(jnp.float32), x_scale, 8)
    if qw.mode == ExecMode.W8A8.value:
        from repro.kernels import ops
        out = ops.w8a8_matmul(x_q, qw.data, x_scale, qw.scale,
                              out_dtype=jnp.float32)
    elif qw.mode == ExecMode.W4A8_POW2.value:
        from repro.kernels import ops
        out = ops.w4a8_matmul(x_q, qw.data, x_scale, qw.scale,
                              out_dtype=jnp.float32)
    else:
        raise ValueError(qw.mode)
    return out.reshape(*lead, d_out).astype(out_dtype)


def qdot(x: jax.Array, w, policy: QuantPolicy, *, train: bool) -> jax.Array:
    """Unified quantization-aware (…, d_in) x (d_in, d_out) contraction."""
    if isinstance(w, QuantizedTensor):
        return serve_dot(x, w)
    if train and policy.quantized:
        xq = qat_act(x, policy)
        wq = qat_weight(w, policy, axis=0)
        return jnp.matmul(xq.astype(policy.compute_dtype),
                          wq.astype(policy.compute_dtype))
    return jnp.matmul(x.astype(policy.compute_dtype),
                      w.astype(policy.compute_dtype))
