"""Tier-1 accuracy calibration: per-layer, per-mode quantization noise
measured on *real* model-zoo tensors.

The synthetic SQNR proxy in :mod:`repro.explore.objectives` scores every
layer of every workload with one noise number per PE type, measured once
on a fixed Gaussian tensor.  This module grounds that signal in the
seeded model zoo: for a named config (gemma3-4b, mamba2-130m, …) it
initializes the real parameter tree at calibration width, runs every
projection weight of every layer through the actual fake quantizers
(:class:`repro.quant.quantizers.FakeQuantSpec`), samples activations
from the embedding of a fixed synthetic token batch, and records

* a per-layer, per-PE-type relative noise-power table (weight noise +
  activation noise, per-channel or per-tensor scales),
* per-layer distribution statistics (absmax, percentile scale, std)
  that explain *why* a layer is noisy,

collected once per (model, seed, percentile, per_channel) and cached to
an ``.npz`` keyed by a confighash digest, so the search loop pays one
table lookup per genome.

The calibration model is the zoo config at **full depth but reduced
width** — per-layer structure (and therefore per-layer noise variation)
is preserved while init stays CPU-cheap.  Only the stacked decoder
layers feed the table; shared / cross / encoder blocks are serving
details that the per-layer workload mapping cannot see anyway.

Everything here is import-light on purpose: the module pulls in only
numpy, the PE enum, and the quantizers, so
:mod:`repro.explore.objectives` can source its mode→quantizer pairs from
:data:`PE_QUANT_SPECS` without an import cycle.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import warnings

import numpy as np

from repro.core.pe import PEType
from repro.quant.quantizers import FakeQuantSpec

CALIB_VERSION = 1

_TYPES = tuple(PEType)

# mode -> (weight spec, act spec); None = native precision.  This is THE
# single definition of what quantizers each PE type runs — the synthetic
# tier-0 table in explore/objectives.py and the tier-1 calibrator here
# both consume it, so the two tiers can never drift apart.
PE_QUANT_SPECS: dict[PEType, tuple[FakeQuantSpec | None,
                                   FakeQuantSpec | None]] = {
    PEType.FP32: (None, None),
    PEType.INT16: (FakeQuantSpec("int", 16), FakeQuantSpec("int", 16)),
    PEType.LIGHTPE1: (FakeQuantSpec("pow2"), FakeQuantSpec("int", 8)),
    PEType.LIGHTPE2: (FakeQuantSpec("pow2_2term"), FakeQuantSpec("int", 8)),
}

# projection leaves that the serving path quantizes (Model.quantize_params)
PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
              "wq_x", "wk_img", "wv_img", "wo_x", "in_proj", "out_proj")

_CACHE_STATS = {"hits": 0, "misses": 0}


def calibration_cache_stats() -> dict[str, int]:
    """Copy of the process-wide npz-cache hit/miss counters."""
    return dict(_CACHE_STATS)


def reset_calibration_cache_stats() -> None:
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def calibration_cache_dir() -> pathlib.Path:
    """Cache root: ``$REPRO_CALIB_CACHE`` or ``~/.cache/repro-qappa/calibration``."""
    env = os.environ.get("REPRO_CALIB_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro-qappa" / "calibration"


def _rel_noise(v64: np.ndarray, q) -> float:
    """E[(v - qdq(v))^2] / E[v^2], accumulated in float64."""
    q64 = np.asarray(q, dtype=np.float64)
    return float(np.mean((v64 - q64) ** 2) / np.mean(v64 ** 2))


def _per_channel(spec: FakeQuantSpec) -> FakeQuantSpec:
    """Per-output-channel variant of a weight spec (axis 0 of (d_in, d_out)),
    matching the qlinear serve/QAT convention."""
    return dataclasses.replace(spec, axis=0, per_channel=True)


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """Per-layer, per-PE-type noise table for one calibrated model.

    ``table[l, t]`` is the relative quantization-noise power (weight +
    activation) layer ``l`` pays under PE type ``tuple(PEType)[t]`` —
    the same units as the tier-0 proxy table, so the two tiers are
    directly comparable.  ``per_tensor_table`` carries the per-tensor
    variant as a reported statistic regardless of which scale granularity
    ``table`` was built with.
    """

    model: str
    seed: int
    percentile: float
    per_channel: bool
    table: np.ndarray             # (L, T) float64
    per_tensor_table: np.ndarray  # (L, T) float64
    act_noise: np.ndarray         # (T,) float64, shared activation sample
    absmax: np.ndarray            # (L,) float64
    scale_pctl: np.ndarray        # (L,) float64  |w| percentile per layer
    std: np.ndarray               # (L,) float64

    @property
    def n_layers(self) -> int:
        return self.table.shape[0]

    def digest(self) -> str:
        """Content digest of the table itself (spec digest + data words):
        pinned into search checkpoints so a resumed run can refuse to
        continue against a different calibration."""
        from repro.core.confighash import digest_words, f64_words
        words = list(_spec_words(self.model, self.seed, self.percentile,
                                 self.per_channel))
        for arr in (self.table, self.per_tensor_table, self.act_noise):
            lo, hi = f64_words(np.ascontiguousarray(arr).ravel())
            words += list(lo) + list(hi)
        # scalar words make digest_words wrap in numpy-scalar arithmetic,
        # which warns on (intended) uint32 overflow — silence just that
        with np.errstate(over="ignore"):
            return "".join(f"{int(w):08x}" for w in digest_words(words))

    def state(self) -> dict[str, np.ndarray]:
        """Arrays for checkpoint snapshots (see SearchCheckpointer)."""
        return {"table": self.table,
                "per_tensor_table": self.per_tensor_table,
                "act_noise": self.act_noise,
                "absmax": self.absmax,
                "scale_pctl": self.scale_pctl,
                "std": self.std}


def _spec_words(model: str, seed: int, percentile: float,
                per_channel: bool):
    """Scalar uint32 words identifying a calibration spec (each word is
    absorbed individually by digest_words, so the list stays flat)."""
    from repro.core.confighash import f64_words
    raw = model.encode("utf-8")
    raw += b"\0" * (-len(raw) % 4)
    name_words = list(np.frombuffer(raw, dtype=np.uint32)) if raw else []
    plo, phi = f64_words(np.array([percentile]))
    return name_words + [np.uint32(len(raw)),
                         np.uint32(seed & 0xFFFFFFFF), plo[0], phi[0],
                         np.uint32(bool(per_channel)),
                         np.uint32(CALIB_VERSION)]


def calibration_key(model: str, *, seed: int = 0, percentile: float = 99.9,
                    per_channel: bool = True) -> str:
    """Hex cache key for a calibration spec (confighash digest)."""
    from repro.core.confighash import digest_words
    with np.errstate(over="ignore"):
        d = digest_words(_spec_words(model, seed, percentile, per_channel))
        return "".join(f"{int(w):08x}" for w in d)


def _collect_layer_weights(params, n_layers: int) -> list[list[np.ndarray]]:
    """Per-layer list of (d_in, d_out) float64 projection weights from the
    stacked ``params['layers']`` tree (the leaves quantize_params touches)."""
    per_layer: list[list[np.ndarray]] = [[] for _ in range(n_layers)]
    for name, leaf in sorted(params["layers"].items()):
        if name not in PROJ_NAMES:
            continue
        arr = np.asarray(leaf, dtype=np.float64)
        if arr.ndim != 3:      # stacked experts etc. stay unquantized
            continue
        for l in range(n_layers):
            per_layer[l].append(arr[l])
    return per_layer


def _measure(model: str, seed: int, percentile: float,
             per_channel: bool) -> CalibrationTable:
    import jax

    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import Model
    from repro.quant.quantizers import quantize_dequantize

    cfg = get_config(model)
    # full depth, reduced width: keeps per-layer structure, cheap on CPU
    calib_cfg = reduced(cfg, n_layers=cfg.n_layers)
    m = Model(calib_cfg)
    params = m.init(jax.random.key(seed))
    layers = _collect_layer_weights(params, calib_cfg.n_layers)
    if not any(layers):
        raise ValueError(
            f"model {model!r} exposes no stacked projection weights to "
            f"calibrate (families must populate params['layers'])")

    # one shared activation sample: embedding rows of a fixed token batch
    data = SyntheticLM(DataConfig(vocab=calib_cfg.vocab, seq_len=64,
                                  global_batch=4, seed=seed + 1))
    toks = np.asarray(data.batch(0)["tokens"]).ravel()
    embed = np.asarray(params["embed"], dtype=np.float64)
    act64 = embed[toks].ravel()
    act32 = np.asarray(act64, dtype=np.float32)

    T, L = len(_TYPES), calib_cfg.n_layers
    act_noise = np.zeros(T, dtype=np.float64)
    for t, (_, aspec) in PE_QUANT_SPECS.items():
        if aspec is not None:
            act_noise[_TYPES.index(t)] = _rel_noise(
                act64, quantize_dequantize(act32, aspec))

    w_pc = np.zeros((L, T), dtype=np.float64)
    w_pt = np.zeros((L, T), dtype=np.float64)
    absmax = np.zeros(L, dtype=np.float64)
    scale_pctl = np.zeros(L, dtype=np.float64)
    std = np.zeros(L, dtype=np.float64)
    for l, ws in enumerate(layers):
        flat = np.concatenate([w.ravel() for w in ws])
        absmax[l] = np.abs(flat).max()
        scale_pctl[l] = np.percentile(np.abs(flat), percentile)
        std[l] = flat.std()
        counts = np.array([w.size for w in ws], dtype=np.float64)
        shares = counts / counts.sum()
        for t, (wspec, _) in PE_QUANT_SPECS.items():
            ti = _TYPES.index(t)
            if wspec is None:
                continue
            for w64, share in zip(ws, shares):
                w32 = np.asarray(w64, dtype=np.float32)
                w_pc[l, ti] += share * _rel_noise(
                    w64, quantize_dequantize(w32, _per_channel(wspec)))
                w_pt[l, ti] += share * _rel_noise(
                    w64, quantize_dequantize(w32, wspec))

    table = (w_pc if per_channel else w_pt) + act_noise[None, :]
    return CalibrationTable(
        model=model, seed=seed, percentile=percentile,
        per_channel=per_channel, table=table,
        per_tensor_table=w_pt + act_noise[None, :], act_noise=act_noise,
        absmax=absmax, scale_pctl=scale_pctl, std=std)


def _analytic_fallback(model: str, seed: int, percentile: float,
                       per_channel: bool) -> CalibrationTable:
    """jax-unusable path: broadcast the tier-0 proxy table over the
    config's layer count so exploration still runs (loudly)."""
    from repro.configs.base import get_config
    from repro.explore.objectives import mode_noise_table

    L = get_config(model).n_layers
    row = np.asarray(mode_noise_table(), dtype=np.float64)
    table = np.tile(row, (L, 1))
    z = np.zeros(L, dtype=np.float64)
    return CalibrationTable(
        model=model, seed=seed, percentile=percentile,
        per_channel=per_channel, table=table, per_tensor_table=table.copy(),
        act_noise=np.zeros(len(_TYPES)), absmax=z, scale_pctl=z.copy(),
        std=z.copy())


def calibrate_model(model: str, *, seed: int = 0, percentile: float = 99.9,
                    per_channel: bool = True, cache_dir=None,
                    refresh: bool = False) -> CalibrationTable:
    """Calibrated per-layer noise table for a zoo model, npz-cached.

    The cache file name is the confighash digest of (model, seed,
    percentile, per_channel, CALIB_VERSION) — bumping :data:`CALIB_VERSION`
    invalidates every cached table; ``refresh=True`` bypasses one entry.
    """
    key = calibration_key(model, seed=seed, percentile=percentile,
                          per_channel=per_channel)
    cdir = pathlib.Path(cache_dir) if cache_dir else calibration_cache_dir()
    path = cdir / f"calib_{key}.npz"
    meta = dict(model=model, seed=seed, percentile=percentile,
                per_channel=per_channel)
    if path.exists() and not refresh:
        try:
            with np.load(path, allow_pickle=False) as z:
                tab = CalibrationTable(
                    table=z["table"], per_tensor_table=z["per_tensor_table"],
                    act_noise=z["act_noise"], absmax=z["absmax"],
                    scale_pctl=z["scale_pctl"], std=z["std"], **meta)
            _CACHE_STATS["hits"] += 1
            return tab
        except Exception as exc:      # corrupt cache entry: re-measure
            warnings.warn(f"unreadable calibration cache {path}: {exc}; "
                          f"re-measuring", RuntimeWarning, stacklevel=2)
    _CACHE_STATS["misses"] += 1
    try:
        tab = _measure(model, seed, percentile, per_channel)
    except ImportError as exc:
        warnings.warn(
            f"jax unusable ({exc}); calibration for {model!r} falls back "
            f"to the analytic proxy broadcast over layers",
            RuntimeWarning, stacklevel=2)
        return _analytic_fallback(model, seed, percentile, per_channel)
    try:
        cdir.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp.npz")
        np.savez(tmp, **tab.state())
        os.replace(tmp, path)
    except OSError as exc:            # read-only FS: table still usable
        warnings.warn(f"cannot write calibration cache {path}: {exc}",
                      RuntimeWarning, stacklevel=2)
    return tab
