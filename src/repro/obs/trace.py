"""Nestable span tracing with a bounded ring, Chrome-trace and JSONL export.

The exploration runtime's stage-level clock: a *span* is a named interval
with wall and CPU duration, structured attributes, and a parent — the
synthesis of chunk 17, generation 42 of an NSGA-II run, one checkpoint
save.  Spans land in a bounded in-memory ring (oldest evicted first) and,
when configured, are appended to a JSONL event log that survives
preemption alongside checkpoints (each line is a complete JSON object
flushed at span end, so a SIGKILL loses at most the spans still open).

Two recording APIs:

* ``with span("synthesize", chunk=i):`` — the common nested form; spans
  nest per thread, and each records its parent and depth.
* ``h = span_start("kernel", chunk=i)`` / ``span_end(h)`` — explicit
  start/stop for work whose begin and end live in different scopes
  (async kernel dispatch: started at dispatch, ended when the stream
  drains the chunk).

**The disabled path is a no-op**: ``span()`` returns a shared singleton
context manager and ``span_start`` returns ``None`` — no allocation, no
clock reads — so instrumented hot loops cost nothing until
:func:`configure` turns tracing on (the ``telemetry-smoke`` CI job gates
the *enabled* overhead at <2% on a real sweep).

``configure(jax_annotations=True)`` additionally wraps every
context-manager span in ``jax.profiler.TraceAnnotation``, so the same
stage names show up inside XLA device profiles.

Exports: :func:`export_chrome_trace` writes the standard
``{"traceEvents": [...]}`` Chrome ``trace_event`` document (loadable in
Perfetto / ``chrome://tracing``); :func:`load_jsonl` replays an event
log back into span dicts, tolerating the torn final line a SIGKILL can
leave.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time


class Span:
    """One closed (or still-open) traced interval."""

    __slots__ = ("span_id", "parent_id", "name", "t0_s", "dur_s",
                 "cpu_dur_s", "tid", "depth", "attrs", "status",
                 "_cpu0_s")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 t0_s: float, tid: int, depth: int, attrs: dict):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0_s = t0_s            # seconds since the tracer epoch
        self.dur_s: float | None = None
        self.cpu_dur_s: float | None = None
        self.tid = tid
        self.depth = depth
        self.attrs = attrs
        self.status = "ok"
        self._cpu0_s = time.process_time()

    def set(self, **attrs) -> None:
        """Attach/overwrite structured attributes while the span is open."""
        self.attrs.update(attrs)

    def as_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0_s": self.t0_s,
            "dur_s": self.dur_s,
            "cpu_dur_s": self.cpu_dur_s,
            "tid": self.tid,
            "depth": self.depth,
            "status": self.status,
            "attrs": self.attrs,
            "pid": os.getpid(),
        }


class _NoopSpan:
    """Shared do-nothing stand-in returned while tracing is disabled —
    supports the full ``Span`` surface so instrumented code never
    branches on the telemetry switch itself."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NOOP = _NoopSpan()


class _SpanCtx:
    """Context-manager wrapper that opens/closes one traced span (and,
    when configured, a ``jax.profiler.TraceAnnotation`` of the same
    name)."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_jax_ctx")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Span | None = None
        self._jax_ctx = None

    def __enter__(self) -> Span:
        self._span = self._tracer.start(self._name, self._attrs,
                                        on_stack=True)
        ann = _STATE["jax_annotation"]
        if ann is not None:
            try:
                self._jax_ctx = ann(self._name)
                self._jax_ctx.__enter__()
            except Exception:       # device profiler not active / usable
                self._jax_ctx = None
        return self._span

    def __exit__(self, exc_type, exc, tb):
        if self._jax_ctx is not None:
            with contextlib.suppress(Exception):
                self._jax_ctx.__exit__(exc_type, exc, tb)
        self._tracer.end(self._span,
                         status="error" if exc_type is not None else "ok",
                         pop_stack=True)
        return False


class Tracer:
    """Bounded ring of spans plus the per-thread nesting stacks.

    ``ring_size`` bounds memory for marathon runs: the ring keeps the
    newest N *closed* spans (eviction counted in ``n_evicted``), while
    the JSONL log — when configured — keeps everything.
    """

    def __init__(self, ring_size: int = 65536):
        self.ring_size = int(ring_size)
        self._ring: list[Span] = []
        self._ring_pos = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._ids = itertools.count(1)
        self.epoch_s = time.perf_counter()
        self.epoch_unix_s = time.time()
        self.n_recorded = 0
        self.n_evicted = 0

    # -- per-thread nesting ------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def current(self) -> Span | None:
        st = self._stack()
        return st[-1] if st else None

    # -- record ------------------------------------------------------------
    def start(self, name: str, attrs: dict, *,
              on_stack: bool = False) -> Span:
        st = self._stack()
        parent = st[-1] if st else None
        sp = Span(span_id=next(self._ids),
                  parent_id=parent.span_id if parent is not None else None,
                  name=name,
                  t0_s=time.perf_counter() - self.epoch_s,
                  tid=threading.get_ident(),
                  depth=len(st),
                  attrs=attrs)
        if on_stack:
            st.append(sp)
        return sp

    def end(self, sp: Span, *, status: str = "ok",
            pop_stack: bool = False) -> None:
        sp.dur_s = time.perf_counter() - self.epoch_s - sp.t0_s
        sp.cpu_dur_s = time.process_time() - sp._cpu0_s
        sp.status = status
        if pop_stack:
            st = self._stack()
            if st and st[-1] is sp:
                st.pop()
        with self._lock:
            if len(self._ring) < self.ring_size:
                self._ring.append(sp)
            else:
                self._ring[self._ring_pos] = sp
                self._ring_pos = (self._ring_pos + 1) % self.ring_size
                self.n_evicted += 1
            self.n_recorded += 1
        sink = _STATE["jsonl"]
        if sink is not None:
            _write_jsonl(sink, sp)

    # -- read --------------------------------------------------------------
    def spans(self, name: str | None = None) -> list[Span]:
        """Closed spans in end order (oldest surviving first)."""
        with self._lock:
            out = self._ring[self._ring_pos:] + self._ring[:self._ring_pos]
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring = []
            self._ring_pos = 0
            self.n_recorded = 0
            self.n_evicted = 0


# ---------------------------------------------------------------------------
# Module state: one process tracer behind one enable switch
# ---------------------------------------------------------------------------

_TRACER = Tracer()
_STATE: dict = {
    "enabled": False,
    "jsonl": None,              # open file object (append mode) or None
    "jsonl_path": None,
    "jsonl_lock": threading.Lock(),
    "jax_annotation": None,     # jax.profiler.TraceAnnotation when wired
}


def _write_jsonl(sink, sp: Span) -> None:
    line = json.dumps(sp.as_dict(), separators=(",", ":"),
                      default=_json_default)
    with _STATE["jsonl_lock"]:
        sink.write(line + "\n")
        sink.flush()            # each closed span survives a later SIGKILL


def _json_default(o):
    # numpy scalars and other non-JSON attrs degrade to their repr rather
    # than poisoning the whole log line
    try:
        return o.item()
    except Exception:
        return repr(o)


def is_enabled() -> bool:
    return _STATE["enabled"]


def get_tracer() -> Tracer:
    """The process tracer (its ring fills only while tracing is enabled)."""
    return _TRACER


def configure(enabled: bool = True, *,
              jsonl_path=None,
              ring_size: int | None = None,
              jax_annotations: bool = False,
              reset: bool = False) -> None:
    """Flip the process-wide tracing switch.

    ``jsonl_path`` opens (append) a line-per-span event log flushed at
    every span end; ``ring_size`` rebuilds the in-memory ring with a new
    bound; ``jax_annotations`` mirrors every context-manager span into
    ``jax.profiler.TraceAnnotation`` so stages appear in XLA device
    profiles (silently skipped when jax is unavailable); ``reset`` clears
    the ring first.  Disabling closes the JSONL log.
    """
    if ring_size is not None:
        _TRACER.ring_size = int(ring_size)
        _TRACER.clear()
    elif reset:
        _TRACER.clear()
    if _STATE["jsonl"] is not None and (
            not enabled or jsonl_path is None
            or str(jsonl_path) != _STATE["jsonl_path"]):
        with contextlib.suppress(Exception):
            _STATE["jsonl"].close()
        _STATE["jsonl"] = None
        _STATE["jsonl_path"] = None
    if enabled and jsonl_path is not None and _STATE["jsonl"] is None:
        path = os.fspath(jsonl_path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        _STATE["jsonl"] = open(path, "a", encoding="utf-8")
        _STATE["jsonl_path"] = path
    ann = None
    if enabled and jax_annotations:
        try:
            from jax.profiler import TraceAnnotation as ann
        except Exception:
            ann = None
    _STATE["jax_annotation"] = ann
    _STATE["enabled"] = bool(enabled)


def disable() -> None:
    """Turn tracing off and close the JSONL log (ring is kept)."""
    configure(enabled=False)


@contextlib.contextmanager
def configured(telemetry):
    """Scoped :func:`configure` for the facade's ``ExploreSpec(telemetry=...)``.

    ``None`` leaves the global switch untouched; ``True``/``False`` flip
    it for the duration; a dict is splatted into :func:`configure`
    (e.g. ``{"jsonl_path": ..., "jax_annotations": True}``).  The prior
    state is restored on exit, so one instrumented ``run()`` never leaks
    its telemetry setup into the next.
    """
    if telemetry is None:
        yield
        return
    prev = {"enabled": _STATE["enabled"],
            "jsonl_path": _STATE["jsonl_path"],
            "jax": _STATE["jax_annotation"] is not None}
    if isinstance(telemetry, dict):
        configure(**{"enabled": True, **telemetry})
    else:
        configure(enabled=bool(telemetry))
    try:
        yield
    finally:
        configure(enabled=prev["enabled"],
                  jsonl_path=prev["jsonl_path"],
                  jax_annotations=prev["jax"])


# ---------------------------------------------------------------------------
# Recording API used by instrumented code
# ---------------------------------------------------------------------------

def span(name: str, **attrs):
    """Context manager recording one nested span; a shared no-op while
    tracing is disabled (no allocation, no clock reads)."""
    if not _STATE["enabled"]:
        return _NOOP
    return _SpanCtx(_TRACER, name, attrs)


def span_start(name: str, **attrs) -> Span | None:
    """Open an *un-stacked* span for work that ends in another scope
    (async kernel dispatch).  Returns ``None`` while disabled — pass the
    handle straight to :func:`span_end`, which ignores ``None``."""
    if not _STATE["enabled"]:
        return None
    return _TRACER.start(name, attrs)


def span_end(handle: Span | None, *, status: str = "ok", **attrs) -> None:
    """Close a :func:`span_start` handle (no-op for ``None``)."""
    if handle is None:
        return
    if attrs:
        handle.attrs.update(attrs)
    _TRACER.end(handle, status=status)


class timed_span:
    """Span that *also* accumulates its wall duration into a plain dict —
    the bridge that lets legacy ``timings``-style accounting be populated
    by the same clock reads as the trace (``sink[key] += dur``).  Always
    times (the sink needs the number either way); records a span only
    while tracing is enabled.
    """

    __slots__ = ("_name", "_attrs", "_sink", "_key", "_t0", "_ctx")

    def __init__(self, name: str, sink: dict | None = None,
                 key: str | None = None, **attrs):
        self._name = name
        self._attrs = attrs
        self._sink = sink
        self._key = key
        self._ctx = None

    def __enter__(self):
        if _STATE["enabled"]:
            self._ctx = _SpanCtx(_TRACER, self._name, self._attrs)
            self._ctx.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter() - self._t0
        if self._sink is not None:
            self._sink[self._key] = self._sink.get(self._key, 0.0) + dur
        if self._ctx is not None:
            self._ctx.__exit__(exc_type, exc, tb)
        return False


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def export_chrome_trace(path=None, *, tracer: Tracer | None = None) -> dict:
    """Render the ring as a Chrome ``trace_event`` document.

    Complete spans become ``"ph": "X"`` duration events (microsecond
    timestamps relative to the tracer epoch); thread ids are remapped to
    small ints in first-seen order so Perfetto's track names stay
    readable.  When ``path`` is given the document is also written there
    as JSON.  Loadable in ``chrome://tracing`` / https://ui.perfetto.dev.
    """
    tr = tracer if tracer is not None else _TRACER
    tid_map: dict[int, int] = {}
    events = []
    for sp in tr.spans():
        tid = tid_map.setdefault(sp.tid, len(tid_map))
        events.append({
            "name": sp.name,
            "cat": "repro",
            "ph": "X",
            "ts": sp.t0_s * 1e6,
            "dur": (sp.dur_s or 0.0) * 1e6,
            "pid": os.getpid(),
            "tid": tid,
            "args": dict(sp.attrs, span_id=sp.span_id,
                         parent_id=sp.parent_id, status=sp.status,
                         cpu_dur_s=sp.cpu_dur_s),
        })
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "epoch_unix_s": tr.epoch_unix_s,
            "n_recorded": tr.n_recorded,
            "n_evicted": tr.n_evicted,
        },
    }
    if path is not None:
        with open(os.fspath(path), "w", encoding="utf-8") as fh:
            json.dump(doc, fh, default=_json_default)
    return doc


def load_jsonl(path) -> list[dict]:
    """Replay a JSONL event log into span dicts (end order).

    Tolerates the torn final line a SIGKILL can leave mid-write — every
    *complete* line is returned, a trailing partial one is dropped.
    """
    out: list[dict] = []
    with open(os.fspath(path), "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue            # torn tail from a kill mid-write
    return out


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema check for an exported (or re-loaded) Chrome trace document;
    returns a list of problems (empty = valid)."""
    problems: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a dict with a 'traceEvents' key"]
    ev = doc["traceEvents"]
    if not isinstance(ev, list):
        return ["'traceEvents' is not a list"]
    for i, e in enumerate(ev):
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                problems.append(f"event {i} missing {k!r}")
        if e.get("ph") == "X" and "dur" not in e:
            problems.append(f"event {i} is 'X' but has no 'dur'")
        if not isinstance(e.get("ts", 0), (int, float)) \
                or e.get("ts", 0) < 0:
            problems.append(f"event {i} has non-numeric/negative ts")
        if e.get("ph") == "X" and (
                not isinstance(e.get("dur", 0), (int, float))
                or e.get("dur", 0) < 0):
            problems.append(f"event {i} has non-numeric/negative dur")
    return problems
