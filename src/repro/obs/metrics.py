"""Process-local metrics registry: named counters, gauges, histograms.

One flat namespace of cheap instruments shared by every subsystem of the
exploration runtime — the synthesis caches count hits/misses, the
streamed sweep counts chunks/configs/watchdog redispatches, the search
engines count generations and kernel evaluations, the fleet simulator
records SLO attainment.  A single :func:`snapshot` renders everything as
one flat ``{name: number}`` dict that benches embed in their
``BENCH_*.json`` provenance blocks and tests assert against.

Unlike span *tracing* (:mod:`repro.obs.trace`, gated behind
``repro.obs.configure()``), the registry is always on: every instrument
is a plain Python attribute add at chunk/generation granularity — never
per design point — so the cost is unmeasurable against the array work it
accounts for.  Instruments are created on first use; a missing name in a
snapshot simply means that code path never ran.

Naming convention: dotted lowercase paths, ``<subsystem>.<thing>``
(``sweep.chunks``, ``synth_cache.hits``, ``explore.eval_seconds``).
Histogram snapshots expand to ``<name>.count/.sum/.min/.max/.mean``.
"""

from __future__ import annotations

import threading


class Counter:
    """Monotone accumulator (ints or floats — e.g. seconds)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Last-write-wins point-in-time value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Streaming summary: count / sum / min / max (O(1) memory).

    Enough to answer "how many, how much, how skewed" for per-chunk and
    per-generation durations without keeping samples; full distributions
    belong in the span ring (:mod:`repro.obs.trace`).
    """

    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def __repr__(self) -> str:
        return (f"Histogram({self.name}: n={self.count}, "
                f"mean={self.mean:.4g})")


class MetricsRegistry:
    """Name -> instrument store with a flat :meth:`snapshot`.

    Instrument *creation* is locked (threads may race the first use);
    updates on the returned objects are plain attribute math — the
    GIL-level atomicity is sufficient at the chunk/generation
    granularity every caller uses.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # -- instrument accessors (create on first use) -----------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name))
        return h

    # -- convenience write paths ------------------------------------------
    def inc(self, name: str, n=1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v) -> None:
        self.histogram(name).observe(v)

    # -- read side ---------------------------------------------------------
    def snapshot(self) -> dict:
        """Everything as one flat ``{name: number}`` dict (sorted keys).

        Counter/gauge names map straight to their values; histograms
        expand to ``.count/.sum/.min/.max/.mean`` suffixes.  The dict is
        a decoupled copy — JSON-serializable, safe to stash in a bench
        provenance block.
        """
        out: dict = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._hists.items():
            out[f"{name}.count"] = h.count
            out[f"{name}.sum"] = h.total
            if h.count:
                out[f"{name}.min"] = h.min
                out[f"{name}.max"] = h.max
                out[f"{name}.mean"] = h.mean
        return dict(sorted(out.items()))

    def reset(self) -> None:
        """Drop every instrument (tests and per-run scoping)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem writes to."""
    return _REGISTRY


def snapshot() -> dict:
    """Flat snapshot of the process-wide registry."""
    return _REGISTRY.snapshot()


def reset_metrics() -> None:
    """Zero the process-wide registry."""
    _REGISTRY.reset()
