"""End-of-run telemetry summary: per-stage time breakdown + derived rates.

:func:`summarize` folds the span ring and metrics registry into one
machine-readable dict — per-span-name aggregates (count / total / mean /
max wall seconds), the flat metrics snapshot, and the derived numbers the
ISSUE cares about (cache hit rate, evals/s, overlap fraction).
:func:`render_text` pretty-prints that dict for terminal tails of benches
and marathon runs.

Kept import-light on purpose: this module must never drag ``repro.core``
in at import time (core imports ``repro.obs``), and it does not — it only
reads the tracer ring and the registry snapshot.
"""

from __future__ import annotations


def _span_aggregates(spans) -> dict:
    agg: dict = {}
    for sp in spans:
        dur = sp.dur_s or 0.0
        a = agg.get(sp.name)
        if a is None:
            a = agg[sp.name] = {"count": 0, "total_s": 0.0, "max_s": 0.0,
                                "errors": 0}
        a["count"] += 1
        a["total_s"] += dur
        if dur > a["max_s"]:
            a["max_s"] = dur
        if sp.status != "ok":
            a["errors"] += 1
    for a in agg.values():
        a["mean_s"] = a["total_s"] / a["count"] if a["count"] else 0.0
    return dict(sorted(agg.items()))


def _derived(metrics: dict) -> dict:
    d: dict = {}
    hits = metrics.get("synth_cache.hits", 0)
    misses = metrics.get("synth_cache.misses", 0)
    if hits + misses:
        d["synth_cache_hit_rate"] = hits / (hits + misses)
    wall = metrics.get("sweep.wall_s", 0.0)
    if wall:
        d["sweep_configs_per_s"] = metrics.get("sweep.configs", 0) / wall
        synth = metrics.get("sweep.synth_s", 0.0)
        wait = metrics.get("sweep.kernel_wait_s", 0.0)
        # Fraction of host synthesis hidden behind kernel execution: with
        # perfect overlap wall ~= max(synth, kernel), with none it is the
        # sum — so (synth + wait) / wall > 1 means the stages overlapped.
        if synth + wait > 0:
            d["sweep_overlap_fraction"] = max(
                0.0, min(1.0, (synth + wait) / wall - 1.0))
    # device-side throughput: configs over time the kernel was actually
    # executing (busy), not the host wall — the accelerator-bound number
    # the depth-k prefetch queue is trying to saturate
    busy = metrics.get("sweep.kernel_busy_s", 0.0)
    if busy:
        d["sweep_device_configs_per_s"] = (
            metrics.get("sweep.configs", 0) / busy)
    # mean prefetch-queue occupancy: sweep.inflight is a histogram
    # observed once per dispatched chunk; its mean is how many finalize
    # handles the depth-k queue actually kept in flight
    occ_n = metrics.get("sweep.inflight.count", 0)
    if occ_n:
        d["sweep_queue_occupancy_mean"] = (
            metrics.get("sweep.inflight.sum", 0.0) / occ_n)
    ev_s = metrics.get("explore.eval_seconds", 0.0)
    if ev_s:
        d["explore_evals_per_s"] = metrics.get(
            "explore.requested_evals", 0) / ev_s
        d["explore_kernel_evals_per_s"] = metrics.get(
            "explore.kernel_evals", 0) / ev_s
    req = metrics.get("explore.requested_evals", 0)
    memo = metrics.get("explore.memo_hits", 0)
    if req:
        d["explore_memo_hit_rate"] = memo / req
    return d


def summarize(tracer=None, metrics: dict | None = None) -> dict:
    """One dict telling you where the run spent its time.

    ``tracer`` defaults to the process tracer; ``metrics`` defaults to a
    fresh registry :func:`~repro.obs.metrics.snapshot`.  Keys:
    ``spans`` (per-name aggregates), ``metrics`` (flat snapshot),
    ``derived`` (hit rates / rates per second / overlap fraction), and
    ``ring`` (recorded / evicted counts).
    """
    from . import metrics as _m
    from . import trace as _t
    tr = tracer if tracer is not None else _t.get_tracer()
    snap = metrics if metrics is not None else _m.snapshot()
    return {
        "spans": _span_aggregates(tr.spans()),
        "metrics": snap,
        "derived": _derived(snap),
        "ring": {"recorded": tr.n_recorded, "evicted": tr.n_evicted},
    }


def render_text(summary: dict | None = None) -> str:
    """Terminal rendering of :func:`summarize` (pass one, or build fresh)."""
    s = summary if summary is not None else summarize()
    lines = ["== telemetry report =="]
    spans = s.get("spans", {})
    if spans:
        lines.append("-- stages (wall time) --")
        width = max(len(n) for n in spans)
        for name, a in sorted(spans.items(),
                              key=lambda kv: -kv[1]["total_s"]):
            err = f"  errors={a['errors']}" if a.get("errors") else ""
            lines.append(
                f"  {name:<{width}}  n={a['count']:>6}  "
                f"total={a['total_s']:>9.3f}s  mean={a['mean_s']:.4f}s  "
                f"max={a['max_s']:.4f}s{err}")
    derived = s.get("derived", {})
    if derived:
        lines.append("-- derived --")
        for k, v in sorted(derived.items()):
            lines.append(f"  {k}: {v:.4g}" if isinstance(v, float)
                         else f"  {k}: {v}")
    metrics = s.get("metrics", {})
    if metrics:
        lines.append("-- metrics --")
        for k, v in metrics.items():
            lines.append(f"  {k}: {v:.6g}" if isinstance(v, float)
                         else f"  {k}: {v}")
    ring = s.get("ring")
    if ring and ring.get("evicted"):
        lines.append(f"-- ring: {ring['recorded']} recorded, "
                     f"{ring['evicted']} evicted (raise ring_size) --")
    return "\n".join(lines)
