"""repro.obs — unified telemetry for the exploration runtime.

Three zero-dependency pieces:

* :mod:`repro.obs.trace` — nestable span tracing (gated: off by default,
  flip with :func:`configure`), Chrome ``trace_event`` export, JSONL
  event log that survives preemption.
* :mod:`repro.obs.metrics` — always-on registry of named counters /
  gauges / histograms with a flat :func:`snapshot`.
* :mod:`repro.obs.report` — end-of-run summary (:func:`summarize` /
  :func:`render_text`).

This package is imported by ``repro.core`` and must never import it back
at module level.
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    reset_metrics,
    snapshot,
)
from .report import render_text, summarize
from .trace import (
    Span,
    Tracer,
    configure,
    configured,
    disable,
    export_chrome_trace,
    get_tracer,
    is_enabled,
    load_jsonl,
    span,
    span_end,
    span_start,
    timed_span,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "configure",
    "configured",
    "disable",
    "export_chrome_trace",
    "get_registry",
    "get_tracer",
    "is_enabled",
    "load_jsonl",
    "render_text",
    "reset_metrics",
    "snapshot",
    "span",
    "span_end",
    "span_start",
    "summarize",
    "timed_span",
    "validate_chrome_trace",
]
