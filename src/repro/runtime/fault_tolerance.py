"""Fault-tolerant training runtime: restart loop, failure injection,
straggler detection.

On a real fleet these hooks bind to the cluster scheduler; the logic here
is the part that must be correct regardless of fleet plumbing:

* the restart loop resumes from the newest *valid* checkpoint and replays
  the data cursor, giving bitwise-identical training to an uninterrupted
  run (tested in tests/test_runtime.py);
* failure injection kills the step loop at a chosen step to exercise that
  path deterministically;
* the straggler detector keeps an EWMA + variance of step wall-times and
  flags outliers (on a fleet this feeds re-sharding / hot-sparing).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.checkpoint import checkpoint as ckpt_lib


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1
    threshold: float = 3.0        # flag if step > mean + threshold * std
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        # test against the PRE-update statistics: the outlier must not
        # contaminate the baseline it is compared to
        sigma = max(self.var, 1e-12) ** 0.5
        is_straggler = self.n > 5 and \
            dt > self.mean + self.threshold * max(sigma, 0.1 * self.mean)
        delta = dt - self.mean
        if not is_straggler:       # robust EWMA: outliers don't pollute
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var
                                           + self.alpha * delta ** 2)
        self.flagged += int(is_straggler)
        return is_straggler


@dataclasses.dataclass
class TrainLoopResult:
    final_step: int
    restarts: int
    losses: list
    straggler_flags: int


def run_with_restarts(
    *,
    init_state: Callable[[], dict],
    train_step: Callable[[dict, dict], tuple],   # (state, batch) -> (state, loss)
    data_batch: Callable[[int], dict],
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    fail_at: dict[int, int] | None = None,       # {step: n_times_to_fail}
    max_restarts: int = 10,
) -> TrainLoopResult:
    """Checkpoint/restart driver.  ``state`` must contain a 'step' entry."""
    fail_at = dict(fail_at or {})
    restarts = 0
    losses: list = []
    detector = StragglerDetector()

    while True:
        state = init_state()
        step, restored = ckpt_lib.restore_latest(ckpt_dir, state)
        if restored is not None:
            state = restored
            start = int(step) + 1
        else:
            start = 0
        try:
            for s in range(start, total_steps):
                if fail_at.get(s, 0) > 0:
                    fail_at[s] -= 1
                    raise InjectedFailure(f"injected failure at step {s}")
                t0 = time.monotonic()
                state, loss = train_step(state, data_batch(s))
                detector.observe(time.monotonic() - t0)
                losses.append((s, float(loss)))
                if (s + 1) % ckpt_every == 0 or s == total_steps - 1:
                    ckpt_lib.save(ckpt_dir, s, state)
            return TrainLoopResult(final_step=total_steps - 1,
                                   restarts=restarts, losses=losses,
                                   straggler_flags=detector.flagged)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
