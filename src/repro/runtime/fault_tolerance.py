"""Fault-tolerant runtime: restart loop, failure injection, straggler
detection.

On a real fleet these hooks bind to the cluster scheduler; the logic here
is the part that must be correct regardless of fleet plumbing:

* the restart loop resumes from the newest *valid* checkpoint and replays
  the data cursor, giving bitwise-identical training to an uninterrupted
  run (tested in tests/test_runtime.py);
* failure injection kills the step loop at a chosen step to exercise that
  path deterministically;
* the straggler detector keeps an EWMA + variance of step wall-times and
  flags outliers (on a fleet this feeds re-sharding / hot-sparing), and
  re-baselines after a run of consecutive flags so a *permanent*
  distribution shift (slower hardware after resume, a migrated host) is
  adopted as the new normal instead of flagging every step forever.

:func:`restart_loop` is the generic retry driver shared by the training
loop here and the exploration runtime
(:mod:`repro.runtime.dse_checkpoint`): a configurable retryable-exception
set with exponential backoff between restarts.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, TypeVar

from repro.checkpoint import checkpoint as ckpt_lib

T = TypeVar("T")


class InjectedFailure(RuntimeError):
    """Deterministic fault injection — raised at a chosen step / chunk /
    generation boundary to exercise the restart path."""


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1
    threshold: float = 3.0        # flag if step > mean + threshold * std
    rebaseline_after: int = 8     # K consecutive flags => adopt new regime
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0
    consecutive_flags: int = 0
    rebaselines: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        # test against the PRE-update statistics: the outlier must not
        # contaminate the baseline it is compared to
        sigma = max(self.var, 1e-12) ** 0.5
        is_straggler = self.n > 5 and \
            dt > self.mean + self.threshold * max(sigma, 0.1 * self.mean)
        delta = dt - self.mean
        if not is_straggler:       # robust EWMA: outliers don't pollute
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var
                                           + self.alpha * delta ** 2)
            self.consecutive_flags = 0
        else:
            self.flagged += 1
            self.consecutive_flags += 1
            if self.consecutive_flags >= self.rebaseline_after:
                # K flags in a row is not K independent outliers — the
                # distribution shifted (e.g. slower hardware after a
                # resume).  Adopt the new level as the baseline and
                # restart the warm-up so flagging resumes only against
                # the new regime.
                self.mean = dt
                self.var = 0.0
                self.n = 1
                self.consecutive_flags = 0
                self.rebaselines += 1
        return is_straggler


def restart_loop(attempt: Callable[[], T], *,
                 max_restarts: int = 10,
                 retryable: tuple = (InjectedFailure,),
                 backoff_s: float = 0.0,
                 backoff_factor: float = 2.0,
                 max_backoff_s: float = 30.0,
                 on_restart: Callable[[int, BaseException], None]
                 | None = None) -> tuple[int, T]:
    """Run ``attempt()`` until it returns, restarting on ``retryable``
    exceptions with exponential backoff.

    Returns ``(restarts, result)``.  Exceptions outside ``retryable``
    propagate immediately; more than ``max_restarts`` retryable failures
    re-raise the last one.  ``backoff_s`` is the first sleep (0 disables
    sleeping entirely — the default, so tests and in-process resume stay
    instant); each restart multiplies it by ``backoff_factor`` up to
    ``max_backoff_s``.
    """
    retryable = tuple(retryable)
    restarts = 0
    while True:
        try:
            return restarts, attempt()
        except retryable as exc:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, exc)
            if backoff_s > 0:
                time.sleep(min(backoff_s * backoff_factor ** (restarts - 1),
                               max_backoff_s))


@dataclasses.dataclass
class TrainLoopResult:
    final_step: int
    restarts: int
    losses: list
    straggler_flags: int


def run_with_restarts(
    *,
    init_state: Callable[[], dict],
    train_step: Callable[[dict, dict], tuple],   # (state, batch) -> (state, loss)
    data_batch: Callable[[int], dict],
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    fail_at: dict[int, int] | None = None,       # {step: n_times_to_fail}
    max_restarts: int = 10,
    retryable: tuple = (InjectedFailure,),
    backoff_s: float = 0.0,
    backoff_factor: float = 2.0,
    max_backoff_s: float = 30.0,
) -> TrainLoopResult:
    """Checkpoint/restart driver.  ``state`` must contain a 'step' entry.

    ``retryable`` configures which exceptions trigger a restart-from-
    checkpoint (anything else propagates), and ``backoff_s`` /
    ``backoff_factor`` / ``max_backoff_s`` add exponential backoff between
    restarts — on a real fleet a crash loop must not hammer the scheduler.
    """
    fail_at = dict(fail_at or {})
    losses: list = []
    detector = StragglerDetector()

    def attempt() -> int:
        state = init_state()
        step, restored = ckpt_lib.restore_latest(ckpt_dir, state)
        if restored is not None:
            state = restored
            start = int(step) + 1
        else:
            start = 0
        for s in range(start, total_steps):
            if fail_at.get(s, 0) > 0:
                fail_at[s] -= 1
                raise InjectedFailure(f"injected failure at step {s}")
            t0 = time.monotonic()
            state, loss = train_step(state, data_batch(s))
            detector.observe(time.monotonic() - t0)
            losses.append((s, float(loss)))
            if (s + 1) % ckpt_every == 0 or s == total_steps - 1:
                ckpt_lib.save(ckpt_dir, s, state)
        return total_steps - 1

    restarts, final_step = restart_loop(
        attempt, max_restarts=max_restarts, retryable=retryable,
        backoff_s=backoff_s, backoff_factor=backoff_factor,
        max_backoff_s=max_backoff_s)
    return TrainLoopResult(final_step=final_step, restarts=restarts,
                           losses=losses,
                           straggler_flags=detector.flagged)
