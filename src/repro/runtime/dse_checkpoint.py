"""Preemption-safe exploration runtime: checkpoint/resume for streamed
sweeps and evolutionary searches.

A week-long :func:`repro.core.dse_batch._sweep_chunked` stream or NSGA-II
run must survive the preemptions a real fleet guarantees.  This module
extends the training-loop fault-tolerance idiom
(:mod:`repro.runtime.fault_tolerance`) to DSE:

* :class:`SweepCheckpointer` — periodic snapshots of chunked-sweep state:
  stream cursor, running Pareto front, and synthesis-cache rows *and*
  hit/miss accounting, serialized through the self-describing state
  format of :mod:`repro.checkpoint.checkpoint` (atomic publish, content
  checksums, keep-N rotation).
* :class:`SearchCheckpointer` — generation snapshots of NSGA-II state:
  generation index, population, external archive, hypervolume history,
  per-generation objective trail, and the **threaded RNG state**, so the
  resumed tournament draws continue the exact random stream.
* :func:`resume_sweep` / :func:`resume_search` — ``run_with_restarts``-
  style drivers built on :func:`~repro.runtime.fault_tolerance
  .restart_loop`: restore the newest *valid* snapshot, replay, and keep
  restarting (configurable retryable set, exponential backoff) until the
  run completes.  The resumed result is **bit-identical** to an
  uninterrupted run on the numpy backend — Pareto front bytes *and*
  cache hit/miss counters — exercised deterministically via
  ``fail_at={chunk: n}`` / ``fail_at_generation={gen: n}`` injection
  (tests/test_dse_checkpoint.py).

Surfaced on the facade as ``ExploreSpec(checkpoint_dir=...)`` →
:func:`repro.core.dse.run`.
"""

from __future__ import annotations

import json

import numpy as np

from repro.checkpoint.checkpoint import restore_latest_state, save_state
from repro.core.dse_batch import ChunkedSweep, _sweep_chunked
from repro.core.synthesis import PersistentSynthesisCache
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.fault_tolerance import InjectedFailure, restart_loop


class SweepCheckpointer:
    """Snapshot/restore driver for the chunked-sweep stream.

    Duck-typed against ``_sweep_chunked(checkpoint=...)``: the sweep calls
    :meth:`should_save` with the post-chunk cursor, :meth:`save` with the
    stream state captured *at the synthesis boundary of that cursor* (so
    pipelined lookahead never leaks into a snapshot), and
    :meth:`restore` once on entry.
    """

    def __init__(self, ckpt_dir: str, *, every: int = 8, keep: int = 3):
        if every < 1:
            raise ValueError(f"checkpoint every must be >= 1, got {every}")
        self.ckpt_dir = str(ckpt_dir)
        self.every = int(every)
        self.keep = int(keep)
        self.saves = 0

    def should_save(self, cursor: int) -> bool:
        return cursor > 0 and cursor % self.every == 0

    def save(self, *, cursor: int, n_total: int, front_soa: dict,
             front_metrics: dict, cache_state: dict | None) -> str:
        state = {
            "kind": "sweep",
            "cursor": int(cursor),
            "n_total": int(n_total),
            "front_soa": {k: np.asarray(v)
                          for k, v in (front_soa or {}).items()},
            "front_metrics": {k: np.asarray(v)
                              for k, v in (front_metrics or {}).items()},
        }
        if cache_state is not None:
            state["cache"] = cache_state
        with obs_trace.span("checkpoint.save", kind="sweep",
                            cursor=int(cursor)):
            path = save_state(self.ckpt_dir, cursor, state,
                              keep=self.keep)
        self.saves += 1
        obs_metrics.get_registry().inc("checkpoint.saves")
        return path

    def restore(self) -> dict | None:
        with obs_trace.span("checkpoint.restore", kind="sweep"):
            _, state = restore_latest_state(self.ckpt_dir)
        if state is None or state.get("kind") != "sweep":
            return None
        obs_metrics.get_registry().inc("checkpoint.restores")
        return {
            "cursor": int(state["cursor"]),
            "n_total": int(state["n_total"]),
            "front_soa": state.get("front_soa", {}),
            "front_metrics": state.get("front_metrics", {}),
            "cache_state": state.get("cache"),
        }


class SearchCheckpointer:
    """Generation-boundary snapshot/restore driver for NSGA-II
    (:func:`repro.explore.search.nsga2`, ``checkpoint_dir=...``)."""

    def __init__(self, ckpt_dir: str, *, every: int = 5, keep: int = 3):
        if every < 1:
            raise ValueError(f"checkpoint every must be >= 1, got {every}")
        self.ckpt_dir = str(ckpt_dir)
        self.every = int(every)
        self.keep = int(keep)
        self.saves = 0

    def should_save(self, gen: int, done: bool = False) -> bool:
        return done or gen % self.every == 0

    def save(self, *, gen: int, evals: int, pop: np.ndarray, F: np.ndarray,
             arch_g: np.ndarray, arch_F: np.ndarray, ref: np.ndarray,
             history: list, all_F: list, rng_state: dict,
             eps_vec: np.ndarray | None,
             accuracy_state: dict | None = None,
             accuracy_digest: str | None = None) -> str:
        state = {
            "kind": "search",
            "gen": int(gen),
            "evals": int(evals),
            "pop": np.asarray(pop),
            "F": np.asarray(F),
            "arch_g": np.asarray(arch_g),
            "arch_F": np.asarray(arch_F),
            "ref": np.asarray(ref, dtype=np.float64),
            "history_evals": np.array([e for e, _ in history],
                                      dtype=np.int64),
            "history_hv": np.array([h for _, h in history],
                                   dtype=np.float64),
            "all_F": np.concatenate(all_F, axis=0),
            "all_F_lens": np.array([len(a) for a in all_F],
                                   dtype=np.int64),
            # PCG64 state round-trips exactly through JSON (arbitrary-
            # precision ints), so resumed tournament draws continue the
            # same stream bit for bit
            "rng_state": json.dumps(rng_state),
        }
        if eps_vec is not None:
            state["eps_vec"] = np.asarray(eps_vec, dtype=np.float64)
        # the exact accuracy table the run was scored with (tiered
        # accuracy models, repro.explore.accuracy): resume pins it and
        # verifies the digest so a changed calibration can't silently
        # re-score a resumed front
        if accuracy_state is not None:
            state["accuracy_state"] = {k: np.asarray(v)
                                       for k, v in accuracy_state.items()}
        if accuracy_digest is not None:
            state["accuracy_digest"] = str(accuracy_digest)
        with obs_trace.span("checkpoint.save", kind="search",
                            gen=int(gen)):
            path = save_state(self.ckpt_dir, gen, state, keep=self.keep)
        self.saves += 1
        obs_metrics.get_registry().inc("checkpoint.saves")
        return path

    def restore(self) -> dict | None:
        with obs_trace.span("checkpoint.restore", kind="search"):
            _, state = restore_latest_state(self.ckpt_dir)
        if state is None or state.get("kind") != "search":
            return None
        obs_metrics.get_registry().inc("checkpoint.restores")
        lens = state["all_F_lens"].tolist()
        offs = np.cumsum([0] + lens)
        all_F = [state["all_F"][offs[i]:offs[i + 1]]
                 for i in range(len(lens))]
        history = [(int(e), float(h))
                   for e, h in zip(state["history_evals"],
                                   state["history_hv"])]
        return {
            "gen": int(state["gen"]),
            "evals": int(state["evals"]),
            "pop": state["pop"],
            "F": state["F"],
            "arch_g": state["arch_g"],
            "arch_F": state["arch_F"],
            "ref": state["ref"],
            "history": history,
            "all_F": all_F,
            "rng_state": json.loads(state["rng_state"]),
            "eps_vec": state.get("eps_vec"),
            "accuracy_state": state.get("accuracy_state"),
            "accuracy_digest": state.get("accuracy_digest"),
        }


def resume_sweep(workload, configs, *,
                 checkpoint_dir: str,
                 checkpoint_every: int = 8,
                 keep: int = 3,
                 cache=None,
                 max_restarts: int = 10,
                 fail_at: dict[int, int] | None = None,
                 retryable: tuple = (InjectedFailure,),
                 backoff_s: float = 0.0,
                 backoff_factor: float = 2.0,
                 max_backoff_s: float = 30.0,
                 **sweep_kwargs) -> ChunkedSweep:
    """Run a chunked sweep to completion through preemptions.

    ``configs`` must be restartable: a SoA dict, a config sequence, or a
    zero-arg **factory** returning a fresh feed per attempt (a bare
    generator would arrive exhausted at the second attempt).  Each
    attempt restores the newest valid snapshot under ``checkpoint_dir``
    and replays; on the numpy backend the final front and cache hit/miss
    accounting are bit-identical to an uninterrupted run.

    Restart policy (``retryable`` / ``backoff_s`` / ...) goes through
    :func:`~repro.runtime.fault_tolerance.restart_loop`; ``fail_at``
    injects deterministic failures at chunk boundaries, shared across
    attempts so each boundary fails exactly ``n_times``.  The restart
    count lands in ``result.timings["restarts"]``.
    """
    fail_at = dict(fail_at or {})
    cache_baseline = None
    if cache is not None and not isinstance(cache, (str, bytes)) \
            and not hasattr(cache, "__fspath__"):
        # a live cache object keeps rows inserted by a *failed* attempt;
        # rewind it to its entry state each attempt so accounting replays
        # exactly (a snapshot restore then overrides this baseline)
        cache_baseline = cache.export_state()

    def attempt() -> ChunkedSweep:
        ckpt = SweepCheckpointer(checkpoint_dir, every=checkpoint_every,
                                 keep=keep)
        c = cache
        if isinstance(c, (str, bytes)) or hasattr(c, "__fspath__"):
            c = PersistentSynthesisCache(c)
        elif c is not None:
            c.import_state(cache_baseline)
        feed = configs() if callable(configs) else configs
        return _sweep_chunked(workload, feed, checkpoint=ckpt,
                              fail_at=fail_at, cache=c, **sweep_kwargs)

    restarts, sweep = restart_loop(
        attempt, max_restarts=max_restarts, retryable=retryable,
        backoff_s=backoff_s, backoff_factor=backoff_factor,
        max_backoff_s=max_backoff_s)
    if sweep.timings is not None:
        sweep.timings["restarts"] = restarts
    if restarts:
        obs_metrics.get_registry().inc("sweep.restarts", restarts)
    return sweep


def resume_search(space, workload, budget: int, *,
                  checkpoint_dir: str,
                  checkpoint_every: int = 5,
                  method: str = "nsga2",
                  max_restarts: int = 10,
                  fail_at_generation: dict[int, int] | None = None,
                  retryable: tuple = (InjectedFailure,),
                  backoff_s: float = 0.0,
                  backoff_factor: float = 2.0,
                  max_backoff_s: float = 30.0,
                  **search_kwargs):
    """Run an evolutionary search to completion through preemptions.

    Only ``nsga2`` carries resumable state (random search is resumable as
    a sweep; successive halving re-runs cheaply) — anything else raises.
    Each attempt restores the newest valid generation snapshot (including
    the RNG stream) and continues; the resumed front is bit-identical to
    an uninterrupted run on the numpy backend.  The restart count lands
    in ``result.stats["restarts"]``.
    """
    if method != "nsga2":
        raise ValueError(
            f"resume_search supports method='nsga2', got {method!r}")
    from repro.explore.search import nsga2
    fail = dict(fail_at_generation or {})

    def attempt():
        return nsga2(space, workload, budget,
                     checkpoint_dir=checkpoint_dir,
                     checkpoint_every=checkpoint_every,
                     fail_at_generation=fail, **search_kwargs)

    restarts, res = restart_loop(
        attempt, max_restarts=max_restarts, retryable=retryable,
        backoff_s=backoff_s, backoff_factor=backoff_factor,
        max_backoff_s=max_backoff_s)
    res.stats["restarts"] = restarts
    if restarts:
        obs_metrics.get_registry().inc("search.restarts", restarts)
    return res
