"""Elastic re-meshing: reshard a training state onto a different mesh.

When the fleet shrinks/grows (node failure, preemption, scale-up), the
checkpointed state must be laid out for the new device count.  Because
parameter pspecs are *logical* (parallel/sharding.py), resharding is just
device_put with shardings derived from the new mesh — divisibility
fallbacks in param_pspec handle axes that stop dividing evenly.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import tree_pspecs


def reshard(state, new_mesh: Mesh):
    """Re-lay-out a pytree for ``new_mesh`` using the logical param rules."""
    specs = tree_pspecs(state, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        state, specs)


def survivable_mesh(devices, axis_names=("data", "model"),
                    prefer_model: int = 16):
    """Build the largest usable mesh from surviving devices.

    Keeps the model axis at ``prefer_model`` if possible (TP degree is a
    property of the compiled program) and shrinks the data axis.
    """
    import numpy as np
    n = len(devices)
    model = prefer_model
    while model > 1 and n % model != 0:
        model //= 2
    data = n // model
    arr = np.asarray(devices[:data * model]).reshape(data, model)
    return Mesh(arr, axis_names)
