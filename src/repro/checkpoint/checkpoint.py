"""Checkpoint save/restore: step-indexed, checksummed, rotated.

Fault-tolerance contract (runtime/fault_tolerance.py):
  * checkpoints are atomic (write to tmp, fsync, rename);
  * every file carries a content checksum; restore skips corrupt ones and
    falls back to the newest valid checkpoint;
  * the data cursor and RNG state are part of the checkpoint so a restart
    is bitwise-identical to the uninterrupted run.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically save ``tree`` as checkpoints/step_<n>/ and rotate."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    npz = os.path.join(tmp, "arrays.npz")
    np.savez(npz, **arrs)
    with open(npz, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    meta = {"step": step, "n_leaves": len(leaves), "sha256": digest,
            "treedef": str(treedef)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.replace(tmp, path)                      # atomic publish
    _rotate(ckpt_dir, keep)
    return path


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _valid(path: str) -> bool:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        return digest == meta["sha256"]
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in reversed(steps):
        if _valid(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def restore(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (validates checksum)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} is corrupt or missing")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    restored = [np.asarray(r).astype(l.dtype).reshape(l.shape)
                for r, l in zip(restored, leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(ckpt_dir: str, like):
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None
    return s, restore(ckpt_dir, s, like)
