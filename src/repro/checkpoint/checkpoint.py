"""Checkpoint save/restore: step-indexed, checksummed, rotated.

Fault-tolerance contract (runtime/fault_tolerance.py):
  * checkpoints are atomic (write to tmp, fsync, rename);
  * every file carries a content checksum; restore skips corrupt ones and
    falls back to the newest valid checkpoint;
  * the data cursor and RNG state are part of the checkpoint so a restart
    is bitwise-identical to the uninterrupted run.

Two snapshot formats share the directory layout, checksum validation, and
keep-N rotation:

* :func:`save` / :func:`restore` — pytree checkpoints for training state,
  restored into the shape of a ``like`` tree (leaves must match);
* :func:`save_state` / :func:`restore_state` — **self-describing** nested
  dicts of arrays and scalars for exploration state
  (:mod:`repro.runtime.dse_checkpoint`), where shapes grow between
  snapshots (a Pareto front, a synthesis cache) so no ``like`` structure
  can exist at restore time.  Array dtype/shape round-trip exactly.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import numpy as np


def _flatten(tree):
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically save ``tree`` as checkpoints/step_<n>/ and rotate."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    npz = os.path.join(tmp, "arrays.npz")
    np.savez(npz, **arrs)
    with open(npz, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    meta = {"step": step, "n_leaves": len(leaves), "sha256": digest,
            "treedef": str(treedef)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.replace(tmp, path)                      # atomic publish
    _rotate(ckpt_dir, keep)
    return path


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _valid(path: str) -> bool:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        return digest == meta["sha256"]
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in reversed(steps):
        if _valid(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def restore(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (validates checksum)."""
    import jax
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} is corrupt or missing")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    restored = [np.asarray(r).astype(l.dtype).reshape(l.shape)
                for r, l in zip(restored, leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(ckpt_dir: str, like):
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None
    return s, restore(ckpt_dir, s, like)


# ---------------------------------------------------------------------------
# Self-describing state snapshots (nested dicts, no `like` needed)
# ---------------------------------------------------------------------------

_PATH_SEP = "/"


def _flatten_state(state: dict, prefix: str = ""
                   ) -> tuple[dict[str, np.ndarray], dict[str, object]]:
    """Walk a nested dict: arrays by joined path, JSON scalars apart."""
    arrays: dict[str, np.ndarray] = {}
    scalars: dict[str, object] = {}
    for key, val in state.items():
        if not isinstance(key, str) or _PATH_SEP in key:
            raise ValueError(
                f"state keys must be '/'-free strings, got {key!r}")
        path = prefix + key
        if isinstance(val, dict):
            sub_a, sub_s = _flatten_state(val, path + _PATH_SEP)
            arrays.update(sub_a)
            scalars.update(sub_s)
        elif isinstance(val, np.ndarray):
            arrays[path] = val
        elif isinstance(val, (bool, int, float, str)) or val is None:
            scalars[path] = val
        elif isinstance(val, (np.integer, np.floating, np.bool_)):
            scalars[path] = val.item()
        else:
            raise TypeError(
                f"state leaf {path!r} has unsupported type "
                f"{type(val).__name__} (use np.ndarray, int, float, "
                f"bool, str, None, or a nested dict)")
    return arrays, scalars


def _unflatten_state(arrays: dict, scalars: dict) -> dict:
    state: dict = {}
    for path, val in list(arrays.items()) + list(scalars.items()):
        parts = path.split(_PATH_SEP)
        node = state
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return state


def save_state(ckpt_dir: str, step: int, state: dict, *,
               keep: int = 3) -> str:
    """Atomically save a nested dict of arrays/scalars as
    checkpoints/step_<n>/ and rotate.

    Unlike :func:`save`, the snapshot is self-describing: array dtypes,
    shapes, and the dict structure restore exactly with no ``like`` tree —
    required for exploration state whose arrays (Pareto front, synthesis
    cache rows) change shape between snapshots.  Same checksum validation
    and keep-N rotation as pytree checkpoints; the two formats may share a
    directory.
    """
    arrays, scalars = _flatten_state(state)
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if _valid(path):
        # re-saving a step re-serializes identical state (snapshots are
        # deterministic functions of the step); keep the durable copy
        return path
    if os.path.exists(path):
        shutil.rmtree(path)      # corrupt leftover: replace it
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    npz = os.path.join(tmp, "arrays.npz")
    with open(npz, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(npz, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    meta = {"step": step, "format": "state", "sha256": digest,
            "scalars": scalars, "array_paths": sorted(arrays)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.replace(tmp, path)                      # atomic publish
    _rotate(ckpt_dir, keep)
    return path


def restore_state(ckpt_dir: str, step: int) -> dict:
    """Restore a :func:`save_state` snapshot (validates checksum)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} is corrupt or missing")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta.get("format") != "state":
        raise IOError(
            f"checkpoint {path} is a pytree checkpoint, not a state "
            f"snapshot (use restore())")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in meta["array_paths"]}
    return _unflatten_state(arrays, meta["scalars"])


def restore_latest_state(ckpt_dir: str) -> tuple[int | None, dict | None]:
    """``(step, state)`` of the newest *valid* state snapshot, or
    ``(None, None)``.  Corrupt or truncated snapshots are skipped, falling
    back to the next-newest valid one."""
    if not os.path.isdir(ckpt_dir):
        return None, None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in reversed(steps):
        try:
            return s, restore_state(ckpt_dir, s)
        except Exception:
            continue
    return None, None
