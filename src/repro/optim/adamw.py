"""AdamW + cosine schedule + global-norm clipping, pure-pytree.

Optimizer state mirrors the param tree (fp32 moments — the 'master'
precision regardless of the model's quantization mode) and shards exactly
like the params (same tree structure -> same pspecs).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    mu: dict                 # first moments (param tree)
    nu: dict                 # second moments


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * factor).astype(g.dtype), grads), norm


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "lr": lr, "grad_norm": gnorm}
