"""End-to-end training driver (runs at smoke scale on CPU; the same code
lowers for the production mesh — the dry-run proves that).

Wires together: config -> Model -> sharded train_step (pjit) -> synthetic
data pipeline -> AdamW -> checkpoint/restart (fault-tolerant) -> optional
int8 gradient compression on the DP axis.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.sharding import (activation_sharding, data_axes,
                                     default_activation_rules,
                                     tree_pspecs)
from repro.runtime.fault_tolerance import run_with_restarts


def make_train_step(model: Model, mesh, ocfg: adamw.AdamWConfig,
                    *, grad_compression: bool = False):
    rules = default_activation_rules(mesh, seq_sharded=False)

    def train_step(state, batch):
        params, opt, err = state["params"], state["opt"], state["err"]
        with activation_sharding(mesh, rules):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if grad_compression:
            grads, err = compression.compress_roundtrip(grads, err)
        params, opt, _ = adamw.update(ocfg, grads, opt, params)
        return {"params": params, "opt": opt, "err": err}, loss

    return jax.jit(train_step, donate_argnums=(0,))


def train(arch: str, *, steps: int = 20, smoke: bool = True,
          seq_len: int = 64, batch: int = 8, ckpt_dir: str | None = None,
          ckpt_every: int = 10, grad_compression: bool = False,
          fail_at: dict | None = None, log_every: int = 5,
          seed: int = 0):
    cfg = get_config(arch)
    if smoke:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    model = Model(cfg)
    # smoke-scale LR: tiny models on tiny data learn fastest around 3e-3
    ocfg = adamw.AdamWConfig(lr=3e-3, total_steps=steps,
                             warmup_steps=max(1, steps // 10))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                  global_batch=batch, seed=seed))
    step_fn = make_train_step(model, mesh, ocfg,
                              grad_compression=grad_compression)

    def init_state():
        params = model.init(jax.random.key(seed))
        return {"params": params, "opt": adamw.init(params),
                "err": compression.init_error_state(params)
                if grad_compression else jax.tree.map(
                    lambda _: jnp.zeros(()), {})}

    def make_batch(step: int):
        b = data.batch(step)
        if cfg.family in ("vlm", "audio"):
            b["ctx"] = jax.random.normal(
                jax.random.key(step), (batch, cfg.n_ctx_tokens, cfg.d_model),
                jnp.float32) * 0.02
        return b

    if ckpt_dir is None:
        # plain loop, no fault tolerance
        state = init_state()
        losses = []
        for s in range(steps):
            t0 = time.time()
            state, loss = step_fn(state, make_batch(s))
            losses.append((s, float(loss)))
            if s % log_every == 0:
                print(f"step {s}: loss={float(loss):.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
        return losses

    result = run_with_restarts(
        init_state=init_state, train_step=step_fn, data_batch=make_batch,
        total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        fail_at=fail_at)
    for s, l in result.losses[::log_every]:
        print(f"step {s}: loss={l:.4f}", flush=True)
    print(f"restarts={result.restarts} stragglers={result.straggler_flags}")
    return result.losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, smoke=args.smoke,
                   seq_len=args.seq_len, batch=args.batch,
                   ckpt_dir=args.ckpt_dir,
                   grad_compression=args.grad_compression)
    first = losses[0][1]
    last = losses[-1][1]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
