"""Batched serving driver: prefill + decode with (optionally quantized)
weights — the LightPE deployment path at smoke scale on CPU.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b \
      --batch 4 --prompt-len 16 --gen 16 --quant
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model


def serve(arch: str, *, batch: int = 4, prompt_len: int = 16,
          gen: int = 16, quantize: bool = False, smoke: bool = True,
          seed: int = 0, greedy: bool = True):
    cfg = get_config(arch)
    if smoke:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    if quantize:
        params = model.quantize_params(params)

    prompts = jax.random.randint(jax.random.key(seed + 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    ctx = None
    if cfg.family in ("vlm", "audio"):
        ctx = jax.random.normal(jax.random.key(seed + 2),
                                (batch, cfg.n_ctx_tokens, cfg.d_model)) * 0.02

    max_seq = prompt_len + gen
    t0 = time.time()
    caches = model.init_cache(batch, max_seq)
    if cfg.family in ("vlm", "audio") and "ctx_k" in caches:
        caches = _fill_ctx_caches(model, params, caches, ctx)

    # prefill by replaying the prompt through decode (cache build)
    decode = jax.jit(model.decode_step)
    logits = None
    for i in range(prompt_len):
        logits, caches = decode(params, caches, prompts[:, i:i + 1],
                                jnp.int32(i))
    prefill_s = time.time() - t0

    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        out.append(tok)
        logits, caches = decode(params, caches, tok,
                                jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tok_per_s": batch * gen / max(decode_s, 1e-9),
    }


def _fill_ctx_caches(model, params, caches, ctx):
    """Project the modality context to per-cross-layer (k, v) once."""
    from repro.models.attention import context_kv
    cfg, policy = model.cfg, model.policy
    if cfg.family == "audio":
        enc = model._encode(params, ctx, False)
        cls = params["cross_layers"]
    else:
        enc = ctx.astype(model.policy.compute_dtype)
        cls = params["cross_layers"]

    def one(cp):
        return context_kv(enc, cp, cfg, policy=policy, train=False)

    ks, vs = jax.vmap(one)(cls)  # over stacked cross layers
    return dict(caches, ctx_k=ks.astype(caches["ctx_k"].dtype),
                ctx_v=vs.astype(caches["ctx_v"].dtype))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant", action="store_true")
    args = ap.parse_args()
    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, quantize=args.quant)
    print(f"generated shape={res['tokens'].shape} "
          f"prefill={res['prefill_s']:.2f}s decode={res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
