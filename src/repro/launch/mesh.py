"""Production meshes.

Functions (not module constants) so importing never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; everything else sees the real device count.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh axes
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly "auto" on every axis
    AxisType = None


def compat_make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh``: pass explicit Auto ``axis_types``
    where the installed jax supports them, plain mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A mesh over whatever devices exist (CPU tests: usually 1)."""
    n = jax.device_count()
    model = max(1, min(model, n))
    while n % model != 0:
        model -= 1
    return compat_make_mesh((n // model, model), ("data", "model"))
