"""Production meshes.

Functions (not module constants) so importing never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; everything else sees the real device count.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh axes
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly "auto" on every axis
    AxisType = None


def compat_make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh``: pass explicit Auto ``axis_types``
    where the installed jax supports them, plain mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A mesh over whatever devices exist (CPU tests: usually 1)."""
    n = jax.device_count()
    model = max(1, min(model, n))
    while n % model != 0:
        model -= 1
    return compat_make_mesh((n // model, model), ("data", "model"))


def make_sweep_mesh(max_devices: int | None = None):
    """1-D mesh over all (or the first ``max_devices``) devices for
    sharding a DSE sweep's config axis — :func:`repro.core.dse_batch
    .sweep_workload` / :func:`~repro.core.dse_batch.sweep_mixed_many`
    with ``backend="jax"`` and ``mesh=...``."""
    n = jax.device_count()
    if max_devices is not None:
        n = max(1, min(n, int(max_devices)))
    return compat_make_mesh((n,), ("configs",))


def mesh_shards(mesh) -> int:
    """Number of config-axis shards a ``mesh=`` argument implies:
    ``None`` -> 1, a plain int (the numpy backend's simulated shard
    count) -> itself, a ``jax.sharding.Mesh`` -> its device count.
    Delegates to the sweep engine's helper so padding/splitting semantics
    have a single source of truth."""
    from repro.core.dse_batch import _mesh_shards
    return _mesh_shards(mesh)


def compat_shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat ``shard_map`` with the replication check disabled on
    every jax version — the sweep kernel emits replicated layer stats the
    checker cannot verify.  The kwarg spelling moved across releases
    (``check_rep`` -> ``check_vma``), so pick whichever the installed
    ``shard_map`` accepts."""
    import inspect
    sm = jax.shard_map if hasattr(jax, "shard_map") else None
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kwargs = {}
    try:
        params = inspect.signature(sm).parameters
        for name in ("check_vma", "check_rep"):
            if name in params:
                kwargs[name] = False
                break
    except (TypeError, ValueError):   # C-accelerated callable, no sig
        kwargs["check_rep"] = False
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)
