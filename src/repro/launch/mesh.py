"""Production meshes.

Functions (not module constants) so importing never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; everything else sees the real device count.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """A mesh over whatever devices exist (CPU tests: usually 1)."""
    n = jax.device_count()
    model = max(1, min(model, n))
    while n % model != 0:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
