import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer state /
     caches / batch (never allocating),
  2. jits the step with in/out shardings from the logical rules,
  3. ``.lower().compile()`` on the production mesh (16x16 single-pod and
     2x16x16 multi-pod),
  4. records memory_analysis / cost_analysis / parsed collective bytes and
     the three-term TPU roofline into experiments/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.core.hlo_analysis import analyze_compiled
from repro.core.tpu_roofline import roofline_from_stats
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.models import ssm as ssm_mod
from repro.optim import adamw
from repro.parallel.sharding import (activation_sharding, data_axes,
                                     default_activation_rules, param_pspec,
                                     tree_pspecs)

OUT_DIR = "experiments/dryrun"


def _fit(shape, spec, mesh):
    """Drop spec axes whose dim is not divisible by the mesh axis size."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(ax):
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(ax, 1)

    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        out.append(ax if ax is not None and dim % ax_size(ax) == 0 else None)
    return P(*out)


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: long_500k needs sub-quadratic "
                "attention (DESIGN.md §8)")
    return None


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for the step inputs (weak-type-correct,
    shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = {}
    if shape.kind == "train":
        specs["batch"] = {"tokens": tok,
                          "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        specs["batch"] = {"tokens": tok}
    else:  # decode
        specs["batch"] = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                          "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.family in ("vlm", "audio") and shape.kind != "decode":
        specs["batch"]["ctx"] = jax.ShapeDtypeStruct(
            (b, cfg.n_ctx_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def _axis_prod(mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def batch_pspecs(cfg, shape, mesh, batch):
    db = data_axes(mesh)
    b = shape.global_batch
    if b % _axis_prod(mesh) != 0:
        db = ("data",) if b % dict(zip(
            mesh.axis_names, mesh.devices.shape)).get("data", 1) == 0 \
            else None
    out = {}
    for k, v in batch.items():
        if k == "pos":
            out[k] = P()
        elif k == "ctx":
            out[k] = _fit(v.shape, (db, None, None), mesh)
        else:
            out[k] = _fit(v.shape, (db, "model" if shape.kind == "train"
                                    else None), mesh)
    return out


def cache_pspecs(cfg, shape, mesh, caches, *, kv_seq_shard=False):
    """KV caches: batch->data normally; seq->data for batch=1 long ctx;
    ``kv_seq_shard`` additionally shards the cache sequence dim over the
    "model" axis (sharded flash-decode; §Perf)."""
    b = shape.global_batch
    batch1 = b < _axis_prod(mesh) and b == 1
    db = ("pod", "data") if "pod" in mesh.axis_names else "data"
    out = {}
    for k, v in caches.items():
        if k in ("k", "v", "shared_k", "shared_v", "ctx_k", "ctx_v"):
            if batch1:
                spec = (None, None, "data", None, None)
            elif kv_seq_shard:
                spec = (None, db, "model", None, None)
            else:
                spec = (None, db, None, None, None)
        elif k in ("k_local", "v_local"):   # ring buffers: batch only
            spec = (None, db, None, None, None) if not batch1 \
                else (None, None, None, None, None)
        elif k in ("k_local_scale", "v_local_scale"):
            spec = (None, db, None, None) if not batch1 \
                else (None, None, None, None)
        elif k in ("k_scale", "v_scale"):
            if batch1:
                spec = (None, None, "data", None)
            elif kv_seq_shard:
                spec = (None, db, "model", None)
            else:
                spec = (None, db, None, None)
        elif k == "state":
            spec = (None, None, "model", None, None) if batch1 \
                else (None, "data", "model", None, None)
        elif k == "conv":
            spec = (None, None, None, None) if batch1 \
                else (None, "data", None, None)
        else:
            spec = ()
        out[k] = _fit(v.shape, spec, mesh)
    return out


def _bf16_view(params):
    """Cast big f32 projection leaves to bf16 (FSDP gathers + compute in
    bf16; optimizer master stays f32 — §Perf iteration)."""
    def cast(p):
        if hasattr(p, "dtype") and p.dtype == jnp.float32 \
                and p.ndim >= 2 and p.size >= (1 << 17):
            return p.astype(jnp.bfloat16)
        return p
    return jax.tree.map(cast, params)


def build_cell(arch: str, shape_name: str, mesh, *, serve_quant=False,
               kv_quant=False, kv_seq_shard=False, bf16_params=False,
               weight_only_qat=False, mode=None, microbatch: int = 1):
    """Returns (jitted_fn, arg ShapeDtypeStructs, model_flops)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if mode:   # override the exec mode (PE-type analogue), e.g. w4a8_pow2
        cfg = _dc.replace(cfg, quant=mode)
    if os.environ.get("SSM_CHUNK"):
        cfg = _dc.replace(cfg, ssm_chunk=int(os.environ["SSM_CHUNK"]))
    shape = SHAPES[shape_name]
    model = Model(cfg)
    if weight_only_qat:
        model.policy = _dc.replace(model.policy, qat_acts=False)
    pshapes = model.param_shapes()
    if serve_quant and shape.kind != "train":
        pshapes = jax.eval_shape(model.quantize_params, pshapes)
    pspecs = tree_pspecs(pshapes, mesh)
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs)
    specs = input_specs(cfg, shape, mesh)
    bspecs = batch_pspecs(cfg, shape, mesh, specs["batch"])
    bshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), bspecs)
    rules = default_activation_rules(
        mesh, seq_sharded=(shape.kind == "train"),
        batch_1=shape.global_batch == 1)
    tokens_total = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw.init, pshapes)
        ocfg = adamw.AdamWConfig()

        def train_step(params, opt, batch):
            def loss_fn(p, b):
                p = _bf16_view(p) if bf16_params else p
                with activation_sharding(mesh, rules):
                    return model.loss(p, b)

            if microbatch > 1:
                # gradient accumulation: scan over micro-slices so live
                # activations shrink by the microbatch factor (HBM fit
                # for the 95/100-layer train cells)
                def split(x):
                    return x.reshape(microbatch, x.shape[0] // microbatch,
                                     *x.shape[1:])
                mbatch = jax.tree.map(split, batch)

                def acc_step(carry, mb):
                    gsum, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    gsum = jax.tree.map(jnp.add, gsum, g)
                    return (gsum, lsum + l), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    acc_step, (g0, jnp.zeros(())), mbatch)
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                loss = loss / microbatch
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt, metrics = adamw.update(ocfg, grads, opt, params)
            return params, opt, loss

        oshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                              tree_pspecs(opt_shapes, mesh))
        fn = jax.jit(train_step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard,
                                    NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        args = (pshapes, opt_shapes, specs["batch"])
        model_flops = 6.0 * cfg.n_active_params() * shape.global_batch \
            * shape.seq_len
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            with activation_sharding(mesh, rules):
                logits, _ = model.forward(params, batch["tokens"],
                                          ctx=batch.get("ctx"),
                                          train=False, last_only=True)
            return logits

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard),
                     out_shardings=NamedSharding(mesh, P()))
        args = (pshapes, specs["batch"])
        model_flops = 2.0 * cfg.n_active_params() * tokens_total
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     dtype=jnp.bfloat16,
                                     kv_quant=kv_quant))
        cspecs = cache_pspecs(cfg, shape, mesh, cache_shapes,
                              kv_seq_shard=kv_seq_shard)
        cshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cspecs)

        def serve_step(params, caches, batch):
            with activation_sharding(mesh, rules):
                logits, caches = model.decode_step(
                    params, caches, batch["tokens"], batch["pos"])
            return logits, caches

        fn = jax.jit(serve_step,
                     in_shardings=(pshard, cshard, bshard),
                     out_shardings=(NamedSharding(mesh, P()), cshard),
                     donate_argnums=(1,))
        args = (pshapes, cache_shapes, specs["batch"])
        model_flops = 2.0 * cfg.n_active_params() * shape.global_batch
    return fn, args, model_flops


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             serve_quant: bool = False, kv_quant: bool = False,
             kv_seq_shard: bool = False, bf16_params: bool = False,
             weight_only_qat: bool = False, mode: str | None = None,
             microbatch: int = 1,
             variant: str = "", out_dir: str = OUT_DIR) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(cfg, shape)
    suffix = "".join([
        "__quant" if serve_quant else "",
        "__kvq" if kv_quant else "",
        "__kvshard" if kv_seq_shard else "",
        "__bf16p" if bf16_params else "",
        "__woqat" if weight_only_qat else "",
        f"__{mode}" if mode else "",
        f"__mb{microbatch}" if microbatch > 1 else "",
        f"__{variant}" if variant else "",
    ])
    tag = f"{arch}__{shape_name}__{mesh_name}{suffix}"
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        _dump(out_dir, tag, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            fn, args, model_flops = build_cell(
                arch, shape_name, mesh, serve_quant=serve_quant,
                kv_quant=kv_quant, kv_seq_shard=kv_seq_shard,
                bf16_params=bf16_params, weight_only_qat=weight_only_qat,
                mode=mode, microbatch=microbatch)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            stats = analyze_compiled(compiled)
        roof = roofline_from_stats(
            stats, arch=arch, shape=shape_name, mesh=mesh_name,
            chips=mesh.devices.size, model_flops=model_flops)
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "ok", "quant": serve_quant, "variant": suffix,
               "compile_s": round(time.time() - t0, 1),
               "memory_analysis": {
                   "argument_bytes": mem.argument_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "alias_bytes": mem.alias_size_in_bytes,
                   "code_bytes": mem.generated_code_size_in_bytes,
               },
               "stats": stats.as_dict(),
               "roofline": roof.as_dict()}
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    _dump(out_dir, tag, rec)
    return rec


def _dump(out_dir, tag, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", action="store_true",
                    help="serve with quantized weights (decode/prefill)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache with per-(pos,head) scales")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="shard decode KV cache seq dim over 'model'")
    ap.add_argument("--bf16-params", action="store_true",
                    help="bf16 param view inside loss (f32 master)")
    ap.add_argument("--weight-only-qat", action="store_true",
                    help="QAT on weights only (no act fake-quant)")
    ap.add_argument("--mode", default=None,
                    help="override exec mode: fp32|bf16|w8a8|w4a8_pow2")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches (train)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               serve_quant=args.quant,
                               kv_quant=args.kv_quant,
                               kv_seq_shard=args.kv_seq_shard,
                               bf16_params=args.bf16_params,
                               weight_only_qat=args.weight_only_qat,
                               mode=args.mode, microbatch=args.microbatch,
                               out_dir=args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{rec['mesh']}] {arch} x {shape}: {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
