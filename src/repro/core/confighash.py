"""Vectorized counter-based config hashing (threefry-style, 2x32 lanes).

Replaces the per-config ``hashlib.sha256(name + salt)`` synthesis jitter
with a counter-based hash over packed integer field words:

* configs hash from **packed ``uint32`` field arrays** (no Python name
  strings, no per-config Python at all) — the whole batch digests in a
  handful of fused array ops;
* the hash has a CityHash-like shape tuned for tiny fixed-length inputs:
  a 4-lane polynomial (multiply-add) compression absorbs the field words,
  then two cross-keyed **threefry-2x32** blocks (the primitive behind
  ``jax.random``, at R=13 — Random123's minimal Crush-resistant round
  count) finalize the 128-bit digest;
* everything is written against an ``xp`` array namespace using only
  wrapping ``uint32`` mul/add/xor/roll, so the *identical* code runs on
  NumPy and on ``jax.numpy`` under ``jax.jit`` with jax's default
  (x64-disabled) config;
* the scalar path calls the same functions on a length-1 batch, so
  scalar / batched-numpy / batched-jax digests are **bit-identical**
  (property-tested in ``tests/test_confighash.py``).

Digests are 128-bit, wide enough that accidental collisions are not a
practical concern even for 1e9-point design spaces; they key both the
in-process synthesis LRU cache and the on-disk npz cache
(:mod:`repro.core.synthesis`).

Uniform variates for the jitter are built as ``(lane >> 8) * 2**-24``:
24-bit integers scale exactly in float32 *and* float64, so the value is
the same number in either precision — another bit-identity guarantee that
holds under jax's default config.
"""

from __future__ import annotations

import numpy as np

# threefry-2x32 rotation schedule and key-schedule parity constant
_ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = 0x1BD11BDA
_ROUNDS = 13                    # threefry2x32-13: minimal Crush-resistant

# polynomial-compression multipliers (distinct odd constants) and lane IVs
# (first 32-bit words of sqrt(2), sqrt(3), sqrt(5), sqrt(7))
_MULTIPLIERS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)
_IV = (0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A)


def _rotl32(x, d: int, xp):
    return (x << np.uint32(d)) | (x >> np.uint32(32 - d))


def threefry2x32(k0, k1, x0, x1, xp=np, rounds: int = _ROUNDS):
    """Threefry-2x32 block: key ``(k0, k1)``, counter ``(x0, x1)``, all
    wrapping ``uint32`` lanes.  Broadcasts over arrays on any backend."""
    u32 = np.uint32
    k0 = xp.asarray(k0, dtype=u32)
    k1 = xp.asarray(k1, dtype=u32)
    x0 = xp.asarray(x0, dtype=u32) + k0
    x1 = xp.asarray(x1, dtype=u32) + k1
    ks = (k0, k1, k0 ^ k1 ^ u32(_PARITY))
    for r in range((rounds + 3) // 4):
        rots = _ROTATIONS[:4] if r % 2 == 0 else _ROTATIONS[4:]
        for rot in rots[:min(4, rounds - 4 * r)]:
            x0 = x0 + x1
            x1 = _rotl32(x1, rot, xp) ^ x0
        x0 = x0 + ks[(r + 1) % 3]
        x1 = x1 + ks[(r + 2) % 3] + u32(r + 1)
    return x0, x1


def digest_words(words, xp=np):
    """128-bit digest of a sequence of ``uint32`` word arrays.

    4-lane polynomial compression (``h = h * C + w`` per word, wrapping)
    absorbs the words, then two cross-keyed threefry blocks finalize —
    every output lane depends on every input word through both the
    per-lane polynomial and the block cipher.  Returns ``(d0, d1, d2,
    d3)`` uint32 arrays broadcast to the common shape of ``words``.
    """
    u32 = np.uint32
    words = [xp.asarray(w, dtype=u32) for w in words]
    # length word guards against trailing-zero ambiguity between schemas
    words.append(xp.asarray(u32(len(words))))
    h = [xp.asarray(u32(iv)) for iv in _IV]
    cs = [u32(c) for c in _MULTIPLIERS]
    for w in words:
        h = [hi * ci + w for hi, ci in zip(h, cs)]
    a0, a1 = threefry2x32(h[2], h[3], h[0], h[1], xp=xp)
    b0, b1 = threefry2x32(h[0] ^ u32(_PARITY), h[1], h[2], h[3], xp=xp)
    return a0, a1, b0, b1


def uniform01(lane, xp=np, dtype=np.float64):
    """Uniform variate in [0, 1) from one digest lane: the high 24 bits
    scale by 2**-24 — exact in float32 and float64, hence bit-identical
    across numpy / jax-without-x64."""
    return (xp.asarray(lane, dtype=np.uint32) >> np.uint32(8)) \
        .astype(dtype) * dtype(2.0 ** -24)


def f64_words(x) -> tuple[np.ndarray, np.ndarray]:
    """Split a float64 array into (lo, hi) uint32 bit-pattern words.

    NaN payloads are canonicalized so any NaN encoding hashes alike.
    Packing runs in NumPy (it is cache-key preparation, never inside a jax
    trace); the resulting words feed :func:`digest_words` on any backend.
    """
    x = np.ascontiguousarray(x, dtype=np.float64)
    x = np.where(np.isnan(x), np.float64(np.nan), x)  # canonical quiet NaN
    bits = x.view(np.uint64)
    return (bits & np.uint64(0xFFFFFFFF)).astype(np.uint32), \
        (bits >> np.uint64(32)).astype(np.uint32)


def pack_config_words(soa: dict) -> list[np.ndarray]:
    """The packed ``uint32`` field words of a config batch, from its
    struct-of-arrays form (:func:`repro.core.accelerator.configs_to_soa`).

    Every field that defines a design point is folded in — including
    ``clock_cap`` (``+inf`` when unset), which ``AcceleratorConfig.name()``
    omits — so the digest is a complete identity key.
    """
    ints = ["pe_type_idx", "pe_rows", "pe_cols", "ifmap_spad",
            "filter_spad", "psum_spad", "glb_kb"]
    words: list[np.ndarray] = [
        np.asarray(soa[k]).astype(np.uint32) for k in ints]
    for k in ("dram_bw_gbps", "clock_cap"):
        lo, hi = f64_words(soa[k])
        words.extend((lo, hi))
    return words


def config_digests(soa: dict, xp=np):
    """128-bit digests for a config batch: ``(d0, d1, d2, d3)`` uint32."""
    return digest_words(pack_config_words(soa), xp=xp)


def digests_to_u64(d) -> np.ndarray:
    """Stack a 4-lane digest into an ``(N, 2)`` uint64 array (npz format)."""
    d0, d1, d2, d3 = (np.asarray(x, dtype=np.uint64) for x in d)
    return np.stack([(d1 << np.uint64(32)) | d0,
                     (d3 << np.uint64(32)) | d2], axis=-1)


def digest_keys(d) -> list[bytes]:
    """Per-config 16-byte cache keys from a 4-lane digest (one ``bytes``
    per design point — the only per-config Python step, and a cheap one)."""
    flat = np.ascontiguousarray(digests_to_u64(d))
    buf = flat.tobytes()
    return [buf[i:i + 16] for i in range(0, len(buf), 16)]
