"""Design-space exploration (paper Sec. 4, Figs. 3-5).

Sweeps the QAPPA design space, evaluates each design point on a workload via
the row-stationary dataflow model, and reports normalized
performance-per-area vs normalized energy with respect to the *best INT16
configuration* (the paper's anchor).  Also extracts Pareto frontiers.

Two engines produce identical results:

* ``engine="batched"`` (default) — the vectorized struct-of-arrays sweep in
  :mod:`repro.core.dse_batch`: all configs x all layers in a handful of
  fused array ops, with a synthesis-report cache so re-sweeps (new
  workloads, extended spaces) skip the synthesis flow entirely.
* ``engine="scalar"`` — the original O(configs x layers) Python loop, kept
  as the bit-exact reference the batched engine is tested against.

The public entry point is :func:`run` over an :class:`ExploreSpec` —
one declarative description of a campaign built with
``ExploreSpec.single(...)`` (uniform-precision config sweep, optionally
chunk-streamed), ``ExploreSpec.mixed(...)`` (guided mixed-precision
co-exploration, optionally under a serving ``traffic`` trace), or
``ExploreSpec.many(...)`` (workload suites, uniform or mixed).  The
pre-facade functions (``explore`` / ``explore_scalar`` /
``explore_many`` / ``explore_chunked`` / ``coexplore`` /
``coexplore_many``) remain as deprecated shims for one release.
:class:`IncrementalSweep` lets a sweep be resumed/extended without
re-evaluating known design points.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.accelerator import (AcceleratorConfig, configs_to_soa,
                                    design_space)
from repro.core.dataflow import WorkloadResult, run_workload
from repro.core.dse_batch import (ChunkedSweep, _sweep_chunked,
                                  _sweep_workload, pareto_mask)
from repro.core.pe import PEType
from repro.core.synthesis import (config_keys, sweep_synthesis_cache,
                                  synthesize_cached)
from repro.core.workloads import Workload, get_workload


@dataclasses.dataclass(frozen=True)
class DSEPoint:
    config: AcceleratorConfig
    result: WorkloadResult  # or a BatchedWorkloadResult view (duck-typed)

    @property
    def perf_per_area(self) -> float:
        return self.result.perf_per_area

    @property
    def energy_j(self) -> float:
        return self.result.energy_j


@dataclasses.dataclass
class DSEResult:
    workload: str
    points: list[DSEPoint]

    def by_type(self, pe_type: PEType) -> list[DSEPoint]:
        return [p for p in self.points if p.config.pe_type == pe_type]

    def best_perf_per_area(self, pe_type: PEType) -> DSEPoint:
        return max(self.by_type(pe_type), key=lambda p: p.perf_per_area)

    def best_energy(self, pe_type: PEType) -> DSEPoint:
        return min(self.by_type(pe_type), key=lambda p: p.energy_j)

    def normalized(self) -> list[dict]:
        """Per paper Figs. 3-5: normalize against best-perf/area INT16."""
        anchor = self.best_perf_per_area(PEType.INT16)
        out = []
        for p in self.points:
            out.append({
                "config": p.config.name(),
                "pe_type": p.config.pe_type.value,
                "norm_perf_per_area": p.perf_per_area / anchor.perf_per_area,
                "norm_energy": p.energy_j / anchor.energy_j,
            })
        return out

    def headline_ratios(self) -> dict[str, float]:
        """The paper's headline numbers (Sec. 4):

        * LightPE-1 vs best INT16: perf/area and energy improvement
        * LightPE-2 vs best INT16: perf/area and energy improvement
        * INT16 vs best FP32: perf/area and energy improvement
        Each ratio compares the best configuration of each PE type,
        matching "when compared to the best INT16 hardware configuration".
        """
        b = {t: self.best_perf_per_area(t) for t in PEType}
        e = {t: self.best_energy(t) for t in PEType}
        return {
            "lightpe1_perf_per_area_vs_int16":
                b[PEType.LIGHTPE1].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe1_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE1].energy_j,
            "lightpe2_perf_per_area_vs_int16":
                b[PEType.LIGHTPE2].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe2_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE2].energy_j,
            "int16_perf_per_area_vs_fp32":
                b[PEType.INT16].perf_per_area / b[PEType.FP32].perf_per_area,
            "int16_energy_vs_fp32":
                e[PEType.FP32].energy_j / e[PEType.INT16].energy_j,
        }


def pareto_front_scalar(points: Sequence[DSEPoint]) -> list[DSEPoint]:
    """O(n^2) reference: non-dominated set for (max perf/area, min energy)."""
    front: list[DSEPoint] = []
    for p in points:
        dominated = any(
            (q.perf_per_area >= p.perf_per_area and q.energy_j <= p.energy_j
             and (q.perf_per_area > p.perf_per_area
                  or q.energy_j < p.energy_j))
            for q in points)
        if not dominated:
            front.append(p)
    return sorted(front, key=lambda p: p.energy_j)


def pareto_front(points: Sequence[DSEPoint]) -> list[DSEPoint]:
    """Non-dominated set for (maximize perf/area, minimize energy).

    Vectorized dominance test (:func:`repro.core.dse_batch.pareto_mask`);
    identical output to :func:`pareto_front_scalar`.
    """
    if not points:
        return []
    perf = np.array([p.perf_per_area for p in points], dtype=np.float64)
    energy = np.array([p.energy_j for p in points], dtype=np.float64)
    keep = pareto_mask(perf, energy)
    front = [p for p, k in zip(points, keep) if k]
    return sorted(front, key=lambda p: p.energy_j)


def _resolve(workload: Workload | str) -> Workload:
    return get_workload(workload) if isinstance(workload, str) else workload


def _explore_scalar(workload: Workload | str,
                    configs: Iterable[AcceleratorConfig] | None = None,
                    *, use_cache: bool = False) -> DSEResult:
    """The original serial sweep — reference path for the batched engine."""
    workload = _resolve(workload)
    if configs is None:
        configs = design_space()
    points = []
    for cfg in configs:
        rep = synthesize_cached(cfg) if use_cache else None
        points.append(DSEPoint(config=cfg,
                               result=run_workload(workload, cfg, rep)))
    return DSEResult(workload=workload.name, points=points)


def _explore(workload: Workload | str,
             configs: Iterable[AcceleratorConfig] | None = None,
             *,
             engine: str = "batched",
             use_cache: bool = True,
             backend: str = "auto",
             mesh=None,
             outputs: str = "points",
             use_pallas: bool | None = None):
    """Sweep ``configs`` (default: the full paper design space) on a workload.

    ``engine="batched"`` evaluates everything as fused array ops;
    ``engine="scalar"`` runs the legacy per-config Python loop.
    ``backend`` picks the array engine (``"auto" | "numpy" | "jax"``, see
    :func:`repro.core.dse_batch.resolve_backend`): the numpy engine is
    **bit-identical** to the scalar loop, the jax engine (what ``auto``
    picks when an accelerator is attached) matches headline ratios to
    <= 1e-6 under jax's default x64-off config — pin ``backend="numpy"``
    when exact reproducibility across hosts matters.  With
    ``backend="jax"`` a ``mesh`` shards the config axis across devices.

    ``outputs`` picks the result form: ``"points"`` (a
    :class:`DSEResult`), ``"sweep"`` (the raw
    :class:`repro.core.dse_batch.BatchedSweep` with per-layer columns), or
    ``"aggregates"`` (a ``BatchedSweep`` holding per-config aggregates
    only — the cheap form for huge spaces).
    """
    if engine == "scalar":
        if outputs != "points":
            raise ValueError(
                f'engine="scalar" only supports outputs="points", '
                f'got {outputs!r}')
        return _explore_scalar(workload, configs, use_cache=use_cache)
    if engine != "batched":
        raise ValueError(f"unknown DSE engine: {engine!r}")
    workload = _resolve(workload)
    cfgs = tuple(design_space() if configs is None else configs)
    sweep = _sweep_workload(
        workload, cfgs, use_cache=use_cache, backend=backend, mesh=mesh,
        outputs="aggregates" if outputs == "aggregates" else "full",
        use_pallas=use_pallas)
    if outputs in ("sweep", "aggregates"):
        return sweep
    if outputs != "points":
        raise ValueError(
            f"unknown outputs mode {outputs!r} "
            f"(choose from ('points', 'sweep', 'aggregates'))")
    points = [DSEPoint(config=c, result=sweep.result_view(i))
              for i, c in enumerate(cfgs)]
    return DSEResult(workload=workload.name, points=points)


def _explore_many(workloads: Sequence[Workload | str],
                  configs: Iterable[AcceleratorConfig] | None = None,
                  *,
                  use_cache: bool = True,
                  backend: str = "auto",
                  mesh=None,
                  outputs: str = "points",
                  use_pallas: bool | None = None) -> dict:
    """Batched multi-workload sweep.

    Synthesis and the struct-of-arrays conversion run *once* for the config
    batch and are shared across all workloads — sweeping the paper's three
    models costs one synthesis pass plus three array-kernel evaluations.
    ``outputs`` as in :func:`_explore` (applies per workload).
    """
    from repro.core.synthesis import synthesize_soa
    if outputs not in ("points", "sweep", "aggregates"):
        raise ValueError(
            f"unknown outputs mode {outputs!r} "
            f"(choose from ('points', 'sweep', 'aggregates'))")
    cfgs = tuple(design_space() if configs is None else configs)
    soa = configs_to_soa(cfgs)
    cols = (sweep_synthesis_cache().synthesize(soa) if use_cache
            else synthesize_soa(soa))
    out: dict = {}
    for wl in workloads:
        wl = _resolve(wl)
        sweep = _sweep_workload(
            wl, cfgs, cols, soa=soa, backend=backend, mesh=mesh,
            outputs="aggregates" if outputs == "aggregates" else "full",
            use_pallas=use_pallas)
        if outputs in ("sweep", "aggregates"):
            out[wl.name] = sweep
        else:
            out[wl.name] = DSEResult(
                workload=wl.name,
                points=[DSEPoint(config=c, result=sweep.result_view(i))
                        for i, c in enumerate(cfgs)])
    return out


def _explore_chunked(workload: Workload | str,
                     configs,
                     **kwargs) -> ChunkedSweep:
    """Streamed bounded-memory sweep over an arbitrary-size config feed —
    see :func:`repro.core.dse_batch._sweep_chunked` for the knobs
    (chunk size, backend, persisted synthesis cache)."""
    return _sweep_chunked(_resolve(workload), configs, **kwargs)


def _coexplore(workload: Workload | str,
               *,
               preset: str = "default",
               method: str | None = None,
               budget: int | None = None,
               seed: int | None = None,
               backend: str = "auto",
               objectives=None,
               ref_point=None,
               mesh=None,
               space_overrides: dict | None = None,
               traffic=None,
               n_slots: int | None = None,
               accuracy=None,
               chunk_size: int | None = None,
               use_pallas: bool | None = None,
               checkpoint_dir: str | None = None,
               checkpoint_every: int | None = None,
               **method_kwargs):
    """Guided co-exploration of the joint (config x per-layer precision)
    space — the QADAM/QUIDAM-direction entry point.

    Resolves a named search preset (:mod:`repro.configs.coexplore_presets`),
    applies any explicit overrides, sizes the genome space to the
    workload, and runs the chosen engine from
    :mod:`repro.explore.search`.  Returns a
    :class:`repro.explore.search.SearchResult` whose front genomes decode
    to (AcceleratorConfig, per-layer mode) pairs.

    ``accuracy`` (default: the preset's) selects the accuracy tier scoring
    the ``accuracy_noise`` objective — anything
    :func:`repro.explore.accuracy.resolve_accuracy` accepts.  A tier-2
    (``"measured:<model>"``) spec additionally runs the final Pareto
    elites through real quantized forward passes
    (:func:`repro.explore.accuracy.validate_elites`) and attaches the
    re-scored front as ``result.validation``.

    A ``traffic`` trace (name, :class:`repro.serving.traffic.TrafficPreset`
    or :class:`~repro.serving.traffic.TrafficTrace`) switches the search
    to serving-fleet objectives: each genome's per-inference latency and
    energy feed the fleet simulator
    (:func:`repro.serving.fleet_sim.simulate_fleet`) over ``n_slots``
    continuous-batching slots, and the objective set defaults to
    :data:`repro.explore.objectives.DEFAULT_SERVING_OBJECTIVES` unless
    the preset or ``objectives=`` already names serving objectives.

    >>> res = coexplore("vgg16", preset="quick", seed=7)
    >>> res.front_points()[0]["modes"]            # doctest: +SKIP
    """
    from repro.configs.coexplore_presets import get_preset
    from repro.explore.accuracy import resolve_accuracy, validate_elites
    from repro.explore.objectives import (DEFAULT_SERVING_OBJECTIVES,
                                          SERVING_OBJECTIVES)
    from repro.explore.search import SEARCH_METHODS
    from repro.explore.space import space_for_workload

    p = get_preset(preset)
    acc = accuracy if accuracy is not None else p.accuracy
    acc_model = None if acc is None else resolve_accuracy(acc)
    wl = _resolve(workload)
    space = space_for_workload(wl, **(space_overrides or {}))
    method = p.method if method is None else method
    fn = SEARCH_METHODS.get(method)
    if fn is None:
        raise ValueError(
            f"unknown co-exploration method {method!r} "
            f"(choose from {sorted(SEARCH_METHODS)})")
    traffic_resolved = traffic if traffic is not None else p.traffic
    if objectives is not None:
        objs = tuple(objectives)
    elif (traffic is not None
          and not set(p.objectives) & set(SERVING_OBJECTIVES)):
        # explicit traffic over a non-serving preset: flip the default
        # objective set to the serving ones, else the Evaluator rejects
        # the trace as unused.
        objs = DEFAULT_SERVING_OBJECTIVES
    else:
        objs = p.objectives
    kwargs = dict(
        objectives=objs,
        seed=p.seed if seed is None else seed,
        backend=backend,
        chunk_size=p.chunk_size if chunk_size is None else chunk_size,
        ref_point=ref_point, mesh=mesh, use_pallas=use_pallas,
        traffic=traffic_resolved,
        n_slots=p.n_slots if n_slots is None else n_slots,
        accuracy=acc_model)
    if method == "nsga2":
        kwargs.update(pop_size=p.pop_size, mutation_rate=p.mutation_rate)
        if p.archive_epsilon is not None:
            kwargs.setdefault("archive_epsilon", p.archive_epsilon)
    elif method == "successive_halving":
        kwargs.update(eta=p.eta)
    _apply_checkpointing(kwargs, method, checkpoint_dir, checkpoint_every)
    kwargs.update(method_kwargs)
    res = fn(space, wl, p.budget if budget is None else budget, **kwargs)
    if acc_model is not None and acc_model.tier == 2:
        res.validation = validate_elites(res, acc_model)
    return res


def _apply_checkpointing(kwargs: dict, method: str,
                         checkpoint_dir: str | None,
                         checkpoint_every: int | None) -> None:
    """Thread search checkpointing knobs through to the engine — only
    nsga2 carries resumable generation state."""
    if checkpoint_dir is None:
        if checkpoint_every is not None:
            raise ValueError("checkpoint_every needs checkpoint_dir")
        return
    if method != "nsga2":
        raise ValueError(
            f"checkpoint_dir requires method='nsga2' (generation "
            f"snapshots); got method={method!r}")
    kwargs["checkpoint_dir"] = checkpoint_dir
    if checkpoint_every is not None:
        kwargs["checkpoint_every"] = checkpoint_every


def _coexplore_many(workloads: Sequence[Workload | str],
                    *,
                    preset: str = "many-default",
                    method: str | None = None,
                    budget: int | None = None,
                    seed: int | None = None,
                    backend: str = "auto",
                    objectives=None,
                    ref_point=None,
                    weights=None,
                    sqnr_floor_db=None,
                    accuracy=None,
                    mesh=None,
                    space_overrides: dict | None = None,
                    chunk_size: int | None = None,
                    use_pallas: bool | None = None,
                    checkpoint_dir: str | None = None,
                    checkpoint_every: int | None = None,
                    **method_kwargs):
    """Multi-workload co-exploration: one shared hardware config, one
    per-layer precision assignment *per workload* — the full QUIDAM
    setting.

    The genome packs the shared hardware levels plus every workload's
    ragged mode segment into one flat uint row
    (:class:`repro.explore.space.CoExploreManySpace`); each population
    chunk is evaluated against all W workloads in a single fused kernel
    call (:func:`repro.core.dse_batch.sweep_mixed_many`) with synthesis
    shared per hardware digest, so the W-workload evaluation costs ~O(1
    synthesis) per hardware config.  Objectives aggregate across the
    suite: ``worst_*`` objectives are the max over workloads (Pareto
    claims then hold for *every* workload), ``mean_*`` are
    energy-weighted means unless ``weights`` fixes an importance vector,
    and an ``accuracy`` spec with ``floor_db`` (scalar or per-workload;
    successor of the deprecated ``sqnr_floor_db``) turns accuracy floors
    into constraints (see
    :func:`repro.explore.objectives.multi_objective_matrix`).
    ``mesh`` (e.g. :func:`repro.launch.mesh.make_sweep_mesh`) shards
    every evaluation chunk's genome axis across devices via
    ``shard_map``; under the numpy backend an int simulates that many
    shards bit-identically.

    Returns a :class:`repro.explore.search.SearchResult` whose
    ``front_points()`` decode to (config, ``{workload: modes}``) pairs.

    >>> res = coexplore_many(["vgg16", "resnet34", "resnet50"],
    ...                      preset="many-quick", seed=7)  # doctest: +SKIP
    """
    from repro.configs.coexplore_presets import get_preset
    from repro.explore.accuracy import resolve_accuracy
    from repro.explore.search import SEARCH_METHODS
    from repro.explore.space import space_for_workloads

    p = get_preset(preset)
    if sqnr_floor_db is not None and accuracy is None:
        # deprecated floor override: drop the preset's accuracy (which
        # in the committed presets is only a floor) and let the engine
        # fold + warn, preserving the historical override semantics
        acc = None
    else:
        acc = accuracy if accuracy is not None else p.accuracy
    acc_model = None if acc is None else resolve_accuracy(acc)
    if acc_model is not None and acc_model.tier == 2:
        raise ValueError(
            "tier-2 (measured) accuracy is single-workload only: a "
            "multi-workload genome has no single precision plan to run "
            "the calibration model under; use 'calibrated:<model>'")
    wls = tuple(_resolve(w) for w in workloads)
    if not wls:
        raise ValueError("coexplore_many needs at least one workload")
    space = space_for_workloads(wls, **(space_overrides or {}))
    method = p.method if method is None else method
    fn = SEARCH_METHODS.get(method)
    if fn is None:
        raise ValueError(
            f"unknown co-exploration method {method!r} "
            f"(choose from {sorted(SEARCH_METHODS)})")
    kwargs = dict(
        objectives=p.objectives if objectives is None else tuple(objectives),
        seed=p.seed if seed is None else seed,
        backend=backend,
        chunk_size=p.chunk_size if chunk_size is None else chunk_size,
        ref_point=ref_point, mesh=mesh, use_pallas=use_pallas,
        weights=p.weights if weights is None else weights,
        sqnr_floor_db=sqnr_floor_db, accuracy=acc_model)
    if method == "nsga2":
        kwargs.update(pop_size=p.pop_size, mutation_rate=p.mutation_rate)
        if p.archive_epsilon is not None:
            kwargs.setdefault("archive_epsilon", p.archive_epsilon)
    elif method == "successive_halving":
        kwargs.update(eta=p.eta)
    _apply_checkpointing(kwargs, method, checkpoint_dir, checkpoint_every)
    kwargs.update(method_kwargs)
    return fn(space, wls, p.budget if budget is None else budget, **kwargs)


class IncrementalSweep:
    """Resumable/extensible DSE sweep over one workload.

    Each :meth:`extend` call evaluates only configs not seen before (keyed
    by config hash) in one batched pass; :meth:`result` returns the
    accumulated :class:`DSEResult`.  Combined with the synthesis cache this
    makes "widen the design space and re-plot" interactive.
    """

    def __init__(self, workload: Workload | str,
                 configs: Iterable[AcceleratorConfig] | None = None,
                 *, backend: str = "auto"):
        self.workload = _resolve(workload)
        self.backend = backend
        self._points: dict[bytes, DSEPoint] = {}
        if configs is not None:
            self.extend(configs)

    def __len__(self) -> int:
        return len(self._points)

    def extend(self, configs: Iterable[AcceleratorConfig]) -> int:
        """Evaluate any new configs; returns how many were actually new."""
        batch = list(configs)
        fresh: list[AcceleratorConfig] = []
        keys: list[bytes] = []
        seen_now = set()
        for cfg, key in zip(batch, config_keys(batch)):  # one digest pass
            if key in self._points or key in seen_now:
                continue
            seen_now.add(key)
            fresh.append(cfg)
            keys.append(key)
        if fresh:
            sweep = _sweep_workload(self.workload, fresh,
                                    backend=self.backend)
            for i, (cfg, key) in enumerate(zip(fresh, keys)):
                self._points[key] = DSEPoint(config=cfg,
                                             result=sweep.result_view(i))
        return len(fresh)

    def result(self) -> DSEResult:
        return DSEResult(workload=self.workload.name,
                         points=list(self._points.values()))


# --------------------------------------------------------------------------
# Unified exploration facade
# --------------------------------------------------------------------------

_OUTPUT_MODES = ("points", "sweep", "aggregates")


@dataclasses.dataclass(frozen=True)
class ExploreSpec:
    """One declarative description of an exploration campaign.

    ``run(spec)`` is the single public entry point that replaces the old
    nine-function surface (``explore`` / ``explore_scalar`` /
    ``explore_many`` / ``explore_chunked`` / ``coexplore`` /
    ``coexplore_many`` and the ``sweep_*`` family).  Build specs with the
    constructors rather than the raw dataclass:

    * :meth:`ExploreSpec.single` — enumerate a config batch on one
      workload at uniform per-config precision (optionally chunk-streamed
      when ``chunk_size`` is set).
    * :meth:`ExploreSpec.mixed` — guided mixed-precision co-exploration
      of one workload (the QADAM direction), optionally under a serving
      ``traffic`` trace.
    * :meth:`ExploreSpec.many` — a workload suite: uniform precision
      enumerates the batch per workload; ``precision="mixed"`` runs the
      shared-hardware / per-workload-precision QUIDAM search.

    Fields not meaningful for the selected mode must stay at their
    defaults — ``__post_init__`` rejects contradictory combinations
    early, before any evaluation work.
    """

    workloads: tuple = ()
    precision: str = "uniform"          # "uniform" | "mixed"
    # uniform-precision knobs
    configs: tuple | None = None
    engine: str = "batched"             # "batched" | "scalar"
    outputs: str = "points"             # "points" | "sweep" | "aggregates"
    cache: object = None                # persisted synthesis cache (chunked)
    save_cache: bool = True
    overlap: bool = True
    # in-flight chunk bound of the streamed pipeline (chunked sweeps):
    # 1 = serial, 2 = the classic two-stage overlap, deeper queues hide
    # host synthesis behind an accelerator-fast kernel stage
    prefetch_depth: int = 2
    # mixed-precision (search) knobs
    preset: str | None = None
    method: str | None = None
    budget: int | None = None
    objectives: tuple | None = None
    traffic: object = None
    n_slots: int | None = None
    ref_point: tuple | None = None
    weights: tuple | None = None
    sqnr_floor_db: object = None        # deprecated: accuracy floor_db
    # accuracy tier scoring the accuracy_noise objectives: None (the
    # preset's, else tier-0 proxy), a spec string ("proxy" /
    # "calibrated:<model>" / "measured:<model>"), an AccuracySpec, or a
    # live AccuracyModel — see repro.explore.accuracy
    accuracy: object = None
    space_overrides: dict | None = None
    search_kwargs: dict | None = None
    # shared knobs
    seed: int | None = None
    backend: str = "auto"
    mesh: object = None
    use_cache: bool = True
    chunk_size: int | None = None
    # Pallas sweep-kernel routing: None auto-engages it on the jax
    # backend with a real accelerator (no mesh); True forces it (raises
    # where unsupported), False pins the jitted XLA kernel — see
    # repro.core.dse_batch.resolve_use_pallas
    use_pallas: object = None
    # fault tolerance: periodic snapshots + resume (preemption safety).
    # Valid for chunked uniform sweeps (checkpointed stream cursor /
    # front / cache accounting, resumed via
    # repro.runtime.dse_checkpoint.resume_sweep) and mixed-precision
    # nsga2 searches (generation snapshots incl. RNG stream).
    checkpoint_dir: str | None = None
    checkpoint_every: int | None = None
    # telemetry: None leaves the process-wide repro.obs switch untouched;
    # True/False flips span tracing for the duration of run(); a dict is
    # passed to repro.obs.configure() (e.g. {"jsonl_path": ...,
    # "jax_annotations": True}).  The metrics registry is always on.
    telemetry: object = None

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("ExploreSpec needs at least one workload")
        object.__setattr__(self, "workloads", tuple(self.workloads))
        if self.precision not in ("uniform", "mixed"):
            raise ValueError(
                f"precision must be 'uniform' or 'mixed', "
                f"got {self.precision!r}")
        if self.outputs not in _OUTPUT_MODES:
            raise ValueError(
                f"unknown outputs mode {self.outputs!r} "
                f"(choose from {_OUTPUT_MODES})")
        if self.engine not in ("batched", "scalar"):
            raise ValueError(f"unknown DSE engine: {self.engine!r}")
        if self.configs is not None and self.chunk_size is None:
            # chunk-streamed feeds stay lazy (generators of configs or
            # SoA chunks); everything else materializes once up front
            object.__setattr__(self, "configs", tuple(self.configs))
        if self.objectives is not None:
            object.__setattr__(self, "objectives", tuple(self.objectives))
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(
                f"chunk_size must be >= 1, got {self.chunk_size}")
        if int(self.prefetch_depth) < 1:
            raise ValueError(
                f"prefetch_depth must be >= 1, got {self.prefetch_depth}")
        if self.prefetch_depth != 2 and self.chunk_size is None:
            raise ValueError(
                "prefetch_depth tunes the streamed chunk pipeline; it "
                "needs chunk_size=")
        if self.use_pallas is not None \
                and not isinstance(self.use_pallas, bool):
            raise ValueError(
                f"use_pallas must be None (auto) or a bool, got "
                f"{type(self.use_pallas).__name__}")
        if self.use_pallas is True and self.backend == "numpy":
            raise ValueError(
                "use_pallas=True requires the jax backend, not "
                "backend='numpy'")
        if self.use_pallas is True and self.mesh is not None:
            raise ValueError(
                "use_pallas=True does not compose with mesh= sharding "
                "yet; drop mesh= or use_pallas")
        if self.checkpoint_every is not None:
            if self.checkpoint_dir is None:
                raise ValueError(
                    "checkpoint_every needs checkpoint_dir")
            if self.checkpoint_every < 1:
                raise ValueError(
                    f"checkpoint_every must be >= 1, "
                    f"got {self.checkpoint_every}")
        if self.checkpoint_dir is not None \
                and self.precision == "uniform" and self.chunk_size is None:
            raise ValueError(
                "checkpoint_dir applies to chunked uniform sweeps "
                "(chunk_size=) or mixed-precision searches; a one-batch "
                "sweep has no resumable stream")
        if self.telemetry is not None \
                and not isinstance(self.telemetry, (bool, dict)):
            raise ValueError(
                "telemetry must be None, a bool, or a dict of "
                "repro.obs.configure() kwargs, got "
                f"{type(self.telemetry).__name__}")
        if isinstance(self.accuracy, str):
            # validate + normalize spec strings early, before any work
            from repro.explore.accuracy import AccuracySpec
            object.__setattr__(self, "accuracy",
                               AccuracySpec.parse(self.accuracy))
        if self.precision == "uniform":
            bad = [n for n, v in (
                ("preset", self.preset), ("method", self.method),
                ("budget", self.budget), ("objectives", self.objectives),
                ("traffic", self.traffic), ("n_slots", self.n_slots),
                ("ref_point", self.ref_point), ("weights", self.weights),
                ("sqnr_floor_db", self.sqnr_floor_db),
                ("accuracy", self.accuracy),
                ("space_overrides", self.space_overrides),
                ("search_kwargs", self.search_kwargs)) if v is not None]
            if bad:
                raise ValueError(
                    f"search knob(s) {bad} only apply to "
                    f'precision="mixed" specs')
            if self.chunk_size is not None and len(self.workloads) > 1:
                raise ValueError(
                    "chunked streaming (chunk_size=) supports a single "
                    "workload; sweep the suite per workload instead")
            if self.engine == "scalar" and (self.outputs != "points"
                                            or self.chunk_size is not None):
                raise ValueError(
                    'engine="scalar" only supports outputs="points" '
                    'without chunking')
        else:
            bad = [n for n, v in (
                ("configs", self.configs),
                ("cache", self.cache)) if v is not None]
            if self.engine != "batched":
                bad.append("engine")
            if self.outputs != "points":
                bad.append("outputs")
            if bad:
                raise ValueError(
                    f"sweep knob(s) {bad} only apply to "
                    f'precision="uniform" specs')
            if (self.weights is not None or self.sqnr_floor_db is not None) \
                    and len(self.workloads) == 1:
                raise ValueError(
                    "weights/sqnr_floor_db aggregate across a workload "
                    "suite; pass >= 2 workloads")

    # -- constructors ------------------------------------------------------

    @classmethod
    def single(cls, workload, configs=None, *, engine: str = "batched",
               outputs: str = "points", chunk_size: int | None = None,
               backend: str = "auto", mesh=None, use_cache: bool = True,
               cache=None, save_cache: bool = True,
               overlap: bool = True, prefetch_depth: int = 2,
               use_pallas: bool | None = None,
               checkpoint_dir: str | None = None,
               checkpoint_every: int | None = None,
               telemetry=None) -> "ExploreSpec":
        """Uniform-precision sweep of one workload over a config batch
        (the whole design space when ``configs`` is None).  A
        ``chunk_size`` streams an arbitrary-size config feed with bounded
        memory and returns the accumulated :class:`ChunkedSweep`; a
        ``checkpoint_dir`` makes the stream preemption-safe (periodic
        snapshots, resumed automatically — ``configs`` should then be a
        re-iterable feed or a zero-arg factory)."""
        return cls(workloads=(workload,), precision="uniform",
                   configs=configs, engine=engine, outputs=outputs,
                   chunk_size=chunk_size, backend=backend, mesh=mesh,
                   use_cache=use_cache, cache=cache,
                   save_cache=save_cache, overlap=overlap,
                   prefetch_depth=prefetch_depth, use_pallas=use_pallas,
                   checkpoint_dir=checkpoint_dir,
                   checkpoint_every=checkpoint_every,
                   telemetry=telemetry)

    @classmethod
    def mixed(cls, workload, *, preset: str | None = None,
              method: str | None = None, budget: int | None = None,
              objectives=None, traffic=None, n_slots: int | None = None,
              accuracy=None,
              seed: int | None = None, ref_point=None,
              space_overrides: dict | None = None,
              chunk_size: int | None = None, backend: str = "auto",
              mesh=None, use_pallas: bool | None = None,
              checkpoint_dir: str | None = None,
              checkpoint_every: int | None = None, telemetry=None,
              **search_kwargs) -> "ExploreSpec":
        """Guided mixed-precision co-exploration of one workload; a
        ``traffic`` trace switches the objectives to the serving-fleet
        set (tail latency / SLO attainment / throughput / energy per
        served token).  ``accuracy`` picks the accuracy tier —
        ``"measured:<model>"`` additionally re-scores the final Pareto
        elites with real quantized forward passes
        (``result.validation``).  A ``checkpoint_dir`` snapshots the
        search each ``checkpoint_every`` generations and resumes
        bit-identically (nsga2 only)."""
        return cls(workloads=(workload,), precision="mixed",
                   preset=preset, method=method, budget=budget,
                   objectives=objectives, traffic=traffic, n_slots=n_slots,
                   accuracy=accuracy, seed=seed, ref_point=ref_point,
                   space_overrides=space_overrides, chunk_size=chunk_size,
                   backend=backend, mesh=mesh, use_pallas=use_pallas,
                   checkpoint_dir=checkpoint_dir,
                   checkpoint_every=checkpoint_every, telemetry=telemetry,
                   search_kwargs=search_kwargs or None)

    @classmethod
    def many(cls, workloads, *, precision: str = "uniform",
             configs=None, outputs: str = "points",
             preset: str | None = None, method: str | None = None,
             budget: int | None = None, objectives=None,
             weights=None, sqnr_floor_db=None, accuracy=None,
             seed: int | None = None,
             ref_point=None, space_overrides: dict | None = None,
             chunk_size: int | None = None, backend: str = "auto",
             mesh=None, use_cache: bool = True,
             use_pallas: bool | None = None,
             checkpoint_dir: str | None = None,
             checkpoint_every: int | None = None, telemetry=None,
             **search_kwargs) -> "ExploreSpec":
        """A workload suite.  ``precision="uniform"`` enumerates the
        config batch once per workload (synthesis shared);
        ``precision="mixed"`` searches one shared hardware config with a
        per-workload precision assignment (the QUIDAM setting)."""
        if precision == "uniform" and search_kwargs:
            raise ValueError(
                f"search kwarg(s) {sorted(search_kwargs)} only apply to "
                f'precision="mixed" specs')
        return cls(workloads=tuple(workloads), precision=precision,
                   configs=None if configs is None else tuple(configs),
                   outputs=outputs, preset=preset, method=method,
                   budget=budget, objectives=objectives, weights=weights,
                   sqnr_floor_db=sqnr_floor_db, accuracy=accuracy,
                   seed=seed,
                   ref_point=ref_point, space_overrides=space_overrides,
                   chunk_size=chunk_size, backend=backend, mesh=mesh,
                   use_cache=use_cache, use_pallas=use_pallas,
                   checkpoint_dir=checkpoint_dir,
                   checkpoint_every=checkpoint_every, telemetry=telemetry,
                   search_kwargs=search_kwargs or None)


def run(spec: ExploreSpec):
    """Execute an :class:`ExploreSpec` — the unified exploration entry
    point.

    Returns, by mode:

    * uniform, one workload — :class:`DSEResult` /
      :class:`~repro.core.dse_batch.BatchedSweep` (per ``outputs``), or a
      :class:`~repro.core.dse_batch.ChunkedSweep` when ``chunk_size``
      streams the feed.
    * uniform, many workloads — ``{workload_name: result}`` dict.
    * mixed — a :class:`repro.explore.search.SearchResult`.
    """
    if not isinstance(spec, ExploreSpec):
        raise TypeError(
            f"run() takes an ExploreSpec, got {type(spec).__name__}; "
            f"build one with ExploreSpec.single/.mixed/.many")
    from repro.obs import trace as obs_trace
    with obs_trace.configured(spec.telemetry):
        return _run_dispatch(spec)


def _run_dispatch(spec: ExploreSpec):
    extra = dict(spec.search_kwargs or {})
    if spec.precision == "mixed":
        if len(spec.workloads) == 1:
            return _coexplore(
                spec.workloads[0],
                preset="default" if spec.preset is None else spec.preset,
                method=spec.method, budget=spec.budget, seed=spec.seed,
                backend=spec.backend, objectives=spec.objectives,
                ref_point=spec.ref_point, mesh=spec.mesh,
                space_overrides=spec.space_overrides,
                traffic=spec.traffic, n_slots=spec.n_slots,
                accuracy=spec.accuracy,
                chunk_size=spec.chunk_size, use_pallas=spec.use_pallas,
                checkpoint_dir=spec.checkpoint_dir,
                checkpoint_every=spec.checkpoint_every, **extra)
        return _coexplore_many(
            spec.workloads,
            preset="many-default" if spec.preset is None else spec.preset,
            method=spec.method, budget=spec.budget, seed=spec.seed,
            backend=spec.backend, objectives=spec.objectives,
            ref_point=spec.ref_point, weights=spec.weights,
            sqnr_floor_db=spec.sqnr_floor_db,
            accuracy=spec.accuracy, mesh=spec.mesh,
            space_overrides=spec.space_overrides,
            chunk_size=spec.chunk_size, use_pallas=spec.use_pallas,
            checkpoint_dir=spec.checkpoint_dir,
            checkpoint_every=spec.checkpoint_every, **extra)
    # uniform precision
    if len(spec.workloads) > 1:
        return _explore_many(
            spec.workloads, spec.configs, use_cache=spec.use_cache,
            backend=spec.backend, mesh=spec.mesh, outputs=spec.outputs,
            use_pallas=spec.use_pallas)
    wl = spec.workloads[0]
    if spec.chunk_size is not None:
        if spec.configs is None:
            raise ValueError(
                "chunked streaming needs an explicit config feed "
                "(configs=); the default design space fits in one batch")
        if spec.outputs != "points":
            raise ValueError(
                "chunked streaming returns a ChunkedSweep (aggregates "
                'only); leave outputs="points"')
        if spec.checkpoint_dir is not None:
            from repro.runtime.dse_checkpoint import resume_sweep
            kwargs = {} if spec.checkpoint_every is None \
                else {"checkpoint_every": spec.checkpoint_every}
            return resume_sweep(
                _resolve(wl), spec.configs,
                checkpoint_dir=spec.checkpoint_dir,
                chunk_size=spec.chunk_size, backend=spec.backend,
                use_cache=spec.use_cache, cache=spec.cache,
                save_cache=spec.save_cache, mesh=spec.mesh,
                overlap=spec.overlap,
                prefetch_depth=spec.prefetch_depth,
                use_pallas=spec.use_pallas, **kwargs)
        return _explore_chunked(
            wl, spec.configs, chunk_size=spec.chunk_size,
            backend=spec.backend, use_cache=spec.use_cache,
            cache=spec.cache, save_cache=spec.save_cache, mesh=spec.mesh,
            overlap=spec.overlap, prefetch_depth=spec.prefetch_depth,
            use_pallas=spec.use_pallas)
    return _explore(wl, spec.configs, engine=spec.engine,
                    use_cache=spec.use_cache, backend=spec.backend,
                    mesh=spec.mesh, outputs=spec.outputs,
                    use_pallas=spec.use_pallas)


# --------------------------------------------------------------------------
# Deprecated entry points (pre-ExploreSpec API), kept one release.
# --------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    import warnings
    warnings.warn(
        f"{old} is deprecated; use {new} instead",
        DeprecationWarning, stacklevel=3)


def explore(*args, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.single(workload, configs))``."""
    _deprecated("repro.core.dse.explore",
                "repro.core.dse.run(ExploreSpec.single(workload, configs))")
    return _explore(*args, **kwargs)


def explore_scalar(*args, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.single(workload, configs, engine="scalar"))``."""
    _deprecated(
        "repro.core.dse.explore_scalar",
        'repro.core.dse.run(ExploreSpec.single(workload, configs, '
        'engine="scalar"))')
    return _explore_scalar(*args, **kwargs)


def explore_many(*args, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.many(workloads, configs=...))``."""
    _deprecated("repro.core.dse.explore_many",
                "repro.core.dse.run(ExploreSpec.many(workloads, "
                "configs=...))")
    return _explore_many(*args, **kwargs)


def explore_chunked(workload, configs, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.single(workload, configs, chunk_size=...))``."""
    _deprecated(
        "repro.core.dse.explore_chunked",
        "repro.core.dse.run(ExploreSpec.single(workload, configs, "
        "chunk_size=...))")
    kwargs.setdefault("chunk_size", 32768)
    kwargs.setdefault("use_cache", False)
    return _explore_chunked(workload, configs, **kwargs)


def coexplore(*args, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.mixed(workload, preset=...))``."""
    _deprecated("repro.core.dse.coexplore",
                "repro.core.dse.run(ExploreSpec.mixed(workload, "
                "preset=...))")
    return _coexplore(*args, **kwargs)


def coexplore_many(*args, **kwargs):
    """Deprecated alias — use
    ``run(ExploreSpec.many(workloads, precision="mixed", preset=...))``."""
    _deprecated(
        "repro.core.dse.coexplore_many",
        'repro.core.dse.run(ExploreSpec.many(workloads, '
        'precision="mixed", preset=...))')
    return _coexplore_many(*args, **kwargs)
