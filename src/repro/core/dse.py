"""Design-space exploration (paper Sec. 4, Figs. 3-5).

Sweeps the QAPPA design space, evaluates each design point on a workload via
the row-stationary dataflow model, and reports normalized
performance-per-area vs normalized energy with respect to the *best INT16
configuration* (the paper's anchor).  Also extracts Pareto frontiers.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.accelerator import AcceleratorConfig, design_space
from repro.core.dataflow import WorkloadResult, run_workload
from repro.core.pe import PEType
from repro.core.synthesis import synthesize
from repro.core.workloads import Workload, get_workload


@dataclasses.dataclass(frozen=True)
class DSEPoint:
    config: AcceleratorConfig
    result: WorkloadResult

    @property
    def perf_per_area(self) -> float:
        return self.result.perf_per_area

    @property
    def energy_j(self) -> float:
        return self.result.energy_j


@dataclasses.dataclass
class DSEResult:
    workload: str
    points: list[DSEPoint]

    def by_type(self, pe_type: PEType) -> list[DSEPoint]:
        return [p for p in self.points if p.config.pe_type == pe_type]

    def best_perf_per_area(self, pe_type: PEType) -> DSEPoint:
        return max(self.by_type(pe_type), key=lambda p: p.perf_per_area)

    def best_energy(self, pe_type: PEType) -> DSEPoint:
        return min(self.by_type(pe_type), key=lambda p: p.energy_j)

    def normalized(self) -> list[dict]:
        """Per paper Figs. 3-5: normalize against best-perf/area INT16."""
        anchor = self.best_perf_per_area(PEType.INT16)
        out = []
        for p in self.points:
            out.append({
                "config": p.config.name(),
                "pe_type": p.config.pe_type.value,
                "norm_perf_per_area": p.perf_per_area / anchor.perf_per_area,
                "norm_energy": p.energy_j / anchor.energy_j,
            })
        return out

    def headline_ratios(self) -> dict[str, float]:
        """The paper's headline numbers (Sec. 4):

        * LightPE-1 vs best INT16: perf/area and energy improvement
        * LightPE-2 vs best INT16: perf/area and energy improvement
        * INT16 vs best FP32: perf/area and energy improvement
        Each ratio compares the best configuration of each PE type,
        matching "when compared to the best INT16 hardware configuration".
        """
        b = {t: self.best_perf_per_area(t) for t in PEType}
        e = {t: self.best_energy(t) for t in PEType}
        return {
            "lightpe1_perf_per_area_vs_int16":
                b[PEType.LIGHTPE1].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe1_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE1].energy_j,
            "lightpe2_perf_per_area_vs_int16":
                b[PEType.LIGHTPE2].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe2_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE2].energy_j,
            "int16_perf_per_area_vs_fp32":
                b[PEType.INT16].perf_per_area / b[PEType.FP32].perf_per_area,
            "int16_energy_vs_fp32":
                e[PEType.FP32].energy_j / e[PEType.INT16].energy_j,
        }


def pareto_front(points: Sequence[DSEPoint]) -> list[DSEPoint]:
    """Non-dominated set for (maximize perf/area, minimize energy)."""
    front: list[DSEPoint] = []
    for p in points:
        dominated = any(
            (q.perf_per_area >= p.perf_per_area and q.energy_j <= p.energy_j
             and (q.perf_per_area > p.perf_per_area
                  or q.energy_j < p.energy_j))
            for q in points)
        if not dominated:
            front.append(p)
    return sorted(front, key=lambda p: p.energy_j)


def explore(workload: Workload | str,
            configs: Iterable[AcceleratorConfig] | None = None) -> DSEResult:
    if isinstance(workload, str):
        workload = get_workload(workload)
    if configs is None:
        configs = design_space()
    points = []
    for cfg in configs:
        rep = synthesize(cfg)
        points.append(DSEPoint(config=cfg,
                               result=run_workload(workload, cfg, rep)))
    return DSEResult(workload=workload.name, points=points)
