"""Design-space exploration (paper Sec. 4, Figs. 3-5).

Sweeps the QAPPA design space, evaluates each design point on a workload via
the row-stationary dataflow model, and reports normalized
performance-per-area vs normalized energy with respect to the *best INT16
configuration* (the paper's anchor).  Also extracts Pareto frontiers.

Two engines produce identical results:

* ``engine="batched"`` (default) — the vectorized struct-of-arrays sweep in
  :mod:`repro.core.dse_batch`: all configs x all layers in a handful of
  fused array ops, with a synthesis-report cache so re-sweeps (new
  workloads, extended spaces) skip the synthesis flow entirely.
* ``engine="scalar"`` — the original O(configs x layers) Python loop, kept
  as the bit-exact reference the batched engine is tested against.

``explore_many`` amortizes synthesis + SoA conversion across workloads,
:class:`IncrementalSweep` lets a sweep be resumed/extended without
re-evaluating known design points, :func:`coexplore` runs the guided
mixed-precision co-exploration engine (:mod:`repro.explore`) over the
joint (config x per-layer precision) space, and :func:`coexplore_many`
extends it to a workload *suite* sharing one hardware config with
per-workload precision assignments (the full QUIDAM setting).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.accelerator import (AcceleratorConfig, configs_to_soa,
                                    design_space)
from repro.core.dataflow import WorkloadResult, run_workload
from repro.core.dse_batch import (ChunkedSweep, pareto_mask, sweep_chunked,
                                  sweep_workload)
from repro.core.pe import PEType
from repro.core.synthesis import (config_keys, sweep_synthesis_cache,
                                  synthesize_cached)
from repro.core.workloads import Workload, get_workload


@dataclasses.dataclass(frozen=True)
class DSEPoint:
    config: AcceleratorConfig
    result: WorkloadResult  # or a BatchedWorkloadResult view (duck-typed)

    @property
    def perf_per_area(self) -> float:
        return self.result.perf_per_area

    @property
    def energy_j(self) -> float:
        return self.result.energy_j


@dataclasses.dataclass
class DSEResult:
    workload: str
    points: list[DSEPoint]

    def by_type(self, pe_type: PEType) -> list[DSEPoint]:
        return [p for p in self.points if p.config.pe_type == pe_type]

    def best_perf_per_area(self, pe_type: PEType) -> DSEPoint:
        return max(self.by_type(pe_type), key=lambda p: p.perf_per_area)

    def best_energy(self, pe_type: PEType) -> DSEPoint:
        return min(self.by_type(pe_type), key=lambda p: p.energy_j)

    def normalized(self) -> list[dict]:
        """Per paper Figs. 3-5: normalize against best-perf/area INT16."""
        anchor = self.best_perf_per_area(PEType.INT16)
        out = []
        for p in self.points:
            out.append({
                "config": p.config.name(),
                "pe_type": p.config.pe_type.value,
                "norm_perf_per_area": p.perf_per_area / anchor.perf_per_area,
                "norm_energy": p.energy_j / anchor.energy_j,
            })
        return out

    def headline_ratios(self) -> dict[str, float]:
        """The paper's headline numbers (Sec. 4):

        * LightPE-1 vs best INT16: perf/area and energy improvement
        * LightPE-2 vs best INT16: perf/area and energy improvement
        * INT16 vs best FP32: perf/area and energy improvement
        Each ratio compares the best configuration of each PE type,
        matching "when compared to the best INT16 hardware configuration".
        """
        b = {t: self.best_perf_per_area(t) for t in PEType}
        e = {t: self.best_energy(t) for t in PEType}
        return {
            "lightpe1_perf_per_area_vs_int16":
                b[PEType.LIGHTPE1].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe1_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE1].energy_j,
            "lightpe2_perf_per_area_vs_int16":
                b[PEType.LIGHTPE2].perf_per_area / b[PEType.INT16].perf_per_area,
            "lightpe2_energy_vs_int16":
                e[PEType.INT16].energy_j / e[PEType.LIGHTPE2].energy_j,
            "int16_perf_per_area_vs_fp32":
                b[PEType.INT16].perf_per_area / b[PEType.FP32].perf_per_area,
            "int16_energy_vs_fp32":
                e[PEType.FP32].energy_j / e[PEType.INT16].energy_j,
        }


def pareto_front_scalar(points: Sequence[DSEPoint]) -> list[DSEPoint]:
    """O(n^2) reference: non-dominated set for (max perf/area, min energy)."""
    front: list[DSEPoint] = []
    for p in points:
        dominated = any(
            (q.perf_per_area >= p.perf_per_area and q.energy_j <= p.energy_j
             and (q.perf_per_area > p.perf_per_area
                  or q.energy_j < p.energy_j))
            for q in points)
        if not dominated:
            front.append(p)
    return sorted(front, key=lambda p: p.energy_j)


def pareto_front(points: Sequence[DSEPoint]) -> list[DSEPoint]:
    """Non-dominated set for (maximize perf/area, minimize energy).

    Vectorized dominance test (:func:`repro.core.dse_batch.pareto_mask`);
    identical output to :func:`pareto_front_scalar`.
    """
    if not points:
        return []
    perf = np.array([p.perf_per_area for p in points], dtype=np.float64)
    energy = np.array([p.energy_j for p in points], dtype=np.float64)
    keep = pareto_mask(perf, energy)
    front = [p for p, k in zip(points, keep) if k]
    return sorted(front, key=lambda p: p.energy_j)


def _resolve(workload: Workload | str) -> Workload:
    return get_workload(workload) if isinstance(workload, str) else workload


def explore_scalar(workload: Workload | str,
                   configs: Iterable[AcceleratorConfig] | None = None,
                   use_cache: bool = False) -> DSEResult:
    """The original serial sweep — reference path for the batched engine."""
    workload = _resolve(workload)
    if configs is None:
        configs = design_space()
    points = []
    for cfg in configs:
        rep = synthesize_cached(cfg) if use_cache else None
        points.append(DSEPoint(config=cfg,
                               result=run_workload(workload, cfg, rep)))
    return DSEResult(workload=workload.name, points=points)


def explore(workload: Workload | str,
            configs: Iterable[AcceleratorConfig] | None = None,
            *,
            engine: str = "batched",
            use_cache: bool = True,
            backend: str = "auto",
            mesh=None) -> DSEResult:
    """Sweep ``configs`` (default: the full paper design space) on a workload.

    ``engine="batched"`` evaluates everything as fused array ops;
    ``engine="scalar"`` runs the legacy per-config Python loop.
    ``backend`` picks the array engine (``"auto" | "numpy" | "jax"``, see
    :func:`repro.core.dse_batch.resolve_backend`): the numpy engine is
    **bit-identical** to the scalar loop, the jax engine (what ``auto``
    picks when an accelerator is attached) matches headline ratios to
    <= 1e-6 under jax's default x64-off config — pin ``backend="numpy"``
    when exact reproducibility across hosts matters.  With
    ``backend="jax"`` a ``mesh`` shards the config axis across devices.
    """
    if engine == "scalar":
        return explore_scalar(workload, configs, use_cache=use_cache)
    if engine != "batched":
        raise ValueError(f"unknown DSE engine: {engine!r}")
    workload = _resolve(workload)
    cfgs = tuple(design_space() if configs is None else configs)
    sweep = sweep_workload(workload, cfgs, use_cache=use_cache,
                           backend=backend, mesh=mesh)
    points = [DSEPoint(config=c, result=sweep.result_view(i))
              for i, c in enumerate(cfgs)]
    return DSEResult(workload=workload.name, points=points)


def explore_many(workloads: Sequence[Workload | str],
                 configs: Iterable[AcceleratorConfig] | None = None,
                 *,
                 use_cache: bool = True,
                 backend: str = "auto",
                 mesh=None) -> dict[str, DSEResult]:
    """Batched multi-workload sweep.

    Synthesis and the struct-of-arrays conversion run *once* for the config
    batch and are shared across all workloads — sweeping the paper's three
    models costs one synthesis pass plus three array-kernel evaluations.
    """
    from repro.core.synthesis import synthesize_soa
    cfgs = tuple(design_space() if configs is None else configs)
    soa = configs_to_soa(cfgs)
    cols = (sweep_synthesis_cache().synthesize(soa) if use_cache
            else synthesize_soa(soa))
    out: dict[str, DSEResult] = {}
    for wl in workloads:
        wl = _resolve(wl)
        sweep = sweep_workload(wl, cfgs, cols, soa=soa, backend=backend,
                               mesh=mesh)
        out[wl.name] = DSEResult(
            workload=wl.name,
            points=[DSEPoint(config=c, result=sweep.result_view(i))
                    for i, c in enumerate(cfgs)])
    return out


def explore_chunked(workload: Workload | str,
                    configs,
                    **kwargs) -> ChunkedSweep:
    """Streamed bounded-memory sweep over an arbitrary-size config feed —
    see :func:`repro.core.dse_batch.sweep_chunked` for the knobs
    (chunk size, backend, persisted synthesis cache)."""
    return sweep_chunked(_resolve(workload), configs, **kwargs)


def coexplore(workload: Workload | str,
              *,
              preset: str = "default",
              method: str | None = None,
              budget: int | None = None,
              seed: int | None = None,
              backend: str = "auto",
              objectives=None,
              ref_point=None,
              mesh=None,
              space_overrides: dict | None = None,
              **method_kwargs):
    """Guided co-exploration of the joint (config x per-layer precision)
    space — the QADAM/QUIDAM-direction entry point.

    Resolves a named search preset (:mod:`repro.configs.coexplore_presets`),
    applies any explicit overrides, sizes the genome space to the
    workload, and runs the chosen engine from
    :mod:`repro.explore.search`.  Returns a
    :class:`repro.explore.search.SearchResult` whose front genomes decode
    to (AcceleratorConfig, per-layer mode) pairs.

    >>> res = coexplore("vgg16", preset="quick", seed=7)
    >>> res.front_points()[0]["modes"]            # doctest: +SKIP
    """
    from repro.configs.coexplore_presets import get_preset
    from repro.explore.search import SEARCH_METHODS
    from repro.explore.space import space_for_workload

    p = get_preset(preset)
    wl = _resolve(workload)
    space = space_for_workload(wl, **(space_overrides or {}))
    method = p.method if method is None else method
    fn = SEARCH_METHODS.get(method)
    if fn is None:
        raise ValueError(
            f"unknown co-exploration method {method!r} "
            f"(choose from {sorted(SEARCH_METHODS)})")
    kwargs = dict(
        objectives=p.objectives if objectives is None else tuple(objectives),
        seed=p.seed if seed is None else seed,
        backend=backend, chunk_size=p.chunk_size, ref_point=ref_point,
        mesh=mesh)
    if method == "nsga2":
        kwargs.update(pop_size=p.pop_size, mutation_rate=p.mutation_rate)
    elif method == "successive_halving":
        kwargs.update(eta=p.eta)
    kwargs.update(method_kwargs)
    return fn(space, wl, p.budget if budget is None else budget, **kwargs)


def coexplore_many(workloads: Sequence[Workload | str],
                   *,
                   preset: str = "many-default",
                   method: str | None = None,
                   budget: int | None = None,
                   seed: int | None = None,
                   backend: str = "auto",
                   objectives=None,
                   ref_point=None,
                   weights=None,
                   sqnr_floor_db=None,
                   mesh=None,
                   space_overrides: dict | None = None,
                   **method_kwargs):
    """Multi-workload co-exploration: one shared hardware config, one
    per-layer precision assignment *per workload* — the full QUIDAM
    setting.

    The genome packs the shared hardware levels plus every workload's
    ragged mode segment into one flat uint row
    (:class:`repro.explore.space.CoExploreManySpace`); each population
    chunk is evaluated against all W workloads in a single fused kernel
    call (:func:`repro.core.dse_batch.sweep_mixed_many`) with synthesis
    shared per hardware digest, so the W-workload evaluation costs ~O(1
    synthesis) per hardware config.  Objectives aggregate across the
    suite: ``worst_*`` objectives are the max over workloads (Pareto
    claims then hold for *every* workload), ``mean_*`` are
    energy-weighted means unless ``weights`` fixes an importance vector,
    and ``sqnr_floor_db`` turns per-workload accuracy floors into
    constraints (see
    :func:`repro.explore.objectives.multi_objective_matrix`).
    ``mesh`` (e.g. :func:`repro.launch.mesh.make_sweep_mesh`) shards
    every evaluation chunk's genome axis across devices via
    ``shard_map``; under the numpy backend an int simulates that many
    shards bit-identically.

    Returns a :class:`repro.explore.search.SearchResult` whose
    ``front_points()`` decode to (config, ``{workload: modes}``) pairs.

    >>> res = coexplore_many(["vgg16", "resnet34", "resnet50"],
    ...                      preset="many-quick", seed=7)  # doctest: +SKIP
    """
    from repro.configs.coexplore_presets import get_preset
    from repro.explore.search import SEARCH_METHODS
    from repro.explore.space import space_for_workloads

    p = get_preset(preset)
    wls = tuple(_resolve(w) for w in workloads)
    if not wls:
        raise ValueError("coexplore_many needs at least one workload")
    space = space_for_workloads(wls, **(space_overrides or {}))
    method = p.method if method is None else method
    fn = SEARCH_METHODS.get(method)
    if fn is None:
        raise ValueError(
            f"unknown co-exploration method {method!r} "
            f"(choose from {sorted(SEARCH_METHODS)})")
    kwargs = dict(
        objectives=p.objectives if objectives is None else tuple(objectives),
        seed=p.seed if seed is None else seed,
        backend=backend, chunk_size=p.chunk_size, ref_point=ref_point,
        mesh=mesh,
        weights=p.weights if weights is None else weights,
        sqnr_floor_db=(p.sqnr_floor_db if sqnr_floor_db is None
                       else sqnr_floor_db))
    if method == "nsga2":
        kwargs.update(pop_size=p.pop_size, mutation_rate=p.mutation_rate)
    elif method == "successive_halving":
        kwargs.update(eta=p.eta)
    kwargs.update(method_kwargs)
    return fn(space, wls, p.budget if budget is None else budget, **kwargs)


class IncrementalSweep:
    """Resumable/extensible DSE sweep over one workload.

    Each :meth:`extend` call evaluates only configs not seen before (keyed
    by config hash) in one batched pass; :meth:`result` returns the
    accumulated :class:`DSEResult`.  Combined with the synthesis cache this
    makes "widen the design space and re-plot" interactive.
    """

    def __init__(self, workload: Workload | str,
                 configs: Iterable[AcceleratorConfig] | None = None,
                 *, backend: str = "auto"):
        self.workload = _resolve(workload)
        self.backend = backend
        self._points: dict[bytes, DSEPoint] = {}
        if configs is not None:
            self.extend(configs)

    def __len__(self) -> int:
        return len(self._points)

    def extend(self, configs: Iterable[AcceleratorConfig]) -> int:
        """Evaluate any new configs; returns how many were actually new."""
        batch = list(configs)
        fresh: list[AcceleratorConfig] = []
        keys: list[bytes] = []
        seen_now = set()
        for cfg, key in zip(batch, config_keys(batch)):  # one digest pass
            if key in self._points or key in seen_now:
                continue
            seen_now.add(key)
            fresh.append(cfg)
            keys.append(key)
        if fresh:
            sweep = sweep_workload(self.workload, fresh,
                                   backend=self.backend)
            for i, (cfg, key) in enumerate(zip(fresh, keys)):
                self._points[key] = DSEPoint(config=cfg,
                                             result=sweep.result_view(i))
        return len(fresh)

    def result(self) -> DSEResult:
        return DSEResult(workload=self.workload.name,
                         points=list(self._points.values()))
