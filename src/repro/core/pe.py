"""Processing-element models for the QAPPA accelerator template.

Four PE types from the paper (Sec. 3):

* ``FP32``     -- fp32 multiply-accumulate.
* ``INT16``    -- 16-bit integer MAC.
* ``LightPE-1``-- 8-bit activations x 4-bit power-of-two weights; the
  multiplier is replaced by ONE barrel shift (LightNN, Ding et al. 2018).
* ``LightPE-2``-- 8-bit activations x 8-bit weights constrained to a sum of
  <=2 powers of two; the multiplier is replaced by two shifts + one add.

Per-op energy/area/delay constants are grounded in published 45 nm numbers
(Horowitz, ISSCC'14; FreePDK45-era synthesis literature).  They stand in for
the paper's Synopsys DC + FreePDK45 synthesis flow -- see DESIGN.md §2.
Energy in pJ, area in um^2, delay in ns.
"""

from __future__ import annotations

import dataclasses
import enum
import functools

import numpy as np


class PEType(str, enum.Enum):
    FP32 = "fp32"
    INT16 = "int16"
    LIGHTPE1 = "lightpe1"
    LIGHTPE2 = "lightpe2"

    @property
    def pretty(self) -> str:
        return {
            PEType.FP32: "FP32",
            PEType.INT16: "INT16",
            PEType.LIGHTPE1: "LightPE-1",
            PEType.LIGHTPE2: "LightPE-2",
        }[self]


# ---------------------------------------------------------------------------
# 45nm per-op constants.
#
# Baseline values follow Horowitz (ISSCC'14); the per-PE-type aggregates are
# then CALIBRATED against the QAPPA paper's reported synthesis ratios (the
# raw Synopsys DC / FreePDK45 data is not public), standing in for their
# flow: the FP32 datapath is a pipelined, DVFS-tuned FPU macro rather than a
# naive unpipelined MAC, and the LightPE datapaths take the LightNN paper's
# synthesis results (Ding et al. 2018).  See DESIGN.md §2 and
# EXPERIMENTS.md §Paper-claims for the calibration story.
# ---------------------------------------------------------------------------
# energy per MAC-equivalent op (pJ), datapath + local pipeline registers
_E_FP32_MAC = 1.38      # pipelined + voltage-tuned fused fp32 MAC macro
_E_INT16_MAC = 1.00     # 16b integer MAC incl. pipeline registers
_E_L1_MAC = 0.105       # one 8b barrel shift + 24b accumulate (LightNN)
_E_L2_MAC = 0.135       # two shifts + adder tree + 24b accumulate

# datapath + per-PE control/NoC-port area (um^2)
_A_FP32_MAC = 12050.0   # FPU macro + 32b operand buses + wide control
_A_INT16_MAC = 8850.0   # 16b MAC, 32b accumulator, pipeline + control
_A_L1_MAC = 1430.0      # shifter + 24b accumulator + narrow control
_A_L2_MAC = 1450.0      # two shifters + adder + 24b accumulator

# critical-path delay (ns) -> bounds the PE-array clock
_D_FP32_MAC = 1.39      # ~0.72 GHz pipelined fp32 MAC @45nm
_D_INT16_MAC = 1.25     # ~0.80 GHz
_D_SHIFT_ADD = 0.80     # ~1.25 GHz  (shift + short add)
_D_SHIFT2_ADD = 0.893   # ~1.12 GHz  (two shifts + adder tree)

_P_PE_LEAK_UW = {       # static power per PE (uW) -- scales with area
    PEType.FP32: 14.0,
    PEType.INT16: 3.0,
    PEType.LIGHTPE1: 0.9,
    PEType.LIGHTPE2: 1.3,
}


@dataclasses.dataclass(frozen=True)
class PESpec:
    """Resolved datapath characteristics of one PE type."""

    pe_type: PEType
    act_bits: int
    weight_bits: int
    psum_bits: int
    mac_energy_pj: float          # energy of one MAC-equivalent op
    mac_area_um2: float           # datapath area (no scratchpads)
    mac_delay_ns: float           # critical path -> max clock
    multiplier_free: bool         # LightPE: shifts instead of multiplies

    @property
    def max_clock_ghz(self) -> float:
        return 1.0 / self.mac_delay_ns

    def scratchpad_bits(self, ifmap_entries: int, filter_entries: int,
                        psum_entries: int) -> int:
        """Total per-PE scratchpad storage in bits (quantization-aware)."""
        return (ifmap_entries * self.act_bits
                + filter_entries * self.weight_bits
                + psum_entries * self.psum_bits)


_SPECS = {
    PEType.FP32: PESpec(
        pe_type=PEType.FP32, act_bits=32, weight_bits=32, psum_bits=32,
        mac_energy_pj=_E_FP32_MAC, mac_area_um2=_A_FP32_MAC,
        mac_delay_ns=_D_FP32_MAC, multiplier_free=False,
    ),
    PEType.INT16: PESpec(
        pe_type=PEType.INT16, act_bits=16, weight_bits=16, psum_bits=32,
        mac_energy_pj=_E_INT16_MAC, mac_area_um2=_A_INT16_MAC,
        mac_delay_ns=_D_INT16_MAC, multiplier_free=False,
    ),
    # 8b act x 4b pow2 weight: one shift + 24b accumulate
    PEType.LIGHTPE1: PESpec(
        pe_type=PEType.LIGHTPE1, act_bits=8, weight_bits=4, psum_bits=24,
        mac_energy_pj=_E_L1_MAC, mac_area_um2=_A_L1_MAC,
        mac_delay_ns=_D_SHIFT_ADD, multiplier_free=True,
    ),
    # 8b act x 8b (sum of <=2 pow2) weight: two shifts + adds
    PEType.LIGHTPE2: PESpec(
        pe_type=PEType.LIGHTPE2, act_bits=8, weight_bits=8, psum_bits=24,
        mac_energy_pj=_E_L2_MAC, mac_area_um2=_A_L2_MAC,
        mac_delay_ns=_D_SHIFT2_ADD, multiplier_free=True,
    ),
}


def pe_spec(pe_type: PEType | str) -> PESpec:
    return _SPECS[PEType(pe_type)]


# ---------------------------------------------------------------------------
# Precision-scalable execution modes (mixed-precision co-exploration).
#
# A datapath built for PE type ``hw`` can execute a layer in the *mode* of a
# narrower PE type: operands are stored/streamed at the mode's widths and the
# unused datapath slices gate off, so byte counts and MAC energy follow the
# mode while area / clock / leakage stay those of the synthesized hardware.
# ---------------------------------------------------------------------------

def supports_mode(hw: PEType | str, mode: PEType | str) -> bool:
    """Can ``hw`` hardware execute layers in ``mode`` precision?

    True iff the mode's activation and weight widths both fit the
    hardware's native widths (e.g. INT16 hardware runs int16/w8a8/w4a8
    layers but not fp32 ones).
    """
    h, m = pe_spec(hw), pe_spec(mode)
    return m.act_bits <= h.act_bits and m.weight_bits <= h.weight_bits


def supported_modes(hw: PEType | str) -> tuple[PEType, ...]:
    """All PE-type modes executable on ``hw`` hardware, in enum order."""
    return tuple(t for t in PEType if supports_mode(hw, t))


@functools.lru_cache(maxsize=1)
def mode_compat_matrix() -> np.ndarray:
    """``(T, T)`` bool matrix: ``[hw_idx, mode_idx]`` = mode runs on hw.
    Row/column order is ``tuple(PEType)`` — the index convention of
    :func:`repro.core.accelerator.soa_from_fields` (``pe_type_idx``).
    Cached; treat the returned array as read-only."""
    types = tuple(PEType)
    return np.array([[supports_mode(h, m) for m in types] for h in types],
                    dtype=bool)


# ---------------------------------------------------------------------------
# SRAM macro models (CACTI-style scaling, 45 nm).
#
# These accept scalars or arrays: the batched DSE engine
# (core/dse_batch.py) and vectorized synthesis (core/synthesis.py) call
# them on whole config batches, so the constants and the zero-size guard
# live in exactly one place.  ``xp`` selects the array namespace — pass
# ``jax.numpy`` when calling under a jit trace.
# ---------------------------------------------------------------------------

def rf_access_energy_pj(size_bits, xp=np):
    """Per-access energy of a small PE-local register-file scratchpad.

    Port energy dominates for these small RFs, so the per-access cost is
    (to first order) independent of the word width and scales weakly with
    capacity.  ~0.03 pJ for an Eyeriss-sized 0.5 kB spad.
    """
    size_kb = xp.maximum(size_bits / 8192.0, 0.03125)
    return 0.035 * xp.sqrt(size_kb) + 0.015


def sram_access_energy_pj(size_bits, word_bits: int = 32, xp=np):
    """Per-access energy of a banked SRAM (the global buffer).

    The GLB has fixed-width ports (one element per access regardless of the
    PE type's payload width -- the RTL keeps a common interface across
    precisions), so this is per *element*, not per byte.
    """
    size_kb = xp.maximum(size_bits / 8192.0, 0.03125)
    del word_bits  # fixed-width port
    return 0.09 * xp.sqrt(size_kb) + 0.04


def sram_area_um2(size_bits, xp=np):
    """Area of an SRAM macro.  ~0.55 um^2/bit @45nm + fixed periphery."""
    return xp.where(xp.asarray(size_bits) > 0, 0.55 * size_bits + 300.0, 0.0)


def dram_energy_pj_per_byte() -> float:
    """LPDDR @45nm-era: ~80 pJ/byte.  NOTE: used only for system-level
    context; the paper's energy metric is post-synthesis accelerator energy
    (Design Compiler + VCS) and the DRAM is *not in the netlist*, so the
    paper-faithful energy model in :mod:`repro.core.dataflow` excludes it.
    """
    return 80.0
