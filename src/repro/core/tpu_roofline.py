"""Three-term TPU roofline model over compiled dry-run artifacts.

This is QAPPA's methodology (fast analytical PPA over a parameterized design
space) re-targeted at the TPU pod scale: instead of synthesizing RTL we
lower+compile the real program and derive

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links * link_bw)

(cost_analysis/HLO text are per-device after SPMD partitioning, so the
"/chips" of the assignment's formulas is already applied.)

Hardware constants: TPU v5e-class chip.
"""

from __future__ import annotations

import dataclasses

from repro.core.hlo_analysis import CompiledStats


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12       # per chip
    hbm_bw: float = 819e9                 # bytes/s
    ici_link_bw: float = 50e9             # bytes/s per link
    ici_links: int = 4                    # 2D torus: 4 links usable
    hbm_gb: float = 16.0
    vmem_bytes: int = 128 * 1024 * 1024   # ~128 MiB v5e vector memory


V5E = ChipSpec()


@dataclasses.dataclass
class Roofline:
    """Per (arch x shape x mesh) roofline report."""

    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6*N*D (dense) / 6*N_active*D (MoE), global
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful.

        Catches remat/redundancy waste.  >1 would mean XLA found algebraic
        savings; <1 means recompute or non-model compute (optimizer etc.).
        """
        total_hlo = self.hlo_flops_per_device * self.chips
        if total_hlo <= 0:
            return 0.0
        return self.model_flops / total_hlo

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the bound step time (MFU-like)."""
        if self.step_time_s <= 0:
            return 0.0
        achieved = self.model_flops / self.step_time_s
        peak = self.chips * V5E.peak_bf16_flops
        return achieved / peak

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "step_time_s": self.step_time_s,
        }


def roofline_from_stats(stats: CompiledStats, *, arch: str, shape: str,
                        mesh: str, chips: int, model_flops: float,
                        chip: ChipSpec = V5E) -> Roofline:
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        compute_s=stats.flops / chip.peak_bf16_flops,
        memory_s=stats.bytes_accessed / chip.hbm_bw,
        collective_s=stats.collectives.total_bytes
        / (chip.ici_links * chip.ici_link_bw),
        model_flops=model_flops,
        hlo_flops_per_device=stats.flops,
        hlo_bytes_per_device=stats.bytes_accessed,
        collective_bytes_per_device=stats.collectives.total_bytes,
    )


def dense_model_flops(n_params: float, tokens: float) -> float:
    """6*N*D training FLOPs (fwd+bwd).  For inference use 2*N*D."""
    return 6.0 * n_params * tokens


def serve_model_flops(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens
