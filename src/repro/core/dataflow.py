"""Row-stationary dataflow model (Eyeriss-style) for the QAPPA template.

Maps a conv/FC layer onto the 2-D PE array the way Eyeriss does:

* a *PE set* of ``R x E_tile`` computes one (channel, filter) plane —
  PE ``(r, e)`` slides filter row ``r`` across ifmap row ``e*stride + r``,
  producing ``F`` outputs of ``S`` MACs each;
* PE sets are stacked vertically (``sets_fit = pe_rows // R``) over
  channels first (so psums accumulate spatially), then filters;
* output columns fold over the array width (``fit_horz``).

From the mapping we derive compute cycles, utilization, and the access
counts at every level of the storage hierarchy (spad / GLB / DRAM), all of
which are quantization-aware: byte counts scale with the PE type's
activation / weight / psum widths.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import (PEType, pe_spec, rf_access_energy_pj,
                           sram_access_energy_pj, supports_mode)
from repro.core.workloads import ConvLayer, Workload


@dataclasses.dataclass(frozen=True)
class LayerResult:
    name: str
    macs: int
    compute_cycles: int
    mem_cycles: int
    total_cycles: int
    utilization: float
    spad_accesses: int            # word accesses (MAC-local)
    glb_bytes: int
    dram_bytes: int
    energy_pj: float

    @property
    def bound(self) -> str:
        return "memory" if self.mem_cycles > self.compute_cycles else "compute"


@dataclasses.dataclass(frozen=True)
class WorkloadResult:
    workload: str
    config_name: str
    layers: tuple[LayerResult, ...]
    area_mm2: float
    clock_ghz: float

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_cycles(self) -> int:
        return sum(l.total_cycles for l in self.layers)

    @property
    def latency_s(self) -> float:
        return self.total_cycles / (self.clock_ghz * 1e9)

    @property
    def energy_j(self) -> float:
        return sum(l.energy_pj for l in self.layers) / 1e12

    @property
    def throughput_gmacs(self) -> float:
        return self.total_macs / self.latency_s / 1e9

    @property
    def perf_per_area(self) -> float:
        """GMAC/s per mm^2 — the paper's performance-per-area metric."""
        return self.throughput_gmacs / self.area_mm2

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s


def map_layer(layer: ConvLayer, cfg: AcceleratorConfig,
              clock_ghz: float, area_mm2: float,
              leakage_mw: float, mode: PEType | None = None) -> LayerResult:
    """Map one layer onto ``cfg``.

    ``mode`` (default: the config's own PE type) selects the *execution
    precision* of this layer on a precision-scalable datapath: operand
    byte counts and per-MAC energy follow the mode's widths, while
    physical quantities — array dims, scratchpad storage, clock, area,
    leakage — stay those of the synthesized hardware.  ``mode=None`` is
    bit-identical to the original homogeneous path.
    """
    s = cfg.spec
    ms = s if mode is None else pe_spec(mode)
    r, e, f_, ss = layer.r, layer.e, layer.f, layer.s
    c, k, n = layer.c, layer.k, layer.batch

    # ---- spatial mapping ---------------------------------------------------
    sets_fit = max(1, cfg.pe_rows // r)            # PE sets stacked vertically
    c_simult = min(c, sets_fit)                    # channels accumulated in-array
    k_simult = max(1, sets_fit // c_simult)        # filters in parallel
    fit_horz = min(e, cfg.pe_cols)                 # output rows across width
    n_e_groups = math.ceil(e / fit_horz)
    n_c_groups = math.ceil(c / c_simult)
    n_k_groups = math.ceil(k / k_simult)

    passes = n * n_e_groups * n_c_groups * n_k_groups
    compute_cycles = passes * ss * f_
    macs = layer.macs
    utilization = macs / max(1, compute_cycles * cfg.num_pes)

    # ---- element / byte counts (quantization-aware) -------------------------
    ab, wb = ms.act_bits, ms.weight_bits
    ifmap_elems = n * c * layer.h * layer.w
    weight_elems = k * c * r * ss
    ofmap_elems = n * k * e * f_
    ifmap_bytes = ifmap_elems * ab // 8
    weight_bytes = weight_elems * wb // 8
    ofmap_bytes = ofmap_elems * ab // 8

    # DRAM traffic (streaming DMA packs elements into bursts, so *bytes*
    # scale with precision): weights stream once; the ifmap is re-streamed
    # per filter group that does not fit the GLB (half of the GLB is
    # allocated to each of ifmap/weights).
    glb_half = cfg.glb_kb * 1024 // 2
    filt_bytes_one = max(1, c * r * ss * wb // 8)
    k_fit_glb = max(1, glb_half // filt_bytes_one)
    n_k_glb = math.ceil(k / k_fit_glb)
    ifmap_resident = ifmap_bytes <= glb_half
    ifmap_dram = ifmap_bytes * (1 if ifmap_resident else n_k_glb)
    dram_bytes = ifmap_dram + weight_bytes + ofmap_bytes

    # GLB traffic in *elements* (the GLB port is fixed-width; see pe.py):
    # fills/drains mirror the DRAM stream, the ifmap is multicast-read once
    # per filter iteration, weights re-read when the filter spad cannot hold
    # its working set, psums spill between channel groups when the psum spad
    # cannot hold an output strip.
    dram_elems = ifmap_elems * (1 if ifmap_resident else n_k_glb) \
        + weight_elems + ofmap_elems
    # the ifmap row parked in the ifmap spad is reused across all filters
    # whose rows are simultaneously resident in the filter spad (k_res),
    # so the GLB multicast-read repeats only per filter *residency* group
    k_res = max(1, cfg.filter_spad // max(1, ss))
    glb_ifmap = ifmap_elems * math.ceil(n_k_groups / k_res)
    w_res = min(n_e_groups, max(1, cfg.filter_spad // max(1, ss)))
    glb_weight = weight_elems * max(1, n_e_groups // w_res)
    psum_strip = f_  # psum entries a PE must hold per pass
    spill = 0 if cfg.psum_spad >= psum_strip else (n_c_groups - 1)
    glb_psum = 2 * ofmap_elems * max(0, spill)
    glb_elems = 2 * dram_elems + glb_ifmap + glb_weight + glb_psum
    glb_bytes = glb_elems * ab // 8  # reported for reference

    # ---- stalls -------------------------------------------------------------
    bw_bytes_per_cycle = cfg.dram_bw_gbps / clock_ghz
    mem_cycles = int(dram_bytes / max(1e-9, bw_bytes_per_cycle))
    total_cycles = max(compute_cycles, mem_cycles)   # double-buffered overlap

    # ---- energy (paper-faithful: post-synthesis accelerator energy; the
    # DRAM is not in the netlist, so DRAM energy is excluded -- DESIGN.md §2)
    spad_bits = s.scratchpad_bits(cfg.ifmap_spad, cfg.filter_spad,
                                  cfg.psum_spad)
    # ifmap read + weight read + ~1 psum spad access per MAC (the running
    # sum lives in a register; the spad is touched on row hand-off).
    spad_accesses = 3 * macs
    e_spad = spad_accesses * rf_access_energy_pj(spad_bits)
    e_mac = macs * ms.mac_energy_pj
    e_glb = glb_elems * sram_access_energy_pj(cfg.glb_bits)
    e_leak = leakage_mw * 1e-3 * (total_cycles / (clock_ghz * 1e9)) * 1e12
    energy_pj = e_mac + e_spad + e_glb + e_leak

    return LayerResult(
        name=layer.name, macs=macs,
        compute_cycles=compute_cycles, mem_cycles=mem_cycles,
        total_cycles=total_cycles, utilization=utilization,
        spad_accesses=spad_accesses, glb_bytes=glb_bytes,
        dram_bytes=dram_bytes, energy_pj=energy_pj,
    )


def leakage_mw(cfg: AcceleratorConfig) -> float:
    """Static power of one design point (PE leakage + GLB leakage), shared
    by the scalar path here and the batched engine in dse_batch."""
    from repro.core.pe import _P_PE_LEAK_UW
    return cfg.num_pes * _P_PE_LEAK_UW[cfg.pe_type] * 1e-3 \
        + 0.002 * cfg.glb_kb


def leakage_mw_soa(soa: dict) -> "np.ndarray":
    """Vectorized :func:`leakage_mw` over a struct-of-arrays config batch
    — the single source of the leakage model for the batched synthesis
    (:func:`repro.core.synthesis.synthesize_soa`) and the sweep kernel
    inputs (:func:`repro.core.dse_batch.sweep_workload`)."""
    return soa["num_pes"] * soa["leak_uw"] * 1e-3 + 0.002 * soa["glb_kb"]


def run_workload(workload: Workload, cfg: AcceleratorConfig,
                 report=None) -> WorkloadResult:
    """Evaluate a workload on a design point (synthesis report optional)."""
    if report is None:
        from repro.core.synthesis import synthesize
        report = synthesize(cfg)
    leak = leakage_mw(cfg)
    layers = tuple(
        map_layer(l, cfg, report.clock_ghz, report.area_mm2, leak)
        for l in workload.layers)
    return WorkloadResult(
        workload=workload.name, config_name=cfg.name(), layers=layers,
        area_mm2=report.area_mm2, clock_ghz=report.clock_ghz,
    )


def run_workload_mixed(workload: Workload, cfg: AcceleratorConfig,
                       assignment, report=None) -> WorkloadResult:
    """Evaluate a workload with a per-layer execution-precision assignment.

    ``assignment`` is one PE-type mode per layer (PEType values or their
    string forms).  This is the scalar reference for the batched
    mixed-precision kernel (:func:`repro.core.dse_batch.sweep_mixed`):
    synthesis stays a function of the hardware config alone, so the same
    synthesis report/cache serves every assignment on that hardware.
    """
    modes = tuple(PEType(m) for m in assignment)
    if len(modes) != len(workload.layers):
        raise ValueError(
            f"assignment length {len(modes)} != {len(workload.layers)} "
            f"layers of workload {workload.name!r}")
    bad = [m.value for m in modes if not supports_mode(cfg.pe_type, m)]
    if bad:
        raise ValueError(
            f"mode(s) {sorted(set(bad))} not executable on "
            f"{cfg.pe_type.value} hardware (operand widths exceed the "
            f"datapath)")
    if report is None:
        from repro.core.synthesis import synthesize
        report = synthesize(cfg)
    leak = leakage_mw(cfg)
    layers = tuple(
        map_layer(l, cfg, report.clock_ghz, report.area_mm2, leak, mode=m)
        for l, m in zip(workload.layers, modes))
    return WorkloadResult(
        workload=workload.name, config_name=cfg.name(), layers=layers,
        area_mm2=report.area_mm2, clock_ghz=report.clock_ghz,
    )
