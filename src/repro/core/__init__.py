"""QAPPA paper core: accelerator template, PE models, synthesis oracle,
row-stationary dataflow, polynomial PPA regression, DSE, RTL generation,
and the TPU roofline re-targeting."""

from repro.core.accelerator import AcceleratorConfig, design_space  # noqa
from repro.core.dataflow import map_layer, run_workload             # noqa
from repro.core.dse import (DSEResult, ExploreSpec, explore,        # noqa
                            pareto_front, run)
from repro.core.pe import PEType, pe_spec                           # noqa
from repro.core.ppa_model import fit_poly_model, fit_ppa_suite      # noqa
from repro.core.rtl import generate_rtl                             # noqa
from repro.core.synthesis import SynthesisReport, synthesize        # noqa
from repro.core.tpu_roofline import Roofline, roofline_from_stats   # noqa
from repro.core.workloads import get_workload                       # noqa
