"""Analytical synthesis oracle — stands in for Synopsys DC + VCS @ FreePDK45.

The paper obtains "actual" power / area / timing from a commercial synthesis
flow and then fits polynomial models to them.  That flow is unavailable here,
so this module produces the ground-truth side from gate-level analytical
models (constants in :mod:`repro.core.pe`), with a small deterministic,
config-dependent "process" perturbation so the regression fit in
:mod:`repro.core.ppa_model` is a genuine estimation problem rather than an
identity.  DESIGN.md §2 records this substitution.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import (rf_access_energy_pj, sram_access_energy_pj,
                           sram_area_um2)


@dataclasses.dataclass(frozen=True)
class SynthesisReport:
    """What the synthesis + simulation flow reports for one design."""

    area_mm2: float            # post-synthesis cell area
    power_mw: float            # dynamic + leakage at nominal activity
    clock_ghz: float           # achieved clock after timing closure
    throughput_gmacs: float    # peak effective GMAC/s at that clock

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


def _jitter(cfg: AcceleratorConfig, salt: str, scale: float) -> float:
    """Deterministic multiplicative perturbation in [1-scale, 1+scale].

    Emulates synthesis noise (placement, wire load, timing closure slack)
    in a reproducible way: hash of the config name + salt.
    """
    h = hashlib.sha256((cfg.name() + salt).encode()).digest()
    u = int.from_bytes(h[:8], "little") / float(1 << 64)   # [0,1)
    return 1.0 + scale * (2.0 * u - 1.0)


def synthesize(cfg: AcceleratorConfig) -> SynthesisReport:
    """Run the analytical 'synthesis flow' for one design point."""
    s = cfg.spec
    n = cfg.num_pes

    # ---- area ------------------------------------------------------------
    spad_bits = s.scratchpad_bits(cfg.ifmap_spad, cfg.filter_spad,
                                  cfg.psum_spad)
    pe_area = s.mac_area_um2 + sram_area_um2(spad_bits)
    glb_area = sram_area_um2(cfg.glb_bits)
    # NoC + control overhead grows slightly super-linearly with array size
    noc_area = 120.0 * n * (1.0 + 0.004 * math.sqrt(n))
    area_um2 = (n * pe_area + glb_area + noc_area) * _jitter(cfg, "area", 0.03)
    area_mm2 = area_um2 / 1e6

    # ---- timing ----------------------------------------------------------
    # Wire delay degrades the achievable clock for very large arrays.
    wire_penalty = 1.0 + 0.002 * math.sqrt(n)
    clock_ghz = (s.max_clock_ghz / wire_penalty) * _jitter(cfg, "clk", 0.02)
    if cfg.clock_ghz is not None:
        clock_ghz = min(clock_ghz, cfg.clock_ghz)

    # ---- power at nominal activity (70% MAC utilization) ------------------
    util = 0.70
    mac_pw = n * util * s.mac_energy_pj * clock_ghz * 1e9 * 1e-12      # mW
    # each MAC: ifmap read + weight read + ~1 psum spad access
    e_spad = rf_access_energy_pj(spad_bits)
    spad_pw = n * util * 3.0 * e_spad * clock_ghz * 1e9 * 1e-12
    # GLB serves ~1 access per 8 MACs across the array (row-stationary reuse)
    e_glb = sram_access_energy_pj(cfg.glb_bits)
    glb_pw = n * util * (1.0 / 8.0) * e_glb * clock_ghz * 1e9 * 1e-12
    from repro.core.pe import _P_PE_LEAK_UW  # static power per PE type
    leak_mw = n * _P_PE_LEAK_UW[s.pe_type] * 1e-3 \
        + 0.002 * cfg.glb_kb                      # GLB leakage ~2uW/kB
    power_mw = (mac_pw + spad_pw + glb_pw + leak_mw) \
        * _jitter(cfg, "power", 0.04)

    return SynthesisReport(
        area_mm2=area_mm2,
        power_mw=power_mw,
        clock_ghz=clock_ghz,
        throughput_gmacs=n * clock_ghz,
    )
