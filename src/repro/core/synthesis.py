"""Analytical synthesis oracle — stands in for Synopsys DC + VCS @ FreePDK45.

The paper obtains "actual" power / area / timing from a commercial synthesis
flow and then fits polynomial models to them.  That flow is unavailable here,
so this module produces the ground-truth side from gate-level analytical
models (constants in :mod:`repro.core.pe`), with a small deterministic,
config-dependent "process" perturbation so the regression fit in
:mod:`repro.core.ppa_model` is a genuine estimation problem rather than an
identity.  DESIGN.md §2 records this substitution.

The perturbation is a **counter-based hash** over the config's packed
integer field words (:mod:`repro.core.confighash`) — fully vectorized, no
per-config Python, and bit-identical between the scalar, batched-numpy,
and jax paths (the scalar path simply evaluates a length-1 batch).  The
same 128-bit digest keys the in-process LRU report cache and the on-disk
npz cache, so a cold run over a previously seen space skips synthesis
entirely.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import pathlib
from typing import Sequence

import numpy as np

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.confighash import (config_digests, digest_keys,
                                   digests_to_u64, uniform01)
from repro.core.dataflow import leakage_mw_soa
from repro.core.pe import (rf_access_energy_pj, sram_access_energy_pj,
                           sram_area_um2)
from repro.obs import metrics as obs_metrics

@dataclasses.dataclass(frozen=True)
class SynthesisReport:
    """What the synthesis + simulation flow reports for one design."""

    area_mm2: float            # post-synthesis cell area
    power_mw: float            # dynamic + leakage at nominal activity
    clock_ghz: float           # achieved clock after timing closure
    throughput_gmacs: float    # peak effective GMAC/s at that clock

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


# columns of the array-form synthesis result, in stable (npz) order
REPORT_COLUMNS = ("area_mm2", "power_mw", "clock_ghz", "throughput_gmacs")


def synthesize_soa(soa: dict[str, np.ndarray],
                   digests=None, xp=np) -> dict[str, np.ndarray]:
    """Run the analytical synthesis flow for a whole config batch.

    Pure fused array math over the struct-of-arrays form
    (:func:`repro.core.accelerator.configs_to_soa`): every op is
    elementwise, so any row of a batch is bit-identical to a length-1
    evaluation of that config — the scalar :func:`synthesize` is literally
    this function on one row.  Returns ``{column: (N,) float64}`` for
    :data:`REPORT_COLUMNS`.
    """
    if digests is None:
        digests = config_digests(soa, xp=xp)
    f = np.float64
    # one independent digest lane per perturbed quantity
    jit_area = 1.0 + 0.03 * (2.0 * uniform01(digests[0], xp=xp) - 1.0)
    jit_clk = 1.0 + 0.02 * (2.0 * uniform01(digests[1], xp=xp) - 1.0)
    jit_pw = 1.0 + 0.04 * (2.0 * uniform01(digests[2], xp=xp) - 1.0)

    n = soa["num_pes"].astype(f)
    glb_bits = soa["glb_bits"].astype(f)
    spad_bits = soa["spad_bits"].astype(f)

    # ---- area ------------------------------------------------------------
    pe_area = soa["mac_area_um2"] + sram_area_um2(spad_bits, xp=xp)
    glb_area = sram_area_um2(glb_bits, xp=xp)
    # NoC + control overhead grows slightly super-linearly with array size
    noc_area = 120.0 * n * (1.0 + 0.004 * xp.sqrt(n))
    area_mm2 = (n * pe_area + glb_area + noc_area) * jit_area / 1e6

    # ---- timing ----------------------------------------------------------
    # Wire delay degrades the achievable clock for very large arrays.
    wire_penalty = 1.0 + 0.002 * xp.sqrt(n)
    clock_ghz = xp.minimum((soa["max_clock_ghz"] / wire_penalty) * jit_clk,
                           soa["clock_cap"])

    # ---- power at nominal activity (70% MAC utilization) ------------------
    util = 0.70
    mac_pw = n * util * soa["mac_energy_pj"] * clock_ghz * 1e9 * 1e-12  # mW
    # each MAC: ifmap read + weight read + ~1 psum spad access
    e_spad = rf_access_energy_pj(spad_bits, xp=xp)
    spad_pw = n * util * 3.0 * e_spad * clock_ghz * 1e9 * 1e-12
    # GLB serves ~1 access per 8 MACs across the array (row-stationary reuse)
    e_glb = sram_access_energy_pj(glb_bits, xp=xp)
    glb_pw = n * util * (1.0 / 8.0) * e_glb * clock_ghz * 1e9 * 1e-12
    leak_mw = leakage_mw_soa(soa)                         # GLB ~2uW/kB
    power_mw = (mac_pw + spad_pw + glb_pw + leak_mw) * jit_pw

    return {
        "area_mm2": area_mm2,
        "power_mw": power_mw,
        "clock_ghz": clock_ghz,
        "throughput_gmacs": n * clock_ghz,
    }


def synthesize(cfg: AcceleratorConfig) -> SynthesisReport:
    """Run the analytical 'synthesis flow' for one design point — a
    length-1 batch through :func:`synthesize_soa`, so scalar and batched
    results are bit-identical by construction."""
    cols = synthesize_soa(configs_to_soa((cfg,)))
    return SynthesisReport(**{k: float(cols[k][0]) for k in REPORT_COLUMNS})


def config_hash(cfg: AcceleratorConfig) -> str:
    """Stable identity key for one design point: the hex form of its
    128-bit packed-field digest.  Folds in *every* field — including
    ``clock_ghz``, which ``cfg.name()`` omits but which changes timing
    closure.  Batch paths should use :func:`config_keys` instead."""
    return config_keys((cfg,))[0].hex()


def config_keys(configs: Sequence[AcceleratorConfig],
                soa: dict[str, np.ndarray] | None = None) -> list[bytes]:
    """16-byte digest keys for a config batch (vectorized)."""
    if soa is None:
        soa = configs_to_soa(tuple(configs))
    return digest_keys(config_digests(soa))


# ---------------------------------------------------------------------------
# In-process report cache: bounded LRU keyed by the 16-byte digest.
# ---------------------------------------------------------------------------

_SYNTH_CACHE: collections.OrderedDict[bytes, SynthesisReport] = \
    collections.OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_CACHE_LIMIT = 1 << 18          # ~260k reports ≈ tens of MB, bounded


def synthesis_cache_stats() -> dict[str, int]:
    stats = dict(_CACHE_STATS, size=len(_SYNTH_CACHE), limit=_CACHE_LIMIT)
    stats.update(array_hits=_SWEEP_CACHE.hits, array_misses=_SWEEP_CACHE.misses,
                 array_size=len(_SWEEP_CACHE),
                 array_evictions=_SWEEP_CACHE.evictions)
    return stats


def set_synthesis_cache_limit(limit: int) -> int:
    """Cap both in-process synthesis caches (entries/rows); returns the
    old cap.  Shrinking evicts oldest entries immediately — in the object
    LRU and in the sweep engine's array store alike."""
    global _CACHE_LIMIT
    old, _CACHE_LIMIT = _CACHE_LIMIT, max(0, int(limit))
    _evict_to_limit()
    _SWEEP_CACHE.max_rows = _CACHE_LIMIT
    _SWEEP_CACHE._compact()
    return old


def clear_synthesis_cache() -> None:
    _SYNTH_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0, evictions=0)
    _SWEEP_CACHE.clear()


def _evict_to_limit() -> None:
    while len(_SYNTH_CACHE) > _CACHE_LIMIT:
        _SYNTH_CACHE.popitem(last=False)
        _CACHE_STATS["evictions"] += 1


def _cache_put(key: bytes, rep: SynthesisReport) -> None:
    _SYNTH_CACHE[key] = rep
    _evict_to_limit()


def synthesize_cached(cfg: AcceleratorConfig) -> SynthesisReport:
    """`synthesize` with memoization — re-sweeping a design space (new
    workload, extended sweep) never re-runs the flow for a known config."""
    key = config_keys((cfg,))[0]
    hit = _SYNTH_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        _SYNTH_CACHE.move_to_end(key)
        return hit
    _CACHE_STATS["misses"] += 1
    rep = synthesize(cfg)
    _cache_put(key, rep)
    return rep


def synthesize_many(configs: Sequence[AcceleratorConfig],
                    use_cache: bool = True,
                    soa: dict[str, np.ndarray] | None = None
                    ) -> list[SynthesisReport]:
    """Vectorized synthesis for a batch of design points.

    Digests, jitter, and the PPA math all evaluate as fused array
    expressions across the whole batch; cached configs are skipped
    entirely.  ``soa`` (from
    :func:`repro.core.accelerator.configs_to_soa`) can be passed to reuse
    an existing struct-of-arrays conversion.
    """
    configs = list(configs)
    if not configs:
        return []
    if soa is None:
        soa = configs_to_soa(configs)
    out: list[SynthesisReport | None] = [None] * len(configs)
    digests = config_digests(soa)
    if use_cache:
        keys = digest_keys(digests)
        todo = []
        for i, key in enumerate(keys):
            hit = _SYNTH_CACHE.get(key)
            if hit is not None:
                _CACHE_STATS["hits"] += 1
                _SYNTH_CACHE.move_to_end(key)
                out[i] = hit
            else:
                _CACHE_STATS["misses"] += 1
                todo.append(i)
        if not todo:
            return out  # type: ignore[return-value]
        idx = np.array(todo, dtype=np.intp)
        sub = {k: v[idx] for k, v in soa.items()}
        cols = synthesize_soa(sub, digests=tuple(d[idx] for d in digests))
        for j, i in enumerate(todo):
            rep = SynthesisReport(
                **{k: float(cols[k][j]) for k in REPORT_COLUMNS})
            out[i] = rep
            _cache_put(keys[i], rep)
        return out  # type: ignore[return-value]
    cols = synthesize_soa(soa, digests=digests)
    return [SynthesisReport(**{k: float(cols[k][i])
                               for k in REPORT_COLUMNS})
            for i in range(len(configs))]


# ---------------------------------------------------------------------------
# Persisted synthesis cache: npz of (N, 2) uint64 digest keys + one float64
# column per REPORT_COLUMNS entry.  Array-level (no report objects), so the
# streamed sweep driver can hydrate 1M-config spaces in bounded time.
# ---------------------------------------------------------------------------

class PersistentSynthesisCache:
    """Digest-keyed synthesis store with npz persistence.

    ``lookup`` / ``insert`` operate on whole chunks; rows live in one
    growing value matrix so hits gather with a single fancy index.  A cold
    sweep over a previously saved space does zero synthesis math.

    ``max_rows`` bounds memory: on overflow the oldest half of the rows is
    dropped and the store compacted (counted in ``evictions``).
    """

    def __init__(self, path: str | pathlib.Path | None = None,
                 max_rows: int | None = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.max_rows = max_rows
        self._index: dict[bytes, int] = {}
        self._keys = np.empty((0, 2), dtype=np.uint64)
        self._vals = np.empty((0, len(REPORT_COLUMNS)), dtype=np.float64)
        self._n = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        if self.path is not None and self.path.exists():
            try:
                self.load(self.path)
            except Exception as exc:
                # a corrupted / truncated / foreign npz must never poison
                # the cache with garbage rows: warn and rebuild from empty
                # (the next save overwrites the bad file).  An *explicit*
                # load() call still raises.
                import warnings
                warnings.warn(
                    f"persistent synthesis cache at {self.path} is "
                    f"unreadable ({type(exc).__name__}: {exc}); starting "
                    f"with an empty cache and rebuilding",
                    RuntimeWarning, stacklevel=2)

    def clear(self) -> None:
        """Drop all rows and stats; keeps the cap and the save path."""
        path, self.path = self.path, None     # don't reload from disk
        self.__init__(path=None, max_rows=self.max_rows)
        self.path = path

    def _compact(self) -> None:
        if self.max_rows is None or self._n <= self.max_rows:
            return
        keep = self.max_rows // 2           # newest half survives
        drop = self._n - keep
        self._keys[:keep] = self._keys[drop:self._n]
        self._vals[:keep] = self._vals[drop:self._n]
        self._n = keep
        self.evictions += drop
        buf = np.ascontiguousarray(self._keys[:keep]).tobytes()
        self._index = {buf[16 * i:16 * (i + 1)]: i for i in range(keep)}

    def __len__(self) -> int:
        return self._n

    def _grow(self, extra: int) -> None:
        need = self._n + extra
        cap = len(self._keys)
        if need > cap:
            cap = max(need, 2 * cap, 1024)
            self._keys = np.resize(self._keys, (cap, 2))
            self._vals = np.resize(self._vals, (cap, len(REPORT_COLUMNS)))

    def lookup(self, digests) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        """(hit_mask, columns) for a digest batch; missed rows are zero."""
        keys = digest_keys(digests)
        rows = np.array([self._index.get(k, -1) for k in keys],
                        dtype=np.intp)
        mask = rows >= 0
        vals = np.zeros((len(keys), len(REPORT_COLUMNS)), dtype=np.float64)
        if mask.any():
            vals[mask] = self._vals[rows[mask]]
        nh = int(mask.sum())
        nm = len(keys) - nh
        self.hits += nh
        self.misses += nm
        reg = obs_metrics.get_registry()
        reg.inc("synth_cache.hits", nh)
        reg.inc("synth_cache.misses", nm)
        return mask, {c: vals[:, j] for j, c in enumerate(REPORT_COLUMNS)}

    def insert(self, digests, cols: dict[str, np.ndarray],
               rows_mask: np.ndarray | None = None) -> int:
        """Store (a masked subset of) a digest batch's columns.

        Bulk path: rows append en masse and the index updates with one
        ``dict.update``.  Duplicate keys (re-inserted or repeated within
        the batch) leave their older rows in place as dead weight and
        point the index at the newest — values for a given digest are
        identical by construction, so this only costs bytes, not
        correctness.
        """
        u64 = np.ascontiguousarray(digests_to_u64(digests))
        vals = np.stack([np.asarray(cols[c], dtype=np.float64)
                         for c in REPORT_COLUMNS], axis=-1)
        if rows_mask is not None:
            u64, vals = np.ascontiguousarray(u64[rows_mask]), vals[rows_mask]
        m = len(u64)
        if m == 0:
            return 0
        self._grow(m)
        self._keys[self._n:self._n + m] = u64
        self._vals[self._n:self._n + m] = vals
        buf = u64.tobytes()
        before = len(self._index)
        self._index.update(
            zip((buf[16 * i:16 * (i + 1)] for i in range(m)),
                range(self._n, self._n + m)))
        self._n += m
        self._compact()
        return len(self._index) - before

    def save(self, path: str | pathlib.Path | None = None) -> int:
        """Write all rows to ``path`` (default: the constructor path).

        Atomic: the npz goes to a sibling temp file first and is
        ``os.replace``d over the target, so a crash mid-save leaves the
        previous cache intact instead of a truncated file the constructor
        would have to discard and rebuild.
        """
        path = pathlib.Path(path) if path is not None else self.path
        if path is None:
            raise ValueError("PersistentSynthesisCache.save: no path")
        # write through a handle: np.savez would append ".npz" to a
        # suffix-less path and orphan the cache on the next load
        tmp = path.with_name(path.name + f".tmp{os.getpid()}")
        try:
            with open(tmp, "wb") as fh:
                np.savez_compressed(
                    fh, keys=self._keys[:self._n],
                    **{c: self._vals[:self._n, j]
                       for j, c in enumerate(REPORT_COLUMNS)})
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        finally:
            if tmp.exists():
                tmp.unlink()
        return self._n

    def export_state(self) -> dict:
        """Rows + accounting as a plain dict of arrays/scalars — the
        synthesis-cache slice of an exploration checkpoint
        (:mod:`repro.runtime.dse_checkpoint`).  Counters ride along so a
        resumed run's hit/miss accounting matches the uninterrupted run
        exactly."""
        return {
            "keys": self._keys[:self._n].copy(),
            "vals": self._vals[:self._n].copy(),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }

    def import_state(self, state: dict) -> None:
        """Replace rows and counters with an :meth:`export_state`
        snapshot (the inverse: existing contents are dropped, not
        merged)."""
        keys = np.ascontiguousarray(state["keys"], dtype=np.uint64)
        vals = np.asarray(state["vals"], dtype=np.float64)
        if keys.ndim != 2 or keys.shape[1] != 2 \
                or vals.shape != (len(keys), len(REPORT_COLUMNS)):
            raise ValueError(
                f"cache snapshot shapes {keys.shape} / {vals.shape} are "
                f"not (N, 2) / (N, {len(REPORT_COLUMNS)})")
        self._keys = keys.copy()
        self._vals = vals.copy()
        self._n = len(keys)
        buf = keys.tobytes()
        self._index = {buf[16 * i:16 * (i + 1)]: i
                       for i in range(self._n)}
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.evictions = int(state["evictions"])
        self._compact()

    def load(self, path: str | pathlib.Path) -> int:
        """Merge rows from an npz file; returns how many were new.

        Raises (``ValueError`` for a structurally wrong file, whatever
        ``np.load`` raises for a corrupt one) instead of ever merging
        garbage — the constructor catches this and rebuilds, an explicit
        call surfaces it.
        """
        with np.load(pathlib.Path(path)) as z:
            missing = {"keys", *REPORT_COLUMNS} - set(z.files)
            if missing:
                raise ValueError(
                    f"synthesis cache {path} is missing array(s) "
                    f"{sorted(missing)}")
            keys = np.ascontiguousarray(z["keys"], dtype=np.uint64)
            if keys.ndim != 2 or keys.shape[1] != 2:
                raise ValueError(
                    f"synthesis cache {path}: keys shape {keys.shape} "
                    f"!= (N, 2)")
            vals = np.stack([z[c] for c in REPORT_COLUMNS], axis=-1)
            if vals.shape != (len(keys), len(REPORT_COLUMNS)):
                raise ValueError(
                    f"synthesis cache {path}: {len(keys)} keys but "
                    f"value block {vals.shape}")
            if not np.isfinite(vals).all():
                raise ValueError(
                    f"synthesis cache {path}: non-finite report values")
        before = self._n
        self._grow(len(keys))
        buf = keys.tobytes()
        for i in range(len(keys)):
            key = buf[16 * i:16 * (i + 1)]
            if key in self._index:
                continue
            row = self._n
            self._index[key] = row
            self._keys[row] = keys[i]
            self._vals[row] = vals[i]
            self._n += 1
        self._compact()
        return self._n - before

    def synthesize(self, soa: dict[str, np.ndarray]
                   ) -> dict[str, np.ndarray]:
        """Cache-through batched synthesis: hit rows gather from the
        store, miss rows run :func:`synthesize_soa` and are inserted."""
        digests = config_digests(soa)
        mask, cols = self.lookup(digests)
        miss = ~mask
        if miss.any():
            idx = np.nonzero(miss)[0]
            sub = {k: v[idx] for k, v in soa.items()}
            fresh = synthesize_soa(sub, digests=tuple(d[idx]
                                                      for d in digests))
            for c in REPORT_COLUMNS:
                cols[c][idx] = fresh[c]
            self.insert(tuple(d[idx] for d in digests), fresh)
        return cols


# module-level array store: the batched sweep engine's synthesis cache
# (object-free twin of _SYNTH_CACHE, bounded the same way)
_SWEEP_CACHE = PersistentSynthesisCache(max_rows=_CACHE_LIMIT)


def sweep_synthesis_cache() -> PersistentSynthesisCache:
    """The process-wide array-level synthesis cache used by
    :func:`repro.core.dse_batch.sweep_workload` and friends."""
    return _SWEEP_CACHE
