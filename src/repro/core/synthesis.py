"""Analytical synthesis oracle — stands in for Synopsys DC + VCS @ FreePDK45.

The paper obtains "actual" power / area / timing from a commercial synthesis
flow and then fits polynomial models to them.  That flow is unavailable here,
so this module produces the ground-truth side from gate-level analytical
models (constants in :mod:`repro.core.pe`), with a small deterministic,
config-dependent "process" perturbation so the regression fit in
:mod:`repro.core.ppa_model` is a genuine estimation problem rather than an
identity.  DESIGN.md §2 records this substitution.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Sequence

import numpy as np

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import (rf_access_energy_pj, sram_access_energy_pj,
                           sram_area_um2)


@dataclasses.dataclass(frozen=True)
class SynthesisReport:
    """What the synthesis + simulation flow reports for one design."""

    area_mm2: float            # post-synthesis cell area
    power_mw: float            # dynamic + leakage at nominal activity
    clock_ghz: float           # achieved clock after timing closure
    throughput_gmacs: float    # peak effective GMAC/s at that clock

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


def _jitter_named(name: str, salt: str, scale: float) -> float:
    h = hashlib.sha256((name + salt).encode()).digest()
    u = int.from_bytes(h[:8], "little") / float(1 << 64)   # [0,1)
    return 1.0 + scale * (2.0 * u - 1.0)


def _jitter(cfg: AcceleratorConfig, salt: str, scale: float) -> float:
    """Deterministic multiplicative perturbation in [1-scale, 1+scale].

    Emulates synthesis noise (placement, wire load, timing closure slack)
    in a reproducible way: hash of the config name + salt.
    """
    return _jitter_named(cfg.name(), salt, scale)


def config_hash(cfg: AcceleratorConfig) -> str:
    """Stable key for the synthesis cache.

    ``cfg.name()`` omits ``clock_ghz``, which changes timing closure, so the
    key folds every field in.  A plain formatted string (not a digest): it
    is exact, stable across processes, and ~50x cheaper than hashing a
    deep-copied ``dataclasses.astuple``.
    """
    return (f"{cfg.pe_type.value}:{cfg.pe_rows}:{cfg.pe_cols}"
            f":{cfg.ifmap_spad}:{cfg.filter_spad}:{cfg.psum_spad}"
            f":{cfg.glb_kb}:{cfg.dram_bw_gbps!r}:{cfg.clock_ghz!r}")


_SYNTH_CACHE: dict[str, SynthesisReport] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def synthesis_cache_stats() -> dict[str, int]:
    return dict(_CACHE_STATS, size=len(_SYNTH_CACHE))


def clear_synthesis_cache() -> None:
    _SYNTH_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def synthesize_cached(cfg: AcceleratorConfig) -> SynthesisReport:
    """`synthesize` with memoization — re-sweeping a design space (new
    workload, extended sweep) never re-runs the flow for a known config."""
    key = config_hash(cfg)
    hit = _SYNTH_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        return hit
    _CACHE_STATS["misses"] += 1
    rep = synthesize(cfg)
    _SYNTH_CACHE[key] = rep
    return rep


def synthesize_many(configs: Sequence[AcceleratorConfig],
                    use_cache: bool = True,
                    soa: dict[str, np.ndarray] | None = None
                    ) -> list[SynthesisReport]:
    """Vectorized synthesis for a batch of design points.

    The per-op math is evaluated as NumPy array expressions across the whole
    batch (identical op order to :func:`synthesize`, so results bit-match);
    only the SHA-based process jitter stays a per-config Python step.  Cached
    configs are skipped entirely.  ``soa`` (from
    :func:`repro.core.accelerator.configs_to_soa`) can be passed to reuse an
    existing struct-of-arrays conversion.
    """
    configs = list(configs)
    if not configs:
        return []
    out: list[SynthesisReport | None] = [None] * len(configs)
    todo: list[int] = []
    keys: list[str | None] = [None] * len(configs)
    for i, cfg in enumerate(configs):
        if use_cache:
            keys[i] = key = config_hash(cfg)
            hit = _SYNTH_CACHE.get(key)
            if hit is not None:
                _CACHE_STATS["hits"] += 1
                out[i] = hit
                continue
            _CACHE_STATS["misses"] += 1
        todo.append(i)
    if todo:
        if soa is None:
            from repro.core.accelerator import configs_to_soa
            soa = configs_to_soa(configs)
        f = np.float64
        idx = np.array(todo, dtype=np.intp)
        n = soa["num_pes"][idx].astype(f)
        glb_bits = soa["glb_bits"][idx].astype(f)
        glb_kb = soa["glb_kb"][idx].astype(f)
        spad_bits = soa["spad_bits"][idx].astype(f)
        mac_area = soa["mac_area_um2"][idx]
        mac_e = soa["mac_energy_pj"][idx]
        max_clk = soa["max_clock_ghz"][idx]
        leak_uw = soa["leak_uw"][idx]
        clk_cap = soa["clock_cap"][idx]
        names = [configs[i].name() for i in todo]
        jit_area = np.array([_jitter_named(nm, "area", 0.03)
                             for nm in names], dtype=f)
        jit_clk = np.array([_jitter_named(nm, "clk", 0.02)
                            for nm in names], dtype=f)
        jit_pw = np.array([_jitter_named(nm, "power", 0.04)
                           for nm in names], dtype=f)

        pe_area = mac_area + sram_area_um2(spad_bits)
        glb_area = sram_area_um2(glb_bits)
        noc_area = 120.0 * n * (1.0 + 0.004 * np.sqrt(n))
        area_mm2 = (n * pe_area + glb_area + noc_area) * jit_area / 1e6

        wire_penalty = 1.0 + 0.002 * np.sqrt(n)
        clock_ghz = np.minimum((max_clk / wire_penalty) * jit_clk, clk_cap)

        util = 0.70
        mac_pw = n * util * mac_e * clock_ghz * 1e9 * 1e-12
        e_spad = rf_access_energy_pj(spad_bits)
        spad_pw = n * util * 3.0 * e_spad * clock_ghz * 1e9 * 1e-12
        e_glb = sram_access_energy_pj(glb_bits)
        glb_pw = n * util * (1.0 / 8.0) * e_glb * clock_ghz * 1e9 * 1e-12
        leak_mw = n * leak_uw * 1e-3 + 0.002 * glb_kb
        power_mw = (mac_pw + spad_pw + glb_pw + leak_mw) * jit_pw

        for j, i in enumerate(todo):
            rep = SynthesisReport(
                area_mm2=float(area_mm2[j]), power_mw=float(power_mw[j]),
                clock_ghz=float(clock_ghz[j]),
                throughput_gmacs=float(n[j] * clock_ghz[j]))
            out[i] = rep
            if use_cache:
                _SYNTH_CACHE[keys[i]] = rep
    return out  # type: ignore[return-value]


def synthesize(cfg: AcceleratorConfig) -> SynthesisReport:
    """Run the analytical 'synthesis flow' for one design point."""
    s = cfg.spec
    n = cfg.num_pes

    # ---- area ------------------------------------------------------------
    spad_bits = s.scratchpad_bits(cfg.ifmap_spad, cfg.filter_spad,
                                  cfg.psum_spad)
    pe_area = s.mac_area_um2 + sram_area_um2(spad_bits)
    glb_area = sram_area_um2(cfg.glb_bits)
    # NoC + control overhead grows slightly super-linearly with array size
    noc_area = 120.0 * n * (1.0 + 0.004 * math.sqrt(n))
    area_um2 = (n * pe_area + glb_area + noc_area) * _jitter(cfg, "area", 0.03)
    area_mm2 = area_um2 / 1e6

    # ---- timing ----------------------------------------------------------
    # Wire delay degrades the achievable clock for very large arrays.
    wire_penalty = 1.0 + 0.002 * math.sqrt(n)
    clock_ghz = (s.max_clock_ghz / wire_penalty) * _jitter(cfg, "clk", 0.02)
    if cfg.clock_ghz is not None:
        clock_ghz = min(clock_ghz, cfg.clock_ghz)

    # ---- power at nominal activity (70% MAC utilization) ------------------
    util = 0.70
    mac_pw = n * util * s.mac_energy_pj * clock_ghz * 1e9 * 1e-12      # mW
    # each MAC: ifmap read + weight read + ~1 psum spad access
    e_spad = rf_access_energy_pj(spad_bits)
    spad_pw = n * util * 3.0 * e_spad * clock_ghz * 1e9 * 1e-12
    # GLB serves ~1 access per 8 MACs across the array (row-stationary reuse)
    e_glb = sram_access_energy_pj(cfg.glb_bits)
    glb_pw = n * util * (1.0 / 8.0) * e_glb * clock_ghz * 1e9 * 1e-12
    from repro.core.pe import _P_PE_LEAK_UW  # static power per PE type
    leak_mw = n * _P_PE_LEAK_UW[s.pe_type] * 1e-3 \
        + 0.002 * cfg.glb_kb                      # GLB leakage ~2uW/kB
    power_mw = (mac_pw + spad_pw + glb_pw + leak_mw) \
        * _jitter(cfg, "power", 0.04)

    return SynthesisReport(
        area_mm2=area_mm2,
        power_mw=power_mw,
        clock_ghz=clock_ghz,
        throughput_gmacs=n * clock_ghz,
    )
