"""QAPPA's PPA models: polynomial regression + k-fold CV model selection.

The paper (Sec. 3.3) collects power/area/timing from the synthesis flow over
many design points and fits polynomial regression models per PE type, using
k-fold cross-validation (Mosteller & Tukey 1968) to select the model.  This
module implements exactly that on top of the analytical synthesis oracle:

    configs --synthesize--> (power, area, perf) "actual"
    features(configs) --poly expand--> ridge fit, degree & lambda by k-fold CV

Fitted models then predict PPA for *unseen* configs orders of magnitude
faster than re-running the oracle or a synthesis flow (paper Fig. 2).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import PEType
from repro.core.synthesis import SynthesisReport, synthesize, synthesize_many

FEATURE_ORDER = (
    "num_pes", "ifmap_spad", "filter_spad", "psum_spad", "glb_kb",
    "dram_bw_gbps",
)

TARGETS = ("power_mw", "area_mm2", "throughput_gmacs")


def feature_matrix(configs: Sequence[AcceleratorConfig]) -> np.ndarray:
    rows = []
    for c in configs:
        f = c.features()
        rows.append([f[k] for k in FEATURE_ORDER])
    return np.asarray(rows, dtype=np.float64)


def poly_expand(x: np.ndarray, degree: int) -> np.ndarray:
    """Polynomial feature expansion with interactions up to ``degree``."""
    n, d = x.shape
    cols = [np.ones(n)]
    for deg in range(1, degree + 1):
        for combo in itertools.combinations_with_replacement(range(d), deg):
            col = np.ones(n)
            for j in combo:
                col = col * x[:, j]
            cols.append(col)
    return np.stack(cols, axis=1)


def _ridge_fit(phi: np.ndarray, y: np.ndarray, lam: float) -> np.ndarray:
    a = phi.T @ phi + lam * np.eye(phi.shape[1])
    return np.linalg.solve(a, phi.T @ y)


def kfold_indices(n: int, k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    for i in range(k):
        val = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        yield train, val


@dataclasses.dataclass
class PolyModel:
    """One fitted polynomial model (one PE type x one target)."""

    degree: int
    lam: float
    mean: np.ndarray
    std: np.ndarray
    coef: np.ndarray
    log_target: bool
    cv_rmse: float

    def predict(self, configs: Sequence[AcceleratorConfig]) -> np.ndarray:
        x = (feature_matrix(configs) - self.mean) / self.std
        phi = poly_expand(x, self.degree)
        y = phi @ self.coef
        return np.exp(y) if self.log_target else y


def fit_poly_model(
    configs: Sequence[AcceleratorConfig],
    y: np.ndarray,
    degrees: Sequence[int] = (1, 2, 3),
    lams: Sequence[float] = (1e-6, 1e-4, 1e-2),
    k: int = 5,
    log_target: bool = True,
    seed: int = 0,
) -> PolyModel:
    """Model selection over (degree, lambda) by k-fold CV (paper Sec. 3.3)."""
    x_raw = feature_matrix(configs)
    mean = x_raw.mean(0)
    std = x_raw.std(0) + 1e-12
    x = (x_raw - mean) / std
    t = np.log(np.maximum(y, 1e-12)) if log_target else y

    best = None
    for degree in degrees:
        phi_full = poly_expand(x, degree)
        for lam in lams:
            errs = []
            for tr, va in kfold_indices(len(x), k, seed):
                coef = _ridge_fit(phi_full[tr], t[tr], lam)
                pred = phi_full[va] @ coef
                errs.append(np.mean((pred - t[va]) ** 2))
            rmse = float(np.sqrt(np.mean(errs)))
            if best is None or rmse < best[0]:
                best = (rmse, degree, lam)
    rmse, degree, lam = best
    phi = poly_expand(x, degree)
    coef = _ridge_fit(phi, t, lam)
    return PolyModel(degree=degree, lam=lam, mean=mean, std=std, coef=coef,
                     log_target=log_target, cv_rmse=rmse)


@dataclasses.dataclass
class PPAModelSuite:
    """Per-PE-type polynomial models for power, area, and performance."""

    models: dict[PEType, dict[str, PolyModel]]

    def predict(self, cfg: AcceleratorConfig) -> dict[str, float]:
        ms = self.models[cfg.pe_type]
        return {t: float(ms[t].predict([cfg])[0]) for t in TARGETS}

    def predict_batch(
            self, configs: Sequence[AcceleratorConfig]
    ) -> dict[str, np.ndarray]:
        """Vectorized prediction for a mixed-PE-type batch: one model
        evaluation per (PE type x target), scattered back in input order."""
        n = len(configs)
        out = {t: np.empty(n, dtype=np.float64) for t in TARGETS}
        for pe_type, ms in self.models.items():
            idx = [i for i, c in enumerate(configs) if c.pe_type == pe_type]
            if not idx:
                continue
            sub = [configs[i] for i in idx]
            for t in TARGETS:
                out[t][idx] = ms[t].predict(sub)
        return out


def fit_ppa_suite(
    configs_by_type: dict[PEType, Sequence[AcceleratorConfig]],
    oracle: Callable[[AcceleratorConfig], SynthesisReport] = synthesize,
    **fit_kwargs,
) -> tuple[PPAModelSuite, dict]:
    """Fit the full suite and return (suite, accuracy stats per model)."""
    suite: dict[PEType, dict[str, PolyModel]] = {}
    stats: dict[str, dict[str, float]] = {}
    for pe_type, configs in configs_by_type.items():
        if oracle is synthesize:   # default flow: vectorized + report cache
            reports = synthesize_many(configs)
        else:
            reports = [oracle(c) for c in configs]
        actual = {t: np.array([getattr(r, t) for r in reports])
                  for t in TARGETS}
        suite[pe_type] = {}
        for target in TARGETS:
            m = fit_poly_model(configs, actual[target], **fit_kwargs)
            suite[pe_type][target] = m
            pred = m.predict(configs)
            resid = pred - actual[target]
            ss_res = float(np.sum(resid ** 2))
            ss_tot = float(np.sum((actual[target]
                                   - actual[target].mean()) ** 2))
            stats[f"{pe_type.value}/{target}"] = {
                "r2": 1.0 - ss_res / max(ss_tot, 1e-12),
                "mape": float(np.mean(np.abs(resid) /
                                      np.maximum(actual[target], 1e-12))),
                "degree": m.degree, "lam": m.lam, "cv_rmse": m.cv_rmse,
                "n": len(configs),
            }
    return PPAModelSuite(models=suite), stats
