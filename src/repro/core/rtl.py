"""RTL generation for the QAPPA accelerator template.

The paper's framework "generates RTL output based on the input hardware
configuration" so designers "can also use the automatically generated RTL
code to follow the design synthesis flow" (Sec. 3.1) — the stated
differentiator vs SCALE-Sim / Aladdin (Sec. 2).  This module emits
synthesizable Verilog-2001 for one :class:`AcceleratorConfig`:

* a MAC unit per PE type — behavioural fp32 stub, int16 multiplier, or
  the LightPE shift / shift-add datapaths (sign|exp coded weights);
* per-PE scratchpads (ifmap / filter / psum) as inferred-BRAM register
  arrays of the config's quantization-aware widths/depths;
* the PE (datapath + spads + row-stationary control handshake);
* the 2-D array with row-broadcast ifmap, column psum chaining, and a
  global-buffer port per column.

tests/test_rtl.py checks structural invariants (module set, port widths,
spad depths, shift-datapath presence for LightPEs, balanced begin/end).
"""

from __future__ import annotations

import math

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import PEType


def _clog2(n: int) -> int:
    return max(1, math.ceil(math.log2(max(2, n))))


def _mac_module(cfg: AcceleratorConfig) -> str:
    s = cfg.spec
    a, w, p = s.act_bits, s.weight_bits, s.psum_bits
    hdr = (f"module mac_unit #(parameter AW={a}, WW={w}, PW={p}) (\n"
           "  input  wire clk,\n  input  wire en,\n"
           f"  input  wire signed [AW-1:0] act,\n"
           f"  input  wire [WW-1:0] weight,\n"
           f"  input  wire signed [PW-1:0] psum_in,\n"
           f"  output reg  signed [PW-1:0] psum_out\n);\n")
    if cfg.pe_type == PEType.FP32:
        body = (
            "  // behavioural fp32 MAC stub; synthesis binds an FPU macro\n"
            "  wire signed [PW-1:0] prod;\n"
            "  fp32_mac_macro u_fp (.a(act), .b(weight), .p(prod));\n"
            "  always @(posedge clk) if (en) psum_out <= psum_in + prod;\n")
    elif cfg.pe_type == PEType.INT16:
        body = (
            "  wire signed [WW-1:0] w_s = weight;\n"
            "  wire signed [AW+WW-1:0] prod = act * w_s;\n"
            "  always @(posedge clk) if (en)\n"
            "    psum_out <= psum_in + {{(PW-AW-WW){prod[AW+WW-1]}}, prod};\n")
    elif cfg.pe_type == PEType.LIGHTPE1:
        body = (
            "  // LightPE-1: one barrel shift (weight = sign|3-bit exp)\n"
            "  wire        w_sign = weight[3];\n"
            "  wire [2:0]  w_exp  = weight[2:0];\n"
            "  wire signed [PW-1:0] act_ext = {{(PW-AW){act[AW-1]}}, act};\n"
            "  wire signed [PW-1:0] shifted = act_ext <<< w_exp;\n"
            "  wire signed [PW-1:0] addend  = w_sign ? -shifted : shifted;\n"
            "  always @(posedge clk) if (en) psum_out <= psum_in + addend;\n")
    else:  # LIGHTPE2: two shifts + add (weight = sign|exp1|exp2 packed)\n
        body = (
            "  // LightPE-2: two shifts + add (sum of <=2 powers of two)\n"
            "  wire        w_sign = weight[7];\n"
            "  wire [2:0]  w_exp1 = weight[6:4];\n"
            "  wire [2:0]  w_exp2 = weight[2:0];\n"
            "  wire        w_two  = weight[3];\n"
            "  wire signed [PW-1:0] act_ext = {{(PW-AW){act[AW-1]}}, act};\n"
            "  wire signed [PW-1:0] sh1 = act_ext <<< w_exp1;\n"
            "  wire signed [PW-1:0] sh2 = w_two ? (act_ext <<< w_exp2)"
            " : {PW{1'b0}};\n"
            "  wire signed [PW-1:0] mag = sh1 + sh2;\n"
            "  wire signed [PW-1:0] addend = w_sign ? -mag : mag;\n"
            "  always @(posedge clk) if (en) psum_out <= psum_in + addend;\n")
    return hdr + body + "endmodule\n"


def _spad_module(name: str, width: int, depth: int) -> str:
    aw = _clog2(depth)
    return (
        f"module {name}_spad #(parameter W={width}, D={depth}, A={aw}) (\n"
        "  input  wire clk,\n  input  wire we,\n"
        "  input  wire [A-1:0] waddr,\n  input  wire [A-1:0] raddr,\n"
        "  input  wire [W-1:0] wdata,\n  output reg  [W-1:0] rdata\n);\n"
        f"  reg [W-1:0] mem [0:D-1];\n"
        "  always @(posedge clk) begin\n"
        "    if (we) mem[waddr] <= wdata;\n"
        "    rdata <= mem[raddr];\n  end\nendmodule\n")


def _pe_module(cfg: AcceleratorConfig) -> str:
    s = cfg.spec
    a, w, p = s.act_bits, s.weight_bits, s.psum_bits
    ia = _clog2(cfg.ifmap_spad)
    fa = _clog2(cfg.filter_spad)
    pa = _clog2(cfg.psum_spad)
    return (
        "module pe (\n"
        "  input  wire clk, rst, en,\n"
        f"  input  wire [{a - 1}:0] ifmap_in,\n"
        f"  input  wire [{w - 1}:0] filter_in,\n"
        "  input  wire ifmap_we, filter_we,\n"
        f"  input  wire [{ia - 1}:0] ifmap_addr,\n"
        f"  input  wire [{fa - 1}:0] filter_addr,\n"
        f"  input  wire [{pa - 1}:0] psum_addr,\n"
        f"  input  wire signed [{p - 1}:0] psum_in,\n"
        f"  output wire signed [{p - 1}:0] psum_out\n);\n"
        f"  wire [{a - 1}:0] act_r;\n"
        f"  wire [{w - 1}:0] wgt_r;\n"
        f"  wire signed [{p - 1}:0] mac_out;\n"
        "  ifmap_spad  u_if (.clk(clk), .we(ifmap_we), .waddr(ifmap_addr),\n"
        "                    .raddr(ifmap_addr), .wdata(ifmap_in),"
        " .rdata(act_r));\n"
        "  filter_spad u_fl (.clk(clk), .we(filter_we),"
        " .waddr(filter_addr),\n"
        "                    .raddr(filter_addr), .wdata(filter_in),"
        " .rdata(wgt_r));\n"
        "  mac_unit    u_mac (.clk(clk), .en(en), .act($signed(act_r)),\n"
        "                     .weight(wgt_r), .psum_in(psum_in),"
        " .psum_out(mac_out));\n"
        "  assign psum_out = mac_out;\n"
        "endmodule\n")


def _array_module(cfg: AcceleratorConfig) -> str:
    s = cfg.spec
    a, w, p = s.act_bits, s.weight_bits, s.psum_bits
    r, c = cfg.pe_rows, cfg.pe_cols
    glb_aw = _clog2(cfg.glb_kb * 1024)
    lines = [
        f"// QAPPA spatial array: {cfg.name()}",
        f"// {r}x{c} {cfg.pe_type.pretty} PEs, row-stationary dataflow",
        "module pe_array (",
        "  input  wire clk, rst, en,",
        f"  input  wire [{a * r - 1}:0] ifmap_rows,    // one act per row",
        f"  input  wire [{w * c - 1}:0] filter_cols,   // one wgt per col",
        "  input  wire ifmap_we, filter_we,",
        f"  input  wire [{glb_aw - 1}:0] glb_addr,",
        f"  output wire [{p * c - 1}:0] psum_cols      // column outputs",
        ");",
        f"  wire signed [{p - 1}:0] psum_chain [0:{r}][0:{c - 1}];",
        "  genvar gi, gj;",
        "  generate",
        f"    for (gj = 0; gj < {c}; gj = gj + 1) begin : col",
        f"      assign psum_chain[0][gj] = {{{p}{{1'b0}}}};",
        f"      for (gi = 0; gi < {r}; gi = gi + 1) begin : row",
        "        pe u_pe (",
        "          .clk(clk), .rst(rst), .en(en),",
        f"          .ifmap_in(ifmap_rows[gi*{a} +: {a}]),",
        f"          .filter_in(filter_cols[gj*{w} +: {w}]),",
        "          .ifmap_we(ifmap_we), .filter_we(filter_we),",
        f"          .ifmap_addr({{{_clog2(cfg.ifmap_spad)}{{1'b0}}}}),",
        f"          .filter_addr({{{_clog2(cfg.filter_spad)}{{1'b0}}}}),",
        f"          .psum_addr({{{_clog2(cfg.psum_spad)}{{1'b0}}}}),",
        "          .psum_in(psum_chain[gi][gj]),",
        "          .psum_out(psum_chain[gi+1][gj])",
        "        );",
        "      end",
        f"      assign psum_cols[gj*{p} +: {p}] = psum_chain[{r}][gj];",
        "    end",
        "  endgenerate",
        "endmodule",
    ]
    return "\n".join(lines) + "\n"


def generate_rtl(cfg: AcceleratorConfig) -> str:
    """Full Verilog for one design point (the paper's RTL output)."""
    s = cfg.spec
    parts = [
        f"// Generated by QAPPA-repro for config: {cfg.name()}",
        f"// PE type: {cfg.pe_type.pretty}  act={s.act_bits}b "
        f"wgt={s.weight_bits}b psum={s.psum_bits}b",
        f"// array {cfg.pe_rows}x{cfg.pe_cols}, GLB {cfg.glb_kb} kB, "
        f"BW {cfg.dram_bw_gbps} GB/s",
        "",
        _mac_module(cfg),
        _spad_module("ifmap", s.act_bits, cfg.ifmap_spad),
        _spad_module("filter", s.weight_bits, cfg.filter_spad),
        _spad_module("psum", s.psum_bits, cfg.psum_spad),
        _pe_module(cfg),
        _array_module(cfg),
    ]
    return "\n".join(parts)


def rtl_stats(rtl: str) -> dict:
    """Crude structural stats for validation/reporting."""
    return {
        "modules": rtl.count("\nmodule ") + rtl.startswith("module "),
        "endmodules": rtl.count("endmodule"),
        "has_shift": "<<<" in rtl,
        "has_multiplier": "act * " in rtl,
        "lines": rtl.count("\n") + 1,
    }
