"""HLO-level analysis of compiled XLA artifacts.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers program is undercounted by ~n_layers; and it reports no
collective traffic at all.  This module therefore implements a cost model
directly over the optimized HLO text:

* per-computation symbol tables (every op line declares its result type)
  give operand shapes;
* ``dot`` FLOPs = 2 * batch * M * N * K from the inline contracting/batch
  dims; elementwise/fusion ops are approximated at 1 FLOP per output
  element (documented approximation — dots dominate every model here);
* bytes-accessed per op = operand bytes + result bytes at fusion
  boundaries (XLA's own fusion cost convention);
* a call graph (while bodies x trip count, fusions/calls x 1) aggregates
  to module totals — trip counts are parsed from the loop condition's
  ``compare(_, constant(N)), direction=LT`` pattern;
* collective traffic = sum of *operand* sizes of every all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute call site.

After SPMD partitioning the module is the per-device program, so all
quantities are per-device.  tests/test_hlo_analysis.py validates the
parser against ``cost_analysis`` on loop-free programs and against
hand-counted scans.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-,%\s]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) over all dtype[dims] tokens."""
    elems = bytes_ = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _operand_section(line: str, open_idx: int) -> str:
    depth = 0
    for i in range(open_idx, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[open_idx + 1:i]
    return line[open_idx + 1:]


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    attrs: str
    line: str


def _parse_computations(text: str) -> dict[str, dict]:
    comps: dict[str, dict] = {}
    current = None
    for raw in text.splitlines():
        line = raw.strip()
        if current is None:
            m = _HEADER_RE.match(line)
            if m and line.endswith("{"):
                current = m.group(2)
                comps[current] = {"ops": [], "entry": bool(m.group(1))}
            continue
        if line == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        open_idx = line.index(m.group(0)) + len(m.group(0)) - 1
        osec = _operand_section(line, open_idx)
        operands = re.findall(r"%([\w\.\-]+)", osec)
        attrs = line[open_idx + len(osec) + 2:]
        comps[current]["ops"].append(
            _Op(name=name, opcode=opcode, result_type=rtype,
                operands=operands, attrs=attrs, line=line))
    return comps


_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "cosine", "sine", "logistic", "expm1", "log1p", "fusion", "select",
    "compare", "and", "or", "reduce", "reduce-window", "clamp",
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id",
}


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    lhs_t = symtab.get(op.operands[0], "")
    rhs_t = symtab.get(op.operands[1], "") if len(op.operands) > 1 else ""
    lm = _SHAPE_RE.search(lhs_t)
    rm = _SHAPE_RE.search(rhs_t)
    if not lm or not rm:
        # fall back: result elements * 2 (can't see operand shapes)
        elems, _ = _shape_info(op.result_type)
        return 2.0 * elems

    def dims_of(m):
        return [int(d) for d in m.group(2).split(",") if d]

    lhs, rhs = dims_of(lm), dims_of(rm)

    def attr_dims(key):
        m = re.search(key + r"=\{([0-9,]*)\}", op.line)
        return [int(d) for d in m.group(1).split(",") if d] if m else []

    lc = attr_dims("lhs_contracting_dims")
    lb = attr_dims("lhs_batch_dims")
    k = 1
    for d in lc:
        k *= lhs[d]
    b = 1
    for d in lb:
        b *= lhs[d]
    m_ = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m_ *= d
    rc = attr_dims("rhs_contracting_dims")
    rb = attr_dims("rhs_batch_dims")
    n_ = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n_ *= d
    return 2.0 * b * m_ * n_ * k


def _trip_count(cond_name: str, comps: dict) -> int:
    """Parse `compare(iter, constant(N)), direction=LT` in the condition."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    symtab = {op.name: op for op in comp["ops"]}
    for op in comp["ops"]:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for operand in op.operands:
                target = symtab.get(operand)
                if target is not None and target.opcode == "constant":
                    m = _CONST_RE.search(target.line)
                    if m:
                        return int(m.group(1))
        # compare may be wrapped in a fusion; search constants directly
    consts = [int(m.group(1)) for op in comp["ops"]
              for m in [_CONST_RE.search(op.line)] if m]
    return max(consts) if consts else 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes_accessed: float
    transcendentals: float
    collectives: CollectiveStats


def analyze_hlo_text(text: str) -> ModuleCost:
    comps = _parse_computations(text)
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    # computations reachable only as fusion bodies are costed at call site
    fusion_targets = set()
    for c in comps.values():
        for op in c["ops"]:
            if op.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", op.line)
                if m:
                    fusion_targets.add(m.group(1))

    memo: dict[str, tuple] = {}

    def cost_of(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 50:
            return (0.0, 0.0, 0.0, defaultdict(float), defaultdict(int))
        flops = bytes_ = transc = 0.0
        coll_b: dict[str, float] = defaultdict(float)
        coll_c: dict[str, int] = defaultdict(int)
        symtab = {op.name: op.result_type for op in comp["ops"]}
        for op in comp["ops"]:
            relems, rbytes = _shape_info(op.result_type)
            obytes = sum(_shape_info(symtab.get(o, ""))[1]
                         for o in op.operands)
            if op.opcode in _FREE_OPS:
                continue
            if op.opcode == "while":
                m = re.search(r"condition=%([\w\.\-]+)", op.line)
                cond = m.group(1) if m else None
                m = re.search(r"body=%([\w\.\-]+)", op.line)
                body = m.group(1) if m else None
                trips = _trip_count(cond, comps) if cond else 1
                bf, bb, bt, bcb, bcc = cost_of(body, depth + 1) if body \
                    else (0, 0, 0, {}, {})
                flops += bf * trips
                bytes_ += bb * trips
                transc += bt * trips
                for k, v in bcb.items():
                    coll_b[k] += v * trips
                for k, v in bcc.items():
                    coll_c[k] += v * trips
                continue
            if op.opcode in ("call", "conditional", "custom-call"):
                m = _CALL_ATTR_RE.search(op.line)
                if m:
                    for target in re.findall(r"[\w\.\-]+", m.group(1)):
                        tf, tb, tt, tcb, tcc = cost_of(target, depth + 1)
                        flops += tf
                        bytes_ += tb
                        transc += tt
                        for k, v in tcb.items():
                            coll_b[k] += v
                        for k, v in tcc.items():
                            coll_c[k] += v
                bytes_ += rbytes + obytes
                continue
            # leaf-ish ops.  Slicing/in-place ops move only the slice, not
            # the buffer they index into (XLA aliases the buffer through
            # the loop): charge 2x the moved region, not the operand.
            if op.opcode == "dynamic-slice":
                bytes_ += 2 * rbytes
                continue
            if op.opcode == "dynamic-update-slice":
                upd = _shape_info(symtab.get(op.operands[1], ""))[1] \
                    if len(op.operands) > 1 else rbytes
                bytes_ += 2 * upd
                continue
            if op.opcode == "fusion" and (
                    "dynamic-update-slice" in op.name
                    or "dynamic-slice" in op.name
                    or "dynamic_update_slice" in op.name):
                # DUS/DS-rooted fusion: result/largest operand are the
                # aliased buffer; traffic = everything else, twice.
                sizes = sorted((_shape_info(symtab.get(o, ""))[1]
                                for o in op.operands), reverse=True)
                moved = sum(sizes[1:]) if sizes else 0
                bytes_ += 2 * max(moved, 1)
                m = re.search(r"calls=%([\w\.\-]+)", op.line)
                if m:
                    ff, _, ft, _, _ = cost_of(m.group(1), depth + 1)
                    flops += ff
                    transc += ft
                continue
            bytes_ += rbytes + obytes
            if op.opcode == "dot":
                flops += _dot_flops(op, symtab)
            elif op.opcode == "convolution":
                flops += 2.0 * relems  # no conv ops emitted by our models
            elif op.opcode in _COLLECTIVES or \
                    op.opcode.rstrip("-start") in _COLLECTIVES:
                kind = op.opcode.replace("-start", "")
                if kind in _COLLECTIVES:
                    coll_b[kind] += obytes
                    coll_c[kind] += 1
            elif op.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", op.line)
                if m:
                    ff, _, ft, _, _ = cost_of(m.group(1), depth + 1)
                    flops += ff
                    transc += ft
            elif op.opcode in _ELEMENTWISE_FLOP_OPS:
                flops += relems
                if op.opcode in ("exponential", "log", "tanh", "logistic",
                                 "power", "expm1", "log1p", "cosine",
                                 "sine"):
                    transc += relems
        out = (flops, bytes_, transc, coll_b, coll_c)
        memo[name] = out
        return out

    if entry is None:
        return ModuleCost(0.0, 0.0, 0.0, CollectiveStats({}, {}))
    f, b, t, cb, cc = cost_of(entry)
    return ModuleCost(flops=f, bytes_accessed=b, transcendentals=t,
                      collectives=CollectiveStats(dict(cb), dict(cc)))


# fusion computations cost their internals for flops, but their internal
# bytes are free (VMEM-resident) — handled above by only adding rbytes /
# obytes at call sites.


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    return analyze_hlo_text(hlo_text).collectives


@dataclasses.dataclass
class CompiledStats:
    """Everything the roofline needs about one compiled step."""

    flops: float                 # per-device, while-trip-corrected
    bytes_accessed: float
    transcendentals: float
    collectives: CollectiveStats
    xla_flops: float             # raw cost_analysis (body-once) for x-ref
    xla_bytes: float
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    generated_code_bytes: int

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "transcendentals": self.transcendentals,
            "collective_bytes": self.collectives.total_bytes,
            "collective_count": self.collectives.total_count,
            "collective_bytes_by_kind": self.collectives.bytes_by_kind,
            "collective_count_by_kind": self.collectives.count_by_kind,
            "xla_cost_analysis_flops": self.xla_flops,
            "xla_cost_analysis_bytes": self.xla_bytes,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "generated_code_bytes": self.generated_code_bytes,
        }


def cost_analysis_dict(compiled) -> dict:
    """Version-compat: ``Compiled.cost_analysis()`` returns a dict on new
    jax but a one-element list of dicts on jax <= 0.4.x."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_compiled(compiled, hlo_text: str | None = None) -> CompiledStats:
    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    if hlo_text is None:
        hlo_text = compiled.as_text()
    mc = analyze_hlo_text(hlo_text)
    return CompiledStats(
        flops=mc.flops,
        bytes_accessed=mc.bytes_accessed,
        transcendentals=mc.transcendentals,
        collectives=mc.collectives,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)),
        argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
        output_bytes=getattr(mem, "output_size_in_bytes", 0),
        temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", 0),
    )
