"""Parameterized spatial-array accelerator template (QAPPA Fig. 1).

A 2-D array of PEs + per-PE scratchpads (ifmap / filter / psum), a shared
global buffer, and a bandwidth-limited device interface.  Every structural
parameter the paper sweeps is a field here.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

import numpy as np

from repro.core.pe import PEType, PESpec, pe_spec


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """One hardware design point in the QAPPA design space."""

    pe_type: PEType = PEType.INT16
    pe_rows: int = 12
    pe_cols: int = 14
    # per-PE scratchpad capacities in *entries* (words of the native width)
    ifmap_spad: int = 12
    filter_spad: int = 224
    psum_spad: int = 24
    glb_kb: int = 128              # shared global buffer capacity (kB)
    dram_bw_gbps: float = 12.8     # device bandwidth, GB/s
    clock_ghz: float | None = None  # None -> PE critical path sets the clock

    def __post_init__(self):
        object.__setattr__(self, "pe_type", PEType(self.pe_type))

    @property
    def num_pes(self) -> int:
        return self.pe_rows * self.pe_cols

    @property
    def spec(self) -> PESpec:
        return pe_spec(self.pe_type)

    @property
    def effective_clock_ghz(self) -> float:
        max_clk = self.spec.max_clock_ghz
        if self.clock_ghz is None:
            return max_clk
        return min(self.clock_ghz, max_clk)

    @property
    def peak_macs_per_s(self) -> float:
        return self.num_pes * self.effective_clock_ghz * 1e9

    @property
    def glb_bits(self) -> int:
        return self.glb_kb * 1024 * 8

    def name(self) -> str:
        return (f"{self.pe_type.value}_{self.pe_rows}x{self.pe_cols}"
                f"_glb{self.glb_kb}k_sp{self.ifmap_spad}-{self.filter_spad}-"
                f"{self.psum_spad}_bw{self.dram_bw_gbps:g}")

    def features(self) -> dict[str, float]:
        """Numeric features used by the polynomial PPA models."""
        s = self.spec
        return {
            "num_pes": float(self.num_pes),
            "pe_rows": float(self.pe_rows),
            "pe_cols": float(self.pe_cols),
            "ifmap_spad": float(self.ifmap_spad),
            "filter_spad": float(self.filter_spad),
            "psum_spad": float(self.psum_spad),
            "glb_kb": float(self.glb_kb),
            "dram_bw_gbps": float(self.dram_bw_gbps),
            "act_bits": float(s.act_bits),
            "weight_bits": float(s.weight_bits),
        }


def soa_from_fields(pe_type_idx: np.ndarray,
                    pe_rows: np.ndarray, pe_cols: np.ndarray,
                    ifmap_spad: np.ndarray, filter_spad: np.ndarray,
                    psum_spad: np.ndarray, glb_kb: np.ndarray,
                    dram_bw_gbps: np.ndarray,
                    clock_cap: np.ndarray) -> dict[str, np.ndarray]:
    """Assemble the full struct-of-arrays form from raw field arrays.

    Per-PE-type constants come from small lookup tables gathered by type
    index (no per-config spec resolution).  This is the common tail of
    :func:`configs_to_soa` (object batch) and :func:`design_space_soa`
    (grid expansion with no objects at all).
    """
    from repro.core.pe import _P_PE_LEAK_UW, _SPECS
    i8, f8 = np.int64, np.float64
    ti = np.asarray(pe_type_idx, dtype=i8)
    specs = [_SPECS[t] for t in PEType]
    soa = {
        "pe_type_idx": ti,
        "pe_rows": np.asarray(pe_rows, dtype=i8),
        "pe_cols": np.asarray(pe_cols, dtype=i8),
        "ifmap_spad": np.asarray(ifmap_spad, dtype=i8),
        "filter_spad": np.asarray(filter_spad, dtype=i8),
        "psum_spad": np.asarray(psum_spad, dtype=i8),
        "glb_kb": np.asarray(glb_kb, dtype=i8),
        "dram_bw_gbps": np.asarray(dram_bw_gbps, dtype=f8),
        "clock_cap": np.asarray(clock_cap, dtype=f8),
        "act_bits": np.array([s.act_bits for s in specs], dtype=i8)[ti],
        "weight_bits": np.array([s.weight_bits for s in specs],
                                dtype=i8)[ti],
        "psum_bits": np.array([s.psum_bits for s in specs], dtype=i8)[ti],
        "mac_energy_pj": np.array([s.mac_energy_pj for s in specs],
                                  dtype=f8)[ti],
        "mac_area_um2": np.array([s.mac_area_um2 for s in specs],
                                 dtype=f8)[ti],
        "max_clock_ghz": np.array([s.max_clock_ghz for s in specs],
                                  dtype=f8)[ti],
        "leak_uw": np.array([_P_PE_LEAK_UW[t] for t in PEType], dtype=f8)[ti],
    }
    soa["glb_bits"] = soa["glb_kb"] * (1024 * 8)
    soa["num_pes"] = soa["pe_rows"] * soa["pe_cols"]
    soa["spad_bits"] = (soa["ifmap_spad"] * soa["act_bits"]
                        + soa["filter_spad"] * soa["weight_bits"]
                        + soa["psum_spad"] * soa["psum_bits"])
    return soa


def configs_to_soa(
        configs: Sequence[AcceleratorConfig]) -> dict[str, np.ndarray]:
    """Struct-of-arrays view of a config batch for the vectorized sweep.

    One array per structural/PE-derived field across all N design points —
    the input format of :mod:`repro.core.dse_batch`.
    """
    i8 = np.int64
    type_idx = {t: i for i, t in enumerate(PEType)}
    rows = np.array(
        [(c.pe_rows, c.pe_cols, c.ifmap_spad, c.filter_spad, c.psum_spad,
          c.glb_kb, type_idx[c.pe_type]) for c in configs], dtype=i8)
    rows = rows.reshape(-1, 7)       # keep 2-D for the empty batch
    return soa_from_fields(
        pe_type_idx=rows[:, 6], pe_rows=rows[:, 0], pe_cols=rows[:, 1],
        ifmap_spad=rows[:, 2], filter_spad=rows[:, 3], psum_spad=rows[:, 4],
        glb_kb=rows[:, 5],
        dram_bw_gbps=np.array([c.dram_bw_gbps for c in configs],
                              dtype=np.float64),
        clock_cap=np.array([np.inf if c.clock_ghz is None else c.clock_ghz
                            for c in configs], dtype=np.float64))


def soa_to_configs(soa: dict[str, np.ndarray],
                   indices: Sequence[int] | np.ndarray | None = None
                   ) -> list[AcceleratorConfig]:
    """Materialize :class:`AcceleratorConfig` objects back out of SoA form
    (optionally only ``indices``) — used to name streamed Pareto survivors."""
    types = tuple(PEType)
    idx = range(len(soa["pe_rows"])) if indices is None else indices
    return [
        AcceleratorConfig(
            pe_type=types[int(soa["pe_type_idx"][i])],
            pe_rows=int(soa["pe_rows"][i]), pe_cols=int(soa["pe_cols"][i]),
            ifmap_spad=int(soa["ifmap_spad"][i]),
            filter_spad=int(soa["filter_spad"][i]),
            psum_spad=int(soa["psum_spad"][i]),
            glb_kb=int(soa["glb_kb"][i]),
            dram_bw_gbps=float(soa["dram_bw_gbps"][i]),
            clock_ghz=(None if np.isinf(soa["clock_cap"][i])
                       else float(soa["clock_cap"][i])))
        for i in idx]


# the paper's Sec. 3.3 factor grid — single source for the grid sweeps
# below and the co-exploration genome space (repro.explore.space)
DEFAULT_ARRAY_DIMS = ((8, 8), (12, 14), (16, 16), (24, 24), (32, 32))
DEFAULT_SPAD_SCALES = (0.5, 1.0, 2.0)
DEFAULT_GLB_KBS = (64, 128, 256, 512)
DEFAULT_BWS = (6.4, 12.8, 25.6)


def spad_capacities(scale: float) -> tuple[int, int, int]:
    """(ifmap, filter, psum) scratchpad entries for one spad-scale factor
    (Eyeriss-proportioned 12/224/24 baseline, floored)."""
    return (max(4, int(12 * scale)), max(16, int(224 * scale)),
            max(8, int(24 * scale)))


def design_space(
    pe_types: tuple[PEType, ...] = tuple(PEType),
    array_dims: tuple[tuple[int, int], ...] = DEFAULT_ARRAY_DIMS,
    spad_scales: tuple[float, ...] = DEFAULT_SPAD_SCALES,
    glb_kbs: tuple[int, ...] = DEFAULT_GLB_KBS,
    bws: tuple[float, ...] = DEFAULT_BWS,
) -> Iterator[AcceleratorConfig]:
    """Full-factorial QAPPA design space (paper Sec. 3.3)."""
    for pe_type, (r, c), ss, glb, bw in itertools.product(
            pe_types, array_dims, spad_scales, glb_kbs, bws):
        ifs, fls, pss = spad_capacities(ss)
        yield AcceleratorConfig(
            pe_type=pe_type, pe_rows=r, pe_cols=c,
            ifmap_spad=ifs, filter_spad=fls, psum_spad=pss,
            glb_kb=glb, dram_bw_gbps=bw,
        )


def design_space_size(
    pe_types: tuple[PEType, ...] = tuple(PEType),
    array_dims: tuple[tuple[int, int], ...] = DEFAULT_ARRAY_DIMS,
    spad_scales: tuple[float, ...] = DEFAULT_SPAD_SCALES,
    glb_kbs: tuple[int, ...] = DEFAULT_GLB_KBS,
    bws: tuple[float, ...] = DEFAULT_BWS,
) -> int:
    return (len(pe_types) * len(array_dims) * len(spad_scales)
            * len(glb_kbs) * len(bws))


def design_space_soa(
    pe_types: tuple[PEType, ...] = tuple(PEType),
    array_dims: tuple[tuple[int, int], ...] = DEFAULT_ARRAY_DIMS,
    spad_scales: tuple[float, ...] = DEFAULT_SPAD_SCALES,
    glb_kbs: tuple[int, ...] = DEFAULT_GLB_KBS,
    bws: tuple[float, ...] = DEFAULT_BWS,
    chunk_size: int | None = None,
) -> Iterator[dict[str, np.ndarray]]:
    """Full-factorial design space expanded directly to struct-of-arrays
    chunks — **no per-config Python objects**, so million-point spaces
    generate at array speed.  Yields SoA dicts of at most ``chunk_size``
    points (one dict for the whole space when ``None``), enumerated in the
    same order as :func:`design_space`.

    This is the input feed for :func:`repro.core.dse_batch.sweep_chunked`.
    """
    type_idx = {t: i for i, t in enumerate(PEType)}
    f_types = np.array([type_idx[PEType(t)] for t in pe_types],
                       dtype=np.int64)
    f_rows = np.array([d[0] for d in array_dims], dtype=np.int64)
    f_cols = np.array([d[1] for d in array_dims], dtype=np.int64)
    spads = [spad_capacities(s) for s in spad_scales]
    f_if = np.array([s[0] for s in spads], dtype=np.int64)
    f_fl = np.array([s[1] for s in spads], dtype=np.int64)
    f_ps = np.array([s[2] for s in spads], dtype=np.int64)
    f_glb = np.array(glb_kbs, dtype=np.int64)
    f_bw = np.array(bws, dtype=np.float64)

    sizes = (len(f_types), len(f_rows), len(f_if), len(f_glb), len(f_bw))
    total = int(np.prod(sizes))
    if total == 0:
        return
    chunk = total if chunk_size is None else max(1, int(chunk_size))
    # mixed-radix decomposition of the flat enumeration index — itertools
    # .product order without materializing tuples
    strides = np.cumprod((1,) + sizes[:0:-1])[::-1]  # row-major strides
    for start in range(0, total, chunk):
        flat = np.arange(start, min(start + chunk, total), dtype=np.int64)
        it, id_, is_, ig, ib = (flat // strides[j] % sizes[j]
                                for j in range(5))
        yield soa_from_fields(
            pe_type_idx=f_types[it], pe_rows=f_rows[id_], pe_cols=f_cols[id_],
            ifmap_spad=f_if[is_], filter_spad=f_fl[is_], psum_spad=f_ps[is_],
            glb_kb=f_glb[ig], dram_bw_gbps=f_bw[ib],
            clock_cap=np.full(flat.shape, np.inf))
