"""Vectorized batched DSE sweep engine.

The scalar path in :mod:`repro.core.dse` evaluates ``O(configs x layers)``
Python calls per sweep.  This module evaluates the *whole* design space at
once: the config batch becomes struct-of-arrays form (one array per field
across all N design points, :func:`repro.core.accelerator.configs_to_soa`),
the workload becomes one array per layer field, and the row-stationary
mapping from :mod:`repro.core.dataflow` is re-expressed as broadcasted
``(N, L)`` array expressions.

The kernel is written against an ``xp`` array namespace so it runs on NumPy
(default — all shapes here are static, so NumPy is both fastest to dispatch
and bit-exact against the scalar reference) or on ``jax.numpy`` under
``jax.jit`` when 64-bit mode is enabled (``backend="jax"``).

Every arithmetic expression mirrors :func:`repro.core.dataflow.map_layer`
op-for-op, in the same order, so per-layer and aggregate results bit-match
the scalar path (asserted by ``tests/test_dse_batch.py``).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import numpy as np

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dataflow import LayerResult
from repro.core.pe import rf_access_energy_pj, sram_access_energy_pj
from repro.core.synthesis import SynthesisReport, synthesize_many
from repro.core.workloads import Workload


def _ceil_div(a, b):
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class WorkloadBatch:
    """Struct-of-arrays view of a workload: one int64 array per layer field,
    shape ``(L,)``."""

    name: str
    layer_names: tuple[str, ...]
    arrays: dict[str, np.ndarray]

    @classmethod
    def from_workload(cls, wl: Workload) -> "WorkloadBatch":
        i8 = np.int64
        ls = wl.layers
        arrays = {
            "r": np.array([l.r for l in ls], dtype=i8),
            "s": np.array([l.s for l in ls], dtype=i8),
            "e": np.array([l.e for l in ls], dtype=i8),
            "f": np.array([l.f for l in ls], dtype=i8),
            "c": np.array([l.c for l in ls], dtype=i8),
            "k": np.array([l.k for l in ls], dtype=i8),
            "h": np.array([l.h for l in ls], dtype=i8),
            "w": np.array([l.w for l in ls], dtype=i8),
            "batch": np.array([l.batch for l in ls], dtype=i8),
            "macs": np.array([l.macs for l in ls], dtype=i8),
        }
        return cls(name=wl.name, layer_names=tuple(l.name for l in ls),
                   arrays=arrays)

    def __len__(self) -> int:
        return len(self.layer_names)


def _sweep_kernel(xp, cfg: dict, lay: dict) -> dict:
    """All-configs x all-layers row-stationary mapping + energy model.

    ``cfg`` holds ``(N, 1)`` arrays, ``lay`` holds ``(1, L)`` arrays; every
    expression broadcasts to ``(N, L)``.  Mirrors ``map_layer`` exactly.
    """
    r, e, f_, ss = lay["r"], lay["e"], lay["f"], lay["s"]
    c, k, n = lay["c"], lay["k"], lay["batch"]

    # ---- spatial mapping ---------------------------------------------------
    sets_fit = xp.maximum(1, cfg["pe_rows"] // r)
    c_simult = xp.minimum(c, sets_fit)
    k_simult = xp.maximum(1, sets_fit // c_simult)
    fit_horz = xp.minimum(e, cfg["pe_cols"])
    n_e_groups = _ceil_div(e, fit_horz)
    n_c_groups = _ceil_div(c, c_simult)
    n_k_groups = _ceil_div(k, k_simult)

    passes = n * n_e_groups * n_c_groups * n_k_groups
    compute_cycles = passes * ss * f_
    macs = lay["macs"]
    utilization = macs / xp.maximum(1, compute_cycles * cfg["num_pes"])

    # ---- element / byte counts (quantization-aware) -------------------------
    ab, wb = cfg["act_bits"], cfg["weight_bits"]
    ifmap_elems = n * c * lay["h"] * lay["w"]
    weight_elems = k * c * r * ss
    ofmap_elems = n * k * e * f_
    ifmap_bytes = ifmap_elems * ab // 8
    weight_bytes = weight_elems * wb // 8
    ofmap_bytes = ofmap_elems * ab // 8

    glb_half = cfg["glb_kb"] * 1024 // 2
    filt_bytes_one = xp.maximum(1, c * r * ss * wb // 8)
    k_fit_glb = xp.maximum(1, glb_half // filt_bytes_one)
    n_k_glb = _ceil_div(k, k_fit_glb)
    ifmap_restream = xp.where(ifmap_bytes <= glb_half, 1, n_k_glb)
    ifmap_dram = ifmap_bytes * ifmap_restream
    dram_bytes = ifmap_dram + weight_bytes + ofmap_bytes

    dram_elems = ifmap_elems * ifmap_restream + weight_elems + ofmap_elems
    k_res = xp.maximum(1, cfg["filter_spad"] // xp.maximum(1, ss))
    glb_ifmap = ifmap_elems * _ceil_div(n_k_groups, k_res)
    w_res = xp.minimum(n_e_groups,
                       xp.maximum(1, cfg["filter_spad"] // xp.maximum(1, ss)))
    glb_weight = weight_elems * xp.maximum(1, n_e_groups // w_res)
    psum_strip = f_
    spill = xp.where(cfg["psum_spad"] >= psum_strip, 0, n_c_groups - 1)
    glb_psum = 2 * ofmap_elems * xp.maximum(0, spill)
    glb_elems = 2 * dram_elems + glb_ifmap + glb_weight + glb_psum
    glb_bytes = glb_elems * ab // 8

    # ---- stalls -------------------------------------------------------------
    clock_ghz = cfg["clock_ghz"]
    bw_bytes_per_cycle = cfg["dram_bw_gbps"] / clock_ghz
    mem_cycles = (dram_bytes
                  / xp.maximum(1e-9, bw_bytes_per_cycle)).astype(np.int64)
    total_cycles = xp.maximum(compute_cycles, mem_cycles)

    # ---- energy -------------------------------------------------------------
    # the pe.py cost helpers are numpy-ufunc based, so they broadcast over
    # the batch (and trace under jax.jit) — single source for the constants
    e_spad_pj = rf_access_energy_pj(cfg["spad_bits"], xp=xp)
    spad_accesses = 3 * macs
    e_spad = spad_accesses * e_spad_pj
    e_mac = macs * cfg["mac_energy_pj"]
    e_glb_pj = sram_access_energy_pj(cfg["glb_bits"], xp=xp)
    e_glb = glb_elems * e_glb_pj
    e_leak = cfg["leak_mw"] * 1e-3 \
        * (total_cycles / (clock_ghz * 1e9)) * 1e12
    energy_pj = e_mac + e_spad + e_glb + e_leak

    # ---- per-config aggregates (sequential over L to bit-match sum()) ------
    n_layers = energy_pj.shape[1]
    energy_sum = xp.zeros(energy_pj.shape[0], dtype=np.float64)
    for j in range(n_layers):
        energy_sum = energy_sum + energy_pj[:, j]
    total_cycles_sum = xp.sum(total_cycles, axis=1)
    total_macs = xp.sum(macs)

    clk = clock_ghz[:, 0]
    latency_s = total_cycles_sum / (clk * 1e9)
    energy_j = energy_sum / 1e12
    throughput_gmacs = total_macs / latency_s / 1e9
    perf_per_area = throughput_gmacs / cfg["area_mm2"][:, 0]

    return {
        "compute_cycles": compute_cycles, "mem_cycles": mem_cycles,
        "total_cycles": total_cycles, "utilization": utilization,
        "spad_accesses": spad_accesses, "glb_bytes": glb_bytes,
        "dram_bytes": dram_bytes, "energy_pj": energy_pj,
        "total_cycles_sum": total_cycles_sum, "energy_pj_sum": energy_sum,
        "latency_s": latency_s, "energy_j": energy_j,
        "throughput_gmacs": throughput_gmacs, "perf_per_area": perf_per_area,
    }


_JAX_KERNEL = None


def _get_jax_kernel():
    """jit-compiled variant of the sweep kernel (requires jax x64 mode)."""
    global _JAX_KERNEL
    if _JAX_KERNEL is None:
        import jax
        import jax.numpy as jnp
        if not jax.config.read("jax_enable_x64"):
            return None
        _JAX_KERNEL = jax.jit(lambda cfg, lay: _sweep_kernel(jnp, cfg, lay))
    return _JAX_KERNEL


@dataclasses.dataclass
class BatchedSweep:
    """One evaluated sweep: N configs x L layers, all results as arrays.

    ``DSEPoint``/``DSEResult`` in :mod:`repro.core.dse` are thin views over
    this; nothing here is materialized per-point unless asked for.
    """

    workload: str
    configs: tuple[AcceleratorConfig, ...]
    layer_names: tuple[str, ...]
    macs: np.ndarray               # (L,)
    clock_ghz: np.ndarray          # (N,)
    area_mm2: np.ndarray           # (N,)
    arrays: dict[str, np.ndarray]  # kernel outputs

    def __len__(self) -> int:
        return len(self.configs)

    def result_view(self, i: int) -> "BatchedWorkloadResult":
        return BatchedWorkloadResult(self, i)


class BatchedWorkloadResult:
    """Duck-typed :class:`repro.core.dataflow.WorkloadResult` view over one
    row of a :class:`BatchedSweep` — O(1) until ``.layers`` is asked for."""

    __slots__ = ("_sweep", "_i", "_layers")

    def __init__(self, sweep: BatchedSweep, i: int):
        self._sweep = sweep
        self._i = i
        self._layers: tuple[LayerResult, ...] | None = None

    # ---- identity fields ---------------------------------------------------
    @property
    def workload(self) -> str:
        return self._sweep.workload

    @property
    def config_name(self) -> str:
        return self._sweep.configs[self._i].name()

    @property
    def area_mm2(self) -> float:
        return float(self._sweep.area_mm2[self._i])

    @property
    def clock_ghz(self) -> float:
        return float(self._sweep.clock_ghz[self._i])

    # ---- per-layer materialization (lazy) ----------------------------------
    @property
    def layers(self) -> tuple[LayerResult, ...]:
        if self._layers is None:
            a, i = self._sweep.arrays, self._i
            self._layers = tuple(
                LayerResult(
                    name=nm, macs=int(self._sweep.macs[j]),
                    compute_cycles=int(a["compute_cycles"][i, j]),
                    mem_cycles=int(a["mem_cycles"][i, j]),
                    total_cycles=int(a["total_cycles"][i, j]),
                    utilization=float(a["utilization"][i, j]),
                    spad_accesses=int(a["spad_accesses"][0, j]),
                    glb_bytes=int(a["glb_bytes"][i, j]),
                    dram_bytes=int(a["dram_bytes"][i, j]),
                    energy_pj=float(a["energy_pj"][i, j]),
                )
                for j, nm in enumerate(self._sweep.layer_names))
        return self._layers

    # ---- aggregates (precomputed in the kernel) ----------------------------
    @property
    def total_macs(self) -> int:
        return int(self._sweep.macs.sum())

    @property
    def total_cycles(self) -> int:
        return int(self._sweep.arrays["total_cycles_sum"][self._i])

    @property
    def latency_s(self) -> float:
        return float(self._sweep.arrays["latency_s"][self._i])

    @property
    def energy_j(self) -> float:
        return float(self._sweep.arrays["energy_j"][self._i])

    @property
    def throughput_gmacs(self) -> float:
        return float(self._sweep.arrays["throughput_gmacs"][self._i])

    @property
    def perf_per_area(self) -> float:
        return float(self._sweep.arrays["perf_per_area"][self._i])

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s


def sweep_workload(workload: Workload,
                   configs: Sequence[AcceleratorConfig],
                   reports: Sequence[SynthesisReport] | None = None,
                   *,
                   use_cache: bool = True,
                   backend: str = "numpy",
                   soa: dict[str, np.ndarray] | None = None) -> BatchedSweep:
    """Evaluate ``workload`` on every config in one batched pass.

    ``reports``/``soa`` let :func:`repro.core.dse.explore_many` synthesize
    and SoA-convert once and reuse across workloads.
    """
    configs = tuple(configs)
    if soa is None:
        soa = configs_to_soa(configs)
    if reports is None:
        reports = synthesize_many(configs, use_cache=use_cache, soa=soa)
    wb = WorkloadBatch.from_workload(workload)

    clock_ghz = np.array([r.clock_ghz for r in reports], dtype=np.float64)
    area_mm2 = np.array([r.area_mm2 for r in reports], dtype=np.float64)
    leak_mw = soa["num_pes"] * soa["leak_uw"] * 1e-3 \
        + 0.002 * soa["glb_kb"]

    cfg = {k: v[:, None] for k, v in soa.items()}
    cfg["clock_ghz"] = clock_ghz[:, None]
    cfg["area_mm2"] = area_mm2[:, None]
    cfg["leak_mw"] = leak_mw[:, None]
    lay = {k: v[None, :] for k, v in wb.arrays.items()}

    kernel = None
    if backend == "jax":
        kernel = _get_jax_kernel()
        if kernel is None:
            warnings.warn("dse_batch: jax backend requires jax_enable_x64; "
                          "falling back to numpy", stacklevel=2)
    if kernel is not None:
        out = {k: np.asarray(v) for k, v in kernel(cfg, lay).items()}
    else:
        out = _sweep_kernel(np, cfg, lay)

    return BatchedSweep(workload=workload.name, configs=configs,
                        layer_names=wb.layer_names, macs=wb.arrays["macs"],
                        clock_ghz=clock_ghz, area_mm2=area_mm2, arrays=out)


def pareto_mask(perf: np.ndarray, energy: np.ndarray,
                chunk: int = 1024) -> np.ndarray:
    """Boolean mask of non-dominated points for (maximize perf, minimize
    energy) — the vectorized replacement for the O(n^2) Python dominance
    loop (chunked broadcasting keeps memory at ``chunk * n`` bools)."""
    perf = np.asarray(perf, dtype=np.float64)
    energy = np.asarray(energy, dtype=np.float64)
    n = perf.shape[0]
    keep = np.ones(n, dtype=bool)
    for s in range(0, n, chunk):
        p = perf[s:s + chunk, None]
        e = energy[s:s + chunk, None]
        dominated = ((perf[None, :] >= p) & (energy[None, :] <= e)
                     & ((perf[None, :] > p) | (energy[None, :] < e))).any(1)
        keep[s:s + chunk] = ~dominated
    return keep
