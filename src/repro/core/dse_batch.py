"""Vectorized batched DSE sweep engine.

The scalar path in :mod:`repro.core.dse` evaluates ``O(configs x layers)``
Python calls per sweep.  This module evaluates the *whole* design space at
once: the config batch becomes struct-of-arrays form (one array per field
across all N design points, :func:`repro.core.accelerator.configs_to_soa`),
the workload becomes one array per layer field, and the row-stationary
mapping from :mod:`repro.core.dataflow` is re-expressed as broadcasted
``(N, L)`` array expressions.

The kernel is written against an ``xp`` array namespace and a dtype policy:

* ``exact=True`` (NumPy default) — int64/float64, op-for-op identical to
  :func:`repro.core.dataflow.map_layer`, so per-layer and aggregate
  results bit-match the scalar path (``tests/test_dse_batch.py``);
* ``exact=False`` — the **x64-free** policy used under ``jax.jit`` with
  jax's default config: spatial-mapping integers stay int32 (provably
  small), while anything that can overflow 31 bits — MAC counts, byte /
  element tallies, cycle counts, energies — is promoted to float32 with
  explicit ``floor`` where the exact path truncates, and the per-config
  reductions are Kahan-compensated.  Headline ratios agree with the exact
  path to ~1e-7 relative (asserted at 1e-6 in tests).

Backends resolve explicitly (``"auto" | "numpy" | "jax"``): ``"jax"``
raises if jax is unusable instead of silently falling back, and ``"auto"``
picks jax exactly when an accelerator platform is attached.  Under jax the
config axis can be sharded across devices via
:func:`repro.launch.mesh.make_sweep_mesh` (``mesh=...``).

For spaces too large to hold in memory, :func:`sweep_chunked` streams an
arbitrary-size config generator through the same kernel in bounded-memory
chunks with a running Pareto-front reduction, optionally backed by the
on-disk synthesis cache (:class:`repro.core.synthesis
.PersistentSynthesisCache`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core.accelerator import (AcceleratorConfig, configs_to_soa,
                                    soa_to_configs)
from repro.core.dataflow import LayerResult, leakage_mw_soa
from repro.core.pe import rf_access_energy_pj, sram_access_energy_pj
from repro.core.synthesis import (PersistentSynthesisCache, SynthesisReport,
                                  sweep_synthesis_cache, synthesize_soa)
from repro.core.workloads import Workload
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _ceil_div(a, b):
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class WorkloadBatch:
    """Struct-of-arrays view of a workload: one int64 array per layer field,
    shape ``(L,)``."""

    name: str
    layer_names: tuple[str, ...]
    arrays: dict[str, np.ndarray]

    @classmethod
    def from_workload(cls, wl: Workload) -> "WorkloadBatch":
        i8 = np.int64
        ls = wl.layers
        arrays = {
            "r": np.array([l.r for l in ls], dtype=i8),
            "s": np.array([l.s for l in ls], dtype=i8),
            "e": np.array([l.e for l in ls], dtype=i8),
            "f": np.array([l.f for l in ls], dtype=i8),
            "c": np.array([l.c for l in ls], dtype=i8),
            "k": np.array([l.k for l in ls], dtype=i8),
            "h": np.array([l.h for l in ls], dtype=i8),
            "w": np.array([l.w for l in ls], dtype=i8),
            "batch": np.array([l.batch for l in ls], dtype=i8),
            "macs": np.array([l.macs for l in ls], dtype=i8),
        }
        return cls(name=wl.name, layer_names=tuple(l.name for l in ls),
                   arrays=arrays)

    def __len__(self) -> int:
        return len(self.layer_names)


@functools.lru_cache(maxsize=64)
def _workload_batch(wl: Workload) -> WorkloadBatch:
    """SoA conversion cache — workloads are small frozen dataclasses, so
    repeat sweeps of the same model skip the per-layer array build."""
    return WorkloadBatch.from_workload(wl)


def _pack_block_key(cfg: dict) -> np.ndarray | None:
    """Pack the clock/bandwidth-independent config fields into one int64
    key per design point (for unique-row factorization of the kernel's
    mapping/byte block).  Returns None when the fields don't fit 63 bits
    — the caller then falls back to the direct per-config path, so an
    overflow can never alias two distinct configs."""
    fields = (cfg["pe_rows"], cfg["pe_cols"], cfg["act_bits"],
              cfg["weight_bits"], cfg["glb_kb"], cfg["filter_spad"],
              cfg["psum_spad"])
    cols = [np.asarray(a[:, 0]) for a in fields]
    bits = []
    for col in cols:
        lo, hi = int(col.min()), int(col.max())
        if lo < 0:
            return None
        bits.append(max(1, hi.bit_length()))
    if sum(bits) > 63:
        return None
    key = np.zeros_like(cols[0])
    for col, b in zip(cols, bits):
        key = (key << b) | col
    return key


def _kahan_sum_rows(xp, x, dtype):
    """Sequential compensated row-sum over the layer axis.

    The exact path needs plain sequential adds (bit-matching ``sum()``);
    the float32 path compensates so L-layer accumulation error stays at
    one-ulp instead of L ulps."""
    total = xp.zeros(x.shape[0], dtype=dtype)
    comp = xp.zeros(x.shape[0], dtype=dtype)
    for j in range(x.shape[1]):
        y = x[:, j] - comp
        t = total + y
        comp = (t - total) - y
        total = t
    return total


# per-config aggregate output columns — the only outputs the search loop
# and the streamed Pareto reduction need; with ``outputs="aggregates"`` the
# kernel returns just these, so under jax.jit XLA dead-code-eliminates every
# (N, L) layer-level intermediate and the device->host transfer shrinks to
# O(N) (ROADMAP open item)
AGGREGATE_OUTPUTS = ("total_cycles_sum", "energy_pj_sum", "latency_s",
                     "energy_j", "throughput_gmacs", "perf_per_area")
# the (N, L) columns the multi-workload segment reduction consumes — with
# ``outputs="layer_totals"`` the kernel returns only these two, so XLA can
# DCE every other layer-level intermediate before the per-workload sums
LAYER_TOTAL_OUTPUTS = ("total_cycles", "energy_pj")
OUTPUT_MODES = ("full", "aggregates", "layer_totals")


def _sweep_kernel(xp, cfg: dict, lay: dict, *, exact: bool = True,
                  outputs: str = "full") -> dict:
    """All-configs x all-layers row-stationary mapping + energy model.

    ``cfg`` holds ``(N, 1)`` arrays, ``lay`` holds ``(1, L)`` arrays; every
    expression broadcasts to ``(N, L)``.  ``exact=True`` mirrors
    ``map_layer`` bit-for-bit; ``exact=False`` is the x64-free dtype-safe
    policy (see module docstring).

    Mixed precision: the ``act_bits`` / ``weight_bits`` / ``mac_energy_pj``
    config columns may be ``(N, L)`` instead of ``(N, 1)`` — one execution
    mode per (config, layer), see :func:`sweep_mixed`.  The same broadcast
    expressions cover both shapes, so a homogeneous assignment is
    bit-identical to the per-config-scalar path.

    ``outputs="aggregates"`` returns only :data:`AGGREGATE_OUTPUTS`.
    """
    f = np.float64 if exact else np.float32
    r, e, f_, ss = lay["r"], lay["e"], lay["f"], lay["s"]
    c, k, n = lay["c"], lay["k"], lay["batch"]
    macs = lay["macs"]          # int64 when exact, float32 otherwise

    def fl(x):                  # promote a (possibly int) array to f
        return x.astype(f)

    # The mapping / byte-count / GLB-traffic block depends on the config
    # only through (pe_rows, pe_cols, act_bits, weight_bits, glb_kb,
    # filter_spad, psum_spad) — NOT through bandwidth or the synthesized
    # clock.  Factorial design spaces repeat those key fields across
    # thousands of configs (e.g. 240 unique vs 720 points in the paper
    # space), so on the eager numpy path we evaluate the block once per
    # *unique* key row and gather — a bit-identical copy of the same
    # values at a fraction of the (N, L) op count.  The jax path keeps the
    # direct form (np.unique doesn't trace; jit fuses instead).
    _BLOCK_FIELDS = ("pe_rows", "pe_cols", "num_pes", "act_bits",
                     "weight_bits", "glb_kb", "filter_spad", "psum_spad")
    # per-layer precision columns make the block layer-dependent, so the
    # unique-row factorization only applies to homogeneous batches
    homogeneous = all(cfg[k2].shape[1] == 1
                      for k2 in ("act_bits", "weight_bits", "mac_energy_pj"))
    inv = None
    if exact and xp is np and homogeneous and cfg["pe_rows"].shape[0] > 16:
        key = _pack_block_key(cfg)
        if key is not None:
            _, uidx, inv = np.unique(key, return_index=True,
                                     return_inverse=True)
            inv = inv.reshape(-1)
            if len(uidx) == len(key):
                inv = None                  # all distinct: nothing to save
    cb = cfg if inv is None else {k2: cfg[k2][uidx] for k2 in _BLOCK_FIELDS}

    # ---- spatial mapping (small integers: int64 exact / int32 safe) --------
    sets_fit = xp.maximum(1, cb["pe_rows"] // r)
    c_simult = xp.minimum(c, sets_fit)
    k_simult = xp.maximum(1, sets_fit // c_simult)
    fit_horz = xp.minimum(e, cb["pe_cols"])
    n_e_groups = _ceil_div(e, fit_horz)
    n_c_groups = _ceil_div(c, c_simult)
    n_k_groups = _ceil_div(k, k_simult)

    if exact:
        passes = n * n_e_groups * n_c_groups * n_k_groups
        # int multiply is associative: fold the (1, L) factors first so
        # only one product runs per row — value identical to map_layer
        compute_cycles = passes * (ss * f_)
        utilization = macs / xp.maximum(1, compute_cycles * cb["num_pes"])
    else:
        # group products can pass 2**31 — promote the accumulator only
        compute_cycles = (fl(n) * fl(n_e_groups) * fl(n_c_groups)
                          * fl(n_k_groups) * fl(ss) * fl(f_))
        utilization = macs / xp.maximum(
            f(1.0), compute_cycles * fl(cb["num_pes"]))

    # ---- element / byte counts (quantization-aware) -------------------------
    ab, wb = cb["act_bits"], cb["weight_bits"]
    ifmap_elems = n * c * lay["h"] * lay["w"]
    weight_elems = k * c * r * ss
    ofmap_elems = n * k * e * f_
    if exact:
        ifmap_bytes = ifmap_elems * ab // 8
        weight_bytes = weight_elems * wb // 8
        ofmap_bytes = ofmap_elems * ab // 8
    else:
        # elems * bits exceeds int32; float32 with explicit truncation
        ifmap_bytes = xp.floor(fl(ifmap_elems) * fl(ab) / 8.0)
        weight_bytes = xp.floor(fl(weight_elems) * fl(wb) / 8.0)
        ofmap_bytes = xp.floor(fl(ofmap_elems) * fl(ab) / 8.0)

    glb_half = cb["glb_kb"] * 1024 // 2
    filt_bytes_one = xp.maximum(1, c * r * ss * wb // 8)
    k_fit_glb = xp.maximum(1, glb_half // filt_bytes_one)
    n_k_glb = _ceil_div(k, k_fit_glb)
    if exact:
        ifmap_restream = xp.where(ifmap_bytes <= glb_half, 1, n_k_glb)
        ifmap_dram = ifmap_bytes * ifmap_restream
        dram_bytes = ifmap_dram + weight_bytes + ofmap_bytes
        dram_elems = ifmap_elems * ifmap_restream + weight_elems \
            + ofmap_elems
    else:
        ifmap_restream = xp.where(ifmap_bytes <= fl(glb_half),
                                  f(1.0), fl(n_k_glb))
        dram_bytes = ifmap_bytes * ifmap_restream + weight_bytes \
            + ofmap_bytes
        dram_elems = fl(ifmap_elems) * ifmap_restream + fl(weight_elems) \
            + fl(ofmap_elems)

    # map_layer computes this subexpression twice with identical value;
    # evaluate once and share
    filt_res = xp.maximum(1, cb["filter_spad"] // xp.maximum(1, ss))
    k_res = filt_res
    w_res = xp.minimum(n_e_groups, filt_res)
    psum_strip = f_
    spill = xp.where(cb["psum_spad"] >= psum_strip, 0, n_c_groups - 1)
    if exact:
        glb_ifmap = ifmap_elems * _ceil_div(n_k_groups, k_res)
        glb_weight = weight_elems * xp.maximum(1, n_e_groups // w_res)
        glb_psum = 2 * ofmap_elems * xp.maximum(0, spill)
        glb_elems = 2 * dram_elems + glb_ifmap + glb_weight + glb_psum
        glb_bytes = glb_elems * ab // 8
    else:
        glb_ifmap = fl(ifmap_elems) * fl(_ceil_div(n_k_groups, k_res))
        glb_weight = fl(weight_elems) * fl(xp.maximum(1, n_e_groups // w_res))
        glb_psum = 2.0 * fl(ofmap_elems) * fl(xp.maximum(0, spill))
        glb_elems = 2.0 * dram_elems + glb_ifmap + glb_weight + glb_psum
        glb_bytes = xp.floor(glb_elems * fl(ab) / 8.0)

    if inv is not None:                     # scatter back to all N configs
        compute_cycles = compute_cycles[inv]
        utilization = utilization[inv]
        dram_bytes = dram_bytes[inv]
        glb_elems = glb_elems[inv]
        glb_bytes = glb_bytes[inv]

    # ---- stalls -------------------------------------------------------------
    clock_ghz = cfg["clock_ghz"]
    bw_bytes_per_cycle = cfg["dram_bw_gbps"] / clock_ghz
    if exact:
        mem_cycles = (dram_bytes
                      / xp.maximum(1e-9, bw_bytes_per_cycle)
                      ).astype(np.int64)
        total_cycles = xp.maximum(compute_cycles, mem_cycles)
    else:
        mem_cycles = xp.floor(dram_bytes
                              / xp.maximum(f(1e-9), bw_bytes_per_cycle))
        total_cycles = xp.maximum(compute_cycles, mem_cycles)

    # ---- energy -------------------------------------------------------------
    # the pe.py cost helpers are numpy-ufunc based, so they broadcast over
    # the batch (and trace under jax.jit) — single source for the constants
    e_spad_pj = rf_access_energy_pj(cfg["spad_bits"], xp=xp)
    spad_accesses = 3 * macs
    e_spad = spad_accesses * e_spad_pj
    e_mac = macs * cfg["mac_energy_pj"]
    e_glb_pj = sram_access_energy_pj(cfg["glb_bits"], xp=xp)
    e_glb = glb_elems * e_glb_pj
    e_leak = cfg["leak_mw"] * 1e-3 \
        * (total_cycles / (clock_ghz * 1e9)) * 1e12
    energy_pj = e_mac + e_spad + e_glb + e_leak

    if outputs == "layer_totals":
        # the segmented multi-workload reduction happens in the caller
        return {"total_cycles": total_cycles, "energy_pj": energy_pj}

    # ---- per-config aggregates ---------------------------------------------
    if exact:
        # sequential over L to bit-match the scalar sum()
        n_layers = energy_pj.shape[1]
        energy_sum = xp.zeros(energy_pj.shape[0], dtype=np.float64)
        for j in range(n_layers):
            energy_sum = energy_sum + energy_pj[:, j]
        total_cycles_sum = xp.sum(total_cycles, axis=1)
    else:
        energy_sum = _kahan_sum_rows(xp, energy_pj, f)
        total_cycles_sum = _kahan_sum_rows(xp, total_cycles, f)
    total_macs = xp.sum(macs)

    clk = clock_ghz[:, 0]
    latency_s = total_cycles_sum / (clk * 1e9)
    energy_j = energy_sum / 1e12
    throughput_gmacs = total_macs / latency_s / 1e9
    perf_per_area = throughput_gmacs / cfg["area_mm2"][:, 0]

    out = {
        "compute_cycles": compute_cycles, "mem_cycles": mem_cycles,
        "total_cycles": total_cycles, "utilization": utilization,
        "spad_accesses": spad_accesses, "glb_bytes": glb_bytes,
        "dram_bytes": dram_bytes, "energy_pj": energy_pj,
        "total_cycles_sum": total_cycles_sum, "energy_pj_sum": energy_sum,
        "latency_s": latency_s, "energy_j": energy_j,
        "throughput_gmacs": throughput_gmacs, "perf_per_area": perf_per_area,
    }
    if outputs == "aggregates":
        return {k: out[k] for k in AGGREGATE_OUTPUTS}
    return out


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------

BACKENDS = ("auto", "numpy", "jax")


def _probe_jax() -> tuple[bool, str]:
    try:
        import jax
        jax.devices()
    except Exception as exc:  # import error, no platform, bad install...
        return False, f"{type(exc).__name__}: {exc}"
    return True, ""


_JAX_PROBE: tuple[bool, str] | None = None


def _jax_usable() -> tuple[bool, str]:
    global _JAX_PROBE
    if _JAX_PROBE is None:
        _JAX_PROBE = _probe_jax()
    return _JAX_PROBE


def _jax_has_accelerator() -> bool:
    import jax
    return any(d.platform != "cpu" for d in jax.devices())


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``"auto" | "numpy" | "jax"`` to a concrete engine.

    Explicit ``"jax"`` **raises** when jax is unusable — no silent numpy
    fallback.  ``"auto"`` picks jax exactly when an accelerator platform
    (GPU/TPU) is attached; on CPU NumPy is both faster to dispatch and
    bit-exact against the scalar reference, so it wins the tie.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown sweep backend: {backend!r} (choose from {BACKENDS})")
    if backend == "numpy":
        return "numpy"
    usable, why = _jax_usable()
    if backend == "jax":
        if not usable:
            raise RuntimeError(
                f"sweep backend 'jax' requested but jax is unusable ({why})")
        return "jax"
    return "jax" if usable and _jax_has_accelerator() else "numpy"


def resolve_use_pallas(use_pallas: bool | None, backend: str,
                       mesh=None) -> bool:
    """Resolve the ``use_pallas`` routing flag against a *resolved*
    backend.

    ``None`` (auto) engages the Pallas sweep kernel exactly when the jax
    backend is active on a real accelerator platform without ``mesh``
    sharding — on CPU the interpreter-mode kernel is for parity testing,
    not production throughput, so auto keeps the jitted XLA path.
    Explicit ``True`` raises instead of silently falling back when the
    backend can't honor it (numpy, or a sharded mesh — the Pallas kernel
    owns its own tiling and doesn't compose with ``shard_map`` yet).
    """
    if use_pallas is None:
        return (backend == "jax" and mesh is None
                and _jax_usable()[0] and _jax_has_accelerator())
    use_pallas = bool(use_pallas)
    if use_pallas and backend != "jax":
        raise ValueError(
            f"use_pallas=True requires the jax backend, but the sweep "
            f"resolved to backend={backend!r}")
    if use_pallas and mesh is not None:
        raise ValueError(
            "use_pallas=True does not compose with mesh= sharding yet; "
            "drop mesh= or use_pallas")
    return use_pallas


# ---------------------------------------------------------------------------
# jax path: jit cache + x64-free input conversion + optional shard_map
# ---------------------------------------------------------------------------

_JAX_KERNELS: dict = {}

# int32-safe cfg/lay fields under the x64-free policy; everything else
# (counts that can pass 2**31, float quantities) converts to float32
_CFG_INT32 = ("pe_rows", "pe_cols", "ifmap_spad", "filter_spad",
              "psum_spad", "glb_kb", "glb_bits", "num_pes", "act_bits",
              "weight_bits", "spad_bits")
_LAY_INT32 = ("r", "s", "e", "f", "c", "k", "h", "w", "batch")


def _to_jax_inputs(cfg: dict, lay: dict, exact: bool) -> tuple[dict, dict]:
    if exact:
        return cfg, lay
    jcfg = {k: (v.astype(np.int32) if k in _CFG_INT32
                else v.astype(np.float32)) for k, v in cfg.items()}
    jlay = {k: (v.astype(np.int32) if k in _LAY_INT32
                else v.astype(np.float32)) for k, v in lay.items()}
    return jcfg, jlay


def get_jax_kernel(mesh=None, outputs: str = "full"):
    """The jit-compiled sweep kernel for the current jax config.

    Compiled once per (x64-mode, mesh, outputs) and cached — repeat sweeps
    over same-shape batches hit the jit cache with zero retraces (asserted
    in tests via ``_cache_size``).  With ``mesh``, the config axis is
    sharded across the mesh's devices via ``shard_map``; layer arrays are
    replicated.  ``outputs="aggregates"`` jits the aggregates-only kernel,
    whose (N, L) intermediates XLA dead-code-eliminates.
    """
    import jax
    import jax.numpy as jnp

    exact = bool(jax.config.read("jax_enable_x64"))
    key = (exact, _mesh_cache_key(mesh), outputs)
    fn = _JAX_KERNELS.get(key)
    if fn is not None:
        return fn, exact

    def kernel(cfg, lay):
        return _sweep_kernel(jnp, cfg, lay, exact=exact, outputs=outputs)

    if mesh is None:
        fn = jax.jit(kernel)
    else:
        from repro.launch.mesh import compat_shard_map
        P = jax.sharding.PartitionSpec

        def sharded(cfg, lay):
            n = cfg["pe_rows"].shape[0]
            cfg_specs = {k: P("configs", None) for k in cfg}
            lay_specs = {k: P(None, None) for k in lay}
            shapes = jax.eval_shape(kernel, cfg, lay)
            # config-major outputs shard; (1, L) layer stats and 0-d
            # scalars replicate
            out_specs = {
                k: (P("configs", *([None] * (s.ndim - 1)))
                    if s.ndim >= 1 and s.shape[0] == n
                    else P(*([None] * s.ndim)))
                for k, s in shapes.items()}
            return compat_shard_map(
                kernel, mesh=mesh, in_specs=(cfg_specs, lay_specs),
                out_specs=out_specs)(cfg, lay)

        fn = jax.jit(sharded)
    _JAX_KERNELS[key] = fn
    return fn, exact


def _run_kernel(cfg: dict, lay: dict, backend: str,
                mesh=None, outputs: str = "full",
                use_pallas: bool = False) -> dict[str, np.ndarray]:
    if outputs not in OUTPUT_MODES:
        raise ValueError(
            f"unknown sweep outputs: {outputs!r} (choose from "
            f"{OUTPUT_MODES})")
    if backend == "jax" and use_pallas and outputs == "aggregates" \
            and mesh is None:
        # the Pallas kernel covers the aggregate-reduction path (the only
        # one the streamed/search hot loops use); per-layer output modes
        # keep the jitted XLA kernel
        from repro.kernels.sweep_kernel import sweep_aggregates_pallas
        out = sweep_aggregates_pallas(cfg, lay)
        return {k: np.asarray(v) for k, v in out.items()}
    if backend == "jax":
        _require_jax_mesh(mesh)
        fn, exact = get_jax_kernel(mesh, outputs)
        # under the x64-free policy "macs" lands in float32 via
        # _to_jax_inputs (it feeds only float math in the kernel)
        jcfg, jlay = _to_jax_inputs(cfg, lay, exact)
        n = cfg["pe_rows"].shape[0]
        if mesh is not None:
            jcfg = _pad_rows(jcfg, -n % _mesh_shards(mesh))
        out = {k: np.asarray(v)[:n] if np.ndim(v) else np.asarray(v)
               for k, v in fn(jcfg, jlay).items()}
        return out
    return _sweep_kernel(np, cfg, lay, outputs=outputs)


@dataclasses.dataclass
class BatchedSweep:
    """One evaluated sweep: N configs x L layers, all results as arrays.

    ``DSEPoint``/``DSEResult`` in :mod:`repro.core.dse` are thin views over
    this; nothing here is materialized per-point unless asked for.
    """

    workload: str
    configs: tuple[AcceleratorConfig, ...]
    layer_names: tuple[str, ...]
    macs: np.ndarray               # (L,)
    clock_ghz: np.ndarray          # (N,)
    area_mm2: np.ndarray           # (N,)
    arrays: dict[str, np.ndarray]  # kernel outputs

    def __len__(self) -> int:
        return len(self.configs)

    def result_view(self, i: int) -> "BatchedWorkloadResult":
        return BatchedWorkloadResult(self, i)


class BatchedWorkloadResult:
    """Duck-typed :class:`repro.core.dataflow.WorkloadResult` view over one
    row of a :class:`BatchedSweep` — O(1) until ``.layers`` is asked for."""

    __slots__ = ("_sweep", "_i", "_layers")

    def __init__(self, sweep: BatchedSweep, i: int):
        self._sweep = sweep
        self._i = i
        self._layers: tuple[LayerResult, ...] | None = None

    # ---- identity fields ---------------------------------------------------
    @property
    def workload(self) -> str:
        return self._sweep.workload

    @property
    def config_name(self) -> str:
        return self._sweep.configs[self._i].name()

    @property
    def area_mm2(self) -> float:
        return float(self._sweep.area_mm2[self._i])

    @property
    def clock_ghz(self) -> float:
        return float(self._sweep.clock_ghz[self._i])

    # ---- per-layer materialization (lazy) ----------------------------------
    @property
    def layers(self) -> tuple[LayerResult, ...]:
        if self._layers is None:
            a, i = self._sweep.arrays, self._i
            self._layers = tuple(
                LayerResult(
                    name=nm, macs=int(self._sweep.macs[j]),
                    compute_cycles=int(a["compute_cycles"][i, j]),
                    mem_cycles=int(a["mem_cycles"][i, j]),
                    total_cycles=int(a["total_cycles"][i, j]),
                    utilization=float(a["utilization"][i, j]),
                    spad_accesses=int(a["spad_accesses"][0, j]),
                    glb_bytes=int(a["glb_bytes"][i, j]),
                    dram_bytes=int(a["dram_bytes"][i, j]),
                    energy_pj=float(a["energy_pj"][i, j]),
                )
                for j, nm in enumerate(self._sweep.layer_names))
        return self._layers

    # ---- aggregates (precomputed in the kernel) ----------------------------
    @property
    def total_macs(self) -> int:
        return int(self._sweep.macs.sum())

    @property
    def total_cycles(self) -> int:
        return int(self._sweep.arrays["total_cycles_sum"][self._i])

    @property
    def latency_s(self) -> float:
        return float(self._sweep.arrays["latency_s"][self._i])

    @property
    def energy_j(self) -> float:
        return float(self._sweep.arrays["energy_j"][self._i])

    @property
    def throughput_gmacs(self) -> float:
        return float(self._sweep.arrays["throughput_gmacs"][self._i])

    @property
    def perf_per_area(self) -> float:
        return float(self._sweep.arrays["perf_per_area"][self._i])

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s


def _reports_to_cols(reports) -> dict[str, np.ndarray]:
    """Accept synthesis results as a report list *or* column dict."""
    if isinstance(reports, dict):
        return reports
    return {
        "clock_ghz": np.array([r.clock_ghz for r in reports],
                              dtype=np.float64),
        "area_mm2": np.array([r.area_mm2 for r in reports],
                             dtype=np.float64),
    }


def _make_cfg_lay(soa: dict, cols: dict, wb: WorkloadBatch
                  ) -> tuple[dict, dict]:
    leak_mw = leakage_mw_soa(soa)
    cfg = {k: soa[k][:, None] for k in
           ("pe_rows", "pe_cols", "ifmap_spad", "filter_spad", "psum_spad",
            "glb_kb", "glb_bits", "num_pes", "act_bits", "weight_bits",
            "spad_bits", "dram_bw_gbps", "mac_energy_pj")}
    cfg["clock_ghz"] = np.asarray(cols["clock_ghz"],
                                  dtype=np.float64)[:, None]
    cfg["area_mm2"] = np.asarray(cols["area_mm2"], dtype=np.float64)[:, None]
    cfg["leak_mw"] = leak_mw[:, None]
    lay = {k: v[None, :] for k, v in wb.arrays.items()}
    return cfg, lay


def _sweep_workload(workload: Workload,
                    configs: Sequence[AcceleratorConfig],
                    reports: Sequence[SynthesisReport] | dict | None = None,
                    *,
                    use_cache: bool = True,
                    backend: str = "auto",
                    soa: dict[str, np.ndarray] | None = None,
                    mesh=None,
                    outputs: str = "full",
                    use_pallas: bool | None = None) -> BatchedSweep:
    """Evaluate ``workload`` on every config in one batched pass.

    ``reports``/``soa`` let :func:`repro.core.dse.explore_many` synthesize
    and SoA-convert once and reuse across workloads; ``reports`` may be a
    list of :class:`SynthesisReport` or a column dict from
    :func:`repro.core.synthesis.synthesize_soa`.

    ``outputs="aggregates"`` keeps only the per-config columns
    (:data:`AGGREGATE_OUTPUTS`): the result's per-point views still serve
    every aggregate metric, but ``.layers`` is unavailable.
    """
    backend = resolve_backend(backend)
    use_pallas = resolve_use_pallas(use_pallas, backend, mesh)
    configs = tuple(configs)
    if soa is None:
        soa = configs_to_soa(configs)
    if reports is None:
        cols = (sweep_synthesis_cache().synthesize(soa) if use_cache
                else synthesize_soa(soa))
    else:
        cols = _reports_to_cols(reports)
    wb = _workload_batch(workload)
    cfg, lay = _make_cfg_lay(soa, cols, wb)
    out = _run_kernel(cfg, lay, backend, mesh=mesh, outputs=outputs,
                      use_pallas=use_pallas)
    return BatchedSweep(workload=workload.name, configs=configs,
                        layer_names=wb.layer_names, macs=wb.arrays["macs"],
                        clock_ghz=cfg["clock_ghz"][:, 0],
                        area_mm2=cfg["area_mm2"][:, 0], arrays=out)


# ---------------------------------------------------------------------------
# Mixed-precision sweep: one execution mode per (config, layer)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _mode_tables() -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-PE-type (act_bits, weight_bits, mac_energy_pj) lookup tables,
    indexed by the canonical ``tuple(PEType)`` order."""
    from repro.core.pe import PEType, pe_spec
    specs = [pe_spec(t) for t in PEType]
    return (np.array([s.act_bits for s in specs], dtype=np.int64),
            np.array([s.weight_bits for s in specs], dtype=np.int64),
            np.array([s.mac_energy_pj for s in specs], dtype=np.float64))


def mixed_assign_cfg(cfg: dict, assign: np.ndarray) -> dict:
    """Replace the per-config scalar precision columns with per-layer ones.

    ``assign`` is an ``(N, L)`` int array of PE-type indices (canonical
    ``tuple(PEType)`` order).  Only ``act_bits`` / ``weight_bits`` /
    ``mac_energy_pj`` become ``(N, L)``; everything physical (array dims,
    scratchpad storage, clock, area, leakage) keeps its hardware value, so
    synthesis — and its confighash-keyed caches — see only the hardware
    config.
    """
    ab_t, wb_t, me_t = _mode_tables()
    a = np.asarray(assign, dtype=np.int64)
    if a.size and (a.min() < 0 or a.max() >= len(ab_t)):
        raise ValueError(
            f"assignment contains PE-type indices outside "
            f"[0, {len(ab_t)})")
    out = dict(cfg)
    out["act_bits"] = ab_t[a]
    out["weight_bits"] = wb_t[a]
    out["mac_energy_pj"] = me_t[a]
    return out


def check_assignment(soa: dict, assign: np.ndarray) -> None:
    """Raise ``ValueError`` unless every (config, layer) mode is executable
    on that config's hardware (operand widths fit the datapath)."""
    from repro.core.pe import PEType, mode_compat_matrix
    a = np.asarray(assign)
    n_types = len(tuple(PEType))
    if a.ndim != 2 or a.shape[0] != len(soa["pe_rows"]):
        raise ValueError(
            f"assignment shape {a.shape} does not match "
            f"{len(soa['pe_rows'])} configs")
    if a.min(initial=0) < 0 or a.max(initial=0) >= n_types:
        raise ValueError(
            f"assignment contains PE-type indices outside [0, {n_types})")
    ok = mode_compat_matrix()[soa["pe_type_idx"][:, None], a]
    if not ok.all():
        n_bad = int((~ok).sum())
        raise ValueError(
            f"{n_bad} (config, layer) mode assignment(s) are not "
            f"executable on their hardware PE type")


def _sweep_mixed(workload: Workload,
                 soa: dict[str, np.ndarray],
                 assign: np.ndarray,
                 cols: dict[str, np.ndarray] | None = None,
                 *,
                 use_cache: bool = True,
                 backend: str = "auto",
                 outputs: str = "aggregates",
                 mesh=None,
                 use_pallas: bool | None = None) -> dict[str, np.ndarray]:
    """Evaluate a batch of mixed-precision genomes in one fused pass.

    ``soa`` is the hardware half of the genome batch
    (:func:`repro.core.accelerator.soa_from_fields`), ``assign`` the
    ``(N, L)`` per-layer execution-mode half.  Synthesis runs on the
    hardware configs alone — through the digest-keyed sweep cache by
    default, so re-visited hardware (the common case in an evolutionary
    search) skips the flow entirely.  Returns the kernel output columns
    plus ``clock_ghz`` / ``area_mm2``; numpy results are bit-exact vs
    :func:`repro.core.dataflow.run_workload_mixed` row by row.
    """
    backend = resolve_backend(backend)
    use_pallas = resolve_use_pallas(use_pallas, backend, mesh)
    wb = _workload_batch(workload)
    assign = np.asarray(assign, dtype=np.int64)
    if assign.shape != (len(soa["pe_rows"]), len(wb)):
        raise ValueError(
            f"assignment shape {assign.shape} != "
            f"({len(soa['pe_rows'])} configs, {len(wb)} layers)")
    check_assignment(soa, assign)
    if cols is None:
        cols = (sweep_synthesis_cache().synthesize(soa) if use_cache
                else synthesize_soa(soa))
    cfg, lay = _make_cfg_lay(soa, cols, wb)
    cfg = mixed_assign_cfg(cfg, assign)
    out = dict(_run_kernel(cfg, lay, backend, mesh=mesh, outputs=outputs,
                           use_pallas=use_pallas))
    out["clock_ghz"] = cfg["clock_ghz"][:, 0]
    out["area_mm2"] = cfg["area_mm2"][:, 0]
    return out


# ---------------------------------------------------------------------------
# Multi-workload mixed-precision sweep: W workloads per genome batch, one
# fused kernel call, synthesis shared per hardware digest
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _workload_batch_many(wls: tuple[Workload, ...]
                         ) -> tuple[WorkloadBatch, tuple[tuple[int, int], ...]]:
    """Concatenate W workloads into one layer-axis batch plus the
    ``(start, end)`` column bounds of each workload's segment."""
    wbs = [_workload_batch(w) for w in wls]
    bounds: list[tuple[int, int]] = []
    start = 0
    for wb in wbs:
        bounds.append((start, start + len(wb)))
        start += len(wb)
    arrays = {k: np.concatenate([wb.arrays[k] for wb in wbs])
              for k in wbs[0].arrays}
    names = tuple(f"{wb.name}/{nm}" for wb in wbs for nm in wb.layer_names)
    combined = WorkloadBatch(name="+".join(wb.name for wb in wbs),
                             layer_names=names, arrays=arrays)
    return combined, tuple(bounds)


def _segment_aggregates(xp, totals: dict, cfg: dict, lay: dict,
                        bounds: tuple[tuple[int, int], ...],
                        exact: bool) -> dict:
    """Per-workload aggregate columns from the combined layer axis.

    Mirrors the single-workload kernel's aggregate block op-for-op on each
    ``[start, end)`` segment, so workload ``w``'s row is bit-identical
    (exact path) to running that workload through :func:`sweep_mixed`
    alone.  Returns ``{column: (W, N)}`` over :data:`AGGREGATE_OUTPUTS`.
    """
    f = np.float64 if exact else np.float32
    tc, ep = totals["total_cycles"], totals["energy_pj"]
    clk = cfg["clock_ghz"][:, 0]
    area = cfg["area_mm2"][:, 0]
    rows: dict[str, list] = {k: [] for k in AGGREGATE_OUTPUTS}
    for s, e in bounds:
        epw, tcw = ep[:, s:e], tc[:, s:e]
        if exact:
            energy_sum = xp.zeros(epw.shape[0], dtype=np.float64)
            for j in range(epw.shape[1]):
                energy_sum = energy_sum + epw[:, j]
            cycles_sum = xp.sum(tcw, axis=1)
        else:
            energy_sum = _kahan_sum_rows(xp, epw, f)
            cycles_sum = _kahan_sum_rows(xp, tcw, f)
        total_macs = xp.sum(lay["macs"][:, s:e])
        latency_s = cycles_sum / (clk * 1e9)
        energy_j = energy_sum / 1e12
        throughput_gmacs = total_macs / latency_s / 1e9
        perf_per_area = throughput_gmacs / area
        for k, v in zip(AGGREGATE_OUTPUTS,
                        (cycles_sum, energy_sum, latency_s, energy_j,
                         throughput_gmacs, perf_per_area)):
            rows[k].append(v)
    return {k: xp.stack(v, axis=0) for k, v in rows.items()}


_JAX_MANY_KERNELS: dict = {}


def _mesh_shards(mesh) -> int:
    """Config-axis shard count implied by a ``mesh=`` argument: ``None``
    -> 1, an int -> itself (the numpy backend's simulated shard count),
    a ``jax.sharding.Mesh`` -> its device count.  Pure attribute access —
    never imports jax."""
    if mesh is None:
        return 1
    if isinstance(mesh, int):
        if mesh < 1:
            raise ValueError(f"mesh shard count must be >= 1, got {mesh}")
        return mesh
    return int(mesh.devices.size)


def _require_jax_mesh(mesh) -> None:
    if isinstance(mesh, int):
        raise ValueError(
            "backend='jax' needs a jax.sharding.Mesh for mesh=, not "
            "an int shard count (see repro.launch.mesh.make_sweep_mesh)")


def _mesh_cache_key(mesh):
    """Key a mesh by value (axes + device ids), not identity: fresh but
    equivalent meshes reuse one compiled kernel instead of growing the
    jit caches (and pinning executables) without bound."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), mesh.devices.shape,
            tuple(d.id for d in mesh.devices.flat))


def _pad_rows(arrays: dict, pad: int) -> dict:
    """Repeat each array's last row ``pad`` times (row-local kernels make
    the padded rows valid throwaway work; callers slice them back off)."""
    if pad <= 0:
        return arrays
    return {k: np.concatenate([v, v[-1:].repeat(pad, axis=0)])
            for k, v in arrays.items()}


def get_jax_many_kernel(bounds: tuple[tuple[int, int], ...], mesh=None):
    """Jit-compiled multi-workload kernel, cached per (x64-mode, segment
    bounds, mesh): the layer mapping runs once over the concatenated layer
    axis and the per-workload reductions happen inside the same jit, so
    XLA fuses everything into one dispatch and DCEs the (N, L)
    intermediates.  With ``mesh`` the config axis is sharded across the
    mesh's devices via ``shard_map`` — every (config, layer) expression
    and the per-workload segment reductions are row-local, so each device
    reduces its own config shard independently and the stacked ``(W, n)``
    aggregate columns concatenate along the config axis with no
    cross-device collectives at all."""
    import jax
    import jax.numpy as jnp

    exact = bool(jax.config.read("jax_enable_x64"))
    key = (exact, bounds, _mesh_cache_key(mesh))
    fn = _JAX_MANY_KERNELS.get(key)
    if fn is None:
        def kernel(cfg, lay):
            totals = _sweep_kernel(jnp, cfg, lay, exact=exact,
                                   outputs="layer_totals")
            return _segment_aggregates(jnp, totals, cfg, lay, bounds,
                                       exact=exact)

        if mesh is None:
            fn = jax.jit(kernel)
        else:
            from repro.launch.mesh import compat_shard_map
            P = jax.sharding.PartitionSpec

            def sharded(cfg, lay):
                cfg_specs = {k: P("configs", None) for k in cfg}
                lay_specs = {k: P(None, None) for k in lay}
                # every output is a (W, n_local) stack of per-workload
                # aggregates — config-major on axis 1
                out_specs = {k: P(None, "configs")
                             for k in AGGREGATE_OUTPUTS}
                return compat_shard_map(
                    kernel, mesh=mesh,
                    in_specs=(cfg_specs, lay_specs),
                    out_specs=out_specs)(cfg, lay)

            fn = jax.jit(sharded)
        _JAX_MANY_KERNELS[key] = fn
    return fn, exact


def _sweep_mixed_many(workloads: Sequence[Workload],
                      soa: dict[str, np.ndarray],
                      assigns: Sequence[np.ndarray],
                      cols: dict[str, np.ndarray] | None = None,
                      *,
                      use_cache: bool = True,
                      backend: str = "auto",
                      mesh=None,
                      use_pallas: bool | None = None
                      ) -> dict[str, np.ndarray]:
    """Evaluate one genome batch against W workloads in one fused pass.

    ``soa`` is the shared hardware half (N configs); ``assigns`` holds one
    ``(N, L_w)`` per-layer mode matrix per workload — the per-workload
    precision assignment of the QUIDAM co-exploration setting.  The W
    workloads' layer axes are concatenated into a single ``(N, sum L_w)``
    kernel evaluation (layers are independent under the row-stationary
    mapping), then reduced per workload segment, so the whole call costs
    one synthesis pass + one kernel dispatch regardless of W.  Synthesis
    runs on the hardware configs alone through the digest-keyed sweep
    cache by default — revisited hardware (the common case in a search)
    skips the flow entirely, keeping W-workload evaluation ~O(1 synthesis)
    per hardware config.

    Returns ``{column: (W, N)}`` over :data:`AGGREGATE_OUTPUTS` plus
    ``clock_ghz`` / ``area_mm2`` as ``(N,)``.  Workload ``w``'s row is
    bit-identical (numpy) to :func:`sweep_mixed` on that workload alone;
    jax agrees to the usual ~1e-7 relative parity.

    ``mesh`` shards the genome (config) axis: under jax a
    ``jax.sharding.Mesh`` from :func:`repro.launch.mesh.make_sweep_mesh`
    spreads the batch across devices via ``shard_map`` (the batch is
    padded to a device-count multiple and sliced back); under numpy an
    int (or a mesh, whose device count is taken) splits the batch into
    that many contiguous shards evaluated independently — bit-identical
    to the unsharded path, used to test shard-boundary semantics without
    multiple devices.
    """
    backend = resolve_backend(backend)
    use_pallas = resolve_use_pallas(use_pallas, backend, mesh)
    wls = tuple(workloads)
    if not wls:
        raise ValueError("sweep_mixed_many needs at least one workload")
    combined, bounds = _workload_batch_many(wls)
    n = len(soa["pe_rows"])
    assigns = [np.asarray(a, dtype=np.int64) for a in assigns]
    if len(assigns) != len(wls):
        raise ValueError(
            f"{len(assigns)} assignment matrices for {len(wls)} workloads")
    for (s, e), a, wl in zip(bounds, assigns, wls):
        if a.shape != (n, e - s):
            raise ValueError(
                f"assignment shape {a.shape} != ({n} configs, "
                f"{e - s} layers) for workload {wl.name!r}")
    assign_all = np.concatenate(assigns, axis=1)
    check_assignment(soa, assign_all)
    if cols is None:
        cols = (sweep_synthesis_cache().synthesize(soa) if use_cache
                else synthesize_soa(soa))
    cfg, lay = _make_cfg_lay(soa, cols, combined)
    cfg = mixed_assign_cfg(cfg, assign_all)
    if backend == "jax" and use_pallas:
        from repro.kernels.sweep_kernel import sweep_aggregates_pallas
        out = {k: np.asarray(v)
               for k, v in sweep_aggregates_pallas(
                   cfg, lay, bounds=bounds).items()}
    elif backend == "jax":
        _require_jax_mesh(mesh)
        fn, exact = get_jax_many_kernel(bounds, mesh)
        jcfg, jlay = _to_jax_inputs(cfg, lay, exact)
        if mesh is not None:
            jcfg = _pad_rows(jcfg, -n % _mesh_shards(mesh))
        out = {k: np.asarray(v)[:, :n] for k, v in fn(jcfg, jlay).items()}
    else:
        shards = min(_mesh_shards(mesh), max(1, n))
        if shards == 1:
            totals = _sweep_kernel(np, cfg, lay, outputs="layer_totals")
            out = _segment_aggregates(np, totals, cfg, lay, bounds,
                                      exact=True)
        else:
            # simulated sharding: contiguous config-axis splits through
            # the same kernel + segment reduction, concatenated back —
            # every expression is row-local, so this is bit-identical to
            # the single-shard path by construction
            parts = []
            splits = np.array_split(np.arange(n), shards)
            for idx in splits:
                if len(idx) == 0:
                    continue
                cfg_s = {k: v[idx] for k, v in cfg.items()}
                totals = _sweep_kernel(np, cfg_s, lay,
                                       outputs="layer_totals")
                parts.append(_segment_aggregates(np, totals, cfg_s, lay,
                                                 bounds, exact=True))
            out = {k: np.concatenate([p[k] for p in parts], axis=1)
                   for k in AGGREGATE_OUTPUTS}
    out["clock_ghz"] = cfg["clock_ghz"][:, 0]
    out["area_mm2"] = cfg["area_mm2"][:, 0]
    return out


# ---------------------------------------------------------------------------
# Streamed chunked sweep with running Pareto-front reduction
# ---------------------------------------------------------------------------

# per-point metric columns retained for Pareto survivors
_FRONT_METRICS = ("perf_per_area", "energy_j", "latency_s",
                  "throughput_gmacs")
_SOA_ID_FIELDS = ("pe_type_idx", "pe_rows", "pe_cols", "ifmap_spad",
                  "filter_spad", "psum_spad", "glb_kb", "dram_bw_gbps",
                  "clock_cap")


@dataclasses.dataclass
class ChunkedSweep:
    """Result of a streamed sweep: running totals + the Pareto frontier
    (maximize perf/area, minimize energy), *not* the full point set."""

    workload: str
    backend: str
    n_configs: int
    n_chunks: int
    front_soa: dict[str, np.ndarray]      # identity fields of survivors
    front_metrics: dict[str, np.ndarray]  # _FRONT_METRICS columns
    synthesis_cache: PersistentSynthesisCache | None = None
    # stage accounting from the streamed driver: wall_s (whole stream),
    # synth_s (host synthesis + feed pull), kernel_wait_s (time blocked on
    # kernel results — under the overlapped pipeline this shrinks toward
    # zero as synthesis of chunk i+1 hides behind the kernel on chunk i),
    # overlap (whether the two-stage pipeline was active)
    timings: dict | None = None

    @property
    def front_size(self) -> int:
        return len(self.front_metrics["energy_j"])

    def front_configs(self) -> list[AcceleratorConfig]:
        """Materialize the frontier as configs, sorted by energy."""
        order = np.argsort(self.front_metrics["energy_j"], kind="stable")
        return soa_to_configs(self.front_soa, order)

    def front_points(self) -> list[dict]:
        order = np.argsort(self.front_metrics["energy_j"], kind="stable")
        cfgs = soa_to_configs(self.front_soa, order)
        return [
            dict({m: float(self.front_metrics[m][i])
                  for m in _FRONT_METRICS}, config=cfg)
            for i, cfg in zip(order, cfgs)]


def _as_soa_chunks(chunks, chunk_size: int) -> Iterator[dict]:
    """Normalize a config feed — SoA dicts, config sequences, or a flat
    config generator — into bounded-size SoA chunks."""
    pending: list[AcceleratorConfig] = []
    if isinstance(chunks, dict):        # single SoA
        chunks = (chunks,)
    for item in chunks:
        if isinstance(item, dict):
            if pending:
                yield configs_to_soa(tuple(pending))
                pending.clear()
            n = len(item["pe_rows"])
            for s in range(0, n, chunk_size):
                yield {k: v[s:s + chunk_size] for k, v in item.items()}
        elif isinstance(item, AcceleratorConfig):
            pending.append(item)
            if len(pending) >= chunk_size:
                yield configs_to_soa(tuple(pending))
                pending.clear()
        else:                           # a sequence of configs
            for cfg in item:
                pending.append(cfg)
                if len(pending) >= chunk_size:
                    yield configs_to_soa(tuple(pending))
                    pending.clear()
    if pending:
        yield configs_to_soa(tuple(pending))


class ChunkDeadlineExceeded(RuntimeError):
    """A dispatched chunk failed to produce results within the watchdog
    deadline (``chunk_deadline_s``); the stream cancels it and recomputes
    the chunk serially on the exact numpy kernel."""


class ChunkCancelled(RuntimeError):
    """An in-flight chunk's worker future was cancelled — the watchdog
    replaced a zombie executor and dropped its queue.  The stream
    recomputes the chunk serially (no deadline warning: the chunk itself
    did nothing wrong)."""


class _AbandonedFinalizers:
    """Accounting for jax materialize threads the watchdog gave up on.

    A wedged device can pin a chunk's buffers inside ``np.asarray`` for
    as long as it stays wedged — Python cannot kill the thread — but an
    abandoned thread must (a) never park its materialized result in a
    long-lived box and (b) be observable, so repeated watchdog fires show
    up as a bounded ``live`` count instead of silent memory growth.
    """

    def __init__(self):
        import threading
        self._lock = threading.Lock()
        self.abandoned = 0      # watchdog timeouts that orphaned a thread
        self.completed = 0      # orphaned threads that finished + dropped

    def abandon(self) -> None:
        with self._lock:
            self.abandoned += 1
        obs_metrics.get_registry().inc("sweep.abandoned_finalizers")

    def finish(self) -> None:
        with self._lock:
            self.completed += 1

    @property
    def live(self) -> int:
        """Threads still wedged on a materialization (buffers pinned)."""
        with self._lock:
            return self.abandoned - self.completed


#: process-wide abandoned-materialization ledger (tests assert ``live``
#: returns to 0 once a slow — not wedged — device catches up)
abandoned_finalizers = _AbandonedFinalizers()


def _dispatch_chunk(cfg: dict, lay: dict, backend: str, mesh,
                    chunk_size: int, n: int, executor,
                    use_pallas: bool = False):
    """Launch the aggregates kernel for one chunk without blocking.

    Returns a ``finalize(timeout=None)`` producing the host-side ``(n,)``
    aggregate columns.  Under jax the jit call dispatches asynchronously
    and ``finalize`` materializes the device buffers; under numpy with an
    ``executor`` the kernel runs on a worker thread (numpy ufuncs release
    the GIL) so the caller can synthesize the next chunk meanwhile.

    ``timeout`` (seconds) bounds the wait and raises
    :class:`ChunkDeadlineExceeded` on expiry — the watchdog hook of the
    streamed driver.  The plain numpy path (no executor) runs
    synchronously on call, so a deadline cannot preempt it; that path *is*
    the serial fallback the watchdog re-dispatches onto.
    """
    if backend == "jax":
        # pad the tail chunk to the steady-state shape: one jit trace
        # serves the whole stream (padded rows are sliced off below)
        cfg = _pad_rows(cfg, (chunk_size - n % chunk_size) % chunk_size)
        if use_pallas and mesh is None:
            from repro.kernels.sweep_kernel import sweep_aggregates_pallas
            out = sweep_aggregates_pallas(cfg, lay)    # async dispatch
        else:
            fn, exact = get_jax_kernel(mesh, "aggregates")
            jcfg, jlay = _to_jax_inputs(cfg, lay, exact)
            if mesh is not None:
                jcfg = _pad_rows(jcfg,
                                 -len(jcfg["pe_rows"]) % _mesh_shards(mesh))
            out = fn(jcfg, jlay)                       # async dispatch

        def finalize(timeout: float | None = None):
            if timeout is None:
                return {k: np.asarray(v)[:n] for k, v in out.items()}
            # jax materialization has no native timeout: bound it with a
            # daemon-thread join so a wedged device cannot hang the stream
            import threading
            box: dict = {}
            lock = threading.Lock()

            def _materialize(buffers):
                try:
                    res = {k: np.asarray(v)[:n]
                           for k, v in buffers.items()}
                    exc = None
                except BaseException as e:      # surfaced to the caller
                    res, exc = None, e
                buffers = None      # noqa: F841 — drop the device refs
                with lock:
                    if box.get("abandoned"):
                        # the watchdog gave up on this chunk while we
                        # were blocked: discard the result here instead
                        # of parking host+device copies in `box` for the
                        # rest of the process, and mark the orphan done
                        abandoned_finalizers.finish()
                        return
                    if exc is not None:
                        box["exc"] = exc
                    else:
                        box["out"] = res

            th = threading.Thread(target=_materialize, args=(out,),
                                  daemon=True)
            th.start()
            th.join(timeout)
            with lock:
                if "out" not in box and "exc" not in box:
                    # timed out: flag the orphan so its eventual
                    # completion drops the buffers instead of keeping
                    # them reachable through the box
                    box["abandoned"] = True
                    abandoned_finalizers.abandon()
                    raise ChunkDeadlineExceeded(
                        f"jax chunk did not materialize within "
                        f"{timeout}s")
            if "exc" in box:
                raise box["exc"]
            return box["out"]

        return finalize
    kernel = functools.partial(_sweep_kernel, np, cfg, lay,
                               outputs="aggregates")
    if executor is not None:
        fut = executor.submit(kernel)

        def finalize(timeout: float | None = None):
            from concurrent.futures import CancelledError
            from concurrent.futures import TimeoutError as _FutTimeout
            try:
                return fut.result(timeout)
            except _FutTimeout:
                fut.cancel()   # a running kernel cannot be interrupted,
                #                but a still-queued one is dropped
                raise ChunkDeadlineExceeded(
                    f"chunk kernel still running after {timeout}s"
                ) from None
            except CancelledError:
                # the watchdog tore down the executor this chunk was
                # queued on (zombie-worker recovery) — not this chunk's
                # own deadline
                raise ChunkCancelled(
                    "chunk worker future was cancelled by executor "
                    "replacement") from None

        return finalize
    return lambda timeout=None: kernel()


def _sweep_chunked(workload: Workload,
                   configs: Iterable,
                   *,
                   backend: str = "auto",
                   chunk_size: int = 32768,
                   use_cache: bool = False,
                   cache: PersistentSynthesisCache | str | None = None,
                   save_cache: bool = True,
                   mesh=None,
                   overlap: bool = True,
                   prefetch_depth: int = 2,
                   use_pallas: bool | None = None,
                   checkpoint=None,
                   fail_at: dict[int, int] | None = None,
                   chunk_deadline_s: float | None = None,
                   degrade_on_failure: bool = True) -> ChunkedSweep:
    """Stream an arbitrary-size config feed through the sweep engine in
    bounded memory, keeping only running aggregates + the Pareto front.

    ``configs`` may be SoA dicts (e.g. from
    :func:`repro.core.accelerator.design_space_soa` — the fast path, no
    per-config objects), sequences of :class:`AcceleratorConfig`, or a
    flat config generator.  ``cache`` (a
    :class:`~repro.core.synthesis.PersistentSynthesisCache` or an npz
    path) persists synthesis results across runs, so a cold re-sweep of a
    seen space skips synthesis; ``use_cache`` instead routes through the
    in-process array cache.

    ``overlap=True`` (default) runs the stream as a **depth-k prefetch
    pipeline**: up to ``prefetch_depth`` chunks (default 2 — the classic
    two-stage overlap) are dispatched and in flight at once, their
    ``finalize`` handles held in a bounded deque, while the host pulls
    and synthesizes the next chunk; the running Pareto reduction drains
    the deque in FIFO order.  Chunks are synthesized, reduced, and
    cache-inserted in exactly the stream order of the serial path at
    *every* depth, so results, resume points, and
    :class:`~repro.core.synthesis.PersistentSynthesisCache` hit/miss
    accounting are identical (asserted in
    ``tests/test_chunked_pipeline.py``); ``overlap=False`` keeps the
    fully serial per-chunk loop (equivalent to ``prefetch_depth=1``).
    Depths beyond 2 only pay off once the kernel stage outruns host
    synthesis — e.g. the Pallas sweep kernel on a real accelerator
    (``use_pallas=True``; ``None`` auto-engages it exactly there, see
    :func:`resolve_use_pallas`).

    Fault tolerance (``tests/test_dse_checkpoint.py``):

    * ``checkpoint`` — a duck-typed snapshotter (see
      :class:`repro.runtime.dse_checkpoint.SweepCheckpointer`) with
      ``restore() -> snap | None``, ``should_save(cursor) -> bool`` and
      ``save(cursor, n_total, front_soa, front_metrics, cache_state)``.
      On entry the newest valid snapshot restores the stream cursor,
      running front, and cache accounting; already-reduced chunks are
      pulled from the feed but not synthesized, so a resumed run's front
      and hit/miss counters are bit-identical to an uninterrupted one.
    * ``fail_at`` — ``{chunk_index: n_times}`` deterministic
      :class:`~repro.runtime.fault_tolerance.InjectedFailure` injection at
      chunk boundaries (decremented in place so a shared dict fails each
      boundary only ``n_times`` across restarts).
    * ``chunk_deadline_s`` — watchdog: a dispatched chunk exceeding the
      deadline is cancelled and recomputed serially on the exact numpy
      kernel (counted in ``timings["watchdog_redispatches"]``).
    * ``degrade_on_failure`` — a jax failure mid-stream (dispatch or
      materialization) degrades the remaining stream to numpy with a
      warning instead of losing the run; stream order and cache
      accounting are preserved (``timings["degraded"]``).
    """
    import sys
    import time
    import warnings
    from collections import deque
    backend = resolve_backend(backend)
    if backend == "jax":
        _require_jax_mesh(mesh)
    use_pallas = resolve_use_pallas(use_pallas, backend, mesh)
    if int(prefetch_depth) < 1:
        raise ValueError(
            f"prefetch_depth must be >= 1, got {prefetch_depth}")
    # depth 1 <=> the fully serial loop; overlap=False forces it
    depth = int(prefetch_depth) if overlap else 1
    if isinstance(cache, (str, bytes)) or hasattr(cache, "__fspath__"):
        cache = PersistentSynthesisCache(cache)
    wb = _workload_batch(workload)
    fail_at = fail_at if fail_at is not None else {}

    front_soa: dict[str, np.ndarray] | None = None
    front_metrics: dict[str, np.ndarray] | None = None
    n_total = 0
    n_chunks = 0
    resume_cursor = 0
    if checkpoint is not None:
        snap = checkpoint.restore()
        if snap is not None:
            resume_cursor = int(snap["cursor"])
            if resume_cursor > 0:
                n_total = int(snap["n_total"])
                n_chunks = resume_cursor
                front_soa = snap["front_soa"]
                front_metrics = snap["front_metrics"]
                if cache is not None \
                        and snap.get("cache_state") is not None:
                    cache.import_state(snap["cache_state"])
    t_wall = time.perf_counter()
    timings = {"overlap": bool(overlap), "prefetch_depth": depth,
               "use_pallas": bool(use_pallas), "wall_s": 0.0,
               "synth_s": 0.0, "kernel_wait_s": 0.0, "kernel_busy_s": 0.0,
               "watchdog_redispatches": 0, "executor_replacements": 0,
               "cancelled_recomputes": 0, "abandoned_finalizers": 0,
               "degraded": False}
    _reg = obs_metrics.get_registry()
    root_span = obs_trace.span_start(
        "sweep_chunked", workload=workload.name, backend=backend,
        chunk_size=int(chunk_size), overlap=bool(overlap),
        prefetch_depth=depth, use_pallas=bool(use_pallas),
        resume_cursor=resume_cursor)
    n_total0, n_chunks0 = n_total, n_chunks   # restored-from-snapshot base
    telemetry_flushed = False

    def _flush_telemetry(status: str) -> None:
        # Finalize wall_s + registry totals exactly once per attempt —
        # on the success path after the terminal saves (pre-telemetry
        # semantics), and from the error path's finally so an
        # InjectedFailure / crashed attempt still reports its time and
        # the registry sums stay consistent across resumed runs (only
        # work done *this* attempt is counted, not restored totals).
        nonlocal telemetry_flushed
        if telemetry_flushed:
            return
        telemetry_flushed = True
        timings["wall_s"] = time.perf_counter() - t_wall
        _reg.inc("sweep.chunks", n_chunks - n_chunks0)
        _reg.inc("sweep.configs", n_total - n_total0)
        _reg.inc("sweep.wall_s", timings["wall_s"])
        _reg.inc("sweep.synth_s", timings["synth_s"])
        _reg.inc("sweep.kernel_wait_s", timings["kernel_wait_s"])
        _reg.inc("sweep.kernel_busy_s", timings["kernel_busy_s"])
        _reg.set("sweep.prefetch_depth", depth)
        if status != "ok":
            _reg.inc("sweep.failures")
        if timings["wall_s"] > 0:
            _reg.set("sweep.configs_per_s",
                     (n_total - n_total0) / timings["wall_s"])
        obs_trace.span_end(root_span, status=status,
                           configs=n_total, chunks=n_chunks,
                           wall_s=timings["wall_s"])

    def reduce_chunk(soa: dict, n: int, out: dict) -> None:
        nonlocal front_soa, front_metrics
        perf = np.asarray(out["perf_per_area"], dtype=np.float64)[:n]
        energy = np.asarray(out["energy_j"], dtype=np.float64)[:n]
        # prefilter: only the chunk's own frontier can join the global one
        local = pareto_mask(perf, energy)
        idx = np.nonzero(local)[0]
        cand_soa = {k: soa[k][idx] for k in _SOA_ID_FIELDS}
        cand_metrics = {m: np.asarray(out[m], dtype=np.float64)[:n][idx]
                        for m in _FRONT_METRICS}
        if front_soa is None:
            front_soa, front_metrics = cand_soa, cand_metrics
        else:
            front_soa = {k: np.concatenate([front_soa[k], cand_soa[k]])
                         for k in _SOA_ID_FIELDS}
            front_metrics = {
                m: np.concatenate([front_metrics[m], cand_metrics[m]])
                for m in _FRONT_METRICS}
        keep = pareto_mask(front_metrics["perf_per_area"],
                           front_metrics["energy_j"])
        front_soa = {k: v[keep] for k, v in front_soa.items()}
        front_metrics = {m: v[keep] for m, v in front_metrics.items()}

    executor = None

    def _ensure_executor() -> None:
        nonlocal executor
        if overlap and backend == "numpy" and executor is None:
            from concurrent.futures import ThreadPoolExecutor
            executor = ThreadPoolExecutor(max_workers=1)

    def _replace_executor() -> None:
        # zombie-worker recovery: fut.cancel() cannot interrupt a kernel
        # that is already running, so after a watchdog fire the old
        # executor's single worker is still occupied — every later chunk
        # would queue behind it and cascade into its own deadline.  Tear
        # the executor down (without waiting on the zombie) and start a
        # fresh one; still-queued futures of other in-flight chunks are
        # cancelled and surface as ChunkCancelled at their drain.
        nonlocal executor
        if executor is None:
            return
        executor.shutdown(wait=False, cancel_futures=True)
        executor = None
        timings["executor_replacements"] += 1
        _reg.inc("sweep.executor_replacements")
        _ensure_executor()

    _ensure_executor()

    def _degrade(dcfg: dict, dlay: dict, exc: BaseException,
                 what: str) -> dict:
        # jax died mid-stream: warn, recompute this chunk on the exact
        # numpy kernel, and switch the remaining stream to numpy — the
        # run survives instead of losing hours of reduced front
        nonlocal backend
        warnings.warn(
            f"jax backend failed during chunk {what} "
            f"({type(exc).__name__}: {exc}); degrading stream to numpy "
            f"for this and all remaining chunks", RuntimeWarning,
            stacklevel=3)
        backend = "numpy"
        timings["degraded"] = True
        _reg.inc("sweep.degraded")
        _ensure_executor()
        return _sweep_kernel(np, dcfg, dlay, outputs="aggregates")

    # FIFO of in-flight chunks, each:
    # (soa, n, cfg, lay, finalize, backend_at_dispatch, save_info,
    #  cache_state, chunk_index, kernel_span, t_dispatch)
    pending: deque = deque()

    def drain_one() -> None:
        if not pending:
            return
        (psoa, pn, pcfg, play, pfin, pbackend, psave, pcache,
         pci, kspan, tdisp) = pending.popleft()
        t0 = time.perf_counter()
        kstatus = "ok"
        try:
            out = pfin(timeout=chunk_deadline_s)
        except ChunkDeadlineExceeded:
            warnings.warn(
                f"chunk kernel exceeded the {chunk_deadline_s:.3g}s "
                f"watchdog deadline; cancelled and re-dispatched "
                f"serially on the numpy kernel", RuntimeWarning,
                stacklevel=3)
            timings["watchdog_redispatches"] += 1
            _reg.inc("sweep.watchdog_redispatches")
            if pbackend == "jax":
                timings["abandoned_finalizers"] += 1
            kstatus = "watchdog"
            # the deadlined worker (numpy path) is a zombie occupying
            # the 1-worker executor — replace it so the next dispatch
            # doesn't queue behind it and cascade-deadline
            _replace_executor()
            with obs_trace.span("sweep.watchdog_recompute", chunk=pci):
                out = _sweep_kernel(np, pcfg, play, outputs="aggregates")
        except ChunkCancelled:
            # this chunk was queued on an executor the watchdog tore
            # down; recompute serially, no deadline of its own
            timings["cancelled_recomputes"] += 1
            _reg.inc("sweep.cancelled_recomputes")
            kstatus = "cancelled"
            with obs_trace.span("sweep.cancelled_recompute", chunk=pci):
                out = _sweep_kernel(np, pcfg, play, outputs="aggregates")
        except Exception as exc:
            if pbackend != "jax" or not degrade_on_failure:
                obs_trace.span_end(kspan, status="error")
                raise
            kstatus = "degraded"
            out = _degrade(pcfg, play, exc, "materialization")
        now = time.perf_counter()
        timings["kernel_wait_s"] += now - t0
        # dispatch -> finalize span of this chunk: the kernel stage's
        # busy time (overlapping in-flight chunks each count their own)
        timings["kernel_busy_s"] += now - tdisp
        obs_trace.span_end(kspan, status=kstatus)
        with obs_trace.span("sweep.reduce", chunk=pci, n=pn):
            reduce_chunk(psoa, pn, out)
        if psave is not None:
            with obs_trace.span("sweep.checkpoint", cursor=psave[0]):
                checkpoint.save(cursor=psave[0], n_total=psave[1],
                                front_soa=front_soa,
                                front_metrics=front_metrics,
                                cache_state=pcache)

    try:
        feed = _as_soa_chunks(configs, chunk_size)
        ci = -1                 # absolute index of the chunk being pulled
        while True:
            t0 = time.perf_counter()
            with obs_trace.span("sweep.pull"):
                soa = next(feed, None)
            if soa is not None:
                n = len(soa["pe_rows"])
                if n == 0:
                    continue
                ci += 1
                if ci < resume_cursor:
                    # reduced before the restart: advance the feed without
                    # synthesizing — the snapshot already carries this
                    # chunk's rows, front contribution, and cache
                    # accounting
                    continue
                if fail_at.get(ci, 0) > 0:
                    fail_at[ci] -= 1
                    from repro.runtime.fault_tolerance import \
                        InjectedFailure
                    raise InjectedFailure(
                        f"injected failure at chunk boundary {ci}")
                n_total += n
                n_chunks += 1
                # stage 1 (host): synthesis — in stream order, so cache
                # lookups/inserts match the serial path row for row
                with obs_trace.span("sweep.synthesize", chunk=ci, n=n):
                    if cache is not None:
                        cols = cache.synthesize(soa)
                    elif use_cache:
                        cols = sweep_synthesis_cache().synthesize(soa)
                    else:
                        cols = synthesize_soa(soa)
                    cfg, lay = _make_cfg_lay(soa, cols, wb)
                # synth_s keeps its pre-telemetry meaning: host stage-1
                # time including the feed pull (t0 is read before next())
                timings["synth_s"] += time.perf_counter() - t0
                save_info = cache_state = None
                if checkpoint is not None \
                        and checkpoint.should_save(ci + 1):
                    # capture the cache *now*, while its rows and counters
                    # cover exactly chunks 0..ci — under the overlapped
                    # pipeline chunk ci+1 is synthesized before chunk ci's
                    # snapshot is written, and letting its rows leak into
                    # the snapshot would turn its re-synthesis after a
                    # resume into cache hits (accounting drift)
                    save_info = (ci + 1, n_total)
                    if cache is not None:
                        cache_state = cache.export_state()
                # stage 2 (device / worker thread): dispatch the kernel
                kspan = obs_trace.span_start("sweep.kernel", chunk=ci,
                                             n=n, backend=backend)
                try:
                    with obs_trace.span("sweep.dispatch", chunk=ci):
                        finalize = _dispatch_chunk(cfg, lay, backend,
                                                   mesh, chunk_size, n,
                                                   executor, use_pallas)
                except Exception as exc:
                    if backend != "jax" or not degrade_on_failure:
                        obs_trace.span_end(kspan, status="error")
                        raise
                    out_now = _degrade(cfg, lay, exc, "dispatch")
                    finalize = lambda timeout=None, o=out_now: o  # noqa: E731
                pending.append((soa, n, cfg, lay, finalize, backend,
                                save_info, cache_state, ci, kspan,
                                time.perf_counter()))
                _reg.observe("sweep.inflight", len(pending))
                # bounded prefetch: drain FIFO until at most depth-1
                # chunks stay in flight behind the next synthesis
                while len(pending) >= depth:
                    drain_one()
            else:
                while pending:  # feed exhausted: drain the queue dry
                    drain_one()
                break
    finally:
        if executor is not None:
            executor.shutdown(wait=True)
        if sys.exc_info()[0] is not None:
            _flush_telemetry("error")

    if front_soa is None:
        front_soa = {k: np.empty(0, dtype=np.int64)
                     for k in _SOA_ID_FIELDS}
        front_metrics = {m: np.empty(0, dtype=np.float64)
                         for m in _FRONT_METRICS}
    if checkpoint is not None:
        # terminal snapshot: resuming a completed run restores the full
        # front and skips the whole feed (idempotent)
        with obs_trace.span("sweep.checkpoint", cursor=n_chunks,
                            terminal=True):
            checkpoint.save(
                cursor=n_chunks, n_total=n_total, front_soa=front_soa,
                front_metrics=front_metrics,
                cache_state=cache.export_state() if cache is not None
                else None)
    if cache is not None and save_cache and cache.path is not None:
        cache.save()
    _flush_telemetry("ok")
    return ChunkedSweep(workload=workload.name, backend=backend,
                        n_configs=n_total, n_chunks=n_chunks,
                        front_soa=front_soa, front_metrics=front_metrics,
                        synthesis_cache=cache, timings=timings)


def _pareto_mask_bcast(perf: np.ndarray, energy: np.ndarray,
                       chunk: int) -> np.ndarray:
    """O(n^2) chunked-broadcast dominance test (reference for the sorted
    algorithm; memory stays at ``chunk * n`` bools)."""
    n = perf.shape[0]
    keep = np.ones(n, dtype=bool)
    for s in range(0, n, chunk):
        p = perf[s:s + chunk, None]
        e = energy[s:s + chunk, None]
        dominated = ((perf[None, :] >= p) & (energy[None, :] <= e)
                     & ((perf[None, :] > p) | (energy[None, :] < e))).any(1)
        keep[s:s + chunk] = ~dominated
    return keep


def _pareto_mask_sorted(perf: np.ndarray,
                        energy: np.ndarray) -> np.ndarray:
    """O(n log n) dominance test: sort by (energy asc, perf desc), then a
    point survives iff it has its energy-group's max perf and strictly
    beats the running perf max of all lower-energy groups.  Tie semantics
    identical to the broadcast test (duplicates both survive)."""
    n = perf.shape[0]
    order = np.lexsort((-perf, energy))
    ps, es = perf[order], energy[order]
    new_group = np.empty(n, dtype=bool)
    new_group[0] = True
    new_group[1:] = es[1:] != es[:-1]
    # group max perf = first row of the group (perf sorted desc in-group)
    group_id = np.cumsum(new_group) - 1
    group_max = ps[new_group]                       # (G,)
    cummax = np.maximum.accumulate(group_max)
    prev_best = np.full(len(group_max), -np.inf)
    prev_best[1:] = cummax[:-1]                     # strictly lower energy
    survive_sorted = (ps == group_max[group_id]) \
        & (ps > prev_best[group_id])
    keep = np.empty(n, dtype=bool)
    keep[order] = survive_sorted
    return keep


def pareto_mask(perf: np.ndarray, energy: np.ndarray,
                chunk: int = 1024) -> np.ndarray:
    """Boolean mask of non-dominated points for (maximize perf, minimize
    energy).

    Small batches use the chunked-broadcast dominance test; large ones
    switch to the sort-based O(n log n) algorithm (bit-identical output,
    asserted against each other in tests) so the streamed sweep's running
    reduction stays cheap at 1M-config scale.
    """
    perf = np.asarray(perf, dtype=np.float64)
    energy = np.asarray(energy, dtype=np.float64)
    if perf.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    if perf.shape[0] <= 2048:
        return _pareto_mask_bcast(perf, energy, chunk)
    return _pareto_mask_sorted(perf, energy)


# ---------------------------------------------------------------------------
# Deprecated public entry points (one-release shims)
#
# The kernel-level sweep API is consolidated behind
# ``repro.core.dse.run(ExploreSpec)``: config-batch sweeps are
# ``ExploreSpec.single(..., outputs="sweep")`` (add ``chunk_size=`` for the
# streamed engine), and mixed-precision genome evaluation lives in
# ``repro.explore.search.Evaluator`` (driven by ``ExploreSpec.mixed()`` /
# ``.many()``).  These wrappers forward verbatim and warn; in-repo code
# must call the private implementations (CI runs the test suite with
# ``error::DeprecationWarning:repro``).
# ---------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    import warnings
    warnings.warn(
        f"{old} is deprecated; use {new}",
        DeprecationWarning, stacklevel=3)


def sweep_workload(*args, **kwargs) -> BatchedSweep:
    """Deprecated: use ``repro.core.dse.run`` with
    ``ExploreSpec.single(..., outputs="sweep")``."""
    _deprecated("repro.core.dse_batch.sweep_workload",
                'repro.core.dse.run(ExploreSpec.single(..., '
                'outputs="sweep"))')
    return _sweep_workload(*args, **kwargs)


def sweep_mixed(*args, **kwargs) -> dict[str, np.ndarray]:
    """Deprecated: use ``repro.explore.search.Evaluator`` (driven by
    ``repro.core.dse.run`` + ``ExploreSpec.mixed()``)."""
    _deprecated("repro.core.dse_batch.sweep_mixed",
                "repro.explore.search.Evaluator / "
                "repro.core.dse.run(ExploreSpec.mixed(...))")
    return _sweep_mixed(*args, **kwargs)


def sweep_mixed_many(*args, **kwargs) -> dict[str, np.ndarray]:
    """Deprecated: use ``repro.explore.search.Evaluator`` (driven by
    ``repro.core.dse.run`` + ``ExploreSpec.many(precision="mixed")``)."""
    _deprecated("repro.core.dse_batch.sweep_mixed_many",
                "repro.explore.search.Evaluator / "
                'repro.core.dse.run(ExploreSpec.many(..., '
                'precision="mixed"))')
    return _sweep_mixed_many(*args, **kwargs)


def sweep_chunked(*args, **kwargs) -> ChunkedSweep:
    """Deprecated: use ``repro.core.dse.run`` with
    ``ExploreSpec.single(..., outputs="sweep", chunk_size=...)``."""
    _deprecated("repro.core.dse_batch.sweep_chunked",
                'repro.core.dse.run(ExploreSpec.single(..., '
                'outputs="sweep", chunk_size=...))')
    return _sweep_chunked(*args, **kwargs)
