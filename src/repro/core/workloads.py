"""Layer-wise DNN configurations (the paper's workload input, Fig. 1).

The paper evaluates VGG-16 (Simonyan & Zisserman 2014), ResNet-34 and
ResNet-50 (He et al. 2016).  A layer is a conv ``(H, W, C, K, R, S, stride)``
or an FC (conv with R=S=H=W=1).  Shapes are ImageNet-224 standard.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    h: int          # input feature-map height
    w: int          # input feature-map width
    c: int          # input channels
    k: int          # output channels (filters)
    r: int = 3      # filter height
    s: int = 3      # filter width
    stride: int = 1
    batch: int = 1

    @property
    def e(self) -> int:  # output height
        return max(1, (self.h - self.r) // self.stride + 1)

    @property
    def f(self) -> int:  # output width
        return max(1, (self.w - self.s) // self.stride + 1)

    @property
    def macs(self) -> int:
        return self.batch * self.k * self.c * self.r * self.s * self.e * self.f


def fc(name: str, cin: int, cout: int, batch: int = 1) -> ConvLayer:
    return ConvLayer(name=name, h=1, w=1, c=cin, k=cout, r=1, s=1,
                     stride=1, batch=batch)


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    layers: tuple[ConvLayer, ...]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)


def vgg16() -> Workload:
    ls: list[ConvLayer] = []
    cfg = [  # (h, c, k, repeat)
        (224, 3, 64, 1), (224, 64, 64, 1),
        (112, 64, 128, 1), (112, 128, 128, 1),
        (56, 128, 256, 1), (56, 256, 256, 2),
        (28, 256, 512, 1), (28, 512, 512, 2),
        (14, 512, 512, 3),
    ]
    i = 0
    for h, c, k, rep in cfg:
        for _ in range(rep):
            i += 1
            # 'same' padding modeled by padding the input by r-1
            ls.append(ConvLayer(f"conv{i}", h + 2, h + 2, c, k, 3, 3, 1))
    ls.append(fc("fc6", 512 * 7 * 7, 4096))
    ls.append(fc("fc7", 4096, 4096))
    ls.append(fc("fc8", 4096, 1000))
    return Workload("vgg16", tuple(ls))


def _resnet_stem() -> list[ConvLayer]:
    return [ConvLayer("conv1", 230, 230, 3, 64, 7, 7, 2)]


def resnet34() -> Workload:
    ls = _resnet_stem()
    stages = [  # (n_blocks, channels, fmap)
        (3, 64, 56), (4, 128, 28), (6, 256, 14), (3, 512, 7),
    ]
    cin = 64
    for si, (nb, ch, fm) in enumerate(stages):
        for b in range(nb):
            stride = 2 if (b == 0 and si > 0) else 1
            h_in = fm * stride
            ls.append(ConvLayer(f"s{si}b{b}a", h_in + 2, h_in + 2, cin, ch,
                                3, 3, stride))
            ls.append(ConvLayer(f"s{si}b{b}b", fm + 2, fm + 2, ch, ch, 3, 3, 1))
            if stride != 1 or cin != ch:
                ls.append(ConvLayer(f"s{si}b{b}ds", h_in, h_in, cin, ch,
                                    1, 1, stride))
            cin = ch
    ls.append(fc("fc", 512, 1000))
    return Workload("resnet34", tuple(ls))


def resnet50() -> Workload:
    ls = _resnet_stem()
    stages = [  # (n_blocks, bottleneck_ch, fmap)
        (3, 64, 56), (4, 128, 28), (6, 256, 14), (3, 512, 7),
    ]
    cin = 64
    for si, (nb, ch, fm) in enumerate(stages):
        cout = ch * 4
        for b in range(nb):
            stride = 2 if (b == 0 and si > 0) else 1
            h_in = fm * stride
            ls.append(ConvLayer(f"s{si}b{b}a", h_in, h_in, cin, ch,
                                1, 1, stride))
            ls.append(ConvLayer(f"s{si}b{b}b", fm + 2, fm + 2, ch, ch, 3, 3, 1))
            ls.append(ConvLayer(f"s{si}b{b}c", fm, fm, ch, cout, 1, 1, 1))
            if stride != 1 or cin != cout:
                ls.append(ConvLayer(f"s{si}b{b}ds", h_in, h_in, cin, cout,
                                    1, 1, stride))
            cin = cout
    ls.append(fc("fc", 2048, 1000))
    return Workload("resnet50", tuple(ls))


WORKLOADS = {
    "vgg16": vgg16,
    "resnet34": resnet34,
    "resnet50": resnet50,
}


def get_workload(name: str) -> Workload:
    return WORKLOADS[name]()
