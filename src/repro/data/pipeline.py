"""Deterministic synthetic token pipeline.

Production shape: an indexable, stateless source (step -> global batch)
so any worker can reproduce any batch (restart/straggler determinism), a
cursor that is checkpointed, and device placement that matches the batch
sharding.  The "dataset" is a seeded Markov-ish token stream — enough to
drive real training dynamics (loss decreases) without external data.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticLM:
    """step-indexable synthetic LM data: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # a fixed low-rank "grammar": next-token logits = E @ D
        k = 16
        self._emit = rng.standard_normal((cfg.vocab, k)).astype(np.float32)
        self._trans = rng.standard_normal((k, cfg.vocab)).astype(np.float32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        state = self._emit[toks[:, 0]]                     # (b, k)
        for t in range(1, s + 1):
            logits = state @ self._trans                   # (b, V)
            gumbel = rng.gumbel(size=logits.shape).astype(np.float32)
            # sharp transitions -> low-entropy, learnable stream
            nxt = np.argmax(logits * 2.0 + gumbel, axis=-1)
            toks[:, t] = nxt
            state = 0.7 * state + 0.3 * self._emit[nxt]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def batches(self, start_step: int):
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1


def shard_batch(batch: dict, mesh, batch_spec):
    """Place a host batch onto the mesh with the training sharding."""
    from jax.sharding import NamedSharding
    sh = NamedSharding(mesh, batch_spec)
    return jax.tree.map(lambda x: jax.device_put(x, sh), batch)
