"""whisper-medium [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356].  Backbone only; input_specs provides precomputed
frame embeddings (b, 1500, d)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, encoder_layers=24,
    n_ctx_tokens=1500, mlp_kind="gelu", quant="w8a8",
))
