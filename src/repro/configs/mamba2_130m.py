"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, ssm_state=128, quant="w8a8",
    supports_long_context=True,
))
