"""Search presets for the co-exploration engines
(`repro.core.dse.coexplore` / `repro.core.dse.coexplore_many`).

A preset bundles the knobs of one search campaign — engine, evaluation
budget, population sizing, objective set — so experiments are named and
reproducible instead of ad-hoc kwargs.  ``quick`` is the CI smoke setting;
``default`` matches the benchmark; ``thorough`` turns on the full
5-objective set (perf/area, energy, EDP, area, quantization noise).

The ``many-*`` presets target the multi-workload setting (one shared
hardware config, per-workload precision assignments): their objective
names come from :data:`repro.explore.objectives.MULTI_OBJECTIVES`
(worst-case / energy-weighted-mean across the suite).

``accuracy`` selects the accuracy tier scoring the ``accuracy_noise``
objectives — an :class:`repro.explore.accuracy.AccuracySpec` or a spec
string (``"proxy"`` / ``"calibrated:<model>"`` / ``"measured:<model>"``);
its ``floor_db`` turns accuracy floors into constraints (the successor of
the deprecated ``sqnr_floor_db`` knob, which still folds in with a
warning).  ``calibrated-quick`` is the committed tier-1 campaign: the
same budget as ``quick`` but scored on a table calibrated from real
``mamba2-130m`` tensors — its front *membership* differs from the proxy's
(asserted in ``tests/test_accuracy.py``).

The ``serving-*`` presets score every genome on a serving fleet instead
of a single inference: ``traffic`` names a
:data:`repro.serving.traffic.TRAFFIC_PRESETS` trace that the fleet
simulator replays per candidate over ``n_slots`` continuous-batching
slots, and the objectives come from
:data:`repro.explore.objectives.SERVING_OBJECTIVES` (tail latency, SLO
attainment, throughput under load, energy per served token).
"""

from __future__ import annotations

import dataclasses
import warnings

from repro.explore.accuracy import AccuracySpec
from repro.explore.objectives import (DEFAULT_MULTI_OBJECTIVES,
                                      DEFAULT_OBJECTIVES,
                                      DEFAULT_SERVING_OBJECTIVES,
                                      MULTI_OBJECTIVES, OBJECTIVES,
                                      SERVING_OBJECTIVES,
                                      resolve_objectives)


@dataclasses.dataclass(frozen=True)
class CoExplorePreset:
    name: str
    method: str = "nsga2"            # random | nsga2 | successive_halving
    budget: int = 2048               # requested genome evaluations
    pop_size: int = 64               # nsga2 population
    mutation_rate: float = 0.08
    objectives: tuple[str, ...] = DEFAULT_OBJECTIVES
    seed: int = 0
    chunk_size: int = 4096
    eta: int = 3                     # successive-halving reduction factor
    sqnr_floor_db: float | tuple[float, ...] | None = None   # deprecated
    accuracy: AccuracySpec | str | None = None
    weights: tuple[float, ...] | None = None   # None = energy-weighted
    traffic: str | None = None       # TRAFFIC_PRESETS name (serving mode)
    n_slots: int = 8                 # fleet slots (serving mode)
    # nsga2 external-archive bound: relative epsilon-dominance grid
    # resolution (fraction of each objective's span), None = unbounded
    archive_epsilon: float | None = None

    def __post_init__(self):
        # canonicalize legacy objective names (DeprecationWarning lands
        # on whoever constructed the preset, 4 frames up through the
        # generated __init__)
        object.__setattr__(self, "objectives", resolve_objectives(
            self.objectives, stacklevel=4))
        if isinstance(self.accuracy, str):
            object.__setattr__(self, "accuracy",
                               AccuracySpec.parse(self.accuracy))
        if self.sqnr_floor_db is not None:
            warnings.warn(
                f"preset {self.name!r}: sqnr_floor_db= is deprecated; "
                f"use accuracy=AccuracySpec(floor_db=...)",
                DeprecationWarning, stacklevel=4)
            if self.accuracy is not None:
                raise ValueError(
                    f"preset {self.name!r}: pass either accuracy= or the "
                    f"deprecated sqnr_floor_db=, not both")
            object.__setattr__(self, "accuracy", AccuracySpec(
                floor_db=self.sqnr_floor_db))
            object.__setattr__(self, "sqnr_floor_db", None)
        serving = set(self.objectives) & set(SERVING_OBJECTIVES)
        if serving and self.traffic is None:
            raise ValueError(
                f"preset {self.name!r}: serving objective(s) "
                f"{sorted(serving)} need traffic= (one of "
                f"repro.serving.traffic.TRAFFIC_PRESETS)")
        if self.traffic is not None:
            if not serving:
                raise ValueError(
                    f"preset {self.name!r}: traffic={self.traffic!r} but "
                    f"no serving objective in {self.objectives}")
            if set(self.objectives) & set(MULTI_OBJECTIVES):
                raise ValueError(
                    f"preset {self.name!r}: serving objectives are "
                    f"single-workload only; drop the multi-workload "
                    f"objectives or the traffic")
            from repro.serving.traffic import get_traffic
            get_traffic(self.traffic)          # raises on unknown name
        if self.n_slots < 1:
            raise ValueError(
                f"preset {self.name!r}: n_slots must be >= 1, "
                f"got {self.n_slots}")
        if self.archive_epsilon is not None:
            if self.method != "nsga2":
                raise ValueError(
                    f"preset {self.name!r}: archive_epsilon bounds the "
                    f"nsga2 external archive; method is {self.method!r}")
            if not (0.0 < self.archive_epsilon < 1.0):
                raise ValueError(
                    f"preset {self.name!r}: archive_epsilon must be a "
                    f"relative resolution in (0, 1), "
                    f"got {self.archive_epsilon}")


PRESETS: dict[str, CoExplorePreset] = {p.name: p for p in (
    CoExplorePreset(name="quick", budget=384, pop_size=24),
    CoExplorePreset(name="default"),
    CoExplorePreset(name="thorough", budget=8192, pop_size=96,
                    objectives=OBJECTIVES),
    # week-long-horizon setting: epsilon-bounded archive holds memory
    # constant; pair with ExploreSpec(checkpoint_dir=...) for resumability
    CoExplorePreset(name="marathon", budget=16384, pop_size=96,
                    objectives=OBJECTIVES, archive_epsilon=0.01),
    CoExplorePreset(name="random-baseline", method="random"),
    CoExplorePreset(name="halving", method="successive_halving",
                    budget=4096),
    # tier-1 campaign: quick's budget, scored on a calibration table
    # measured from real mamba2-130m tensors (npz-cached after first run)
    CoExplorePreset(name="calibrated-quick", budget=384, pop_size=24,
                    accuracy="calibrated:mamba2-130m"),
    # multi-workload campaigns (shared hardware, per-workload precision)
    CoExplorePreset(name="many-quick", budget=384, pop_size=24,
                    objectives=DEFAULT_MULTI_OBJECTIVES),
    CoExplorePreset(name="many-default",
                    objectives=DEFAULT_MULTI_OBJECTIVES),
    CoExplorePreset(name="many-thorough", budget=8192, pop_size=96,
                    objectives=("neg_worst_perf_per_area",
                                "total_energy_j", "worst_edp",
                                "worst_accuracy_noise"),
                    accuracy=AccuracySpec(floor_db=20.0)),
    # serving-fleet campaigns (traffic-aware objectives)
    CoExplorePreset(name="serving-quick", budget=384, pop_size=24,
                    objectives=DEFAULT_SERVING_OBJECTIVES,
                    traffic="quick"),
    CoExplorePreset(name="serving-default",
                    objectives=DEFAULT_SERVING_OBJECTIVES,
                    traffic="steady"),
    CoExplorePreset(name="serving-thorough", budget=8192, pop_size=96,
                    objectives=("p99_latency_s", "neg_slo_attainment",
                                "neg_throughput_tps",
                                "energy_per_token_j", "accuracy_noise"),
                    traffic="bursty"),
)}


def get_preset(name: str) -> CoExplorePreset:
    try:
        return PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown co-exploration preset {name!r} "
            f"(known: {sorted(PRESETS)})") from None
