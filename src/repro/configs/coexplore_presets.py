"""Search presets for the co-exploration engines
(`repro.core.dse.coexplore` / `repro.core.dse.coexplore_many`).

A preset bundles the knobs of one search campaign — engine, evaluation
budget, population sizing, objective set — so experiments are named and
reproducible instead of ad-hoc kwargs.  ``quick`` is the CI smoke setting;
``default`` matches the benchmark; ``thorough`` turns on the full
5-objective set (perf/area, energy, EDP, area, quantization noise).

The ``many-*`` presets target the multi-workload setting (one shared
hardware config, per-workload precision assignments): their objective
names come from :data:`repro.explore.objectives.MULTI_OBJECTIVES`
(worst-case / energy-weighted-mean across the suite), and
``sqnr_floor_db`` optionally turns per-workload accuracy floors into
constraints.
"""

from __future__ import annotations

import dataclasses

from repro.explore.objectives import (DEFAULT_MULTI_OBJECTIVES,
                                      DEFAULT_OBJECTIVES, MULTI_OBJECTIVES,
                                      OBJECTIVES)


@dataclasses.dataclass(frozen=True)
class CoExplorePreset:
    name: str
    method: str = "nsga2"            # random | nsga2 | successive_halving
    budget: int = 2048               # requested genome evaluations
    pop_size: int = 64               # nsga2 population
    mutation_rate: float = 0.08
    objectives: tuple[str, ...] = DEFAULT_OBJECTIVES
    seed: int = 0
    chunk_size: int = 4096
    eta: int = 3                     # successive-halving reduction factor
    sqnr_floor_db: float | tuple[float, ...] | None = None
    weights: tuple[float, ...] | None = None   # None = energy-weighted

    def __post_init__(self):
        unknown = set(self.objectives) - set(OBJECTIVES) \
            - set(MULTI_OBJECTIVES)
        if unknown:
            raise ValueError(
                f"preset {self.name!r}: unknown objective(s) "
                f"{sorted(unknown)} (choose from single-workload "
                f"{OBJECTIVES} or multi-workload {MULTI_OBJECTIVES})")


PRESETS: dict[str, CoExplorePreset] = {p.name: p for p in (
    CoExplorePreset(name="quick", budget=384, pop_size=24),
    CoExplorePreset(name="default"),
    CoExplorePreset(name="thorough", budget=8192, pop_size=96,
                    objectives=OBJECTIVES),
    CoExplorePreset(name="random-baseline", method="random"),
    CoExplorePreset(name="halving", method="successive_halving",
                    budget=4096),
    # multi-workload campaigns (shared hardware, per-workload precision)
    CoExplorePreset(name="many-quick", budget=384, pop_size=24,
                    objectives=DEFAULT_MULTI_OBJECTIVES),
    CoExplorePreset(name="many-default",
                    objectives=DEFAULT_MULTI_OBJECTIVES),
    CoExplorePreset(name="many-thorough", budget=8192, pop_size=96,
                    objectives=("neg_worst_perf_per_area",
                                "total_energy_j", "worst_edp",
                                "worst_quant_noise"),
                    sqnr_floor_db=20.0),
)}


def get_preset(name: str) -> CoExplorePreset:
    try:
        return PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown co-exploration preset {name!r} "
            f"(known: {sorted(PRESETS)})") from None
