"""The paper's own workloads (VGG-16 / ResNet-34 / ResNet-50) re-exported
as configs for the DSE benchmarks; see repro.core.workloads."""
from repro.core.workloads import WORKLOADS, get_workload  # noqa: F401
