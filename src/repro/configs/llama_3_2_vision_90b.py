"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  Backbone only; the vision frontend
is a stub (input_specs provides precomputed patch embeddings)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, cross_attn_every=5,
    n_ctx_tokens=1601, quant="w8a8",
))
