"""Architecture configs and input-shape sets (the assigned 10 x 4 grid)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_chunk: int = 512         # SSD chunk length (measured optimum at
                                 # 32k prefill; §Perf cell F sweep)
    shared_attn_every: int = 0   # zamba2: shared attn block period
    # gemma3 local:global
    window: int = 0              # sliding window size for local layers
    global_every: int = 0        # every k-th layer is global
    # vlm
    cross_attn_every: int = 0    # every k-th layer is a cross-attn layer
    n_ctx_tokens: int = 0        # image patches / encoder frames (stub)
    # enc-dec
    encoder_layers: int = 0
    mlp_kind: str = "swiglu"     # swiglu | gelu
    rope_theta: float = 10000.0
    quant: str = "bf16"          # ExecMode value (paper PE-type analogue)
    # full-attention archs skip long_500k (sub-quadratic required)
    supports_long_context: bool = False
    tie_embeddings: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def n_params(self) -> int:
        """Total parameter count (embedding + stacked blocks)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        h, kvh, L = self.n_heads, self.n_kv_heads, self.n_layers
        emb = self.vocab * d
        per_layer = 0
        attn = d * h * hd + 2 * d * kvh * hd + h * hd * d + 2 * d
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + 3 * d * ff + 2 * d
        elif self.family == "moe":
            per_layer = attn + self.n_experts * 3 * d * ff + d * self.n_experts + 2 * d
        elif self.family in ("ssm", "hybrid"):
            from repro.models import ssm as _ssm
            di = 2 * d
            per_layer = d * _ssm.in_proj_dim(self) \
                + _ssm.D_CONV * _ssm.conv_dim(self) + di * d + 2 * d
        total = emb + L * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn + 3 * d * ff + 2 * d          # one shared block
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (attn + 3 * d * ff)
        if self.family == "audio" and self.encoder_layers:
            total += self.encoder_layers * (attn + 2 * d * ff + 2 * d)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        h, kvh, L = self.n_heads, self.n_kv_heads, self.n_layers
        attn = d * h * hd + 2 * d * kvh * hd + h * hd * d
        act = self.vocab * d + L * (attn + self.top_k * 3 * d * ff
                                    + d * self.n_experts)
        return int(act)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import the config modules lazily so registration happens on demand
    from repro import configs as _pkg  # noqa: F401
    import importlib
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro.configs import ALL_ARCHS
    return list(ALL_ARCHS)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2 if not cfg.shared_attn_every else 4,
        d_model=64,
        n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128, vocab=256, head_dim=16,
    )
    if cfg.family == "moe":
        small.update(n_experts=4, top_k=2)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, d_model=64, n_heads=2, n_kv_heads=2,
                     head_dim=32)
    if cfg.shared_attn_every:
        small.update(shared_attn_every=2)
    if cfg.global_every:
        small.update(window=8, global_every=2)
    if cfg.cross_attn_every:
        small.update(cross_attn_every=2, n_ctx_tokens=8)
    if cfg.encoder_layers:
        small.update(encoder_layers=2, n_ctx_tokens=8)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
