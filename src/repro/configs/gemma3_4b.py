"""gemma3-4b [dense] — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt]

Sliding-window (1024) local layers with a global layer every 6th.
long_500k runs: the local majority is sub-quadratic; decode-step cost of
the global layers is linear in cache length (DESIGN.md §8).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    window=1024, global_every=6, quant="w8a8",
    supports_long_context=True,
))
