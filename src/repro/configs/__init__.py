"""Architecture configs (assigned 10-arch pool + the paper's CNNs)."""

ALL_ARCHS = (
    "moonshot-v1-16b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "mamba2-130m",
    "starcoder2-7b",
    "phi4-mini-3.8b",
    "deepseek-67b",
    "gemma3-4b",
    "llama-3.2-vision-90b",
    "whisper-medium",
    "zamba2-1.2b",
)

_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "mamba2-130m": "mamba2_130m",
    "starcoder2-7b": "starcoder2_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "deepseek-67b": "deepseek_67b",
    "gemma3-4b": "gemma3_4b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-medium": "whisper_medium",
    "zamba2-1.2b": "zamba2_1_2b",
}


def get_config(name: str):
    import importlib
    from repro.configs.base import _REGISTRY
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return _REGISTRY[name]
