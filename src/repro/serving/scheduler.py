"""Iteration-level continuous batching (Orca-style) on per-slot positions.

The decode path accepts a per-slot position vector, so slots advance
independently: new requests are admitted into free slots mid-flight and
replay their prompt tokens one iteration at a time while other slots keep
generating — no batch drain, no padding waste.  Slot reuse is safe
because cache reads mask ``ki <= pos`` and a new request overwrites
positions from 0 upward.

This is the serving-layer substrate for the quantized decode path: the
batcher works identically over bf16, int8-KV, and quantized-weight
models (tests/test_scheduler.py).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    # filled by the batcher
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # iteration stamps: admitted at the start of iteration `submit_iter`,
    # done by the end of iteration `complete_iter - 1`.  A request with P
    # prompt and G new tokens completes at submit_iter + P + G - 1 —
    # the contract the fleet simulator (serving/fleet_sim.py) reproduces,
    # with this batcher as the golden latency reference.
    submit_iter: int = -1
    complete_iter: int = 0


class ContinuousBatcher:
    FREE, PREFILL, GEN = 0, 1, 2

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 kv_quant: bool = False):
        self.model = model
        self.params = params
        self.n = n_slots
        self.max_seq = max_seq
        self.caches = model.init_cache(n_slots, max_seq, kv_quant=kv_quant)
        self.queue: deque[Request] = deque()
        self.state = np.full(n_slots, self.FREE)
        self.pos = np.zeros(n_slots, np.int32)
        self.cursor = np.zeros(n_slots, np.int32)      # prompt replay index
        self.slot_req: list = [None] * n_slots
        self.next_tok = np.zeros(n_slots, np.int32)
        self._step = jax.jit(model.decode_step)
        self.completed: list[Request] = []
        self.it = 0                       # iteration counter (wall clock)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n):
            if self.state[s] == self.FREE and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                req.submit_iter = self.it
                self.state[s] = self.PREFILL
                self.pos[s] = 0
                self.cursor[s] = 0
                self.next_tok[s] = req.prompt[0]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or bool((self.state != self.FREE).any())

    def step(self):
        """One iteration: every non-free slot advances one token.

        The iteration counter advances even when every slot is idle, so
        a caller pacing submissions against wall-clock arrival times can
        model idle gaps (this is what makes the batcher usable as the
        fleet-sim golden reference).
        """
        self._admit()
        if not (self.state != self.FREE).any():
            self.it += 1
            return
        tokens = jnp.asarray(self.next_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.caches = self._step(self.params, self.caches,
                                         tokens, pos)
        sampled = np.asarray(jnp.argmax(logits[:, 0], axis=-1),
                             np.int32)

        for s in range(self.n):
            if self.state[s] == self.FREE:
                continue
            req = self.slot_req[s]
            self.pos[s] += 1
            if self.state[s] == self.PREFILL:
                self.cursor[s] += 1
                if self.cursor[s] < len(req.prompt):
                    self.next_tok[s] = req.prompt[self.cursor[s]]
                else:                     # prompt done -> first gen token
                    self.state[s] = self.GEN
                    req.generated.append(int(sampled[s]))
                    self.next_tok[s] = sampled[s]
            else:                          # GEN
                req.generated.append(int(sampled[s]))
                self.next_tok[s] = sampled[s]
            if self.state[s] == self.GEN and (
                    len(req.generated) >= req.max_new
                    or self.pos[s] >= self.max_seq - 1):
                req.done = True
                req.complete_iter = self.it + 1
                self.completed.append(req)
                self.state[s] = self.FREE
                self.slot_req[s] = None
        self.it += 1

    def run(self, max_iters: int = 10000):
        """Iterate until drained; raise if ``max_iters`` cuts serving short.

        Previously a hit ``max_iters`` silently returned partial results;
        in-flight and queued requests vanished without a trace.
        """
        it = 0
        while self.busy and it < max_iters:
            self.step()
            it += 1
        if self.busy:
            in_flight = sum(1 for r in self.slot_req if r is not None)
            raise RuntimeError(
                f"ContinuousBatcher.run hit max_iters={max_iters} while "
                f"busy: {len(self.completed)} completed, {in_flight} "
                f"in flight, {len(self.queue)} queued — raise max_iters "
                f"or drain incrementally with step()")
        return self.completed
