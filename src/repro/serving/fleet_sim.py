"""Vectorized serving-fleet simulator over the (N candidates, T steps) grid.

Scores every accelerator candidate from the fused sweep on a *serving
fleet* instead of a single inference: each candidate runs an
Orca-style continuous batcher (:mod:`repro.serving.scheduler`) with
``n_slots`` slots against one shared :class:`~repro.serving.traffic
.TrafficTrace`, and the simulator reports per-request completion times,
SLO attainment, throughput under load, and energy per served token.

Model
-----
One batcher iteration on candidate *n* takes ``step_s[n]`` seconds (the
candidate's fused-sweep latency aggregate) and advances every busy slot
by one token — prompt tokens replay during prefill, decode tokens issue
one per iteration, and a request with P prompt / G decode tokens holds
its slot for ``P + G - 1`` iterations (the iteration consuming the last
prompt token also emits the first decode token — exactly the
``ContinuousBatcher`` contract, which the tests pin as the golden
reference).  Every *active* iteration dispatches the full ``n_slots``
batch, so it costs ``n_slots * e_token_j[n]`` joules regardless of
occupancy: energy per served token is occupancy-sensitive, which is what
separates serving-fleet fronts from per-inference EDP fronts.

Bit-exactness across backends
-----------------------------
The only float in the simulation is the arrival-time → arrival-iteration
conversion ``ceil(arrival_s / step_s)``, computed once host-side in
float64.  The simulation loop itself is pure integer arithmetic, so the
numpy and jitted-jax paths produce *bit-identical* iteration stamps by
construction (the ``dse_batch`` backend policy asks only for <=1e-6);
the scalar event-driven reference matches them exactly as well.  Derived
metrics are bit-identical integer stamps scaled by ``step_s`` /
``e_token_j``, so when those inputs come from the numpy vs jax sweep
kernels the serving objectives inherit exactly the kernels' <=1e-6
relative noise — no cancellation amplification.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dse_batch import resolve_backend
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.traffic import TrafficTrace, resolve_traffic

_INT32_MAX = np.iinfo(np.int32).max


def _arrival_iters(step_s: np.ndarray, arrival_s: np.ndarray) -> np.ndarray:
    """(N, R) first iteration index at which each request is admissible.

    Request r is in the queue at the start of iteration k iff
    ``arrival_s[r] <= k * step_s[n]``, i.e. ``k >= ceil(arrival/step)``.
    Computed once host-side in float64 so every backend sees the same
    integers.
    """
    a = np.ceil(np.asarray(arrival_s, np.float64)[None, :]
                / np.asarray(step_s, np.float64)[:, None])
    if a.size and a.max() >= _INT32_MAX:
        raise ValueError(
            "trace arrival horizon overflows the iteration grid "
            f"(max arrival iteration {a.max():.3g}); step_s is too small "
            "for this trace — shorten the trace or cap max_iters")
    return a.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Raw per-request iteration stamps plus derived serving metrics.

    ``submit_iter[n, r]`` is the iteration at which request r was
    admitted on candidate n (-1 if never admitted within ``n_iters``);
    ``comp_iter[n, r]`` is the iteration count by which it completed
    (``submit + P + G - 1``; 0 if never admitted).  A request counts as
    *served* iff ``0 < comp_iter <= n_iters``.
    """

    trace: TrafficTrace
    n_slots: int
    n_iters: int
    backend: str
    step_s: np.ndarray        # (N,) float64 seconds per iteration
    e_token_j: np.ndarray     # (N,) float64 joules per token-slot
    submit_iter: np.ndarray   # (N, R) int64, -1 = never admitted
    comp_iter: np.ndarray     # (N, R) int64, 0 = never admitted
    active_iters: np.ndarray  # (N,) int64 iterations with >=1 busy slot

    @property
    def n_candidates(self) -> int:
        return len(self.step_s)

    @property
    def served(self) -> np.ndarray:
        """(N, R) bool: admitted and completed within the horizon."""
        return (self.comp_iter > 0) & (self.comp_iter <= self.n_iters)

    @property
    def latency_s(self) -> np.ndarray:
        """(N, R) float64 queueing+service latency; +inf if unserved.

        Measured on the iteration grid — ``(comp - arrive_iter) * step``,
        i.e. from the first iteration boundary at which the request is
        admissible (the fixed-step clock can't see it earlier) to
        completion.  This drops the sub-step arrival offset (< one
        iteration) but keeps the value a bit-identical integer scaled by
        ``step_s``, so cross-backend noise stays multiplicative (<= the
        kernel's 1e-6 contract) instead of being amplified by
        near-cancellation against the wall-clock arrival time.
        """
        arrive = _arrival_iters(self.step_s,
                                np.asarray(self.trace.arrival_s))
        lat = ((self.comp_iter - arrive).astype(np.float64)
               * self.step_s[:, None])
        return np.where(self.served, lat, np.inf)

    def metrics(self, slo_s: float | None = None) -> dict[str, np.ndarray]:
        """Serving objectives, all (N,) float64.

        Unserved requests poison the latency percentiles to +inf and
        count against ``slo_attainment`` — an overloaded design is
        penalized, not silently excused.  The objectives layer maps the
        infinities onto its finite floor penalty.
        """
        slo = float(self.trace.slo_s if slo_s is None else slo_s)
        n = self.n_candidates
        r = self.trace.n_requests
        svc = np.asarray(self.trace.service_iters, np.int64)
        if r == 0:
            z = np.zeros(n, np.float64)
            return {"p50_latency_s": z.copy(), "p99_latency_s": z.copy(),
                    "slo_attainment": np.ones(n, np.float64),
                    "throughput_tps": z.copy(),
                    "energy_per_token_j": z.copy(),
                    "served_frac": np.ones(n, np.float64)}
        lat = self.latency_s
        served = self.served
        served_tokens = (svc[None, :] * served).sum(axis=1,
                                                    dtype=np.float64)
        makespan = (np.where(served, self.comp_iter, 0).max(axis=1)
                    .astype(np.float64) * self.step_s)
        energy = (self.active_iters.astype(np.float64) * self.n_slots
                  * self.e_token_j)
        with np.errstate(divide="ignore", invalid="ignore"):
            throughput = np.where(makespan > 0,
                                  served_tokens / makespan, 0.0)
            e_per_tok = np.where(served_tokens > 0,
                                 energy / served_tokens, np.inf)
            # percentile interpolates inf-inf to nan; the right answer
            # for an unserved tail is +inf
            p50 = np.nan_to_num(np.percentile(lat, 50.0, axis=1),
                                nan=np.inf, posinf=np.inf)
            p99 = np.nan_to_num(np.percentile(lat, 99.0, axis=1),
                                nan=np.inf, posinf=np.inf)
        return {
            "p50_latency_s": p50,
            "p99_latency_s": p99,
            "slo_attainment": ((lat <= slo).sum(axis=1)
                               / np.float64(r)),
            "throughput_tps": throughput,
            "energy_per_token_j": e_per_tok,
            "served_frac": served.sum(axis=1) / np.float64(r),
        }


def _simulate_numpy(arrive, svc, n_slots, n_iters):
    """Fixed-step integer sim: (N,R) arrive iters -> iteration stamps.

    Event-jumping makes this O(admissions), not O(n_iters): between
    admissions nothing changes except slots draining, so the loop jumps
    straight to the next iteration where *any* candidate can admit and
    counts the skipped window's active iterations in closed form
    (candidate n is busy at iteration j iff ``max(busy_until[n]) > j``).
    Iteration-for-iteration identical to the jax ``fori_loop`` path.
    """
    n, r = arrive.shape
    rows = np.arange(n)
    busy_until = np.zeros((n, n_slots), np.int64)
    next_req = np.zeros(n, np.int64)
    submit = np.full((n, r), -1, np.int64)
    comp = np.zeros((n, r), np.int64)
    active = np.zeros(n, np.int64)
    k = 0
    while k < n_iters:
        for s in range(n_slots):         # slot-order admission, FIFO queue
            idx = np.minimum(next_req, r - 1)
            can = ((next_req < r) & (arrive[rows, idx] <= k)
                   & (busy_until[:, s] <= k))
            done_at = k + svc[idx]
            busy_until[:, s] = np.where(can, done_at, busy_until[:, s])
            submit[rows[can], idx[can]] = k
            comp[rows[can], idx[can]] = done_at[can]
            next_req = next_req + can
        # after the slot pass, each pending head either hasn't arrived
        # (next event = its arrival) or found every slot busy (next event
        # = earliest slot release); drained candidates never admit again
        idx = np.minimum(next_req, r - 1)
        next_adm = np.where(
            next_req < r,
            np.maximum(arrive[rows, idx], busy_until.min(axis=1)),
            n_iters)
        k2 = min(max(int(next_adm.min()), k + 1), n_iters)
        max_bu = busy_until.max(axis=1)
        active += np.clip(np.minimum(max_bu, k2) - k, 0, None)
        k = k2
    return submit, comp, active


_JAX_SIMS: dict = {}


def _jax_sim(n_slots: int, n_iters: int):
    import jax
    import jax.numpy as jnp

    key = (n_slots, n_iters)
    fn = _JAX_SIMS.get(key)
    if fn is not None:
        return fn

    def sim(arrive, svc):
        n, r = arrive.shape
        rows = jnp.arange(n)

        def body(k, state):
            busy_until, next_req, submit, comp, active = state
            for s in range(n_slots):
                idx = jnp.minimum(next_req, r - 1)
                can = ((next_req < r) & (arrive[rows, idx] <= k)
                       & (busy_until[:, s] <= k))
                done_at = k + svc[idx]
                busy_until = busy_until.at[:, s].set(
                    jnp.where(can, done_at, busy_until[:, s]))
                submit = submit.at[rows, idx].set(
                    jnp.where(can, k, submit[rows, idx]))
                comp = comp.at[rows, idx].set(
                    jnp.where(can, done_at, comp[rows, idx]))
                next_req = next_req + can
            active = active + (busy_until > k).any(axis=1)
            return busy_until, next_req, submit, comp, active

        init = (jnp.zeros((n, n_slots), jnp.int32),
                jnp.zeros(n, jnp.int32),
                jnp.full((n, r), -1, jnp.int32),
                jnp.zeros((n, r), jnp.int32),
                jnp.zeros(n, jnp.int32))
        _, _, submit, comp, active = jax.lax.fori_loop(
            0, n_iters, body, init)
        return submit, comp, active

    fn = jax.jit(sim)
    _JAX_SIMS[key] = fn
    return fn


def _simulate_jax(arrive, svc, n_slots, n_iters):
    import jax.numpy as jnp

    # the sim is pure int32 arithmetic: identical to numpy by construction
    fn = _jax_sim(n_slots, n_iters)
    submit, comp, active = fn(jnp.asarray(arrive, jnp.int32),
                              jnp.asarray(svc, jnp.int32))
    return (np.asarray(submit, np.int64), np.asarray(comp, np.int64),
            np.asarray(active, np.int64))


def simulate_fleet(step_s, e_token_j, traffic, *, n_slots: int = 8,
                   max_iters: int | None = None,
                   backend: str = "auto") -> FleetResult:
    """Replay ``traffic`` against N candidates; return iteration stamps.

    ``step_s`` / ``e_token_j`` are (N,) per-candidate seconds-per-
    iteration and joules-per-token-slot from the fused sweep.  With
    ``max_iters=None`` the horizon auto-drains (last arrival plus total
    service, so every request completes); pass a finite ``max_iters`` to
    model a hard serving window, in which case stragglers are unserved.
    """
    trace = resolve_traffic(traffic)
    step = np.atleast_1d(np.asarray(step_s, np.float64))
    e_tok = np.atleast_1d(np.asarray(e_token_j, np.float64))
    if step.ndim != 1 or step.shape != e_tok.shape:
        raise ValueError(
            f"step_s and e_token_j must be matching 1-D arrays, got "
            f"shapes {step.shape} and {e_tok.shape}")
    if len(step) and ((step <= 0).any() or not np.isfinite(step).all()):
        raise ValueError("step_s must be finite and > 0")
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    bk = resolve_backend(backend)
    n, r = len(step), trace.n_requests
    if n == 0 or r == 0:
        return FleetResult(
            trace=trace, n_slots=n_slots, n_iters=0, backend=bk,
            step_s=step, e_token_j=e_tok,
            submit_iter=np.full((n, r), -1, np.int64),
            comp_iter=np.zeros((n, r), np.int64),
            active_iters=np.zeros(n, np.int64))
    arrive = _arrival_iters(step, trace.arrival_s)
    svc = np.asarray(trace.service_iters, np.int64)
    drain = int(arrive.max()) + int(svc.sum()) + 1
    n_iters = drain if max_iters is None else min(int(max_iters), drain)
    if n_iters >= _INT32_MAX:
        raise ValueError(
            f"simulation horizon {n_iters} overflows int32; cap max_iters")
    with obs_trace.span("fleet.simulate", n=n, requests=r,
                        n_iters=n_iters, n_slots=n_slots, backend=bk):
        if bk == "jax":
            submit, comp, active = _simulate_jax(arrive, svc, n_slots,
                                                 n_iters)
        else:
            submit, comp, active = _simulate_numpy(arrive, svc, n_slots,
                                                   n_iters)
    res = FleetResult(trace=trace, n_slots=n_slots, n_iters=n_iters,
                      backend=bk, step_s=step, e_token_j=e_tok,
                      submit_iter=submit, comp_iter=comp,
                      active_iters=active)
    reg = obs_metrics.get_registry()
    reg.inc("fleet.simulations")
    reg.inc("fleet.candidates", n)
    served = res.served
    if served.size:
        reg.set("fleet.served_frac", float(served.mean()))
        if obs_trace.is_enabled():
            # percentile math over (N, R) is not free — only pay for the
            # SLO gauge when telemetry is actually on
            reg.set("fleet.slo_attainment",
                    float(res.metrics()["slo_attainment"].mean()))
    return res


def simulate_fleet_scalar(step_s: float, e_token_j: float, traffic, *,
                          n_slots: int = 8,
                          max_iters: int | None = None) -> FleetResult:
    """Event-driven scalar reference for one candidate.

    Walks requests in FIFO order, admitting each into the
    earliest-freeing slot (lowest index on ties, matching the batcher's
    slot-order ``_admit``).  Arrivals are sorted and a freed slot's next
    admission is never earlier than the previous one's, so FIFO order is
    preserved without an explicit queue.  Must reproduce
    :func:`simulate_fleet`'s stamps bit-exactly (pinned by tests).
    """
    trace = resolve_traffic(traffic)
    r = trace.n_requests
    svc = np.asarray(trace.service_iters, np.int64)
    step = np.asarray([step_s], np.float64)
    e_tok = np.asarray([e_token_j], np.float64)
    if r == 0:
        return simulate_fleet(step, e_tok, trace, n_slots=n_slots,
                              max_iters=max_iters, backend="numpy")
    arrive = _arrival_iters(step, trace.arrival_s)[0]
    drain = int(arrive.max()) + int(svc.sum()) + 1
    n_iters = drain if max_iters is None else min(int(max_iters), drain)
    free_at = np.zeros(n_slots, np.int64)
    submit = np.full(r, -1, np.int64)
    comp = np.zeros(r, np.int64)
    busy_spans: list[tuple[int, int]] = []
    for i in range(r):
        slot = int(np.argmin(free_at))    # earliest free, lowest index
        start = max(int(arrive[i]), int(free_at[slot]))
        if start >= n_iters:
            break                         # horizon hit; rest never admitted
        submit[i] = start
        comp[i] = start + int(svc[i])
        free_at[slot] = comp[i]
        busy_spans.append((start, int(comp[i])))
    # active iterations = union of [start, end) spans clipped to horizon
    active = 0
    cur_s = cur_e = -1
    for s0, e0 in sorted(busy_spans):
        s0, e0 = s0, min(e0, n_iters)
        if s0 >= e0:
            continue
        if s0 > cur_e:
            active += cur_e - cur_s if cur_e > cur_s else 0
            cur_s, cur_e = s0, e0
        else:
            cur_e = max(cur_e, e0)
    active += cur_e - cur_s if cur_e > cur_s else 0
    return FleetResult(trace=trace, n_slots=n_slots, n_iters=n_iters,
                       backend="scalar", step_s=step, e_token_j=e_tok,
                       submit_iter=submit[None, :], comp_iter=comp[None, :],
                       active_iters=np.asarray([active], np.int64))
