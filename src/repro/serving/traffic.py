"""Seeded request-arrival traces for serving-fleet DSE.

A :class:`TrafficTrace` is the workload the fleet simulator
(:mod:`repro.serving.fleet_sim`) replays against every accelerator
candidate: per-request arrival times plus the prefill/decode phase split
(prompt tokens replayed one per iteration, then decode tokens issued one
per iteration — exactly the :class:`repro.serving.scheduler
.ContinuousBatcher` semantics).

Traces are generated from named :class:`TrafficPreset`\\ s — Poisson
("steady" memoryless arrivals) or bursty (Poisson burst *starts*, each
burst a tight cluster of requests) — with all randomness flowing through
one explicit ``numpy.random.Generator`` in data-independent draw order, so
a (preset, seed) pair names one exact trace forever.  Arrival rates are
calibrated to the sweep kernel's per-inference latency range
(~0.02–0.9 s on the paper space), so queueing pressure actually
discriminates design points instead of every candidate trivially keeping
up.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """One replayable serving workload: R requests with arrival times and
    prefill/decode phase lengths.

    ``arrival_s`` must be sorted ascending (FIFO admission is by arrival);
    ``prompt_tokens`` / ``decode_tokens`` are per-request phase lengths in
    tokens (both >= 1).  ``slo_s`` is the per-request completion deadline
    used by the ``slo_attainment`` serving objective.
    """

    name: str
    arrival_s: np.ndarray       # (R,) float64, sorted ascending, >= 0
    prompt_tokens: np.ndarray   # (R,) int64 >= 1
    decode_tokens: np.ndarray   # (R,) int64 >= 1
    slo_s: float = 2.0

    def __post_init__(self):
        arr = np.asarray(self.arrival_s, dtype=np.float64)
        pt = np.asarray(self.prompt_tokens, dtype=np.int64)
        dt = np.asarray(self.decode_tokens, dtype=np.int64)
        if not (arr.ndim == pt.ndim == dt.ndim == 1):
            raise ValueError("trace fields must be 1-D arrays")
        if not (len(arr) == len(pt) == len(dt)):
            raise ValueError(
                f"trace field lengths disagree: {len(arr)} arrivals, "
                f"{len(pt)} prompt lengths, {len(dt)} decode lengths")
        if len(arr) and (not np.isfinite(arr).all() or (arr < 0).any()):
            raise ValueError("arrival times must be finite and >= 0")
        if len(arr) and (np.diff(arr) < 0).any():
            raise ValueError("arrival times must be sorted ascending")
        if len(pt) and ((pt < 1).any() or (dt < 1).any()):
            raise ValueError("prompt/decode token counts must be >= 1")
        if not (np.isfinite(self.slo_s) and self.slo_s > 0):
            raise ValueError(f"slo_s must be positive, got {self.slo_s!r}")
        object.__setattr__(self, "arrival_s", arr)
        object.__setattr__(self, "prompt_tokens", pt)
        object.__setattr__(self, "decode_tokens", dt)

    @property
    def n_requests(self) -> int:
        return len(self.arrival_s)

    @property
    def service_iters(self) -> np.ndarray:
        """Per-request batcher iterations to completion once admitted.

        A request with P prompt tokens and G decode tokens occupies its
        slot for ``P + G - 1`` iterations: the iteration consuming the
        last prompt token also produces the first decode token (the
        :class:`~repro.serving.scheduler.ContinuousBatcher` contract).
        """
        return self.prompt_tokens + self.decode_tokens - 1

    @property
    def total_tokens(self) -> int:
        """Total token-iterations of work in the trace."""
        return int(self.service_iters.sum())


@dataclasses.dataclass(frozen=True)
class TrafficPreset:
    """Named recipe for a trace: arrival process + phase-length mix.

    ``kind="poisson"`` draws exponential inter-arrival gaps at
    ``rate_rps``; ``kind="bursty"`` draws Poisson burst *starts* at
    ``rate_rps / burst_size`` (so the long-run request rate matches the
    steady preset at equal ``rate_rps``) and packs ``burst_size`` requests
    per burst with exponential intra-burst spacing at ``burst_spread_s``
    scale.  Phase lengths are uniform over the inclusive
    ``prompt_tokens`` / ``decode_tokens`` ranges.
    """

    name: str
    kind: str = "poisson"                     # "poisson" | "bursty"
    rate_rps: float = 6.0                     # long-run mean request rate
    n_requests: int = 48
    prompt_tokens: tuple[int, int] = (3, 12)  # inclusive [lo, hi]
    decode_tokens: tuple[int, int] = (4, 12)
    burst_size: int = 8                       # bursty only
    burst_spread_s: float = 0.05              # bursty only
    slo_s: float = 2.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("poisson", "bursty"):
            raise ValueError(
                f"unknown traffic kind {self.kind!r} "
                f"(choose from ('poisson', 'bursty'))")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.n_requests < 0:
            raise ValueError(
                f"n_requests must be >= 0, got {self.n_requests}")
        for rng_name in ("prompt_tokens", "decode_tokens"):
            lo, hi = getattr(self, rng_name)
            if not (1 <= lo <= hi):
                raise ValueError(
                    f"{rng_name} range must satisfy 1 <= lo <= hi, "
                    f"got ({lo}, {hi})")
        if self.kind == "bursty" and self.burst_size < 1:
            raise ValueError(
                f"burst_size must be >= 1, got {self.burst_size}")


# calibrated against the paper design space's per-inference latencies
# (~0.02-0.9 s/iteration): "steady"/"bursty" load the mid-range designs
# without drowning the fast ones, "interactive" pressures SLO latency,
# "batch" rewards raw throughput, "quick" is the CI smoke trace
TRAFFIC_PRESETS: dict[str, TrafficPreset] = {p.name: p for p in (
    TrafficPreset(name="steady", kind="poisson", rate_rps=6.0,
                  n_requests=48, prompt_tokens=(3, 12),
                  decode_tokens=(4, 12), slo_s=2.0),
    TrafficPreset(name="bursty", kind="bursty", rate_rps=6.0,
                  n_requests=48, prompt_tokens=(3, 12),
                  decode_tokens=(4, 12), burst_size=8,
                  burst_spread_s=0.05, slo_s=2.5),
    TrafficPreset(name="interactive", kind="poisson", rate_rps=10.0,
                  n_requests=64, prompt_tokens=(2, 6),
                  decode_tokens=(3, 8), slo_s=1.0),
    TrafficPreset(name="batch", kind="poisson", rate_rps=1.5,
                  n_requests=24, prompt_tokens=(16, 40),
                  decode_tokens=(12, 32), slo_s=12.0),
    TrafficPreset(name="quick", kind="poisson", rate_rps=8.0,
                  n_requests=16, prompt_tokens=(2, 6),
                  decode_tokens=(3, 6), slo_s=1.0),
)}


def get_traffic(name: str) -> TrafficPreset:
    try:
        return TRAFFIC_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown traffic preset {name!r} "
            f"(known: {sorted(TRAFFIC_PRESETS)})") from None


def make_trace(preset: TrafficPreset | str, *, seed: int | None = None,
               n_requests: int | None = None) -> TrafficTrace:
    """Materialize a preset into a concrete :class:`TrafficTrace`.

    Draw order is fixed (arrival process, then prompt lengths, then
    decode lengths), so equal (preset, seed) pairs give bit-identical
    traces regardless of numpy version-independent quantities.
    """
    p = get_traffic(preset) if isinstance(preset, str) else preset
    seed = p.seed if seed is None else seed
    n = p.n_requests if n_requests is None else int(n_requests)
    rng = np.random.default_rng(seed)
    if p.kind == "poisson":
        arrival = np.cumsum(rng.exponential(1.0 / p.rate_rps, size=n))
    else:                                   # bursty
        n_bursts = -(-n // p.burst_size)
        burst_rate = p.rate_rps / p.burst_size
        starts = np.cumsum(rng.exponential(1.0 / burst_rate,
                                           size=n_bursts))
        offsets = rng.exponential(p.burst_spread_s,
                                  size=(n_bursts, p.burst_size))
        arrival = np.sort(
            (starts[:, None] + np.cumsum(offsets, axis=1)).ravel()[:n])
    prompt = rng.integers(p.prompt_tokens[0], p.prompt_tokens[1] + 1,
                          size=n, dtype=np.int64)
    decode = rng.integers(p.decode_tokens[0], p.decode_tokens[1] + 1,
                          size=n, dtype=np.int64)
    name = p.name if seed == p.seed and n == p.n_requests \
        else f"{p.name}(seed={seed},n={n})"
    return TrafficTrace(name=name, arrival_s=arrival,
                        prompt_tokens=prompt, decode_tokens=decode,
                        slo_s=p.slo_s)


def resolve_traffic(spec) -> TrafficTrace:
    """Accept a trace, a preset, or a preset name; return the trace."""
    if isinstance(spec, TrafficTrace):
        return spec
    if isinstance(spec, TrafficPreset):
        return make_trace(spec)
    if isinstance(spec, str):
        return make_trace(get_traffic(spec))
    raise TypeError(
        f"traffic must be a TrafficTrace, TrafficPreset, or preset name, "
        f"got {type(spec).__name__}")
