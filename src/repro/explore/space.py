"""Mixed-precision co-exploration search space (QUIDAM/QADAM direction).

A *genome* is one point of the joint (accelerator config x per-layer
execution precision) space, encoded as a packed ``uint16`` row:

* ``genome[:N_HW_GENES]`` — factor-level indices of the hardware half
  (PE type, array dims, spad scale, GLB capacity, DRAM bandwidth), the
  same factors :func:`repro.core.accelerator.design_space` enumerates;
* ``genome[N_HW_GENES:]`` — one PE-type index per workload layer
  (canonical ``tuple(PEType)`` order), the layer's execution mode on the
  precision-scalable datapath.

Everything here is vectorized over genome *populations* — decode produces
the struct-of-arrays form that :func:`repro.core.dse_batch.sweep_mixed`
consumes directly, and the hardware half of every genome is digested by
:mod:`repro.core.confighash`, so repeated hardware (the common case in an
evolutionary search) hits the existing synthesis caches.  Genome digests
(hardware + assignment words through the same counter hash) key the
search's evaluation memo.

All randomness flows through an explicit ``numpy.random.Generator``; random
draws are made in data-independent order so equal seeds give bit-identical
populations regardless of genome contents.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.accelerator import (DEFAULT_ARRAY_DIMS, DEFAULT_BWS,
                                    DEFAULT_GLB_KBS, DEFAULT_SPAD_SCALES,
                                    soa_from_fields, spad_capacities)
from repro.core.confighash import digest_keys, digest_words
from repro.core.pe import PEType, mode_compat_matrix

# genome layout: hardware factor levels, then one mode gene per layer
N_HW_GENES = 5
GENE_NAMES = ("pe_type", "array_dim", "spad_scale", "glb_kb", "dram_bw")

_TYPES = tuple(PEType)
_TYPE_IDX = {t: i for i, t in enumerate(_TYPES)}


@functools.lru_cache(maxsize=1)
def _mode_choice_table() -> tuple[np.ndarray, np.ndarray]:
    """``(counts, choices)``: for hardware type ``h``, the executable mode
    indices are ``choices[h, :counts[h]]`` (padded with the hw index)."""
    compat = mode_compat_matrix()
    t = len(_TYPES)
    counts = compat.sum(axis=1).astype(np.int64)
    choices = np.full((t, t), -1, dtype=np.int64)
    for h in range(t):
        ms = np.nonzero(compat[h])[0]
        choices[h, :len(ms)] = ms
        choices[h, len(ms):] = h          # padding never selected
    return counts, choices


@dataclasses.dataclass(frozen=True)
class CoExploreSpace:
    """Factor grid of the joint design space for one workload shape.

    The hardware factors default to the paper's Sec. 3.3 sweep; the
    per-layer mode alphabet is always the full ``PEType`` set, constrained
    at sample/repair time to modes the hardware can execute.
    """

    n_layers: int
    pe_types: tuple[PEType, ...] = _TYPES
    array_dims: tuple[tuple[int, int], ...] = DEFAULT_ARRAY_DIMS
    spad_scales: tuple[float, ...] = DEFAULT_SPAD_SCALES
    glb_kbs: tuple[int, ...] = DEFAULT_GLB_KBS
    bws: tuple[float, ...] = DEFAULT_BWS

    def __post_init__(self):
        if self.n_layers < 1:
            raise ValueError("n_layers must be >= 1")
        object.__setattr__(self, "pe_types",
                           tuple(PEType(t) for t in self.pe_types))

    # ---- layout ------------------------------------------------------------
    @property
    def genome_width(self) -> int:
        return N_HW_GENES + self.n_layers

    @property
    def hw_levels(self) -> tuple[int, ...]:
        """Number of levels of each hardware gene."""
        return (len(self.pe_types), len(self.array_dims),
                len(self.spad_scales), len(self.glb_kbs), len(self.bws))

    def size(self) -> float:
        """Cardinality of the joint space (float: overflows int64 fast)."""
        counts, _ = _mode_choice_table()
        hw = float(np.prod(self.hw_levels))
        per_type = [float(counts[_TYPE_IDX[t]]) ** self.n_layers
                    for t in self.pe_types]
        return hw / len(self.pe_types) * sum(per_type)

    # ---- factor tables (absolute values per level) -------------------------
    def _tables(self) -> dict[str, np.ndarray]:
        # one build per space instance (frozen dataclass, so the factors
        # never change); level -> value mapping shared with the grid
        # sweeps via accelerator.spad_capacities + DEFAULT_* constants
        tbl = getattr(self, "_tbl", None)
        if tbl is None:
            spads = [spad_capacities(s) for s in self.spad_scales]
            tbl = {
                "type_idx": np.array([_TYPE_IDX[t] for t in self.pe_types],
                                     dtype=np.int64),
                "rows": np.array([d[0] for d in self.array_dims],
                                 dtype=np.int64),
                "cols": np.array([d[1] for d in self.array_dims],
                                 dtype=np.int64),
                "ifmap": np.array([s[0] for s in spads], dtype=np.int64),
                "filt": np.array([s[1] for s in spads], dtype=np.int64),
                "psum": np.array([s[2] for s in spads], dtype=np.int64),
                "glb": np.array(self.glb_kbs, dtype=np.int64),
                "bw": np.array(self.bws, dtype=np.float64),
            }
            object.__setattr__(self, "_tbl", tbl)
        return tbl

    # ---- encode / decode ---------------------------------------------------
    def decode(self, genomes: np.ndarray, *, skip_validation: bool = False
               ) -> tuple[dict[str, np.ndarray], np.ndarray]:
        """Genome matrix -> (hardware SoA, ``(N, L)`` mode assignment).

        The SoA is exactly what :func:`repro.core.dse_batch.sweep_mixed`
        and the synthesis caches consume; invalid genomes raise.
        ``skip_validation`` is for hot loops whose rows were already
        validated at the batch boundary (e.g. the search evaluator).
        """
        g = self.validate(genomes, raise_on_invalid=not skip_validation)
        t = self._tables()
        it, id_ = g[:, 0], g[:, 1]
        is_, ig, ib = g[:, 2], g[:, 3], g[:, 4]
        soa = soa_from_fields(
            pe_type_idx=t["type_idx"][it],
            pe_rows=t["rows"][id_], pe_cols=t["cols"][id_],
            ifmap_spad=t["ifmap"][is_], filter_spad=t["filt"][is_],
            psum_spad=t["psum"][is_], glb_kb=t["glb"][ig],
            dram_bw_gbps=t["bw"][ib],
            clock_cap=np.full(len(g), np.inf))
        assign = g[:, N_HW_GENES:].astype(np.int64)
        return soa, assign

    def validate(self, genomes: np.ndarray,
                 raise_on_invalid: bool = False) -> np.ndarray:
        """Check level ranges + hardware/mode compatibility.

        Returns the validated ``(N, W)`` int64 matrix, or raises with a
        count of offending genomes when ``raise_on_invalid``; otherwise
        use :meth:`valid_mask`.
        """
        g = np.asarray(genomes, dtype=np.int64)
        if g.ndim != 2 or g.shape[1] != self.genome_width:
            raise ValueError(
                f"genome matrix shape {g.shape} != "
                f"(N, {self.genome_width}) for {self.n_layers} layers")
        if raise_on_invalid:
            bad = ~self.valid_mask(g)
            if bad.any():
                raise ValueError(
                    f"{int(bad.sum())} invalid genome(s): hardware levels "
                    f"out of range or modes unsupported by their hardware")
        return g

    def valid_mask(self, genomes: np.ndarray) -> np.ndarray:
        """Per-genome validity: levels in range and modes executable."""
        g = np.asarray(genomes, dtype=np.int64)
        levels = np.array(self.hw_levels, dtype=np.int64)
        ok = ((g[:, :N_HW_GENES] >= 0).all(axis=1)
              & (g[:, :N_HW_GENES] < levels[None, :]).all(axis=1))
        modes = g[:, N_HW_GENES:]
        in_range = (modes >= 0).all(axis=1) & (modes < len(_TYPES)).all(axis=1)
        ok &= in_range
        if ok.any():
            hw = np.where(ok, g[:, 0], 0)
            hw_abs = self._tables()["type_idx"][hw]
            compat = mode_compat_matrix()[hw_abs[:, None],
                                          np.where(in_range[:, None],
                                                   modes, 0)]
            ok &= compat.all(axis=1)
        return ok

    # ---- sampling / variation (seed-threaded, data-independent draws) ------
    def random_population(self, n: int,
                          rng: np.random.Generator) -> np.ndarray:
        """``n`` uniform-random valid genomes."""
        levels = self.hw_levels
        g = np.empty((n, self.genome_width), dtype=np.int64)
        for j, lv in enumerate(levels):
            g[:, j] = rng.integers(0, lv, size=n)
        counts, choices = _mode_choice_table()
        hw_abs = self._tables()["type_idx"][g[:, 0]]
        u = rng.random((n, self.n_layers))
        pick = np.floor(u * counts[hw_abs][:, None]).astype(np.int64)
        g[:, N_HW_GENES:] = choices[hw_abs[:, None], pick]
        return g

    def repair(self, genomes: np.ndarray) -> np.ndarray:
        """Clamp layer modes unsupported by their hardware to the
        hardware's own type (deterministic, in place on a copy)."""
        g = np.asarray(genomes, dtype=np.int64).copy()
        hw_abs = self._tables()["type_idx"][g[:, 0]]
        modes = g[:, N_HW_GENES:]
        ok = mode_compat_matrix()[hw_abs[:, None], modes]
        g[:, N_HW_GENES:] = np.where(ok, modes, hw_abs[:, None])
        return g

    def mutate(self, genomes: np.ndarray, rng: np.random.Generator,
               rate: float = 0.08) -> np.ndarray:
        """Per-gene resampling mutation followed by compatibility repair.

        Every random draw happens unconditionally (mask applied after), so
        the RNG stream — and hence the whole search trajectory — depends
        only on the seed and population shapes, not on genome values.
        """
        g = np.asarray(genomes, dtype=np.int64).copy()
        n = len(g)
        flip = rng.random(g.shape) < rate
        levels = self.hw_levels
        for j, lv in enumerate(levels):
            fresh = rng.integers(0, lv, size=n)
            g[:, j] = np.where(flip[:, j], fresh, g[:, j])
        counts, choices = _mode_choice_table()
        hw_abs = self._tables()["type_idx"][g[:, 0]]
        u = rng.random((n, self.n_layers))
        pick = np.floor(u * counts[hw_abs][:, None]).astype(np.int64)
        fresh_modes = choices[hw_abs[:, None], pick]
        lay = g[:, N_HW_GENES:]
        g[:, N_HW_GENES:] = np.where(flip[:, N_HW_GENES:], fresh_modes, lay)
        return self.repair(g)

    def crossover(self, a: np.ndarray, b: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
        """Uniform crossover of two parent matrices + repair."""
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        take_a = rng.random(a.shape) < 0.5
        return self.repair(np.where(take_a, a, b))

    # ---- identity ----------------------------------------------------------
    def _digest_salt(self) -> tuple[int, ...]:
        """Extra words folded into every genome digest, so genomes of
        structurally different spaces (layer counts, workload boundaries)
        can never alias."""
        return (self.n_layers,)

    def genome_digests(self, genomes: np.ndarray):
        """128-bit counter-hash digests of whole genomes (hardware levels
        + assignment), via the same primitive that keys the synthesis
        caches (:mod:`repro.core.confighash`)."""
        g = self.validate(genomes)
        words = [g[:, j].astype(np.uint32)
                 for j in range(self.genome_width)]
        # fold the space's structure in so equal prefixes of different
        # spaces cannot alias
        for salt in self._digest_salt():
            words.append(np.full(len(g), salt, dtype=np.uint32))
        return digest_words(words)

    def genome_keys(self, genomes: np.ndarray) -> list[bytes]:
        """16-byte memo keys, one per genome."""
        return digest_keys(self.genome_digests(genomes))

    # ---- storage (uint16 pack / unpack) ------------------------------------
    def pack_genomes(self, genomes: np.ndarray) -> np.ndarray:
        """Validated genome matrix -> compact ``uint16`` form.

        Every gene is a small factor level or mode index (all < 2**16 by
        construction), so the packed matrix is a lossless 4x-smaller
        serialization — archives, golden files, and npz checkpoints store
        this form.  Round-trips bit-identically through
        :meth:`unpack_genomes` (property-tested).
        """
        g = self.validate(genomes, raise_on_invalid=True)
        return g.astype(np.uint16)

    def unpack_genomes(self, packed: np.ndarray) -> np.ndarray:
        """Inverse of :meth:`pack_genomes`; validates on the way out so a
        corrupted archive fails loudly instead of decoding garbage."""
        g = np.asarray(packed, dtype=np.uint16).astype(np.int64)
        return self.validate(g, raise_on_invalid=True)


@dataclasses.dataclass(frozen=True)
class CoExploreManySpace(CoExploreSpace):
    """Joint design space for W workloads sharing one accelerator.

    The QUIDAM co-exploration setting: one hardware config serves every
    workload, but each workload gets its own per-layer execution-precision
    assignment.  The genome stays a single flat uint row —

    * ``genome[:N_HW_GENES]`` — the shared hardware half (unchanged);
    * ``genome[N_HW_GENES:]`` — the W workloads' ragged per-layer mode
      segments packed back to back, workload ``w`` occupying columns
      ``[N_HW_GENES + offset_w, N_HW_GENES + offset_w + layer_counts[w])``.

    Because mode validity depends only on the shared hardware (never on
    which workload a layer belongs to), every inherited operator —
    sampling, mutation, crossover, repair, validation, digests —
    works on the packed layout unchanged; :meth:`split_assign` recovers
    the per-workload ``(N, L_w)`` matrices that
    :func:`repro.core.dse_batch.sweep_mixed_many` consumes.
    """

    layer_counts: tuple[int, ...] = ()
    workload_names: tuple[str, ...] = ()

    def __post_init__(self):
        counts = tuple(int(c) for c in self.layer_counts)
        if not counts or any(c < 1 for c in counts):
            raise ValueError(
                f"layer_counts must be a non-empty tuple of positive "
                f"ints, got {self.layer_counts!r}")
        object.__setattr__(self, "layer_counts", counts)
        if self.n_layers != sum(counts):
            raise ValueError(
                f"n_layers={self.n_layers} != sum(layer_counts)="
                f"{sum(counts)}")
        if self.workload_names and len(self.workload_names) != len(counts):
            raise ValueError(
                f"{len(self.workload_names)} workload names for "
                f"{len(counts)} layer-count segments")
        super().__post_init__()

    @property
    def n_workloads(self) -> int:
        return len(self.layer_counts)

    @property
    def segment_bounds(self) -> tuple[tuple[int, int], ...]:
        """``(start, end)`` column bounds of each workload's mode segment
        within the ``(N, sum L_w)`` assignment matrix."""
        bounds = []
        start = 0
        for c in self.layer_counts:
            bounds.append((start, start + c))
            start += c
        return tuple(bounds)

    def split_assign(self, assign: np.ndarray) -> list[np.ndarray]:
        """Split the packed ``(N, sum L_w)`` assignment into per-workload
        ``(N, L_w)`` views (no copy)."""
        a = np.asarray(assign)
        if a.ndim != 2 or a.shape[1] != self.n_layers:
            raise ValueError(
                f"assignment shape {a.shape} != (N, {self.n_layers})")
        return [a[:, s:e] for s, e in self.segment_bounds]

    def _digest_salt(self) -> tuple[int, ...]:
        # fold every segment boundary in: (3, 5) and (5, 3) share a total
        # layer count but are different spaces
        return (self.n_layers, self.n_workloads, *self.layer_counts)


def space_for_workload(workload, **overrides) -> CoExploreSpace:
    """A :class:`CoExploreSpace` sized to ``workload``'s layer count."""
    from repro.core.workloads import Workload, get_workload
    wl = get_workload(workload) if isinstance(workload, str) else workload
    assert isinstance(wl, Workload)
    return CoExploreSpace(n_layers=len(wl.layers), **overrides)


def space_for_workloads(workloads, **overrides) -> CoExploreManySpace:
    """A :class:`CoExploreManySpace` sized to a workload suite (names may
    be strings from :data:`repro.core.workloads.WORKLOADS`)."""
    from repro.core.workloads import Workload, get_workload
    wls = [get_workload(w) if isinstance(w, str) else w for w in workloads]
    if not wls:
        raise ValueError("space_for_workloads needs at least one workload")
    assert all(isinstance(w, Workload) for w in wls)
    counts = tuple(len(w.layers) for w in wls)
    return CoExploreManySpace(n_layers=sum(counts), layer_counts=counts,
                              workload_names=tuple(w.name for w in wls),
                              **overrides)
