"""k-objective Pareto tools: dominance mask, non-dominated sort, crowding
distance, and an exact hypervolume indicator.

Generalizes the 2-D :func:`repro.core.dse_batch.pareto_mask` (max perf,
min energy) to arbitrary objective counts under an all-minimization
convention; the 2-objective case delegates to the existing vectorized
kernel, so both agree bit-for-bit (property-tested).

Tie semantics match the 2-D kernel: a point is dominated only by a point
that is no worse everywhere and *strictly* better somewhere, so exact
duplicates all survive.
"""

from __future__ import annotations

import numpy as np

from repro.core.dse_batch import pareto_mask


def pareto_mask_k(F: np.ndarray, chunk: int = 1024) -> np.ndarray:
    """Boolean non-dominated mask of an ``(N, K)`` minimization matrix.

    ``K == 2`` delegates to the sorted/broadcast 2-D kernel; ``K >= 3``
    runs a chunked-broadcast dominance test (memory ``chunk * N`` bools —
    population-scale inputs, not million-point sweeps).
    """
    F = np.asarray(F, dtype=np.float64)
    if F.ndim != 2:
        raise ValueError(f"objective matrix must be (N, K), got {F.shape}")
    n, k = F.shape
    if n == 0:
        return np.zeros(0, dtype=bool)
    if k == 1:
        return F[:, 0] == F[:, 0].min()
    if k == 2:
        # maximize -f0 == minimize f0
        return pareto_mask(-F[:, 0], F[:, 1])
    keep = np.ones(n, dtype=bool)
    for s in range(0, n, chunk):
        block = F[s:s + chunk]                      # (B, K)
        # q dominates p: q <= p everywhere, q < p somewhere
        no_worse = (F[None, :, :] <= block[:, None, :]).all(-1)
        better = (F[None, :, :] < block[:, None, :]).any(-1)
        keep[s:s + chunk] = ~(no_worse & better).any(1)
    return keep


def nondominated_sort(F: np.ndarray) -> np.ndarray:
    """NSGA-II front ranks: 0 for the Pareto front, 1 for the front of the
    remainder, and so on.  Returns an ``(N,)`` int array."""
    F = np.asarray(F, dtype=np.float64)
    n = len(F)
    ranks = np.full(n, -1, dtype=np.int64)
    remaining = np.arange(n)
    rank = 0
    while len(remaining):
        mask = pareto_mask_k(F[remaining])
        ranks[remaining[mask]] = rank
        remaining = remaining[~mask]
        rank += 1
    return ranks


def crowding_distance(F: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance within one front (larger = lonelier;
    boundary points get ``inf``).  Ties broken stably by index."""
    F = np.asarray(F, dtype=np.float64)
    n, k = F.shape
    if n <= 2:
        return np.full(n, np.inf)
    d = np.zeros(n, dtype=np.float64)
    for j in range(k):
        order = np.argsort(F[:, j], kind="stable")
        fj = F[order, j]
        span = fj[-1] - fj[0]
        d[order[0]] = d[order[-1]] = np.inf
        if span > 0:
            d[order[1:-1]] += (fj[2:] - fj[:-2]) / span
    return d


# ---------------------------------------------------------------------------
# Hypervolume (exact, minimization, reference point r: hv of the region
# dominated by the set and dominating r)
# ---------------------------------------------------------------------------

def _hv2d(F: np.ndarray, ref: np.ndarray) -> float:
    """Closed-form 2-D hypervolume: sort by f0 and sweep."""
    order = np.lexsort((F[:, 1], F[:, 0]))
    hv = 0.0
    prev1 = ref[1]
    for p0, p1 in F[order]:
        if p1 < prev1:
            hv += (ref[0] - p0) * (prev1 - p1)
            prev1 = p1
    return hv


def _hv_recursive(F: np.ndarray, ref: np.ndarray) -> float:
    k = len(ref)
    if len(F) == 0:
        return 0.0
    if k == 1:
        return float(ref[0] - F[:, 0].min())
    if k == 2:
        return _hv2d(F, ref)
    # slice along the last objective (HSO): between consecutive levels the
    # (k-1)-D cross-section is the projection of every point at or below
    # the lower level
    order = np.argsort(F[:, -1], kind="stable")
    F = F[order]
    zs = np.unique(F[:, -1])
    hv = 0.0
    for j, z in enumerate(zs):
        z_next = zs[j + 1] if j + 1 < len(zs) else ref[-1]
        sub = F[F[:, -1] <= z, :-1]
        sub = sub[pareto_mask_k(sub)]               # shrink the recursion
        hv += (z_next - z) * _hv_recursive(sub, ref[:-1])
    return hv


def hypervolume(F: np.ndarray, ref: np.ndarray) -> float:
    """Exact hypervolume of an ``(N, K)`` minimization set w.r.t. ``ref``.

    Points not strictly better than ``ref`` in every objective contribute
    nothing (standard clipping), so a fixed reference lets fronts from
    different searches be compared on one scale.  Exact algorithms are
    exponential in ``K`` in the worst case — fine for the K <= 5 objective
    sets and population-sized fronts used here.
    """
    F = np.asarray(F, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if F.ndim != 2 or F.shape[1] != len(ref):
        raise ValueError(
            f"objective matrix {F.shape} does not match reference point "
            f"of dimension {len(ref)}")
    F = F[(F < ref[None, :]).all(axis=1)]
    if len(F) == 0:
        return 0.0
    F = np.unique(F, axis=0)
    F = F[pareto_mask_k(F)]
    return float(_hv_recursive(F, ref))


# ---------------------------------------------------------------------------
# Epsilon-dominance archive (Laumanns et al. 2002): the external archive of
# a long-horizon search bounded by a grid instead of growing without limit
# ---------------------------------------------------------------------------

class EpsilonDominanceArchive:
    """Grid-bounded external archive under epsilon-dominance
    (minimization).

    Every point maps to a grid box ``floor(F / epsilon)``.  The archive
    keeps one representative per non-dominated box: a candidate is
    rejected if any archived box dominates its box (componentwise <=,
    somewhere <); an accepted candidate evicts every archived point whose
    box it dominates; within one box the point closest to the box's lower
    corner wins (squared distance in epsilon units, ties broken stably by
    insertion order).  The number of boxes a mutually non-dominated set
    can occupy is bounded by the grid resolution, so a week-long run's
    archive holds **constant memory** regardless of evaluation count,
    while every archived point is within one grid cell of some true
    non-dominated point — hypervolume is preserved up to grid resolution
    (asserted in tests/test_epsilon_archive.py).

    Deterministic: the final contents depend only on the sequence of
    ``add`` batches, and re-inserting the archived points into a fresh
    archive reproduces it exactly (the checkpoint/resume path,
    :mod:`repro.runtime.dse_checkpoint`).
    """

    def __init__(self, epsilon, n_objectives: int | None = None):
        eps = np.atleast_1d(np.asarray(epsilon, dtype=np.float64))
        if n_objectives is not None and len(eps) == 1:
            eps = np.repeat(eps, n_objectives)
        if (eps <= 0).any() or not np.isfinite(eps).all():
            raise ValueError(
                f"epsilon must be positive and finite, got {eps}")
        self.epsilon = eps
        self._genomes: np.ndarray | None = None
        self._F = np.empty((0, len(eps)), dtype=np.float64)
        self._boxes = np.empty((0, len(eps)), dtype=np.int64)

    def __len__(self) -> int:
        return len(self._F)

    @property
    def genomes(self) -> np.ndarray:
        if self._genomes is None:
            return np.empty((0, 0), dtype=np.int64)
        return self._genomes

    @property
    def objectives(self) -> np.ndarray:
        return self._F

    def _box(self, F: np.ndarray) -> np.ndarray:
        return np.floor(F / self.epsilon[None, :]).astype(np.int64)

    def add(self, genomes: np.ndarray, F: np.ndarray) -> int:
        """Offer a batch; returns how many points the archive now holds.

        The batch is folded in insertion order so resume-time replay is
        bit-identical to the original pass.
        """
        genomes = np.asarray(genomes)
        F = np.asarray(F, dtype=np.float64)
        if F.ndim != 2 or F.shape[1] != len(self.epsilon):
            raise ValueError(
                f"objective matrix {F.shape} does not match epsilon of "
                f"dimension {len(self.epsilon)}")
        if len(genomes) != len(F):
            raise ValueError(
                f"{len(genomes)} genomes vs {len(F)} objective rows")
        if self._genomes is None and len(genomes):
            self._genomes = np.empty((0,) + genomes.shape[1:],
                                     dtype=genomes.dtype)
        boxes = self._box(F)
        for i in range(len(F)):
            self._offer(genomes[i], F[i], boxes[i])
        return len(self._F)

    def _offer(self, g, f, b) -> None:
        if len(self._boxes):
            no_worse = (self._boxes <= b[None, :]).all(axis=1)
            better = (self._boxes < b[None, :]).any(axis=1)
            if (no_worse & better).any():
                return                      # box-dominated: reject
            same = (self._boxes == b[None, :]).all(axis=1)
            if same.any():
                j = int(np.nonzero(same)[0][0])   # one rep per box
                # closer to the box's lower corner wins; incumbent keeps
                # ties (stable under replay)
                corner = b * self.epsilon
                d_new = float(np.sum(((f - corner) / self.epsilon) ** 2))
                d_old = float(np.sum(
                    ((self._F[j] - corner) / self.epsilon) ** 2))
                if d_new < d_old:
                    self._genomes[j] = g
                    self._F[j] = f
                    self._boxes[j] = b
                return
            # accepted: evict every box the new box dominates
            dominated = ((b[None, :] <= self._boxes).all(axis=1)
                         & (b[None, :] < self._boxes).any(axis=1))
            if dominated.any():
                keep = ~dominated
                self._genomes = self._genomes[keep]
                self._F = self._F[keep]
                self._boxes = self._boxes[keep]
        self._genomes = np.concatenate([self._genomes, g[None]])
        self._F = np.concatenate([self._F, f[None]])
        self._boxes = np.concatenate([self._boxes, b[None]])

    def front(self) -> tuple[np.ndarray, np.ndarray]:
        """The archive's own non-dominated (genomes, objectives) — box
        representatives can still dominate each other within resolution."""
        keep = pareto_mask_k(self._F)
        return self.genomes[keep], self._F[keep]


def epsilon_from_reference(ref: np.ndarray, ideal: np.ndarray,
                           rel: float) -> np.ndarray:
    """An absolute per-objective epsilon vector from a relative grid
    resolution: ``rel`` of the (ideal, reference) span per objective —
    the convention :func:`repro.explore.search.nsga2` uses to interpret a
    scalar ``archive_epsilon``."""
    if not (0.0 < rel < 1.0):
        raise ValueError(f"relative epsilon must be in (0, 1), got {rel}")
    ref = np.asarray(ref, dtype=np.float64)
    ideal = np.asarray(ideal, dtype=np.float64)
    span = np.abs(ref - ideal)
    span = np.where(span > 0, span, np.maximum(np.abs(ref), 1.0))
    return rel * span


def reference_point(F: np.ndarray, margin: float = 0.05) -> np.ndarray:
    """A reference point slightly worse than every observed objective —
    the convention used to seed a search's hypervolume history."""
    F = np.asarray(F, dtype=np.float64)
    worst = F.max(axis=0)
    span = worst - F.min(axis=0)
    pad = margin * np.where(span > 0, span, np.maximum(np.abs(worst), 1.0))
    return worst + pad
