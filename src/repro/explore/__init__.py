"""Quantization-aware co-exploration subsystem (QADAM/QUIDAM direction).

Searches the joint (accelerator config x per-layer execution precision)
space under k-objective Pareto optimality, on top of the fused sweep
engine.  See :mod:`repro.explore.space` for the genome encoding,
:mod:`repro.explore.search` for the engines, and
:func:`repro.core.dse.coexplore` for the one-call entry point.
"""

from repro.explore.objectives import (DEFAULT_OBJECTIVES, OBJECTIVES,
                                      mode_noise_table, mode_sqnr_db,
                                      objective_matrix, quant_noise)
from repro.explore.pareto import (crowding_distance, hypervolume,
                                  nondominated_sort, pareto_mask_k,
                                  reference_point)
from repro.explore.search import (SEARCH_METHODS, Evaluator, SearchResult,
                                  nsga2, random_search, successive_halving)
from repro.explore.space import CoExploreSpace, space_for_workload

__all__ = [
    "CoExploreSpace", "space_for_workload",
    "OBJECTIVES", "DEFAULT_OBJECTIVES", "objective_matrix", "quant_noise",
    "mode_noise_table", "mode_sqnr_db",
    "pareto_mask_k", "nondominated_sort", "crowding_distance",
    "hypervolume", "reference_point",
    "Evaluator", "SearchResult", "SEARCH_METHODS",
    "random_search", "nsga2", "successive_halving",
]
