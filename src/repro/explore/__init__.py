"""Quantization-aware co-exploration subsystem (QADAM/QUIDAM direction).

Searches the joint (accelerator config x per-layer execution precision)
space under k-objective Pareto optimality, on top of the fused sweep
engine.  See :mod:`repro.explore.space` for the genome encoding,
:mod:`repro.explore.search` for the engines,
:mod:`repro.explore.accuracy` for the tiered accuracy models, and
:func:`repro.core.dse.run` for the one-call entry point.
"""

from repro.explore.accuracy import (AccuracyModel, AccuracySpec,
                                    CalibratedAccuracy, EliteValidation,
                                    ProxyAccuracy, resolve_accuracy,
                                    validate_elites)
from repro.explore.objectives import (DEFAULT_MULTI_OBJECTIVES,
                                      DEFAULT_OBJECTIVES,
                                      LEGACY_OBJECTIVE_ALIASES,
                                      MULTI_OBJECTIVES, OBJECTIVE_REGISTRY,
                                      OBJECTIVES, ObjectiveSpec,
                                      accuracy_floor_violation,
                                      mode_noise_table, mode_sqnr_db,
                                      multi_objective_matrix,
                                      objective_matrix, quant_noise,
                                      reset_sqnr_table, resolve_objectives,
                                      sqnr_floor_violation)
from repro.explore.pareto import (crowding_distance, hypervolume,
                                  nondominated_sort, pareto_mask_k,
                                  reference_point)
from repro.explore.search import (SEARCH_METHODS, Evaluator, SearchResult,
                                  nsga2, random_search, successive_halving)
from repro.explore.space import (CoExploreManySpace, CoExploreSpace,
                                 space_for_workload, space_for_workloads)

__all__ = [
    "CoExploreSpace", "CoExploreManySpace",
    "space_for_workload", "space_for_workloads",
    "OBJECTIVES", "DEFAULT_OBJECTIVES", "objective_matrix", "quant_noise",
    "MULTI_OBJECTIVES", "DEFAULT_MULTI_OBJECTIVES",
    "multi_objective_matrix", "sqnr_floor_violation",
    "accuracy_floor_violation", "ObjectiveSpec", "OBJECTIVE_REGISTRY",
    "LEGACY_OBJECTIVE_ALIASES", "resolve_objectives", "reset_sqnr_table",
    "mode_noise_table", "mode_sqnr_db",
    "AccuracyModel", "AccuracySpec", "ProxyAccuracy", "CalibratedAccuracy",
    "resolve_accuracy", "validate_elites", "EliteValidation",
    "pareto_mask_k", "nondominated_sort", "crowding_distance",
    "hypervolume", "reference_point",
    "Evaluator", "SearchResult", "SEARCH_METHODS",
    "random_search", "nsga2", "successive_halving",
]
