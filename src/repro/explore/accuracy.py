"""Tiered accuracy models for co-exploration, behind one protocol.

Three tiers, one ``score(assign, layer_macs) -> (N,)`` contract (relative
quantization-noise power, MAC-share weighted, 0 = fp32-everywhere):

* **tier 0** (:class:`ProxyAccuracy`) — the synthetic SQNR proxy: one
  noise number per PE type measured once on a fixed Gaussian tensor
  (:func:`repro.explore.objectives.mode_noise_table`).
* **tier 1** (:class:`CalibratedAccuracy`) — per-layer, per-mode noise
  calibrated on real model-zoo tensors
  (:func:`repro.quant.calibrate.calibrate_model`), npz-cached; the
  search loop still pays one table gather per genome.
* **tier 2** — tier-1 scoring during search, plus
  :func:`validate_elites`: the Pareto elites run *actual quantized
  forward passes* (per-layer fake-quantized weights through
  ``quant/quantizers``) on a fixed eval batch, and the front is
  re-scored with measured loss deltas.

Every model exposes ``state()`` / ``restore_state()`` / ``digest()`` so
search checkpoints can pin the exact table a run was scored with —
resumed searches replay bit-identically even if the cache or zoo
changes underneath, and refuse (by digest) to resume against a
different calibration.

Scoring stays pure numpy with row-local reductions (never BLAS gemv),
preserving the bit-identical cross-backend / resume contract of
:func:`repro.explore.objectives.quant_noise`.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.pe import PEType

_TYPES = tuple(PEType)

_TIER_NAMES = {0: "proxy", 1: "calibrated", 2: "measured"}


@runtime_checkable
class AccuracyModel(Protocol):
    """What the exploration stack needs from an accuracy tier."""

    tier: int
    floor_db: float | None

    def score(self, assign: np.ndarray,
              layer_macs: np.ndarray) -> np.ndarray: ...

    def state(self) -> dict[str, np.ndarray]: ...

    def restore_state(self, state: dict[str, np.ndarray]) -> None: ...

    def digest(self) -> str: ...


@dataclasses.dataclass(frozen=True)
class AccuracySpec:
    """Declarative accuracy-tier request (``ExploreSpec(accuracy=...)``).

    ``tier`` 0 needs no model; tiers 1/2 calibrate on zoo config
    ``model``.  ``floor_db`` is the minimum acceptable MAC-weighted SQNR
    — a scalar or (multi-workload) one value per workload; the successor
    of the deprecated ``sqnr_floor_db`` side-channel, valid at any tier.
    ``eval_batch`` / ``eval_seq`` / ``max_elites`` only matter at tier 2
    (the quantized-forward validation pass).
    """

    tier: int = 0
    model: str | None = None
    seed: int = 0
    percentile: float = 99.9
    per_channel: bool = True
    floor_db: float | tuple[float, ...] | None = None
    cache_dir: str | None = None
    eval_batch: int = 4
    eval_seq: int = 64
    max_elites: int = 16

    def __post_init__(self):
        if self.tier not in (0, 1, 2):
            raise ValueError(f"tier must be 0, 1, or 2; got {self.tier}")
        if self.tier == 0 and self.model is not None:
            raise ValueError(
                "tier 0 is the synthetic proxy and takes no model=; use "
                "tier=1/2 (or 'calibrated:<model>' / 'measured:<model>')")
        if self.tier >= 1 and not self.model:
            raise ValueError(
                f"tier {self.tier} calibrates on a zoo model; pass "
                f"model= (e.g. 'mamba2-130m')")
        if self.floor_db is not None:
            fl = (float(self.floor_db) if np.ndim(self.floor_db) == 0
                  else tuple(float(x) for x in np.asarray(self.floor_db)))
            if np.any(np.asarray(fl) <= 0):
                raise ValueError(f"floor_db must be > 0 dB, "
                                 f"got {self.floor_db}")
            object.__setattr__(self, "floor_db", fl)
        if self.tier == 2 and self.max_elites < 1:
            raise ValueError("max_elites must be >= 1")

    @classmethod
    def parse(cls, text: str) -> "AccuracySpec":
        """``"proxy"`` | ``"calibrated:<model>"`` | ``"measured:<model>"``."""
        kind, _, model = text.partition(":")
        tiers = {v: k for k, v in _TIER_NAMES.items()}
        if kind not in tiers or (kind == "proxy") != (not model):
            raise ValueError(
                f"bad accuracy spec {text!r}: expected 'proxy', "
                f"'calibrated:<model>', or 'measured:<model>'")
        return cls(tier=tiers[kind], model=model or None)


def _mac_weighted(table_rows: np.ndarray, assign: np.ndarray,
                  layer_macs: np.ndarray) -> np.ndarray:
    """MAC-share weighted noise with a per-layer (L, T) table.

    Row-local axis-1 reduction, NOT ``@`` (BLAS gemv): gemv blocking
    depends on the batch size N, so the same genome scored in two batch
    compositions would drift by ~1 ulp and break bit-identical resume.
    """
    a = np.asarray(assign, dtype=np.int64)
    macs = np.asarray(layer_macs, dtype=np.float64)
    wts = macs / macs.sum()
    rows = np.arange(a.shape[1])[None, :]
    return (table_rows[rows, a] * wts).sum(axis=1)


def _table_digest(tier: int, table: np.ndarray) -> str:
    from repro.core.confighash import digest_words, f64_words
    lo, hi = f64_words(np.ascontiguousarray(table).ravel())
    words = [np.uint32(tier)] + list(lo) + list(hi)
    with np.errstate(over="ignore"):
        return "".join(f"{int(w):08x}" for w in digest_words(words))


class ProxyAccuracy:
    """Tier 0: the synthetic per-PE-type SQNR proxy.

    Unpinned instances delegate to :func:`objectives.quant_noise` —
    bit-identical to the historical behaviour, so existing golden fronts
    are untouched.  ``restore_state`` pins the exact (T,) table a
    checkpointed run measured, making resume immune to a host whose
    proxy measurement fell back to the analytic model.
    """

    tier = 0

    def __init__(self, spec: AccuracySpec | None = None):
        self.spec = spec or AccuracySpec()
        self.floor_db = self.spec.floor_db
        self._pinned: np.ndarray | None = None

    def _table(self) -> np.ndarray:
        if self._pinned is not None:
            return self._pinned
        from repro.explore.objectives import mode_noise_table
        return np.asarray(mode_noise_table(), dtype=np.float64)

    def score(self, assign, layer_macs) -> np.ndarray:
        if self._pinned is None:
            from repro.explore.objectives import quant_noise
            return quant_noise(assign, layer_macs)
        macs = np.asarray(layer_macs, dtype=np.float64)
        wts = macs / macs.sum()
        a = np.asarray(assign, dtype=np.int64)
        return (self._pinned[a] * wts).sum(axis=1)

    def state(self) -> dict[str, np.ndarray]:
        return {"mode_table": self._table().copy()}

    def restore_state(self, state) -> None:
        self._pinned = np.asarray(state["mode_table"], dtype=np.float64)

    def digest(self) -> str:
        return _table_digest(self.tier, self._table())


class CalibratedAccuracy:
    """Tiers 1/2: per-layer noise from a calibrated zoo model.

    The calibration model's L_m layers are mapped proportionally onto a
    workload's L layers (layer ``i`` reads model row ``floor(i*L_m/L)``)
    so any workload depth shares one table.  Successive-halving prefix
    rungs (m < L) rescale that mapping — a screening heuristic only;
    final fronts are always scored at full depth.
    """

    def __init__(self, spec: AccuracySpec):
        if spec.tier not in (1, 2):
            raise ValueError(f"CalibratedAccuracy needs tier 1/2 "
                             f"spec, got tier {spec.tier}")
        from repro.quant.calibrate import calibrate_model
        self.spec = spec
        self.tier = spec.tier
        self.floor_db = spec.floor_db
        self._table = calibrate_model(
            spec.model, seed=spec.seed, percentile=spec.percentile,
            per_channel=spec.per_channel, cache_dir=spec.cache_dir)
        self._maps: dict[int, np.ndarray] = {}

    @property
    def calibration(self):
        """The underlying :class:`repro.quant.calibrate.CalibrationTable`."""
        return self._table

    def layer_table(self, n_layers: int) -> np.ndarray:
        """(n_layers, T) view of the calibration table for one workload."""
        t = self._maps.get(n_layers)
        if t is None:
            lm = self._table.n_layers
            idx = (np.arange(n_layers, dtype=np.int64) * lm) // n_layers
            t = np.ascontiguousarray(self._table.table[idx])
            self._maps[n_layers] = t
        return t

    def score(self, assign, layer_macs) -> np.ndarray:
        a = np.asarray(assign)
        return _mac_weighted(self.layer_table(a.shape[1]), a, layer_macs)

    def state(self) -> dict[str, np.ndarray]:
        return self._table.state()

    def restore_state(self, state) -> None:
        from repro.quant.calibrate import CalibrationTable
        s = self.spec
        self._table = CalibrationTable(
            model=s.model, seed=s.seed, percentile=s.percentile,
            per_channel=s.per_channel,
            **{k: np.asarray(v, dtype=np.float64) for k, v in state.items()})
        self._maps.clear()

    def digest(self) -> str:
        return self._table.digest()


def resolve_accuracy(accuracy) -> AccuracyModel:
    """Coerce ``None`` / string / :class:`AccuracySpec` / model instance
    to an :class:`AccuracyModel` (the single entry every consumer uses)."""
    if accuracy is None:
        return ProxyAccuracy()
    if isinstance(accuracy, str):
        accuracy = AccuracySpec.parse(accuracy)
    if isinstance(accuracy, AccuracySpec):
        if accuracy.tier == 0:
            return ProxyAccuracy(accuracy)
        return CalibratedAccuracy(accuracy)
    if isinstance(accuracy, AccuracyModel):
        return accuracy
    raise TypeError(
        f"accuracy must be None, a spec string, an AccuracySpec, or an "
        f"AccuracyModel; got {type(accuracy).__name__}")


# ---------------------------------------------------------------------------
# Tier 2: quantized-forward elite validation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EliteValidation:
    """Measured re-scoring of a Pareto front's elites (tier 2).

    ``loss_delta[k]`` is the measured eval-loss increase of elite
    ``elite_indices[k]``'s precision plan over the unquantized fp32
    baseline, from a real forward pass with per-layer fake-quantized
    weights.  ``measured_objectives`` is the elite rows of the front
    matrix with the accuracy column (``accuracy_column``) replaced by
    the measured deltas — or, when the objective set carries no accuracy
    column, with the deltas appended — and ``pareto_mask`` is Pareto
    membership recomputed over those measured rows.
    """

    model: str
    objectives: tuple
    elite_indices: np.ndarray
    baseline_loss: float
    quant_loss: np.ndarray
    loss_delta: np.ndarray
    measured_objectives: np.ndarray
    accuracy_column: int | None
    pareto_mask: np.ndarray

    def summary(self) -> dict:
        return {
            "model": self.model,
            "n_elites": int(len(self.elite_indices)),
            "baseline_loss": float(self.baseline_loss),
            "max_loss_delta": float(self.loss_delta.max()),
            "min_loss_delta": float(self.loss_delta.min()),
            "n_surviving": int(self.pareto_mask.sum()),
        }


def _accuracy_column(objectives) -> int | None:
    acc = {"accuracy_noise", "quant_noise",
           "worst_accuracy_noise", "worst_quant_noise",
           "mean_accuracy_noise", "mean_quant_noise"}
    for k, name in enumerate(objectives):
        if name in acc:
            return k
    return None


def validate_elites(result, accuracy) -> EliteValidation:
    """Run the Pareto elites of a single-workload search through real
    quantized forward passes and re-score the front with measured loss
    deltas (the tier-2 contract).

    Each elite's per-layer precision plan is mapped onto the calibration
    model's layers; every projection weight is fake-quantized with its
    layer's mode (the same :data:`repro.quant.calibrate.PE_QUANT_SPECS`
    the tier-1 table was built from) and the model's loss is measured on
    a fixed synthetic eval batch.  Deterministic end to end: fixed init
    seed, fixed batch, elites deduplicated by mapped plan.
    """
    import jax

    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.explore.pareto import pareto_mask_k
    from repro.models.model import Model
    from repro.quant.calibrate import (PE_QUANT_SPECS, PROJ_NAMES,
                                       _per_channel)
    from repro.quant.quantizers import quantize_dequantize

    model = resolve_accuracy(accuracy)
    spec = getattr(model, "spec", None)
    if spec is None or spec.tier == 0 or not spec.model:
        raise ValueError(
            "validate_elites needs a calibrated accuracy "
            "('calibrated:<model>' / 'measured:<model>' or a tier-1/2 "
            "AccuracySpec), not the tier-0 proxy")
    if getattr(result.space, "n_workloads", 1) > 1:
        raise ValueError(
            "tier-2 elite validation is single-workload only (a "
            "multi-workload genome has no single precision plan to "
            "run the model under)")

    _, assign = result.space.decode(result.genomes)
    n = assign.shape[0]
    if n > spec.max_elites:       # evenly spaced, deterministic subset
        sel = np.unique(np.round(
            np.linspace(0, n - 1, spec.max_elites)).astype(np.int64))
    else:
        sel = np.arange(n, dtype=np.int64)

    cfg = get_config(spec.model)
    calib_cfg = reduced(cfg, n_layers=cfg.n_layers)
    m = Model(calib_cfg)
    params = m.init(jax.random.key(spec.seed))
    data = SyntheticLM(DataConfig(vocab=calib_cfg.vocab,
                                  seq_len=spec.eval_seq,
                                  global_batch=spec.eval_batch,
                                  seed=spec.seed + 2))
    batch = data.batch(0)
    baseline = float(m.loss(params, batch, train=False))

    lm, lw = calib_cfg.n_layers, assign.shape[1]
    # model layer j runs under the plan of workload layer floor(j*lw/lm)
    wl_of = (np.arange(lm, dtype=np.int64) * lw) // lm

    def quantized_loss(plan: np.ndarray) -> float:
        layers = dict(params["layers"])
        for name, leaf in params["layers"].items():
            if name not in PROJ_NAMES or np.ndim(leaf) != 3:
                continue
            rows = []
            for j in range(lm):
                wspec = PE_QUANT_SPECS[_TYPES[int(plan[j])]][0]
                if wspec is not None and spec.per_channel:
                    wspec = _per_channel(wspec)
                w = leaf[j]
                rows.append(w if wspec is None
                            else quantize_dequantize(w, wspec))
            layers[name] = jax.numpy.stack(rows)
        return float(m.loss({**params, "layers": layers}, batch,
                            train=False))

    plans = assign[sel][:, wl_of]                    # (M, lm) mode indices
    losses = np.zeros(len(sel), dtype=np.float64)
    seen: dict[bytes, float] = {}
    for k, plan in enumerate(plans):
        key = plan.astype(np.int64).tobytes()
        if key not in seen:
            seen[key] = quantized_loss(plan)
        losses[k] = seen[key]

    delta = losses - baseline
    F = np.asarray(result.front_objectives, dtype=np.float64)[sel]
    col = _accuracy_column(result.objectives)
    measured = F.copy()
    if col is None:
        measured = np.concatenate([measured, delta[:, None]], axis=1)
    else:
        measured[:, col] = delta
    return EliteValidation(
        model=spec.model, objectives=tuple(result.objectives),
        elite_indices=sel, baseline_loss=baseline, quant_loss=losses,
        loss_delta=delta, measured_objectives=measured,
        accuracy_column=col, pareto_mask=pareto_mask_k(measured))
