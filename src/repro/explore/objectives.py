"""Objective functions for co-exploration (all minimized).

Hardware objectives come straight from the fused sweep's aggregate columns
(perf/area negated, energy, EDP, area).  The *accuracy* objective
(``accuracy_noise``) is a quantization-noise score: by default the tier-0
synthetic proxy — each layer contributes its MAC share times the relative
noise power (1/SQNR) of its assigned execution mode, with per-PE-type
SQNR measured on the actual quantizers in :mod:`repro.quant.quantizers` —
and, when an :mod:`repro.explore.accuracy` model is threaded in
(``accuracy=``), the tier-1 table calibrated on real model-zoo tensors.

Every known objective lives in :data:`OBJECTIVE_REGISTRY`; the historical
``quant_noise`` / ``worst_quant_noise`` / ``mean_quant_noise`` objective
*names* remain accepted everywhere through :func:`resolve_objectives`
with a ``DeprecationWarning``.

The tier-0 SQNR table is measured once per (jax backend, x64 flag)
(seeded, float32) — deterministic, and keyed so flipping the backend or
enabling x64 mid-process cannot silently reuse a stale table
(:func:`reset_sqnr_table` clears the cache for tests).  When jax is
unusable the table falls back to the standard analytic SQNR model
(~6.02 dB/bit for integer, LightNN-published figures for pow2) so the
search still runs.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.pe import PEType


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """One registered objective: canonical name, which evaluation scope
    provides it, and a one-line description for reports."""

    name: str
    scope: str          # "single" | "serving" | "multi"
    description: str

    def __post_init__(self):
        if self.scope not in ("single", "serving", "multi"):
            raise ValueError(f"bad scope {self.scope!r}")


_REGISTRY_SPECS = (
    ObjectiveSpec("neg_perf_per_area", "single",
                  "negated TOPS/mm^2 of the synthesized design"),
    ObjectiveSpec("energy_j", "single", "energy per inference"),
    ObjectiveSpec("edp", "single", "energy-delay product"),
    ObjectiveSpec("area_mm2", "single", "die area"),
    ObjectiveSpec("accuracy_noise", "single",
                  "MAC-weighted relative quantization-noise power "
                  "(tier-0 proxy or tier-1 calibrated)"),
    # serving-fleet objectives (single-workload only): the candidate's
    # fused sweep aggregates feed the trace-driven fleet simulator
    # (repro.serving.fleet_sim) and the search optimizes what a serving
    # deployment actually pays for — tail latency under load, SLO hit
    # rate, sustained token throughput, energy per *served* token.  All
    # minimized, so attainment/throughput are negated.
    ObjectiveSpec("p50_latency_s", "serving", "median request latency"),
    ObjectiveSpec("p99_latency_s", "serving", "tail request latency"),
    ObjectiveSpec("neg_slo_attainment", "serving",
                  "negated fraction of requests inside the SLO"),
    ObjectiveSpec("neg_throughput_tps", "serving",
                  "negated sustained tokens/s"),
    ObjectiveSpec("energy_per_token_j", "serving",
                  "energy per served token (occupancy-sensitive)"),
    # multi-workload objectives (shared hardware, per-workload
    # assignments): worst_* is the max over the workload suite, mean_*
    # the weighted mean (default weights: each workload's share of the
    # genome's total energy)
    ObjectiveSpec("neg_worst_perf_per_area", "multi",
                  "negated worst-case perf/area over the suite"),
    ObjectiveSpec("worst_latency_s", "multi", "worst-case latency"),
    ObjectiveSpec("mean_latency_s", "multi", "weighted-mean latency"),
    ObjectiveSpec("worst_edp", "multi", "worst-case EDP"),
    ObjectiveSpec("mean_edp", "multi", "weighted-mean EDP"),
    ObjectiveSpec("total_energy_j", "multi", "suite energy"),
    ObjectiveSpec("worst_accuracy_noise", "multi",
                  "worst-case accuracy noise over the suite"),
    ObjectiveSpec("mean_accuracy_noise", "multi",
                  "weighted-mean accuracy noise"),
)

OBJECTIVE_REGISTRY: dict[str, ObjectiveSpec] = {
    s.name: s for s in _REGISTRY_SPECS}

# historical objective names -> canonical (all still accepted, warning)
LEGACY_OBJECTIVE_ALIASES = {
    "quant_noise": "accuracy_noise",
    "worst_quant_noise": "worst_accuracy_noise",
    "mean_quant_noise": "mean_accuracy_noise",
}


def _scope(scope: str) -> tuple[str, ...]:
    return tuple(s.name for s in _REGISTRY_SPECS if s.scope == scope)


OBJECTIVES = _scope("single")
SERVING_OBJECTIVES = _scope("serving")
MULTI_OBJECTIVES = _scope("multi")
DEFAULT_OBJECTIVES = ("neg_perf_per_area", "energy_j", "accuracy_noise")
DEFAULT_SERVING_OBJECTIVES = ("p99_latency_s", "energy_per_token_j",
                              "accuracy_noise")
DEFAULT_MULTI_OBJECTIVES = ("neg_worst_perf_per_area", "total_energy_j",
                            "worst_accuracy_noise")


def resolve_objectives(objectives, *, stacklevel: int = 2,
                       scope: str | None = None) -> tuple[str, ...]:
    """Canonicalize an objective-name sequence against the registry.

    Legacy aliases (:data:`LEGACY_OBJECTIVE_ALIASES`) resolve to their
    canonical names with a ``DeprecationWarning`` attributed
    ``stacklevel`` frames up; unknown names raise.  ``scope`` restricts
    the registry ("single" additionally admits serving objectives, which
    are single-workload by construction).
    """
    out = []
    for name in objectives:
        if name in LEGACY_OBJECTIVE_ALIASES:
            new = LEGACY_OBJECTIVE_ALIASES[name]
            warnings.warn(
                f"objective name {name!r} is deprecated; use {new!r}",
                DeprecationWarning, stacklevel=stacklevel)
            name = new
        spec = OBJECTIVE_REGISTRY.get(name)
        if spec is None:
            raise ValueError(
                f"unknown objective {name!r} (choose from "
                f"{tuple(OBJECTIVE_REGISTRY)})")
        if scope == "single" and spec.scope == "multi":
            raise ValueError(
                f"objective {name!r} is multi-workload only")
        if scope == "multi" and spec.scope != "multi":
            if spec.scope == "serving":
                raise ValueError(
                    f"serving objective {name!r} is single-workload only "
                    f"(one traffic trace drives one fleet)")
            raise ValueError(
                f"objective {name!r} is not a multi-workload objective "
                f"(choose from {MULTI_OBJECTIVES})")
        out.append(name)
    return tuple(out)

# static-penalty scale for SQNR-floor constraint violations: any genome
# breaking an accuracy floor lands far outside the feasible objective
# ranges in every dimension, so feasible points always dominate it
FLOOR_PENALTY = 1e9

_TYPES = tuple(PEType)

# analytic fallback noise powers (weight + activation, relative to signal):
# integer b-bit symmetric quantization ~ 10**(-(6.02*b + 1.76)/10); pow2
# codes measured in the LightNN paper are a few dB worse than int at equal
# width.  Order: tuple(PEType) = (FP32, INT16, LIGHTPE1, LIGHTPE2).
_ANALYTIC_NOISE = {
    PEType.FP32: 0.0,
    PEType.INT16: 2 * 10.0 ** (-(6.02 * 16 + 1.76) / 10.0),
    PEType.LIGHTPE1: 10.0 ** (-(6.02 * 4 - 4.0) / 10.0)
    + 10.0 ** (-(6.02 * 8 + 1.76) / 10.0),
    PEType.LIGHTPE2: 10.0 ** (-(6.02 * 8 - 4.0) / 10.0)
    + 10.0 ** (-(6.02 * 8 + 1.76) / 10.0),
}

# measured tier-0 tables, keyed on (jax backend, x64 flag): a process
# that flips jax.config.jax_enable_x64 or lands on a different backend
# re-measures instead of silently reusing a table from another numerics
# regime.  ("analytic", False) keys the jax-unusable fallback.
_NOISE_TABLES: dict[tuple[str, bool], np.ndarray] = {}


def _noise_table_key() -> tuple[str, bool]:
    import jax
    return (jax.default_backend(), bool(jax.config.jax_enable_x64))


def reset_sqnr_table() -> None:
    """Drop every memoized tier-0 SQNR table (tests / backend flips)."""
    _NOISE_TABLES.clear()


def _measure_noise_table() -> np.ndarray:
    """Per-PE-type relative quantization-noise power, measured by running
    the repo's own quantizers over a fixed synthetic Gaussian tensor.

    noise(mode) = E[(w - qdq(w))^2]/E[w^2] + E[(x - qdq_act(x))^2]/E[x^2]
    with the weight/activation quantizer pairs every tier shares
    (:data:`repro.quant.calibrate.PE_QUANT_SPECS`).
    """
    import jax.numpy as jnp

    from repro.quant.calibrate import PE_QUANT_SPECS
    from repro.quant.quantizers import quantize_dequantize

    rng = np.random.default_rng(20220516)          # paper's arXiv date
    w = jnp.asarray(rng.normal(size=8192).astype(np.float32))
    x = jnp.asarray(np.abs(rng.normal(size=8192)).astype(np.float32))

    def rel_noise(v, q):
        v64 = np.asarray(v, dtype=np.float64)
        q64 = np.asarray(q, dtype=np.float64)
        return float(np.mean((v64 - q64) ** 2) / np.mean(v64 ** 2))

    table = np.zeros(len(_TYPES), dtype=np.float64)
    for t, (wspec, aspec) in PE_QUANT_SPECS.items():
        n = 0.0
        if wspec is not None:
            n += rel_noise(w, quantize_dequantize(w, wspec))
        if aspec is not None:
            n += rel_noise(x, quantize_dequantize(x, aspec))
        table[_TYPES.index(t)] = n
    return table


def mode_noise_table(refresh: bool = False) -> np.ndarray:
    """``(T,)`` relative noise power per PE type (canonical order), from
    the measured quantizers when jax is usable, else the analytic model."""
    try:
        key = _noise_table_key()
        if key not in _NOISE_TABLES or refresh:
            _NOISE_TABLES[key] = _measure_noise_table()
        return _NOISE_TABLES[key]
    except ImportError as exc:
        # only the jax-unusable case falls back (loudly); a bug inside
        # the measurement must raise, not silently shift the objective
        key = ("analytic", False)
        if key not in _NOISE_TABLES or refresh:
            warnings.warn(
                f"jax unusable ({exc}); quantization-noise objective uses "
                f"the analytic SQNR model instead of measured quantizers",
                RuntimeWarning, stacklevel=2)
            _NOISE_TABLES[key] = np.array(
                [_ANALYTIC_NOISE[t] for t in _TYPES], dtype=np.float64)
        return _NOISE_TABLES[key]


def mode_sqnr_db() -> dict[str, float]:
    """Human-readable SQNR (dB) per PE type, for reports."""
    table = mode_noise_table()
    out = {}
    for t, n in zip(_TYPES, table):
        out[t.value] = float("inf") if n <= 0 else float(-10 * np.log10(n))
    return out


def quant_noise(assign: np.ndarray, layer_macs: np.ndarray) -> np.ndarray:
    """MAC-weighted quantization-noise score per genome.

    ``assign`` is the ``(N, L)`` mode-index matrix, ``layer_macs`` the
    ``(L,)`` MAC counts; the score is the noise power of each layer's mode
    weighted by its share of the workload's MACs — a scale-free [0, ~1)
    proxy where 0 is fp32-everywhere.
    """
    table = mode_noise_table()
    macs = np.asarray(layer_macs, dtype=np.float64)
    wts = macs / macs.sum()
    # row-local axis-1 reduction, NOT `@` (BLAS gemv): gemv blocking
    # depends on the batch size N, so the same genome scored in two
    # different batch compositions drifts by ~1 ulp — which would break
    # the bit-identical resume contract of the exploration checkpoints
    return (table[np.asarray(assign, dtype=np.int64)] * wts).sum(axis=1)


def serving_metrics(agg: dict[str, np.ndarray], traffic, *,
                    n_slots: int = 8,
                    sim_backend: str = "numpy") -> dict[str, np.ndarray]:
    """Fleet-simulator metrics for every candidate in a sweep aggregate.

    Each candidate's ``latency_s`` is one batcher iteration and
    ``energy_j`` one token-slot of energy; the shared ``traffic`` trace
    is replayed on an ``n_slots`` fleet per candidate.  The simulator's
    integer core is bit-identical across its backends, so the default
    ``sim_backend="numpy"`` (which also avoids per-horizon jax
    recompiles) loses nothing — parity is pinned in
    ``tests/test_fleet_sim.py``.
    """
    from repro.serving.fleet_sim import simulate_fleet
    res = simulate_fleet(np.asarray(agg["latency_s"], dtype=np.float64),
                         np.asarray(agg["energy_j"], dtype=np.float64),
                         traffic, n_slots=n_slots, backend=sim_backend)
    return res.metrics()


def objective_matrix(agg: dict[str, np.ndarray],
                     assign: np.ndarray,
                     layer_macs: np.ndarray,
                     objectives=DEFAULT_OBJECTIVES, *,
                     traffic=None, n_slots: int = 8,
                     sim_backend: str = "numpy",
                     accuracy=None) -> np.ndarray:
    """Assemble the ``(N, K)`` minimization matrix from sweep aggregates.

    ``agg`` is the fused mixed-precision sweep output (the aggregate
    columns plus ``area_mm2``); every objective is oriented so smaller is
    better.  Serving-fleet objectives (:data:`SERVING_OBJECTIVES`)
    require ``traffic`` — a trace / preset / preset name (see
    :func:`repro.serving.traffic.resolve_traffic`); an overloaded
    candidate's infinite tail latency / energy-per-token is clamped to
    :data:`FLOOR_PENALTY` so it stays comparable yet always dominated.

    ``accuracy`` is an :class:`repro.explore.accuracy.AccuracyModel`
    scoring the ``accuracy_noise`` column (``None`` = the tier-0 proxy,
    identical to the historical behaviour); a model carrying a
    ``floor_db`` turns that floor into a static penalty on every
    objective (see :func:`accuracy_floor_violation`).
    """
    objectives = resolve_objectives(objectives, stacklevel=3,
                                    scope="single")
    score = quant_noise if accuracy is None else accuracy.score
    need_serving = [n for n in objectives if n in SERVING_OBJECTIVES]
    fleet = None
    if need_serving:
        if traffic is None:
            raise ValueError(
                f"objectives {need_serving} need traffic= (a TrafficTrace,"
                f" TrafficPreset, or preset name)")
        fleet = serving_metrics(agg, traffic, n_slots=n_slots,
                                sim_backend=sim_backend)

    def clamp(col):
        return np.minimum(np.asarray(col, dtype=np.float64), FLOOR_PENALTY)

    cols = []
    for name in objectives:
        if name == "neg_perf_per_area":
            cols.append(-np.asarray(agg["perf_per_area"], dtype=np.float64))
        elif name == "energy_j":
            cols.append(np.asarray(agg["energy_j"], dtype=np.float64))
        elif name == "edp":
            cols.append(np.asarray(agg["energy_j"], dtype=np.float64)
                        * np.asarray(agg["latency_s"], dtype=np.float64))
        elif name == "area_mm2":
            cols.append(np.asarray(agg["area_mm2"], dtype=np.float64))
        elif name == "accuracy_noise":
            cols.append(score(assign, layer_macs))
        elif name in ("p50_latency_s", "p99_latency_s"):
            cols.append(clamp(fleet[name]))
        elif name == "neg_slo_attainment":
            cols.append(-np.asarray(fleet["slo_attainment"],
                                    dtype=np.float64))
        elif name == "neg_throughput_tps":
            cols.append(-np.asarray(fleet["throughput_tps"],
                                    dtype=np.float64))
        elif name == "energy_per_token_j":
            cols.append(clamp(fleet["energy_per_token_j"]))
        else:                     # registry-validated: unreachable
            raise AssertionError(name)
    F = np.stack(cols, axis=-1)
    floor_db = getattr(accuracy, "floor_db", None)
    if floor_db is not None:
        v = accuracy_floor_violation([assign], [layer_macs], floor_db,
                                     accuracy=accuracy)
        F = F + (FLOOR_PENALTY * v)[:, None]
    return F


# ---------------------------------------------------------------------------
# Multi-workload objectives (the QUIDAM co-exploration setting)
# ---------------------------------------------------------------------------

def accuracy_floor_violation(assigns, layer_macs_list, floor_db,
                             accuracy=None) -> np.ndarray:
    """Per-genome violation of per-workload SQNR accuracy floors.

    ``floor_db`` is the minimum acceptable MAC-weighted SQNR in dB, a
    scalar (shared floor) or one value per workload.  A workload's
    accuracy-noise score (from ``accuracy``, default the tier-0 proxy)
    must stay below the ceiling ``10**(-floor_db/10)``; the violation is
    the summed relative excess ``max(0, noise_w - ceiling_w)/ceiling_w``
    over workloads — zero for feasible genomes.  Pure function of the
    assignment, so it is backend-independent and memo-safe.
    """
    score = quant_noise if accuracy is None else accuracy.score
    floors = np.broadcast_to(np.asarray(floor_db, dtype=np.float64),
                             (len(assigns),))
    ceil = 10.0 ** (-floors / 10.0)
    v = np.zeros(len(np.asarray(assigns[0])), dtype=np.float64)
    for a, macs, c in zip(assigns, layer_macs_list, ceil):
        noise = score(a, macs)
        v += np.maximum(0.0, noise - c) / c
    return v


def sqnr_floor_violation(assigns, layer_macs_list,
                         floor_db) -> np.ndarray:
    """Deprecated name for :func:`accuracy_floor_violation`."""
    warnings.warn(
        "sqnr_floor_violation is deprecated; use accuracy_floor_violation",
        DeprecationWarning, stacklevel=2)
    return accuracy_floor_violation(assigns, layer_macs_list, floor_db)


def multi_objective_matrix(agg: dict[str, np.ndarray],
                           assigns,
                           layer_macs_list,
                           objectives=DEFAULT_MULTI_OBJECTIVES,
                           weights=None,
                           sqnr_floor_db=None,
                           accuracy=None) -> np.ndarray:
    """Assemble the ``(N, K)`` minimization matrix for a workload suite.

    ``agg`` holds the ``(W, N)`` aggregate columns from
    :func:`repro.core.dse_batch.sweep_mixed_many`, ``assigns`` the
    per-workload ``(N, L_w)`` mode matrices, ``layer_macs_list`` the
    per-workload ``(L_w,)`` MAC counts.

    ``worst_*`` objectives take the max over the workload axis — the
    QUIDAM-style guarantee that Pareto claims hold for *every* workload,
    not just on average.  ``mean_*`` objectives are weighted means:
    ``weights`` is either a fixed ``(W,)`` importance vector (normalized
    internally) or ``None`` for *energy-weighted* means, where each
    workload's weight is its share of the genome's own total energy — a
    workload the design spends most of its energy on dominates the mean.

    ``sqnr_floor_db`` (scalar or per-workload) turns per-workload accuracy
    floors into constraints via a static penalty: the summed relative
    floor violation times :data:`FLOOR_PENALTY` is added to **every**
    objective, so infeasible genomes are dominated by all feasible ones
    while remaining comparable among themselves (less violation wins).

    ``accuracy`` is an :class:`repro.explore.accuracy.AccuracyModel`
    scoring the ``*_accuracy_noise`` columns (``None`` = tier-0 proxy).
    A floor may come from either ``sqnr_floor_db`` or the model's own
    ``floor_db`` — specifying both is an error.
    """
    objectives = resolve_objectives(objectives, stacklevel=3,
                                    scope="multi")
    score = quant_noise if accuracy is None else accuracy.score
    model_floor = getattr(accuracy, "floor_db", None)
    if sqnr_floor_db is not None and model_floor is not None:
        raise ValueError(
            f"both sqnr_floor_db={sqnr_floor_db} and the accuracy "
            f"model's floor_db={model_floor} set an accuracy floor; "
            f"pick one")
    floor_db = model_floor if sqnr_floor_db is None else sqnr_floor_db
    lat = np.asarray(agg["latency_s"], dtype=np.float64)
    energy = np.asarray(agg["energy_j"], dtype=np.float64)
    if lat.ndim != 2:
        raise ValueError(
            f"multi-workload aggregates must be (W, N), got {lat.shape}")
    w_count = lat.shape[0]
    if len(assigns) != w_count or len(layer_macs_list) != w_count:
        raise ValueError(
            f"{len(assigns)} assignment matrices / "
            f"{len(layer_macs_list)} MAC vectors for {w_count} workloads")
    if weights is None:
        # energy-weighted: each workload's share of this genome's energy
        wts = energy / energy.sum(axis=0, keepdims=True)      # (W, N)
    else:
        wts = np.asarray(weights, dtype=np.float64)
        if wts.shape != (w_count,) or (wts < 0).any() or wts.sum() <= 0:
            raise ValueError(
                f"weights must be (W,) non-negative with positive sum, "
                f"got {weights!r}")
        wts = (wts / wts.sum())[:, None]                      # (W, 1)

    edp = energy * lat
    noise = None

    def _noise():
        nonlocal noise
        if noise is None:
            noise = np.stack([score(a, m) for a, m in
                              zip(assigns, layer_macs_list)])  # (W, N)
        return noise

    cols = []
    for name in objectives:
        if name == "neg_worst_perf_per_area":
            ppa = np.asarray(agg["perf_per_area"], dtype=np.float64)
            cols.append(-ppa.min(axis=0))
        elif name == "worst_latency_s":
            cols.append(lat.max(axis=0))
        elif name == "mean_latency_s":
            cols.append((wts * lat).sum(axis=0))
        elif name == "worst_edp":
            cols.append(edp.max(axis=0))
        elif name == "mean_edp":
            cols.append((wts * edp).sum(axis=0))
        elif name == "total_energy_j":
            cols.append(energy.sum(axis=0))
        elif name == "worst_accuracy_noise":
            cols.append(_noise().max(axis=0))
        elif name == "mean_accuracy_noise":
            cols.append((wts * _noise()).sum(axis=0))
        else:                     # registry-validated: unreachable
            raise AssertionError(name)
    F = np.stack(cols, axis=-1)
    if floor_db is not None:
        v = accuracy_floor_violation(assigns, layer_macs_list, floor_db,
                                     accuracy=accuracy)
        F = F + (FLOOR_PENALTY * v)[:, None]
    return F
