"""Guided multi-objective search over the joint design space.

Three engines share one chunked, memoized evaluator that routes every
genome population through the fused mixed-precision sweep kernel
(:func:`repro.core.dse_batch.sweep_mixed`, aggregates-only outputs) and
the digest-keyed synthesis caches:

* :func:`random_search` — the baseline the guided searches must beat at
  equal evaluation budget (benchmarked in ``BENCH_coexplore.json``);
* :func:`nsga2` — NSGA-II-style evolutionary loop: non-dominated sorting,
  crowding distance, binary tournaments, uniform crossover + resampling
  mutation;
* :func:`successive_halving` — a budget-aware racing loop that screens
  large populations on cheap layer-prefix subsets of the workload and
  promotes only the best fraction to full evaluation.

Determinism: every loop threads one explicit ``numpy.random.Generator``
(no hidden global RNG), random draws happen in data-independent order, and
all ranking ties break stably by index — the same seed reproduces the same
search trajectory, and the numpy/jax kernel parity (~1e-7) makes the final
fronts match across backends (asserted in ``tests/test_explore.py``).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Sequence

import numpy as np

from repro.core.dse_batch import (_mesh_shards, _sweep_mixed,
                                  _sweep_mixed_many, resolve_backend,
                                  resolve_use_pallas)
from repro.core.workloads import Workload, get_workload
from repro.explore.accuracy import resolve_accuracy
from repro.explore.objectives import (DEFAULT_MULTI_OBJECTIVES,
                                      DEFAULT_OBJECTIVES,
                                      DEFAULT_SERVING_OBJECTIVES,
                                      SERVING_OBJECTIVES,
                                      multi_objective_matrix,
                                      objective_matrix,
                                      resolve_objectives)
from repro.explore.pareto import (EpsilonDominanceArchive,
                                  crowding_distance, epsilon_from_reference,
                                  hypervolume, nondominated_sort,
                                  pareto_mask_k, reference_point)
from repro.explore.space import CoExploreManySpace, CoExploreSpace
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class SearchResult:
    """Outcome of one co-exploration run.

    ``genomes`` / ``front_objectives`` hold the final non-dominated set;
    ``history`` is ``(evaluations, hypervolume)`` pairs under
    ``ref_point``; ``all_objectives`` keeps every *full-workload*
    objective row (successive-halving's subset-rung rows are excluded —
    they live on a different scale) so runs can be re-scored under a
    shared reference point.
    """

    method: str
    workload: str
    objectives: tuple[str, ...]
    seed: int
    space: CoExploreSpace
    genomes: np.ndarray
    front_objectives: np.ndarray
    ref_point: np.ndarray
    history: list[tuple[int, float]]
    all_objectives: np.ndarray
    n_evals: int
    stats: dict
    # final evolutionary population (nsga2 only): the returned front is the
    # unbounded external archive, which is a superset of this population's
    # own non-dominated set
    population: np.ndarray | None = None
    population_objectives: np.ndarray | None = None
    # tier-2 quantized-forward elite validation, attached by
    # repro.core.dse when the accuracy spec asks for it
    validation: object | None = None

    @property
    def front_size(self) -> int:
        return len(self.genomes)

    def hypervolume(self, ref: np.ndarray | None = None) -> float:
        """Front hypervolume under ``ref`` (default: the run's own)."""
        return hypervolume(self.front_objectives,
                           self.ref_point if ref is None else ref)

    def front_points(self) -> list[dict]:
        """Materialize the front: config objects, per-layer mode names,
        objective values — sorted by the first objective.

        Multi-workload runs report ``modes`` as a dict keyed by workload
        name (each value the workload's own per-layer mode tuple) instead
        of a flat tuple.
        """
        from repro.core.accelerator import soa_to_configs
        from repro.core.pe import PEType
        types = tuple(PEType)
        soa, assign = self.space.decode(self.genomes)
        cfgs = soa_to_configs(soa)
        order = np.argsort(self.front_objectives[:, 0], kind="stable")
        if isinstance(self.space, CoExploreManySpace):
            names = (self.space.workload_names
                     or tuple(f"workload{w}"
                              for w in range(self.space.n_workloads)))

            def modes_of(i):
                return {nm: tuple(types[j].value for j in assign[i, s:e])
                        for nm, (s, e) in zip(names,
                                              self.space.segment_bounds)}
        else:
            def modes_of(i):
                return tuple(types[j].value for j in assign[i])
        return [{
            "config": cfgs[i],
            "modes": modes_of(i),
            **{name: float(self.front_objectives[i, k])
               for k, name in enumerate(self.objectives)},
        } for i in order]


def _fold_floor(accuracy, sqnr_floor_db, *, stacklevel: int = 3):
    """Fold the deprecated ``sqnr_floor_db=`` side-channel into an
    accuracy spec (``AccuracySpec(floor_db=...)``).  Raises if the caller
    supplies both spellings — floors ride on the accuracy model now."""
    if sqnr_floor_db is None:
        return accuracy
    warnings.warn(
        "sqnr_floor_db= is deprecated; pass "
        "accuracy=AccuracySpec(floor_db=...) instead",
        DeprecationWarning, stacklevel=stacklevel)
    if accuracy is not None:
        raise ValueError(
            "pass either accuracy= or the deprecated sqnr_floor_db=, not "
            "both; put the floor on the accuracy spec (floor_db=)")
    from repro.explore.accuracy import AccuracySpec
    return AccuracySpec(floor_db=sqnr_floor_db)


class Evaluator:
    """Chunked, memoized genome evaluation through the fused sweep.

    Populations are decoded to (hardware SoA, assignment) and pushed
    through :func:`sweep_mixed` with ``outputs="aggregates"`` — under jax
    the (N, L) layer intermediates are dead-code-eliminated, chunks are
    padded to power-of-two shapes so a search compiles O(log) kernels.
    Results are memoized by genome digest, so an evolutionary loop that
    re-visits a genome never re-runs the kernel; hardware re-visits hit
    the digest-keyed synthesis cache inside ``sweep_mixed``.

    **Multi-workload mode** (the QUIDAM co-exploration setting): pass a
    *sequence* of workloads together with a
    :class:`~repro.explore.space.CoExploreManySpace` — genomes then carry
    one mode segment per workload, evaluation routes through
    :func:`sweep_mixed_many` (one fused kernel call for all W workloads,
    synthesis shared per hardware digest), and objectives come from
    :func:`repro.explore.objectives.multi_objective_matrix` (worst-case /
    weighted-mean across the suite).

    ``accuracy`` selects the accuracy tier scoring the
    ``accuracy_noise`` columns — anything
    :func:`repro.explore.accuracy.resolve_accuracy` takes (``None`` =
    tier-0 proxy); an :class:`~repro.explore.accuracy.AccuracySpec`
    ``floor_db`` turns per-workload SQNR floors into constraints.
    ``sqnr_floor_db`` is the deprecated spelling of that floor.
    """

    def __init__(self, space: CoExploreSpace,
                 workload: Workload | str | Sequence[Workload | str],
                 objectives: Sequence[str] | None = None,
                 *, backend: str = "auto", chunk_size: int = 4096,
                 use_cache: bool = True, weights=None,
                 sqnr_floor_db=None, mesh=None, traffic=None,
                 n_slots: int = 8, use_pallas: bool | None = None,
                 accuracy=None):
        accuracy = _fold_floor(accuracy, sqnr_floor_db, stacklevel=3)
        self.accuracy = (None if accuracy is None
                         else resolve_accuracy(accuracy))
        self.space = space
        self.multi = isinstance(workload, (list, tuple))
        if self.multi:
            wls = tuple(get_workload(w) if isinstance(w, str) else w
                        for w in workload)
            if not isinstance(space, CoExploreManySpace):
                raise ValueError(
                    "a workload sequence needs a CoExploreManySpace "
                    "(see repro.explore.space.space_for_workloads)")
            counts = tuple(len(w.layers) for w in wls)
            if space.layer_counts != counts:
                raise ValueError(
                    f"space layer_counts {space.layer_counts} != workload "
                    f"layer counts {counts}")
            self.workloads = wls
            self.workload = None
        else:
            wl = (get_workload(workload)
                  if isinstance(workload, str) else workload)
            if space.n_layers != len(wl.layers):
                raise ValueError(
                    f"space has {space.n_layers} layer genes but workload "
                    f"{wl.name!r} has {len(wl.layers)} layers")
            self.workloads = (wl,)
            self.workload = wl
        # traffic= switches the default objective set to the serving
        # triple; explicit serving objectives without a trace are an
        # error (the fleet simulator needs a workload to replay), as is
        # serving in multi-workload mode (one trace drives one fleet)
        if objectives is None:
            if traffic is not None and not self.multi:
                objectives = DEFAULT_SERVING_OBJECTIVES
            else:
                objectives = (DEFAULT_MULTI_OBJECTIVES if self.multi
                              else DEFAULT_OBJECTIVES)
        self.objectives = resolve_objectives(
            objectives, stacklevel=3,
            scope="multi" if self.multi else "single")
        serving = [o for o in self.objectives if o in SERVING_OBJECTIVES]
        if serving and self.multi:
            raise ValueError(
                f"serving objectives {serving} are single-workload only "
                f"(one traffic trace drives one fleet)")
        if serving and traffic is None:
            raise ValueError(
                f"objectives {serving} need traffic= (a TrafficTrace, "
                f"TrafficPreset, or preset name)")
        if traffic is not None and not serving:
            raise ValueError(
                f"traffic= given but no serving objective in "
                f"{self.objectives}; add one of {SERVING_OBJECTIVES} or "
                f"drop traffic=")
        if traffic is not None:
            from repro.serving.traffic import resolve_traffic
            traffic = resolve_traffic(traffic)
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.traffic = traffic
        self.n_slots = int(n_slots)
        self.backend = resolve_backend(backend)
        self.chunk_size = int(chunk_size)
        self.use_cache = use_cache
        self.weights = weights
        # mesh= shards every evaluation chunk's genome axis across devices
        # (jax: shard_map via sweep_mixed / sweep_mixed_many; numpy: an
        # int simulates that many shards bit-identically)
        if self.backend == "jax" and isinstance(mesh, int):
            raise ValueError(
                "backend='jax' needs a jax.sharding.Mesh for mesh=, not "
                "an int shard count (see repro.launch.mesh.make_sweep_mesh)")
        self.mesh = mesh
        # use_pallas routes the fused aggregate reduction through the
        # hand-tiled Pallas sweep kernel (None = auto: only when jax has
        # a real accelerator and no mesh is sharding the genome axis)
        self.use_pallas = resolve_use_pallas(use_pallas, self.backend,
                                             mesh=self.mesh)
        self._memo: dict[tuple[bytes, int], np.ndarray] = {}
        self._subsets: dict[int, tuple] = {}
        self.n_requested = 0
        self.n_kernel = 0
        self.n_memo_hits = 0
        self.eval_seconds = 0.0

    @property
    def name(self) -> str:
        """Workload identity for reports: a single name or ``a+b+c``."""
        return "+".join(w.name for w in self.workloads)

    @property
    def full_subset(self) -> int:
        """The ``m`` that means "every layer": per-workload prefix length
        in multi mode, total layer count otherwise."""
        if self.multi:
            return max(self.space.layer_counts)
        return self.space.n_layers

    def _subset(self, m: int) -> tuple:
        """``(workloads, per-workload macs)`` for prefix length ``m`` —
        in multi mode each workload is cut to its first ``min(m, L_w)``
        layers, so successive-halving rungs race on cheap prefixes of the
        whole suite."""
        if m >= self.full_subset:
            m = self.full_subset
        cached = self._subsets.get(m)
        if cached is None:
            wls = tuple(
                w if m >= len(w.layers) else
                Workload(name=f"{w.name}[:{m}]", layers=w.layers[:m])
                for w in self.workloads)
            macs = tuple(np.array([l.macs for l in w.layers],
                                  dtype=np.float64) for w in wls)
            cached = (wls, macs)
            self._subsets[m] = cached
        return cached

    def _pad(self, n: int) -> int:
        if self.backend != "jax":
            return n
        return min(self.chunk_size, 1 << max(3, (n - 1).bit_length()))

    def _objective_rows(self, wls, macs, soa, assign, n_real) -> np.ndarray:
        """One padded chunk through the fused kernel -> (n_real, K)."""
        if self.multi:
            bounds = self.space.segment_bounds
            assigns = [assign[:, s:e][:, :len(w.layers)]
                       for (s, e), w in zip(bounds, wls)]
            agg = _sweep_mixed_many(wls, soa, assigns,
                                    use_cache=self.use_cache,
                                    backend=self.backend, mesh=self.mesh,
                                    use_pallas=self.use_pallas)
            agg = {k: np.asarray(v)[:, :n_real]
                   for k, v in agg.items() if np.ndim(v) == 2}
            return multi_objective_matrix(
                agg, [a[:n_real] for a in assigns], macs,
                self.objectives, weights=self.weights,
                accuracy=self.accuracy)
        wl, = wls
        agg = _sweep_mixed(wl, soa, assign[:, :len(wl.layers)],
                           use_cache=self.use_cache,
                           backend=self.backend, outputs="aggregates",
                           mesh=self.mesh, use_pallas=self.use_pallas)
        return objective_matrix({k: np.asarray(v)[:n_real]
                                 for k, v in agg.items()},
                                assign[:n_real, :len(wl.layers)],
                                macs[0], self.objectives,
                                traffic=self.traffic,
                                n_slots=self.n_slots,
                                accuracy=self.accuracy)

    def evaluate(self, genomes: np.ndarray,
                 subset: int | None = None) -> np.ndarray:
        """``(N, K)`` objective rows for a genome matrix.

        ``subset`` evaluates on the first ``subset`` layers only (the
        successive-halving rungs; per workload in multi mode); objective
        rows are float64 regardless of backend.
        """
        t0 = time.perf_counter()
        with obs_trace.span("explore.evaluate", n=len(genomes),
                            subset=subset) as esp:
            g = self.space.validate(genomes, raise_on_invalid=True)
            m = self.full_subset if subset is None else min(
                int(subset), self.full_subset)
            self.n_requested += len(g)
            keys = self.space.genome_keys(g)
            out = np.empty((len(g), len(self.objectives)),
                           dtype=np.float64)
            todo: list[int] = []
            for i, key in enumerate(keys):
                row = self._memo.get((key, m))
                if row is None:
                    todo.append(i)
                else:
                    self.n_memo_hits += 1
                    out[i] = row
            wls, macs = self._subset(m)
            for s in range(0, len(todo), self.chunk_size):
                idx = np.asarray(todo[s:s + self.chunk_size],
                                 dtype=np.intp)
                # rows were validated above; skip the per-chunk repeat
                soa, assign = self.space.decode(g[idx],
                                                skip_validation=True)
                pad = self._pad(len(idx)) - len(idx)
                if pad > 0:
                    soa = {k: np.concatenate([v,
                                              v[-1:].repeat(pad, axis=0)])
                           for k, v in soa.items()}
                    assign = np.concatenate(
                        [assign, assign[-1:].repeat(pad, axis=0)])
                out[idx] = self._objective_rows(wls, macs, soa, assign,
                                                len(idx))
                self.n_kernel += len(idx)
                for j, i in enumerate(idx):
                    # copy: the caller owns `out`, and an in-place edit of
                    # the returned matrix must not poison the memo
                    self._memo[(keys[i], m)] = out[i].copy()
            esp.set(kernel=len(todo), memo_hits=len(g) - len(todo))
        dt = time.perf_counter() - t0
        self.eval_seconds += dt
        reg = obs_metrics.get_registry()
        reg.inc("explore.requested_evals", len(g))
        reg.inc("explore.kernel_evals", len(todo))
        reg.inc("explore.memo_hits", len(g) - len(todo))
        reg.inc("explore.eval_seconds", dt)
        return out

    def reset_stats(self) -> None:
        """Zero the per-search counters so a reused evaluator attributes
        ``stats()`` (and ``SearchResult.stats``) to one search instead of
        accumulating across every search it ever served.  The memo and
        subset caches are deliberately kept — resetting accounting must
        not change evaluation behavior."""
        self.n_requested = 0
        self.n_kernel = 0
        self.n_memo_hits = 0
        self.eval_seconds = 0.0

    def stats(self) -> dict:
        return {
            "requested_evals": self.n_requested,
            "kernel_evals": self.n_kernel,
            "memo_hits": self.n_memo_hits,
            "eval_seconds": self.eval_seconds,
            "backend": self.backend,
            "use_pallas": self.use_pallas,
            "n_workloads": len(self.workloads),
            "mesh_shards": (None if self.mesh is None else
                            _mesh_shards(self.mesh)),
            "traffic": (None if self.traffic is None
                        else self.traffic.name),
            "n_slots": (None if self.traffic is None else self.n_slots),
        }


def _front(genomes: np.ndarray, F: np.ndarray
           ) -> tuple[np.ndarray, np.ndarray]:
    keep = pareto_mask_k(F)
    return genomes[keep], F[keep]


def _result(method: str, ev: Evaluator, seed: int, genomes, F,
            ref, history, all_F, n_evals, *, population=None,
            population_objectives=None) -> SearchResult:
    fg, ff = _front(genomes, F)
    return SearchResult(
        method=method, workload=ev.name,
        objectives=ev.objectives, seed=seed, space=ev.space,
        genomes=fg, front_objectives=ff, ref_point=np.asarray(ref),
        history=history, all_objectives=np.concatenate(all_F, axis=0),
        n_evals=n_evals, stats=ev.stats(), population=population,
        population_objectives=population_objectives)


def random_search(space: CoExploreSpace, workload, budget: int, *,
                  objectives: Sequence[str] | None = None,
                  seed: int = 0, backend: str = "auto",
                  chunk_size: int = 4096, batch_size: int | None = None,
                  ref_point: np.ndarray | None = None,
                  weights=None, sqnr_floor_db=None,
                  mesh=None, traffic=None, n_slots: int = 8,
                  use_pallas: bool | None = None,
                  accuracy=None,
                  batch: int | None = None) -> SearchResult:
    """Uniform-random baseline: ``budget`` independent genomes, running
    non-dominated reduction, hypervolume recorded per batch.

    ``workload`` may be a single workload or a sequence (multi-workload
    co-exploration — then ``space`` must be a
    :class:`~repro.explore.space.CoExploreManySpace`; ``weights`` and
    ``accuracy`` configure the suite objectives, see
    :class:`Evaluator`).  ``traffic=`` switches to serving-fleet
    objectives over an ``n_slots`` fleet.  Same for the other engines.
    ``batch=`` is the deprecated spelling of ``batch_size=``,
    ``sqnr_floor_db=`` of ``accuracy=AccuracySpec(floor_db=...)``.
    """
    if batch is not None:
        warnings.warn(
            "random_search(batch=...) is deprecated; use batch_size=",
            DeprecationWarning, stacklevel=2)
        if batch_size is None:
            batch_size = batch
    accuracy = _fold_floor(accuracy, sqnr_floor_db)
    rng = np.random.default_rng(seed)
    ev = Evaluator(space, workload, objectives, backend=backend,
                   chunk_size=chunk_size, weights=weights,
                   accuracy=accuracy, mesh=mesh,
                   traffic=traffic, n_slots=n_slots,
                   use_pallas=use_pallas)
    if budget < 1:
        raise ValueError("budget must be >= 1")
    if batch_size is not None and batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    batch_size = (min(budget, 256) if batch_size is None
                  else min(batch_size, budget))
    front_g = np.empty((0, space.genome_width), dtype=np.int64)
    front_F = np.empty((0, len(ev.objectives)), dtype=np.float64)
    history: list[tuple[int, float]] = []
    all_F: list[np.ndarray] = []
    ref = ref_point
    evals = 0
    while evals < budget:
        n = min(batch_size, budget - evals)
        with obs_trace.span("random_search.batch", n=n, evals=evals):
            g = space.random_population(n, rng)
            F = ev.evaluate(g)
            evals += n
            all_F.append(F)
            if ref is None:
                ref = reference_point(F)
            front_g, front_F = _front(np.concatenate([front_g, g]),
                                      np.concatenate([front_F, F]))
            history.append((evals, hypervolume(front_F, ref)))
    return _result("random", ev, seed, front_g, front_F, ref, history,
                   all_F, evals)


def _ranks_and_crowding(F: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    ranks = nondominated_sort(F)
    crowd = np.empty(len(F), dtype=np.float64)
    for r in np.unique(ranks):
        idx = np.nonzero(ranks == r)[0]
        crowd[idx] = crowding_distance(F[idx])
    return ranks, crowd


def _tournament(rng: np.random.Generator, n_pick: int,
                ranks: np.ndarray, crowd: np.ndarray) -> np.ndarray:
    """Binary tournament on (rank asc, crowding desc, index asc)."""
    a = rng.integers(0, len(ranks), size=n_pick)
    b = rng.integers(0, len(ranks), size=n_pick)
    a_wins = ((ranks[a] < ranks[b])
              | ((ranks[a] == ranks[b]) & (crowd[a] > crowd[b]))
              | ((ranks[a] == ranks[b]) & (crowd[a] == crowd[b])
                 & (a <= b)))
    return np.where(a_wins, a, b)


def nsga2(space: CoExploreSpace, workload, budget: int, *,
          pop_size: int = 64,
          objectives: Sequence[str] | None = None,
          seed: int = 0, backend: str = "auto", chunk_size: int = 4096,
          mutation_rate: float = 0.08,
          ref_point: np.ndarray | None = None,
          weights=None, sqnr_floor_db=None, mesh=None,
          traffic=None, n_slots: int = 8,
          use_pallas: bool | None = None,
          accuracy=None,
          archive_epsilon=None,
          checkpoint_dir: str | None = None,
          checkpoint_every: int = 5,
          fail_at_generation: dict[int, int] | None = None
          ) -> SearchResult:
    """NSGA-II-style evolutionary multi-objective search.

    Classic loop: elitist (mu + lambda) survival over non-domination rank
    then crowding distance, binary-tournament parents, uniform crossover,
    per-gene resampling mutation, compatibility repair.  ``budget`` counts
    requested genome evaluations (initial population included), so runs
    compare 1:1 with :func:`random_search` at the same budget.

    Every evaluated genome also flows through an **external archive** — a
    running non-dominated reduction over the whole search trajectory,
    like random search's running front — so a non-dominated genome that
    crowding truncation drops from the population is never lost.  The
    returned front *is* the archive's non-dominated set (a superset of
    the final population's own front, which is also returned via
    ``population`` / ``population_objectives``); the hypervolume history
    tracks the archive, and with the default unbounded archive is
    therefore monotone.

    ``archive_epsilon`` bounds the archive with an epsilon-dominance grid
    (:class:`~repro.explore.pareto.EpsilonDominanceArchive`) so week-long
    runs hold memory constant: a scalar is a *relative* grid resolution
    (fraction of each objective's (ideal, reference) span,
    :func:`~repro.explore.pareto.epsilon_from_reference`); a sequence is
    an absolute per-objective epsilon.  Hypervolume stays within grid
    resolution of the unbounded archive
    (``tests/test_epsilon_archive.py``); the grid size lands in
    ``stats["archive_epsilon"]`` / ``stats["archive_size"]``.

    ``checkpoint_dir`` snapshots the full search state — generation
    index, population, archive, hypervolume history, and the threaded
    RNG stream — every ``checkpoint_every`` generations
    (:class:`repro.runtime.dse_checkpoint.SearchCheckpointer`); on entry
    the newest valid snapshot is restored and the run continues
    bit-identically.  ``fail_at_generation`` injects deterministic
    :class:`~repro.runtime.fault_tolerance.InjectedFailure`\\ s at
    generation boundaries to exercise that path (decremented in place so
    a dict shared across restarts fails each boundary ``n`` times).
    """
    if budget < 1:
        raise ValueError("budget must be >= 1")
    if pop_size < 4:
        raise ValueError("pop_size must be >= 4")
    fail_at_generation = (fail_at_generation
                          if fail_at_generation is not None else {})

    def maybe_fail(gen: int) -> None:
        if fail_at_generation.get(gen, 0) > 0:
            fail_at_generation[gen] -= 1
            from repro.runtime.fault_tolerance import InjectedFailure
            raise InjectedFailure(
                f"injected failure at generation boundary {gen}")

    ckpt = None
    if checkpoint_dir is not None:
        from repro.runtime.dse_checkpoint import SearchCheckpointer
        ckpt = SearchCheckpointer(checkpoint_dir, every=checkpoint_every)
    accuracy = _fold_floor(accuracy, sqnr_floor_db)
    rng = np.random.default_rng(seed)
    ev = Evaluator(space, workload, objectives, backend=backend,
                   chunk_size=chunk_size, weights=weights,
                   accuracy=accuracy, mesh=mesh,
                   traffic=traffic, n_slots=n_slots,
                   use_pallas=use_pallas)

    def eps_vector(ref, F0) -> np.ndarray | None:
        if archive_epsilon is None:
            return None
        if np.ndim(archive_epsilon) == 0:
            return epsilon_from_reference(ref, F0.min(axis=0),
                                          float(archive_epsilon))
        return np.asarray(archive_epsilon, dtype=np.float64)

    def rebuild_archive(eps, arch_g, arch_F):
        # deterministic reconstruction: re-offering the surviving
        # representatives in stored order reproduces the grid exactly
        archive = EpsilonDominanceArchive(eps)
        archive.add(arch_g, arch_F)
        return archive

    def acc_payload() -> dict:
        if ev.accuracy is None:
            return {}
        return {"accuracy_state": ev.accuracy.state(),
                "accuracy_digest": ev.accuracy.digest()}

    eps_archive = None
    eps_vec = None
    snap = ckpt.restore() if ckpt is not None else None
    if snap is not None:
        # pin the exact accuracy table the interrupted run scored with,
        # and refuse to resume under a *different* calibration (a digest
        # mismatch after restore means the accuracy spec itself changed)
        if ev.accuracy is not None \
                and snap.get("accuracy_state") is not None:
            ev.accuracy.restore_state(snap["accuracy_state"])
            want = snap.get("accuracy_digest")
            got = ev.accuracy.digest()
            if want is not None and want != got:
                raise ValueError(
                    f"checkpoint was scored under accuracy digest "
                    f"{want}; this run's accuracy spec yields {got} — "
                    f"refusing to resume against a different calibration")
        gen = snap["gen"]
        evals = snap["evals"]
        pop, F = snap["pop"], snap["F"]
        arch_g, arch_F = snap["arch_g"], snap["arch_F"]
        ref = snap["ref"]
        history = snap["history"]
        all_F = snap["all_F"]
        rng.bit_generator.state = snap["rng_state"]
        eps_vec = snap["eps_vec"]
        if eps_vec is not None:
            eps_archive = rebuild_archive(eps_vec, arch_g, arch_F)
    else:
        maybe_fail(0)
        pop = space.random_population(min(pop_size, budget), rng)
        F = ev.evaluate(pop)
        evals = len(pop)
        gen = 0
        ref = reference_point(F) if ref_point is None else ref_point
        eps_vec = eps_vector(ref, F)
        if eps_vec is not None:
            eps_archive = EpsilonDominanceArchive(eps_vec)
            eps_archive.add(pop, F)
            arch_g, arch_F = eps_archive.genomes, eps_archive.objectives
        else:
            arch_g, arch_F = _front(pop, F)
        history = [(evals, hypervolume(arch_F, ref))]
        all_F = [F]
        if ckpt is not None and ckpt.should_save(0, done=evals >= budget):
            ckpt.save(gen=0, evals=evals, pop=pop, F=F, arch_g=arch_g,
                      arch_F=arch_F, ref=ref, history=history,
                      all_F=all_F, rng_state=rng.bit_generator.state,
                      eps_vec=eps_vec, **acc_payload())
    reg = obs_metrics.get_registry()
    while evals < budget:
        maybe_fail(gen + 1)
        n_off = min(pop_size, budget - evals)
        with obs_trace.span("nsga2.generation", gen=gen + 1,
                            evals=evals, n_off=n_off):
            ranks, crowd = _ranks_and_crowding(F)
            p1 = _tournament(rng, n_off, ranks, crowd)
            p2 = _tournament(rng, n_off, ranks, crowd)
            children = space.crossover(pop[p1], pop[p2], rng)
            children = space.mutate(children, rng, mutation_rate)
            Fc = ev.evaluate(children)
            evals += n_off
            gen += 1
            all_F.append(Fc)
            if eps_archive is not None:
                eps_archive.add(children, Fc)
                arch_g = eps_archive.genomes
                arch_F = eps_archive.objectives
            else:
                comb_g = np.concatenate([arch_g, children])
                comb_F = np.concatenate([arch_F, Fc])
                # a genome re-visited across generations has an identical
                # memoized objective row; keep one copy (first occurrence)
                # so the archive stays the *set* of non-dominated genomes
                # found
                _, uidx = np.unique(comb_g, axis=0, return_index=True)
                uidx.sort()
                arch_g, arch_F = _front(comb_g[uidx], comb_F[uidx])
            comb = np.concatenate([pop, children])
            Fcomb = np.concatenate([F, Fc])
            ranks2, crowd2 = _ranks_and_crowding(Fcomb)
            order = np.lexsort((np.arange(len(comb)), -crowd2, ranks2))
            sel = order[:pop_size]
            pop, F = comb[sel], Fcomb[sel]
            history.append((evals, hypervolume(arch_F, ref)))
        reg.inc("nsga2.generations")
        reg.set("nsga2.archive_size", int(len(arch_F)))
        if ckpt is not None and ckpt.should_save(gen,
                                                 done=evals >= budget):
            ckpt.save(gen=gen, evals=evals, pop=pop, F=F, arch_g=arch_g,
                      arch_F=arch_F, ref=ref, history=history,
                      all_F=all_F, rng_state=rng.bit_generator.state,
                      eps_vec=eps_vec, **acc_payload())
    res = _result("nsga2", ev, seed, arch_g, arch_F, ref, history, all_F,
                  evals, population=pop, population_objectives=F)
    res.stats["archive_size"] = int(len(arch_F))
    if eps_vec is not None:
        res.stats["archive_epsilon"] = [float(e) for e in eps_vec]
    return res


def successive_halving(space: CoExploreSpace, workload, budget: int, *,
                       eta: int = 3,
                       objectives: Sequence[str] | None = None,
                       seed: int = 0, backend: str = "auto",
                       chunk_size: int = 4096, min_layers: int = 2,
                       ref_point: np.ndarray | None = None,
                       weights=None, sqnr_floor_db=None,
                       mesh=None, traffic=None, n_slots: int = 8,
                       use_pallas: bool | None = None,
                       accuracy=None) -> SearchResult:
    """Successive halving over workload layer-prefix subsets.

    Rung ``r`` evaluates its population on the first ``m_r`` layers only
    (a cheap, correlated proxy of the full workload; per workload in the
    multi-workload setting), keeps the best ``1/eta`` by (non-domination
    rank, crowding), and promotes them to the next, larger subset; the
    final rung is the full workload.  Every requested evaluation counts
    one unit of ``budget`` regardless of subset size, so the comparison
    with the other engines is conservative.
    """
    if budget < 1:
        raise ValueError("budget must be >= 1")
    if eta < 2:
        raise ValueError("eta must be >= 2")
    accuracy = _fold_floor(accuracy, sqnr_floor_db)
    rng = np.random.default_rng(seed)
    ev = Evaluator(space, workload, objectives, backend=backend,
                   chunk_size=chunk_size, weights=weights,
                   accuracy=accuracy, mesh=mesh,
                   traffic=traffic, n_slots=n_slots,
                   use_pallas=use_pallas)
    L = ev.full_subset
    sizes = [L]
    while sizes[-1] > min(min_layers, L) and len(sizes) < 4:
        nxt = max(min(min_layers, L), -(-sizes[-1] // eta))
        if nxt == sizes[-1]:
            break
        sizes.append(nxt)
    sizes = sizes[::-1]                    # small -> full
    r_count = len(sizes)
    # n0 * (1 + 1/eta + ...) ~= budget
    geo = sum(eta ** -r for r in range(r_count))
    n0 = max(eta ** (r_count - 1), int(budget / geo))
    pops = [max(1, n0 // eta ** r) for r in range(r_count)]
    total = sum(pops)
    if total > budget:                      # trim the cheap first rung
        pops[0] = max(1, pops[0] - (total - budget))
    pop = space.random_population(pops[0], rng)
    evals = 0
    all_F = []
    history: list[tuple[int, float]] = []
    F = None
    for r, (m, n_r) in enumerate(zip(sizes, pops)):
        with obs_trace.span("successive_halving.rung", rung=r,
                            subset=m, n=n_r):
            pop = pop[:n_r]
            F = ev.evaluate(pop, subset=None if m == L else m)
            evals += len(pop)
            if m == L:
                # only full-workload rows are comparable across runs;
                # subset-rung objectives live on a different scale and
                # must not leak into all_objectives / shared reference
                # points
                all_F.append(F)
            if r < r_count - 1:
                ranks, crowd = _ranks_and_crowding(F)
                order = np.lexsort((np.arange(len(pop)), -crowd, ranks))
                pop = pop[order]
    # the last rung ran on the full workload: its objectives are the
    # comparable ones
    ref = reference_point(F) if ref_point is None else ref_point
    history.append((evals, hypervolume(F[pareto_mask_k(F)], ref)))
    return _result("successive_halving", ev, seed, pop, F, ref, history,
                   all_F, evals)


SEARCH_METHODS = {
    "random": random_search,
    "nsga2": nsga2,
    "successive_halving": successive_halving,
}
