"""Top-k MoE layer with sort-based dispatch and expert parallelism.

Dispatch is MegaBlocks-style (sort by expert, equal-capacity buffers)
rather than GShard one-hot einsums: the (E, C, d) buffer keeps the
expert GEMMs dense and MXU-shaped, the scatter/gather is cheap data
movement, and the buffer's expert dim shards over the "model" mesh axis
(EP) so XLA lowers dispatch/combine to all-to-all traffic.

Router runs in f32 (precision-sensitive; see quant/policy.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace, where the
    # replication checker mishandles symbolic-zero cotangents through
    # psum/pmean under transpose ('Zero' has no 'reshape') — disable it
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, **kw):
        return _exp_shard_map(f, check_rep=False, **kw)

from repro.parallel.sharding import shard
from repro.quant.qlinear import qdot


def topk_route(x, w_router, n_experts: int, top_k: int):
    """x: (T, d) -> (gates (T,k) f32, experts (T,k) int32, router aux loss)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
    gates, experts = jax.lax.top_k(probs, top_k)       # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum(frac_tokens * frac_probs)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(experts[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return gates, experts, aux


def moe_ffn_ep(x, p, cfg, *, policy, train, capacity_factor: float = 1.25):
    """Expert-parallel MoE via shard_map (EXPERIMENTS.md §Perf, cell B).

    The pjit scatter into a ("model"-sharded) global (E, C, d) buffer
    lowers as replicate + all-reduce of the whole buffer (~64 GB/layer for
    moonshot) — measured at 15.5 TB/step/device of all-reduce traffic.
    Here each (data x model) device dispatches its *local* tokens to its
    *local* experts only (tokens are replicated across "model" at block
    entry, experts are sharded over "model"), runs the local expert GEMMs,
    and a single activation-sized psum over "model" sums the top-k
    contributions.  No buffer-sized collectives remain.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import _mesh, data_axes

    mesh = _mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_ffn(x, p, cfg, policy=policy, train=train,
                       capacity_factor=capacity_factor)
    db = data_axes(mesh)
    E, K = cfg.n_experts, cfg.top_k
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if E % n_model != 0:
        return moe_ffn(x, p, cfg, policy=policy, train=train,
                       capacity_factor=capacity_factor)

    def body(x_l, router, wg, wi, wo):
        b_l, s, d = x_l.shape
        T = b_l * s
        xf = x_l.reshape(T, d)
        gates, experts, aux = topk_route(xf, router, E, K)

        e_l = wg.shape[0]                      # local experts
        e0 = jax.lax.axis_index("model") * e_l
        flat_expert = experts.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(T), K)
        flat_gate = gates.reshape(-1)
        local = (flat_expert >= e0) & (flat_expert < e0 + e_l)
        le = jnp.where(local, flat_expert - e0, 0)
        order = jnp.argsort(jnp.where(local, le, e_l))   # non-local last
        se, st, sg, keepmask = (le[order], flat_token[order],
                                flat_gate[order], local[order])
        counts = jnp.bincount(jnp.where(keepmask, se, e_l),
                              length=e_l + 1)[:e_l]
        starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(T * K) - jnp.where(keepmask, starts[se], 0)
        C = int(max(1, -(-T * K // E) * capacity_factor))
        keep = keepmask & (pos < C)

        buf = jnp.zeros((e_l, C, d), xf.dtype)
        idx_e = jnp.where(keep, se, 0)
        idx_c = jnp.where(keep, pos, 0)
        vals = jnp.where(keep[:, None], xf[st], 0.0)
        buf = buf.at[idx_e, idx_c].add(vals)

        def edot(a, w):
            if train and policy.quantized:
                from repro.quant.qlinear import qat_act, qat_weight
                a = qat_act(a, policy)
                w = qat_weight(w, policy, axis=1)
            return jnp.einsum("ecd,edf->ecf",
                              a.astype(policy.compute_dtype),
                              w.astype(policy.compute_dtype))

        g = edot(buf, wg)
        u = edot(buf, wi)
        hbuf = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd",
                             hbuf.astype(policy.compute_dtype),
                             wo.astype(policy.compute_dtype))
        gathered = out_buf[idx_e, idx_c]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weighted = gathered * sg[:, None].astype(gathered.dtype)
        out = jax.ops.segment_sum(weighted, st, num_segments=T)
        out = jax.lax.psum(out.astype(jnp.float32), "model")
        aux = jax.lax.pmean(aux, db)   # varies over data axes only
        return out.reshape(b_l, s, d).astype(x_l.dtype), aux

    out, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(P(db, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(db, None, None), P()),
    )(x, p["router"], p["w_experts_gate"], p["w_experts_in"],
      p["w_experts_out"])
    return out, aux


def moe_ffn(x, p, cfg, *, policy, train, capacity_factor: float = 1.25):
    """x: (b, s, d) -> (b, s, d).  p: router (d,E),
    w_experts_gate/in (E,d,ff), w_experts_out (E,ff,d)."""
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = b * s
    xf = x.reshape(T, d)

    gates, experts, aux = topk_route(xf, p["router"], E, K)

    # ---- sort-based dispatch -------------------------------------------
    flat_expert = experts.reshape(-1)                     # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)             # (T*K,)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert)                      # stable
    se, st, sg = (flat_expert[order], flat_token[order], flat_gate[order])
    # position of each entry within its expert group
    counts = jnp.bincount(se, length=E)                   # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K) - starts[se]                  # rank in expert
    C = int(max(1, -(-T * K // E) * capacity_factor))     # per-expert cap
    keep = pos < C

    # scatter tokens into the (E, C, d) expert buffer (dropped -> zeros)
    buf = jnp.zeros((E, C, d), xf.dtype)
    idx_e = jnp.where(keep, se, 0)
    idx_c = jnp.where(keep, pos, 0)
    vals = jnp.where(keep[:, None], xf[st], 0.0)
    buf = buf.at[idx_e, idx_c].add(vals)
    buf = shard(buf, "moe_buffer")

    # ---- expert FFNs (batched GEMMs, EP-sharded on E) --------------------
    from repro.models.common import swiglu_mlp  # noqa: F401 (same math)
    def edot(a, w):
        if train and policy.quantized:
            from repro.quant.qlinear import qat_act, qat_weight
            a = qat_act(a, policy)
            w = qat_weight(w, policy, axis=1)
        return jnp.einsum("ecd,edf->ecf", a.astype(policy.compute_dtype),
                          w.astype(policy.compute_dtype))

    g = edot(buf, p["w_experts_gate"])
    u = edot(buf, p["w_experts_in"])
    hbuf = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd",
                         hbuf.astype(policy.compute_dtype),
                         p["w_experts_out"].astype(policy.compute_dtype))
    out_buf = shard(out_buf, "moe_buffer")

    # ---- combine ----------------------------------------------------------
    gathered = out_buf[idx_e, idx_c]                      # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * sg[:, None].astype(gathered.dtype)
    out = jax.ops.segment_sum(weighted, st, num_segments=T)
    return out.reshape(b, s, d).astype(x.dtype), aux
