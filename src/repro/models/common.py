"""Shared model components: norms, RoPE, losses, init helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in f32 (precision-sensitive; stays high precision under
    every quantization mode — see quant/policy.py)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (..., s, h, hd); positions: broadcastable (s,)
    or (b, s)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., s, half)
    cos = jnp.cos(ang)[..., None, :]                         # (..., s, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with an optional z-loss regularizer.

    logits: (b, s, V) any float dtype; labels: (b, s) int32.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss


def gelu_mlp(x, w_in, w_out, policy, train):
    from repro.quant.qlinear import qdot
    h = jax.nn.gelu(qdot(x, w_in, policy, train=train))
    return qdot(h, w_out, policy, train=train)


def swiglu_mlp(x, w_gate, w_up, w_down, policy, train):
    from repro.quant.qlinear import qdot
    from repro.parallel.sharding import shard
    g = qdot(x, w_gate, policy, train=train)
    u = qdot(x, w_up, policy, train=train)
    h = shard(jax.nn.silu(g) * u, "ffn_hidden")
    return qdot(h, w_down, policy, train=train)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype=jnp.float32, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_tree(key, tree_spec: dict):
    """Split a PRNG key into a matching pytree of keys."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
