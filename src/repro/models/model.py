"""Unified quantization-aware LM: all 10 assigned architectures.

One functional ``Model`` facade per ArchConfig:

* ``init(rng)``           -> params pytree (stacked layers, scan-friendly)
* ``loss(params, batch)``  -> scalar train loss (QAT fake-quant active)
* ``forward(params, ...)`` -> logits
* ``init_cache(b)``        -> decode caches (KV / SSM state / conv)
* ``prefill(params, ...)`` -> (logits, caches) for serving
* ``decode_step(params, caches, tokens, pos, ...)`` -> (logits, caches)

Layer stacks are ``lax.scan`` over stacked params (HLO size O(1) in depth)
for the uniform families (dense / moe / ssm / audio / vlm); the zamba2
hybrid (periodic *shared* attention block) is Python-unrolled because its
shared-block KV caches index by application count.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (cross_entropy, normal_init, rms_norm)
from repro.parallel.sharding import shard
from repro.quant.policy import QuantPolicy, policy_for
from repro.quant.qlinear import qdot

GLOBAL_WINDOW = 1 << 30   # "window" value meaning full attention


def _maybe_remat(body, train: bool):
    """Activation checkpointing at layer boundaries: under the layer scan
    only the carry survives the forward pass; the body recomputes during
    backward.  Without this a 95-layer stack stores every intermediate
    (O(TBs) at the production shapes)."""
    return jax.checkpoint(body) if train else body


# ===========================================================================
# parameter construction
# ===========================================================================

def _attn_params(key, cfg, d, scale_out):
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": normal_init(ks[0], (d, h * hd)),
        "wk": normal_init(ks[1], (d, kvh * hd)),
        "wv": normal_init(ks[2], (d, kvh * hd)),
        "wo": normal_init(ks[3], (h * hd, d), scale=scale_out),
    }


def _mlp_params(key, cfg, d):
    ks = jax.random.split(key, 3)
    so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    if cfg.mlp_kind == "swiglu":
        return {"w_gate": normal_init(ks[0], (d, cfg.d_ff)),
                "w_up": normal_init(ks[1], (d, cfg.d_ff)),
                "w_down": normal_init(ks[2], (cfg.d_ff, d), scale=so)}
    return {"w_up": normal_init(ks[0], (d, cfg.d_ff)),
            "w_down": normal_init(ks[1], (cfg.d_ff, d), scale=so)}


def _moe_params(key, cfg, d):
    ks = jax.random.split(key, 4)
    E, ff = cfg.n_experts, cfg.d_ff
    so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "router": normal_init(ks[0], (d, E)),
        "w_experts_gate": normal_init(ks[1], (E, d, ff)),
        "w_experts_in": normal_init(ks[2], (E, d, ff)),
        "w_experts_out": normal_init(ks[3], (E, ff, d), scale=so),
    }


def _mamba_params(key, cfg, d):
    ks = jax.random.split(key, 3)
    d_inner, h, g, n = ssm_mod.dims(cfg)
    so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "in_proj": normal_init(ks[0], (d, ssm_mod.in_proj_dim(cfg))),
        "conv_w": normal_init(ks[1], (ssm_mod.D_CONV, ssm_mod.conv_dim(cfg)),
                              scale=0.2),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": normal_init(ks[2], (d_inner, d), scale=so),
    }


def _cross_params(key, cfg, d):
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wq_x": normal_init(ks[0], (d, h * hd)),
        "wk_img": normal_init(ks[1], (d, kvh * hd)),
        "wv_img": normal_init(ks[2], (d, kvh * hd)),
        "wo_x": normal_init(ks[3], (h * hd, d), scale=so),
    }


def _stack(fns, key, n):
    """vmap a param-builder over layer index -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(fns)(keys)


# ===========================================================================
# blocks
# ===========================================================================

def _dense_block(x, lp, cfg, policy, train, window=None):
    h, _ = attn.self_attention(
        rms_norm(x, lp["ln1"]), lp, cfg, policy=policy, train=train,
        window=window)
    x = shard(x + h, "residual")
    m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, train)
    return shard(x + m, "residual")


def _mlp(xn, lp, cfg, policy, train):
    if cfg.mlp_kind == "swiglu":
        from repro.models.common import swiglu_mlp
        return swiglu_mlp(xn, lp["w_gate"], lp["w_up"], lp["w_down"],
                          policy, train)
    from repro.models.common import gelu_mlp
    return gelu_mlp(xn, lp["w_up"], lp["w_down"], policy, train)


def _moe_block(x, lp, cfg, policy, train):
    h, _ = attn.self_attention(rms_norm(x, lp["ln1"]), lp, cfg,
                               policy=policy, train=train)
    x = shard(x + h, "residual")
    m, aux = moe_mod.moe_ffn_ep(rms_norm(x, lp["ln2"]), lp, cfg,
                                policy=policy, train=train)
    return shard(x + m, "residual"), aux


def _mamba_layer(x, lp, cfg, policy, train):
    h = ssm_mod.mamba2_block(rms_norm(x, lp["ln1"]), lp, cfg,
                             policy=policy, train=train)
    return shard(x + h, "residual")


# ===========================================================================
# Model facade
# ===========================================================================

@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    def __post_init__(self):
        self.policy: QuantPolicy = policy_for(self.cfg.quant)

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        k_emb, k_layers, k_extra = jax.random.split(rng, 3)
        params = {"embed": normal_init(k_emb, (cfg.vocab, d)),
                  "final_norm": jnp.ones((d,), jnp.float32)}

        def layer_fn(key):
            ks = jax.random.split(key, 3)
            lp = {"ln1": jnp.ones((d,), jnp.float32),
                  "ln2": jnp.ones((d,), jnp.float32)}
            so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
            if cfg.family in ("dense", "vlm", "audio"):
                lp.update(_attn_params(ks[0], cfg, d, so))
                lp.update(_mlp_params(ks[1], cfg, d))
            elif cfg.family == "moe":
                lp.update(_attn_params(ks[0], cfg, d, so))
                lp.update(_moe_params(ks[1], cfg, d))
            elif cfg.family in ("ssm", "hybrid"):
                lp.pop("ln2")
                lp.update(_mamba_params(ks[0], cfg, d))
            return lp

        params["layers"] = _stack(layer_fn, k_layers, cfg.n_layers)

        ke = jax.random.split(k_extra, 4)
        if cfg.family == "hybrid":     # zamba2 shared attn+mlp block
            sp = {"ln1": jnp.ones((d,), jnp.float32),
                  "ln2": jnp.ones((d,), jnp.float32)}
            sp.update(_attn_params(ke[0], cfg, d, 0.01))
            sp.update(_mlp_params(ke[1], cfg, d))
            params["shared"] = sp
        if cfg.family == "vlm":        # interleaved cross-attn layers
            n_cross = cfg.n_layers // cfg.cross_attn_every
            def cross_fn(key):
                cp = {"ln_x": jnp.ones((d,), jnp.float32)}
                cp.update(_cross_params(key, cfg, d))
                return cp
            params["cross_layers"] = _stack(cross_fn, ke[2], n_cross)
        if cfg.family == "audio":      # whisper encoder + per-layer cross
            def enc_fn(key):
                ks2 = jax.random.split(key, 2)
                ep = {"ln1": jnp.ones((d,), jnp.float32),
                      "ln2": jnp.ones((d,), jnp.float32)}
                ep.update(_attn_params(ks2[0], cfg, d, 0.01))
                ep.update(_mlp_params(ks2[1], cfg, d))
                return ep
            params["encoder_layers"] = _stack(enc_fn, ke[2],
                                              cfg.encoder_layers)
            def cross_fn(key):
                cp = {"ln_x": jnp.ones((d,), jnp.float32)}
                cp.update(_cross_params(key, cfg, d))
                return cp
            params["cross_layers"] = _stack(cross_fn, ke[3], cfg.n_layers)
        return params

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------------ per-layer
    def _windows(self, seq_hint: int) -> jax.Array | None:
        cfg = self.cfg
        if not cfg.global_every:
            return None
        idx = jnp.arange(cfg.n_layers)
        is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
        return jnp.where(is_global, GLOBAL_WINDOW, cfg.window)

    # ------------------------------------------------------------- forward
    def forward(self, params, tokens, *, ctx=None, train=False,
                last_only=False):
        """tokens: (b, s) -> logits (b, s, V).  ``ctx``: image/audio
        embeddings (b, n_ctx, d) for vlm/audio families.  ``last_only``
        returns logits for the final position only (serving prefill)."""
        cfg, policy = self.cfg, self.policy
        x = jnp.take(params["embed"], tokens, axis=0)
        x = shard(x.astype(policy.compute_dtype), "residual")
        aux = jnp.zeros((), jnp.float32)

        if cfg.family == "dense":
            windows = self._windows(tokens.shape[1])
            def body(carry, xs):
                lp = xs if windows is None else xs[0]
                w = None if windows is None else xs[1]
                return _dense_block(carry, lp, cfg, policy, train,
                                    window=w), None
            xs = params["layers"] if windows is None \
                else (params["layers"], windows)
            x, _ = jax.lax.scan(_maybe_remat(body, train), x, xs)

        elif cfg.family == "moe":
            def body(carry, lp):
                x, aux = carry
                x, a = _moe_block(x, lp, cfg, policy, train)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(_maybe_remat(body, train),
                                       (x, aux), params["layers"])

        elif cfg.family == "ssm":
            def body(carry, lp):
                return _mamba_layer(carry, lp, cfg, policy, train), None
            x, _ = jax.lax.scan(_maybe_remat(body, train), x,
                                params["layers"])

        elif cfg.family == "hybrid":
            x = self._hybrid_forward(params, x, train)

        elif cfg.family == "vlm":
            x = self._vlm_forward(params, x, ctx, train)

        elif cfg.family == "audio":
            x = self._audio_forward(params, x, ctx, train)

        if last_only:
            x = x[:, -1:]
        x = rms_norm(x, params["final_norm"])
        logits = qdot(x, params["embed"].T, policy, train=train)
        return shard(logits, "logits") if not last_only else logits, aux

    # hybrid: python-unrolled mamba stack + shared attn block every k
    def _hybrid_forward(self, params, x, train):
        cfg, policy = self.cfg, self.policy
        every = cfg.shared_attn_every
        mamba = _maybe_remat(
            lambda x, lp: _mamba_layer(x, lp, cfg, policy, train), train)
        shared = _maybe_remat(
            lambda x: _dense_block(x, params["shared"], cfg, policy,
                                   train), train)
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, _l=l: a[_l], params["layers"])
            x = mamba(x, lp)
            if every and (l % every) == every - 1:
                x = shared(x)
        return x

    # vlm: scan over superblocks of (cross_attn_every-1 self + 1 cross)
    def _vlm_forward(self, params, x, ctx, train):
        cfg, policy = self.cfg, self.policy
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        layers = jax.tree.map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["layers"])
        ctx_cache = {}

        def body(carry, xs):
            x = carry
            lps, cp = xs
            for i in range(k - 1):
                lp = jax.tree.map(lambda a: a[i], lps)
                x = _dense_block(x, lp, cfg, policy, train)
            # the k-th layer: self block + cross-attn injection
            lp = jax.tree.map(lambda a: a[k - 1], lps)
            x = _dense_block(x, lp, cfg, policy, train)
            ck, cv = attn.context_kv(ctx, cp, cfg, policy=policy,
                                     train=train)
            h = attn.cross_attention(rms_norm(x, cp["ln_x"]), ck, cv, cp,
                                     cfg, policy=policy, train=train)
            return shard(x + h, "residual"), None

        x, _ = jax.lax.scan(_maybe_remat(body, train), x,
                            (layers, params["cross_layers"]))
        return x

    # audio: whisper encoder (bidir) then decoder w/ per-layer cross-attn
    def _audio_forward(self, params, x, ctx, train):
        cfg, policy = self.cfg, self.policy
        enc = self._encode(params, ctx, train)

        def body(carry, xs):
            x = carry
            lp, cp = xs
            h, _ = attn.self_attention(rms_norm(x, lp["ln1"]), lp, cfg,
                                       policy=policy, train=train)
            x = shard(x + h, "residual")
            ck, cv = attn.context_kv(enc, cp, cfg, policy=policy,
                                     train=train)
            h = attn.cross_attention(rms_norm(x, cp["ln_x"]), ck, cv, cp,
                                     cfg, policy=policy, train=train)
            x = shard(x + h, "residual")
            m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, train)
            return shard(x + m, "residual"), None

        x, _ = jax.lax.scan(_maybe_remat(body, train), x,
                            (params["layers"], params["cross_layers"]))
        return x

    def _encode(self, params, frames, train):
        cfg, policy = self.cfg, self.policy
        x = shard(frames.astype(policy.compute_dtype), "residual")

        def body(carry, lp):
            h, _ = attn.self_attention(rms_norm(carry, lp["ln1"]), lp, cfg,
                                       policy=policy, train=train)
            x = shard(carry + h, "residual")
            m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, train)
            return shard(x + m, "residual"), None

        x, _ = jax.lax.scan(_maybe_remat(body, train), x,
                            params["encoder_layers"])
        return x

    # --------------------------------------------------------------- loss
    def loss(self, params, batch, *, train=True):
        logits, aux = self.forward(params, batch["tokens"],
                                   ctx=batch.get("ctx"), train=train)
        return cross_entropy(logits, batch["labels"]) + 0.01 * aux

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16,
                   kv_quant: bool = False):
        """Decode caches.  Shapes only depend on config + (b, S).
        ``kv_quant``: int8 KV storage with per-(pos, head) scales
        (LightPE-2 / W8A8 arithmetic on the KV path)."""
        cfg = self.cfg
        L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        c = {}
        if kv_quant and cfg.family in ("dense", "moe"):
            dtype = jnp.int8
        elif kv_quant:
            raise NotImplementedError(
                "int8 KV is implemented for dense/moe decode")
        if cfg.family == "dense" and cfg.global_every:
            # sliding-window layers keep a ring buffer of `window`
            # positions; only the global layers store the full sequence
            n_glob = cfg.n_layers // cfg.global_every
            n_loc = cfg.n_layers - n_glob
            W = min(cfg.window, max_seq)
            c["k"] = jnp.zeros((n_glob, batch, max_seq, kvh, hd), dtype)
            c["v"] = jnp.zeros((n_glob, batch, max_seq, kvh, hd), dtype)
            c["k_local"] = jnp.zeros((n_loc, batch, W, kvh, hd), dtype)
            c["v_local"] = jnp.zeros((n_loc, batch, W, kvh, hd), dtype)
            if kv_quant:
                c["k_scale"] = jnp.zeros((n_glob, batch, max_seq, kvh),
                                         jnp.float32)
                c["v_scale"] = jnp.zeros((n_glob, batch, max_seq, kvh),
                                         jnp.float32)
                c["k_local_scale"] = jnp.zeros((n_loc, batch, W, kvh),
                                               jnp.float32)
                c["v_local_scale"] = jnp.zeros((n_loc, batch, W, kvh),
                                               jnp.float32)
        elif cfg.family in ("dense", "moe", "vlm", "audio"):
            c["k"] = jnp.zeros((L, batch, max_seq, kvh, hd), dtype)
            c["v"] = jnp.zeros((L, batch, max_seq, kvh, hd), dtype)
            if kv_quant:
                c["k_scale"] = jnp.zeros((L, batch, max_seq, kvh),
                                         jnp.float32)
                c["v_scale"] = jnp.zeros((L, batch, max_seq, kvh),
                                         jnp.float32)
        if cfg.family in ("ssm", "hybrid"):
            d_inner, h, g, n = ssm_mod.dims(cfg)
            c["state"] = jnp.zeros((L, batch, h, ssm_mod.P_HEADDIM, n),
                                   jnp.float32)
            c["conv"] = jnp.zeros((L, batch, ssm_mod.D_CONV - 1,
                                   ssm_mod.conv_dim(cfg)), dtype)
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            n_apps = sum(1 for l in range(cfg.n_layers)
                         if (l % cfg.shared_attn_every)
                         == cfg.shared_attn_every - 1)
            c["shared_k"] = jnp.zeros((n_apps, batch, max_seq, kvh, hd),
                                      dtype)
            c["shared_v"] = jnp.zeros((n_apps, batch, max_seq, kvh, hd),
                                      dtype)
        if cfg.family in ("vlm", "audio"):
            n_cross = (cfg.n_layers // cfg.cross_attn_every
                       if cfg.family == "vlm" else cfg.n_layers)
            c["ctx_k"] = jnp.zeros((n_cross, batch, cfg.n_ctx_tokens, kvh,
                                    hd), dtype)
            c["ctx_v"] = jnp.zeros((n_cross, batch, cfg.n_ctx_tokens, kvh,
                                    hd), dtype)
        return c

    def decode_step(self, params, caches, tokens, pos, *, window_override=None):
        """One serving step.  tokens: (b, 1) int32; pos: scalar int32
        (current write position; past = [0, pos]).  Returns
        (logits (b, 1, V), new caches)."""
        cfg, policy = self.cfg, self.policy
        train = False
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x.astype(policy.compute_dtype)

        kv_quant = "k_scale" in caches

        if cfg.family == "dense" and cfg.global_every:
            # gemma3: static 5:1 local:global pattern -> python-unrolled so
            # local layers read only a ``window``-sized cache slice
            # (EXPERIMENTS.md §Perf, long_500k hillclimb).
            x, caches = self._windowed_decode(params, caches, x, pos,
                                              kv_quant)
        elif cfg.family in ("dense", "moe"):
            def body(carry, xs):
                x = carry
                if kv_quant:
                    lp, ck, cv, cks, cvs = xs
                    scales = (cks, cvs)
                else:
                    lp, ck, cv = xs
                    scales = None
                xn = rms_norm(x, lp["ln1"])
                res = attn.decode_self_attention(
                    xn, lp, cfg, ck, cv, pos, policy=policy,
                    kv_scales=scales)
                h, nk, nv = res[0], res[1], res[2]
                x = x + h
                if cfg.family == "moe":
                    m, _ = moe_mod.moe_ffn_ep(rms_norm(x, lp["ln2"]), lp,
                                              cfg, policy=policy,
                                              train=train)
                else:
                    m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, train)
                x = x + m
                ys = (nk, nv) + (res[3] if kv_quant else ())
                return x, ys

            xs = (params["layers"], caches["k"], caches["v"])
            if kv_quant:
                xs = xs + (caches["k_scale"], caches["v_scale"])
            x, ys = jax.lax.scan(body, x, xs)
            caches = dict(caches, k=ys[0], v=ys[1])
            if kv_quant:
                caches.update(k_scale=ys[2], v_scale=ys[3])

        elif cfg.family == "ssm":
            def body(carry, xs):
                x = carry
                lp, st, cv = xs
                y, st, cv = ssm_mod.mamba2_decode(
                    rms_norm(x, lp["ln1"]), lp, cfg, st, cv, policy=policy)
                return x + y, (st, cv)
            x, (st, cv) = jax.lax.scan(
                body, x, (params["layers"], caches["state"], caches["conv"]))
            caches = dict(caches, state=st, conv=cv)

        elif cfg.family == "hybrid":
            x, caches = self._hybrid_decode(params, caches, x, pos)

        elif cfg.family == "vlm":
            x, caches = self._vlm_decode(params, caches, x, pos)

        elif cfg.family == "audio":
            x, caches = self._audio_decode(params, caches, x, pos)

        x = rms_norm(x, params["final_norm"])
        logits = qdot(x, params["embed"].T, policy, train=False)
        return logits, caches

    def _windowed_decode(self, params, caches, x, pos, kv_quant):
        """gemma3 decode: unrolled layers; local layers use ring-buffer
        caches of `window` positions (EXPERIMENTS.md §Perf, cell A)."""
        cfg, policy = self.cfg, self.policy
        W = caches["k_local"].shape[2]
        new = {k: [] for k in ("k", "v", "k_local", "v_local", "k_scale",
                               "v_scale", "k_local_scale", "v_local_scale")}
        gi = li = 0
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, _l=l: a[_l], params["layers"])
            is_global = (l % cfg.global_every) == cfg.global_every - 1
            if is_global:
                ck, cv = caches["k"][gi], caches["v"][gi]
                scales = (caches["k_scale"][gi], caches["v_scale"][gi]) \
                    if kv_quant else None
                sw = None
            else:
                ck, cv = caches["k_local"][li], caches["v_local"][li]
                scales = (caches["k_local_scale"][li],
                          caches["v_local_scale"][li]) if kv_quant else None
                sw = W                      # ring mode (S == static_window)
            xn = rms_norm(x, lp["ln1"])
            res = attn.decode_self_attention(
                xn, lp, cfg, ck, cv, pos, policy=policy,
                static_window=sw, window=None, kv_scales=scales)
            x = x + res[0]
            m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, False)
            x = x + m
            pre = "" if is_global else "_local"
            new["k" + pre].append(res[1])
            new["v" + pre].append(res[2])
            if kv_quant:
                new[f"k{pre}_scale"].append(res[3][0])
                new[f"v{pre}_scale"].append(res[3][1])
            if is_global:
                gi += 1
            else:
                li += 1
        out = dict(caches)
        for key, vals in new.items():
            if vals:
                out[key] = jnp.stack(vals)
        return x, out

    def _hybrid_decode(self, params, caches, x, pos):
        cfg, policy = self.cfg, self.policy
        every = cfg.shared_attn_every
        st_all, cv_all = caches["state"], caches["conv"]
        sk_all, sv_all = caches["shared_k"], caches["shared_v"]
        app = 0
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            y, st, cv = ssm_mod.mamba2_decode(
                rms_norm(x, lp["ln1"]), lp, cfg, st_all[l], cv_all[l],
                policy=policy)
            x = x + y
            st_all = st_all.at[l].set(st)
            cv_all = cv_all.at[l].set(cv)
            if every and (l % every) == every - 1:
                sp = params["shared"]
                h, nk, nv = attn.decode_self_attention(
                    rms_norm(x, sp["ln1"]), sp, cfg, sk_all[app],
                    sv_all[app], pos, policy=policy)
                x = x + h
                m = _mlp(rms_norm(x, sp["ln2"]), sp, cfg, policy, False)
                x = x + m
                sk_all = sk_all.at[app].set(nk)
                sv_all = sv_all.at[app].set(nv)
                app += 1
        return x, dict(caches, state=st_all, conv=cv_all,
                       shared_k=sk_all, shared_v=sv_all)

    def _vlm_decode(self, params, caches, x, pos):
        cfg, policy = self.cfg, self.policy
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        layers = jax.tree.map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["layers"])
        ck_all = caches["k"].reshape(n_groups, k, *caches["k"].shape[1:])
        cv_all = caches["v"].reshape(n_groups, k, *caches["v"].shape[1:])

        def body(carry, xs):
            x = carry
            lps, cp, cks, cvs, xk, xv = xs
            nks, nvs = [], []
            for i in range(k):
                lp = jax.tree.map(lambda a: a[i], lps)
                xn = rms_norm(x, lp["ln1"])
                h, nk, nv = attn.decode_self_attention(
                    xn, lp, cfg, cks[i], cvs[i], pos, policy=policy)
                x = x + h
                m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, False)
                x = x + m
                nks.append(nk)
                nvs.append(nv)
            h = attn.cross_attention(rms_norm(x, cp["ln_x"]), xk, xv, cp,
                                     cfg, policy=policy, train=False)
            x = x + h
            return x, (jnp.stack(nks), jnp.stack(nvs))

        x, (nk, nv) = jax.lax.scan(
            body, x, (layers, params["cross_layers"], ck_all, cv_all,
                      caches["ctx_k"], caches["ctx_v"]))
        return x, dict(caches,
                       k=nk.reshape(caches["k"].shape),
                       v=nv.reshape(caches["v"].shape))

    def _audio_decode(self, params, caches, x, pos):
        cfg, policy = self.cfg, self.policy

        def body(carry, xs):
            x = carry
            lp, cp, ck, cv, xk, xv = xs
            xn = rms_norm(x, lp["ln1"])
            h, nk, nv = attn.decode_self_attention(
                xn, lp, cfg, ck, cv, pos, policy=policy)
            x = x + h
            h = attn.cross_attention(rms_norm(x, cp["ln_x"]), xk, xv, cp,
                                     cfg, policy=policy, train=False)
            x = x + h
            m = _mlp(rms_norm(x, lp["ln2"]), lp, cfg, policy, False)
            return x + m, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], params["cross_layers"],
                      caches["k"], caches["v"], caches["ctx_k"],
                      caches["ctx_v"]))
        return x, dict(caches, k=nk, v=nv)

    def quantize_params(self, params):
        """Serving-time weight quantization per the config's mode
        (the paper's LightPE deployment path): every 2-D projection
        becomes a QuantizedTensor (int8 W8A8 or packed pow2-int4 W4A8);
        embeddings / norms / vectors / stacked-3D expert weights stay in
        the compute dtype."""
        from repro.quant.qlinear import quantize_weight
        if not self.policy.quantized:
            return params

        proj_names = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                      "wq_x", "wk_img", "wv_img", "wo_x", "in_proj",
                      "out_proj")

        def leafq(path, leaf):
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            tail = name.rsplit("/", 1)[-1]
            if tail not in proj_names:
                return leaf
            if leaf.ndim == 3:   # stacked (L, d_in, d_out): per-layer quant
                return jax.vmap(lambda w: quantize_weight(w, self.policy))(
                    leaf)
            if leaf.ndim == 2:   # unstacked (shared block)
                return quantize_weight(leaf, self.policy)
            return leaf

        return jax.tree_util.tree_map_with_path(leafq, params)

    def prefill(self, params, tokens, *, ctx=None, max_seq=None):
        """Compute logits and fill decode caches for the prompt.

        Simple implementation: forward for logits + per-layer KV rebuilt
        from a cache-building pass (sufficient for serving tests at smoke
        scale; the 32k dry-run lowers decode_step, not prefill+decode)."""
        cfg = self.cfg
        b, s = tokens.shape
        max_seq = max_seq or s
        logits, _ = self.forward(params, tokens, ctx=ctx, train=False)
        caches = self.init_cache(b, max_seq,
                                 dtype=self.policy.compute_dtype)
        # replay tokens through decode_step to build caches (smoke scale)
        def step(c, i):
            _, c = self.decode_step(params, c, jax.lax.dynamic_slice_in_dim(
                tokens, i, 1, axis=1), i)
            return c, None
        caches, _ = jax.lax.scan(step, caches, jnp.arange(s))
        return logits, caches
