"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Chunked SSD forward (quadratic intra-chunk + linear inter-chunk state
recurrence via lax.scan) and the single-token decode recurrence.  The scan
state stays f32 (precision-sensitive recurrence; quantization applies to
the in/out projections only — DESIGN.md §8).

Layout conventions:
  d_inner = expand * d_model (expand=2), head dim P, heads H = d_inner/P,
  groups G (B/C shared across H/G heads), state N = cfg.ssm_state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qlinear import qdot

P_HEADDIM = 64
D_CONV = 4


def dims(cfg):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // P_HEADDIM
    n_groups = 1
    return d_inner, n_heads, n_groups, cfg.ssm_state


def conv_dim(cfg):
    d_inner, _, g, n = dims(cfg)
    return d_inner + 2 * g * n


def in_proj_dim(cfg):
    d_inner, h, g, n = dims(cfg)
    return 2 * d_inner + 2 * g * n + h     # z, xBC(conv), dt


def _split(zxbcdt, cfg):
    d_inner, h, g, n = dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim(cfg)]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def causal_conv1d(x, w, cache=None):
    """Depthwise causal conv.  x: (b, s, c); w: (D_CONV, c).
    If cache (b, D_CONV-1, c) is given, performs a streaming step on s=1
    and returns (y, new_cache)."""
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)      # (b, D_CONV, c)
        y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        return jax.nn.silu(y).astype(x.dtype), window[:, 1:]
    b, s, c = x.shape
    xp = jnp.pad(x, ((0, 0), (D_CONV - 1, 0), (0, 0)))
    # sum_k w[k] * x[t - (D_CONV-1) + k]
    y = sum(xp[:, k:k + s].astype(jnp.float32)
            * w[k].astype(jnp.float32) for k in range(D_CONV))
    return jax.nn.silu(y).astype(x.dtype), None


def _segsum(log_a):
    """(..., q) -> (..., q, q) lower-triangular cumulative-sum matrix."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, a_log, B, C, *, chunk: int = 256,
                init_state=None):
    """SSD forward.  xh: (b, s, h, p); dt: (b, s, h) (softplus applied);
    a_log: (h,) with A = -exp(a_log); B, C: (b, s, g, n).
    Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = xh.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g
    A = -jnp.exp(a_log.astype(jnp.float32))                   # (h,)
    dA = dt.astype(jnp.float32) * A                           # (b, s, h)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)       # (b, s, h, n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = xh.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunked views: (b, nc, q, ...)
    q = chunk
    dAc = dA.reshape(b, nc, q, h)
    Bc = Bh.reshape(b, nc, q, h, n)
    Cc = Ch.reshape(b, nc, q, h, n)
    xc = xf.reshape(b, nc, q, h, p)

    # intra-chunk (quadratic) term
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))           # (b,nc,h,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)         # (b,nc,h,q,q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, xc)

    # per-chunk input -> end-of-chunk state contribution
    cumA = jnp.cumsum(dAc, axis=2)                            # (b,nc,q,h)
    decay_to_end = jnp.exp(cumA[:, :, -1:, :] - cumA)         # (b,nc,q,h)
    chunk_states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                              Bc, decay_to_end, xc)           # (b,nc,h,p,n)
    chunk_decay = jnp.exp(cumA[:, :, -1, :])                  # (b,nc,h)

    # inter-chunk recurrence over nc (sequential scan)
    def step(state, inp):
        st, dec = inp                                         # (b,h,p,n),(b,h)
        new = state * dec[..., None, None] + st
        return new, state                                     # emit prev

    s0 = init_state if init_state is not None else \
        jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, s0, (chunk_states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    # inter-chunk output: y += C_t · (decay from chunk start) · prev_state
    state_decay = jnp.exp(cumA)                               # (b,nc,q,h)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         Cc, state_decay, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def mamba2_block(x, p, cfg, *, policy, train):
    """Full Mamba-2 mixer.  x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    d_inner, h, g, n = dims(cfg)
    zxbcdt = qdot(x, p["in_proj"], policy, train=train)
    z, xbc, dt = _split(zxbcdt, cfg)
    xbc, _ = causal_conv1d(xbc, p["conv_w"])
    xs = xbc[..., :d_inner].reshape(b, s, h, P_HEADDIM)
    B = xbc[..., d_inner:d_inner + g * n].reshape(b, s, g, n)
    C = xbc[..., d_inner + g * n:].reshape(b, s, g, n)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    c0 = getattr(cfg, "ssm_chunk", 256)
    chunk = min(c0, s) if s % c0 != 0 else c0
    if s % chunk != 0:          # tiny smoke shapes
        chunk = s
    y, _ = ssd_chunked(xs, dt_, p["a_log"], B, C, chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)                                    # gated
    return qdot(y, p["out_proj"], policy, train=train)


def mamba2_decode(x, p, cfg, state, conv_cache, *, policy, train=False):
    """One-token recurrence.  x: (b, 1, d); state: (b, h, p, n) f32;
    conv_cache: (b, D_CONV-1, conv_dim).  Returns (y, state, conv_cache)."""
    b, _, d = x.shape
    d_inner, h, g, n = dims(cfg)
    zxbcdt = qdot(x, p["in_proj"], policy, train=train)
    z, xbc, dt = _split(zxbcdt, cfg)
    xbc, conv_cache = causal_conv1d(xbc, p["conv_w"], cache=conv_cache)
    xs = xbc[..., :d_inner].reshape(b, h, P_HEADDIM)
    B = xbc[..., d_inner:d_inner + g * n].reshape(b, g, n)
    C = xbc[..., d_inner + g * n:].reshape(b, g, n)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)       # (b, h, n)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dt_ = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (b, h)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))               # (h,)
    dA = jnp.exp(dt_ * A)                                      # (b, h)
    xf = xs.astype(jnp.float32) * dt_[..., None]               # (b, h, p)
    state = state * dA[..., None, None] \
        + jnp.einsum("bhp,bhn->bhpn", xf, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return qdot(y, p["out_proj"], policy, train=train), state, conv_cache
