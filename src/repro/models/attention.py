"""GQA / sliding-window / cross attention with KV caches.

Three execution paths, one semantic (kernels/ref.py oracles):

* dense masked attention for short sequences (train_4k smoke scale);
* chunked online-softmax attention (pure JAX lax.scan, O(s) memory) for
  long sequences — this is what the 32k-prefill dry-runs lower;
* the Pallas flash kernel on TPU (ops.flash_attention, impl="pallas").

Decode uses partial-softmax math (kernels/ref.decode_*) so a KV cache
sharded along the sequence axis combines exactly (sharded flash-decode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rope
from repro.parallel.sharding import shard
from repro.quant.qlinear import qdot

DENSE_SEQ_LIMIT = 2048   # above this, use the chunked path
NEG_INF = -1e30


def _broadcast_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, kvh, hd) -> (b, s, H, hd) by repeating each kv head."""
    b, s, kvh, hd = k.shape
    rep = n_heads // kvh
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _mask(qi, ki, causal, window):
    m = jnp.ones(jnp.broadcast_shapes(qi.shape, ki.shape), dtype=bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def dense_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (b,sq,H,hd); k,v: (b,sk,H,hd).  window may be a traced scalar."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = (jnp.arange(sq) + q_offset + (sk - sq))[:, None]
    ki = jnp.arange(sk)[None, :]
    logits = jnp.where(_mask(qi, ki, causal, window)[None, None],
                       logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _block_size(n: int, target: int) -> int:
    """Largest divisor of n that is <= target; if only degenerate divisors
    exist (e.g. prime n), fall back to one block of n."""
    if n <= target:
        return n
    for b in range(target, max(15, target // 8), -1):
        if n % b == 0:
            return b
    return n


def chunked_attention(q, k, v, *, causal=True, window=None,
                      bq: int = 512, bk: int = 512,
                      causal_skip: bool = True, group: int = 4):
    """Memory-efficient attention: q blocks x online-softmax scans over
    kv blocks, O(bq*bk) live logits.

    ``causal_skip``: q blocks are grouped (``group`` per group) into a
    Python loop so each group's kv scan stops at its *static* causal
    bound — strictly-future kv blocks are never computed (≈2× flops/bytes
    at 32k prefill; EXPERIMENTS.md §Perf cell D).  HLO grows O(nq/group)
    scan bodies.  Falls back to the uniform full scan when non-causal.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq = _block_size(sq, bq)
    bk = _block_size(sk, bk)
    scale = hd ** -0.5
    nq, nk = sq // bq, sk // bk
    qb = q.reshape(b, nq, bq, h, hd).astype(jnp.float32)
    kb = k.reshape(b, nk, bk, h, hd).astype(jnp.float32)
    vb = v.reshape(b, nk, bk, h, hd).astype(jnp.float32)

    def q_block(i, qtile, n_kv):  # qtile: (b, tile_q, h, hd)
        tile_q = qtile.shape[1]
        q_off = i * bq + (sk - sq)

        def kv_step(carry, j):
            acc, m, l = carry
            kt = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            vt = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            s = jnp.einsum("bqhd,bkhd->bhqk", qtile, kt) * scale
            qi = (q_off + jnp.arange(tile_q))[:, None]
            ki = (j * bk + jnp.arange(bk))[None, :]
            s = jnp.where(_mask(qi, ki, causal, window)[None, None],
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vt)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, tile_q, hd), jnp.float32)
        m0 = jnp.full((b, h, tile_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, tile_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(n_kv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)   # (b, tile_q, h, hd)

    if causal and causal_skip and nq > 1:
        outs = []
        for g0 in range(0, nq, group):
            g1 = min(nq, g0 + group)
            # static causal bound for the whole group (last row of g1-1)
            hi = min(nk, ((g1 - 1) * bq + (sk - sq) + bq - 1) // bk + 1)
            tile = qb[:, g0:g1].reshape(b, (g1 - g0) * bq, h, hd)
            outs.append(q_block(g0, tile, max(1, hi)))
        out = jnp.concatenate(outs, axis=1)
        return out.reshape(b, sq, h, hd).astype(q.dtype)

    out = jax.lax.map(lambda args: q_block(*args[:2], nk),
                      (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd).astype(q.dtype)


def attend(q, k, v, *, causal=True, window=None, q_offset=0):
    sq, sk = q.shape[1], k.shape[1]
    if max(sq, sk) <= DENSE_SEQ_LIMIT:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Self-attention layer (projections + rope + attend / decode)
# ---------------------------------------------------------------------------

def self_attention(x, p, cfg, *, policy, train, window=None, positions=None):
    """Full-sequence self-attention.  x: (b, s, d)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = qdot(x, p["wq"], policy, train=train).reshape(b, s, h, hd)
    k = qdot(x, p["wk"], policy, train=train).reshape(b, s, kvh, hd)
    v = qdot(x, p["wv"], policy, train=train).reshape(b, s, kvh, hd)
    q = shard(q, "attn_qkv")
    if positions is None:
        positions = jnp.arange(s)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = attend(q, _broadcast_kv(k, h), _broadcast_kv(v, h),
                 causal=True, window=window)
    out = out.reshape(b, s, h * hd)
    return qdot(out, p["wo"], policy, train=train), (k, v)


def decode_self_attention(x, p, cfg, cache_k, cache_v, pos, *,
                          policy, train=False, window=None,
                          static_window: int | None = None,
                          kv_scales=None):
    """One-token decode.  x: (b, 1, d); cache_k/v: (b, S, kvh, hd); pos:
    scalar current position.  Returns (out, new_k, new_v[, new_scales]).

    Optimized paths (EXPERIMENTS.md §Perf):
      * grouped-query attention without materializing the kv->q-head
        broadcast: q reshaped to (b, kvh, rep, hd), dots carry the group
        dim — the cache is read once, in its storage dtype;
      * ``static_window``: local layers (gemma3) slice only the last
        ``window`` cache positions (dynamic_slice, static size) instead of
        scanning the whole sequence;
      * ``kv_scales`` (int8 KV): W8A8 attention — K/V stored int8 with
        per-(position, head) scales; k-scales apply on the logits' output
        dim, v-scales fold into the probabilities before the PV dot
        (QAPPA's LightPE-2 arithmetic on the KV path).
    """
    b, _, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = h // kvh
    S = cache_k.shape[1]
    ring = static_window is not None and S == static_window
    per_slot = getattr(pos, "ndim", 0) == 1           # (b,) positions
    pos_b = pos if per_slot else jnp.full((b,), pos)  # continuous batching
    q = qdot(x, p["wq"], policy, train=train).reshape(b, 1, h, hd)
    k = qdot(x, p["wk"], policy, train=train).reshape(b, 1, kvh, hd)
    v = qdot(x, p["wv"], policy, train=train).reshape(b, 1, kvh, hd)
    posv = pos_b[:, None] if per_slot else jnp.full((1,), pos)
    q = rope(q, posv, cfg.rope_theta)[:, 0]           # (b, h, hd)
    k = rope(k, posv, cfg.rope_theta)
    wpos = jnp.remainder(pos, S) if ring else pos     # ring-buffer write
    wpos_b = jnp.remainder(pos_b, S) if ring else pos_b

    def _write(cache, val):
        if per_slot:   # per-slot scatter write (iteration-level batching)
            return cache.at[jnp.arange(b), wpos_b].set(
                val[:, 0].astype(cache.dtype))
        return jax.lax.dynamic_update_slice_in_dim(
            cache, val.astype(cache.dtype), wpos, 1)

    new_scales = None
    if kv_scales is not None:   # int8 KV cache write
        from repro.quant import quantizers as qz
        ks_all, vs_all = kv_scales                    # (b, S, kvh) f32
        k_s = (jnp.max(jnp.abs(k), axis=-1) / 127.0).astype(jnp.float32)
        v_s = (jnp.max(jnp.abs(v), axis=-1) / 127.0).astype(jnp.float32)
        k_q = jnp.round(k / jnp.maximum(k_s, 1e-8)[..., None]) \
            .astype(jnp.int8)
        v_q = jnp.round(v / jnp.maximum(v_s, 1e-8)[..., None]) \
            .astype(jnp.int8)
        new_k = _write(cache_k, k_q)
        new_v = _write(cache_v, v_q)
        nks = _write(ks_all, k_s)
        nvs = _write(vs_all, v_s)
        new_scales = (nks, nvs)
    else:
        new_k = _write(cache_k, k)
        new_v = _write(cache_v, v)

    # ---- windowed cache read (local layers only touch W positions) ------
    if ring:
        # the cache IS the window: slot r holds absolute position
        # pos - ((pos - r) mod W); stale slots get ki < 0 and mask out
        W = S
        kk, vv = new_k, new_v
        ki = pos_b[:, None] - jnp.remainder(
            pos_b[:, None] - jnp.arange(W)[None, :], W)        # (b, W)
        if new_scales is not None:
            ks_r, vs_r = new_scales
    elif static_window is not None and static_window < S:
        W = static_window
        if per_slot:
            start = jnp.clip(pos_b - W + 1, 0, S - W)           # (b,)
            idx = start[:, None] + jnp.arange(W)[None, :]       # (b, W)
            kk = jnp.take_along_axis(new_k, idx[..., None, None], 1)
            vv = jnp.take_along_axis(new_v, idx[..., None, None], 1)
            ki = idx
            if new_scales is not None:
                ks_r = jnp.take_along_axis(new_scales[0],
                                           idx[..., None], 1)
                vs_r = jnp.take_along_axis(new_scales[1],
                                           idx[..., None], 1)
        else:
            start = jnp.clip(pos - W + 1, 0, S - W)
            kk = jax.lax.dynamic_slice_in_dim(new_k, start, W, 1)
            vv = jax.lax.dynamic_slice_in_dim(new_v, start, W, 1)
            ki = start + jnp.arange(W)
            if new_scales is not None:
                ks_r = jax.lax.dynamic_slice_in_dim(new_scales[0],
                                                    start, W, 1)
                vs_r = jax.lax.dynamic_slice_in_dim(new_scales[1],
                                                    start, W, 1)
    else:
        kk, vv, ki = new_k, new_v, jnp.arange(S)
        if new_scales is not None:
            ks_r, vs_r = new_scales

    # ---- grouped QK^T: (b, kvh, rep, hd) x (b, s, kvh, hd) --------------
    qg = q.reshape(b, kvh, rep, hd)
    scale = hd ** -0.5
    if new_scales is not None:
        # W8A8 attention: int8 q x int8 K -> int32 on the MXU; k-scales
        # land on the logits' output (s) dim.
        q_s = jnp.max(jnp.abs(qg), axis=-1, keepdims=True) / 127.0
        q_q = jnp.round(qg / jnp.maximum(q_s, 1e-8)).astype(jnp.int8)
        li = jnp.einsum("bgrd,bsgd->bgrs", q_q, kk,
                        preferred_element_type=jnp.int32)
        logits = li.astype(jnp.float32) * (q_s * scale) \
            * ks_r.transpose(0, 2, 1)[:, :, None, :]
    else:
        logits = jnp.einsum("bgrd,bsgd->bgrs", qg, kk,
                            preferred_element_type=jnp.float32) * scale
    ki2 = ki if getattr(ki, "ndim", 1) == 2 else \
        jnp.broadcast_to(ki[None, :], (b, ki.shape[0]))       # (b, W)
    pb = pos_b[:, None, None, None]
    valid = (ki2[:, None, None, :] <= pb) \
        & (ki2[:, None, None, :] >= 0)   # ring: stale slots have ki < 0
    if window is not None:
        valid = jnp.logical_and(valid, ki2[:, None, None, :]
                                > pb - window)
    logits = jnp.where(valid, logits, NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)              # (b, g, r, s) f32

    if new_scales is not None:
        # fold v-scales into the probs (s is contracted in PV), quantize
        # the probs row-wise, int8 x int8 PV dot.
        pf = pr * vs_r.transpose(0, 2, 1)[:, :, None, :]
        p_s = jnp.max(jnp.abs(pf), axis=-1, keepdims=True) / 127.0
        p_q = jnp.round(pf / jnp.maximum(p_s, 1e-12)).astype(jnp.int8)
        oi = jnp.einsum("bgrs,bsgd->bgrd", p_q, vv,
                        preferred_element_type=jnp.int32)
        out = oi.astype(jnp.float32) * p_s
    else:
        out = jnp.einsum("bgrs,bsgd->bgrd",
                         pr.astype(vv.dtype) if vv.dtype != jnp.float32
                         else pr, vv,
                         preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    out = qdot(out, p["wo"], policy, train=train)
    if kv_scales is not None:
        return out, new_k, new_v, new_scales
    return out, new_k, new_v


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder, llama-3.2-vision image layers)
# ---------------------------------------------------------------------------

def cross_attention(x, ctx_k, ctx_v, p, cfg, *, policy, train):
    """x: (b, s, d); ctx_k/v: (b, sc, kvh, hd) precomputed from the
    encoder/image context."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = qdot(x, p["wq_x"], policy, train=train).reshape(b, s, h, hd)
    out = attend(q, _broadcast_kv(ctx_k, h), _broadcast_kv(ctx_v, h),
                 causal=False)
    out = out.reshape(b, s, h * hd)
    return qdot(out, p["wo_x"], policy, train=train)


def context_kv(ctx, p, cfg, *, policy, train):
    """Project context embeddings to (k, v) once (cached for decode)."""
    b, sc, d = ctx.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = qdot(ctx, p["wk_img"], policy, train=train).reshape(b, sc, kvh, hd)
    v = qdot(ctx, p["wv_img"], policy, train=train).reshape(b, sc, kvh, hd)
    return k, v
