"""int8 gradient compression with error feedback for DP all-reduce.

QAPPA's low-bit insight applied to the network: before the data-parallel
gradient all-reduce, gradients are quantized to int8 (per-leaf scale) and
the quantization residual is carried to the next step (error feedback,
1-bit-Adam style), keeping convergence unbiased in the long run.

In the pjit world the all-reduce is implicit (XLA inserts it from the
sharding), so compression is expressed as quantize -> (XLA reduces int8*
-> here the mean of dequantized grads) -> dequantize + residual carry.
The compression hook is exact in expectation and unit-tested for the
error-feedback invariant; collective-byte savings appear in the HLO when
the quantized tensors are what crosses the DP axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import quantizers as qz


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err_state):
    """Returns (int8 grads tree, scales tree, new error-feedback tree)."""
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = jax.tree_util.tree_leaves(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(g_leaves, e_leaves):
        gf = g.astype(jnp.float32) + e
        scale = qz.int_scale(gf, 8)
        q = qz.quantize_int(gf, scale, 8)
        deq = qz.dequantize_int(q, scale)
        qs.append(q)
        scales.append(scale)
        errs.append(gf - deq)
    unf = treedef.unflatten
    return unf(qs), unf(scales), unf(errs)


def decompress_grads(qgrads, scales):
    return jax.tree.map(qz.dequantize_int, qgrads, scales)


def compress_roundtrip(grads, err_state):
    """One-step compress+decompress (what each step applies)."""
    qg, scales, err = compress_grads(grads, err_state)
    return decompress_grads(qg, scales), err
