"""Sharding rules: FSDP over "data", TP over "model", SP for activations,
EP for experts, pure DP over "pod".

Models stay pure: they call :func:`shard` with a *logical* name; if an
activation-sharding context is active (set by the launcher), a
``with_sharding_constraint`` is applied, otherwise it is the identity.
Parameter shardings are produced by :func:`param_pspec` from leaf-name
heuristics over the stacked-parameter pytree.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules():
    return getattr(_state, "rules", None)


def _mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict[str, P]):
    """Enable with_sharding_constraint on logical activation names."""
    prev = (_rules(), _mesh())
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def shard(x, name: str):
    """Apply the activation constraint for logical name, if active."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None or name not in rules:
        return x
    spec = rules[name]
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch axes: ("pod","data") on the multi-pod mesh, else ("data",)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_activation_rules(mesh: Mesh, *, seq_sharded: bool,
                             batch_1: bool = False) -> dict[str, P]:
    """Logical-name -> PartitionSpec table.

    * residual: (batch -> data axes, seq -> model [SP], d_model replicated)
    * attn_heads / ffn_hidden: model-parallel inner dims
    * kv_cache: batch -> data (or seq -> data when batch==1, long-context)
    """
    d = data_axes(mesh)
    db = d if not batch_1 else (None,)
    sp = "model" if seq_sharded else None
    return {
        "residual": P(db, sp, None),
        "logits": P(db, sp, None),
        "attn_qkv": P(db, None, "model", None),       # (b, s, heads, hd)
        "ffn_hidden": P(db, None, "model"),           # (b, s, ff)
        "moe_buffer": P("model", None, None),         # (E, C, d)
        "kv_cache": P(db, None, None, None) if not batch_1
        else P(None, ("data",) if "data" in mesh.axis_names else None,
               None, None),                           # (b, S, kvh, hd)
        "ssm_state": P(db, "model", None, None),      # (b, heads, p, n)
    }


# ---------------------------------------------------------------------------
# Parameter shardings (FSDP over "data" + TP over "model")
# ---------------------------------------------------------------------------

_PARAM_RULES: list[tuple[tuple[str, ...], P]] = [
    # name-suffix patterns -> spec for the *logical* (unstacked) dims.
    # Stacked layer params get a leading None for the layer dim.
    (("embed",), P("model", "data")),                 # (V, d): vocab TP
    (("router",), P("data", "model")),                # (d, E)
    (("w_experts_in",), P("model", "data", None)),    # (E, d, ff): EP
    (("w_experts_gate",), P("model", "data", None)),
    (("w_experts_out",), P("model", None, "data")),   # (E, ff, d)
    (("wq",), P("data", "model")),                    # (d, H*hd): head TP
    (("wk",), P("data", "model")),
    (("wv",), P("data", "model")),
    (("wo",), P("model", "data")),                    # (H*hd, d)
    (("w_gate",), P("data", "model")),                # (d, ff): TP
    (("w_up",), P("data", "model")),
    (("w_down",), P("model", "data")),                # (ff, d)
    (("in_proj",), P("data", "model")),               # mamba (d, inner)
    (("out_proj",), P("model", "data")),
    (("wq_x",), P("data", "model")),                  # cross-attn
    (("wk_img",), P("data", "model")),
    (("wv_img",), P("data", "model")),
    (("wo_x",), P("model", "data")),
    (("conv_w", "dt_bias", "a_log", "d_skip", "ln1", "ln2", "ln_x",
      "final_norm"), P()),                            # small: replicate
]


def _axis_size(mesh: Mesh | None, axis) -> int:
    if mesh is None or axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _axis_size(mesh, a)
        return n
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)


def param_pspec(path: str, shape: tuple, stacked: bool,
                mesh: Mesh | None = None) -> P:
    """Sharding spec for one parameter leaf.

    ``path`` is the '/'-joined pytree path; ``stacked`` marks per-layer
    stacked params (leading dim = layers, never sharded).  Any axis whose
    dim is not divisible by the mesh axis size is dropped (replicated) —
    e.g. mamba2's vocab 50280 is not 16-divisible, so it FSDP-shards
    d_model instead of TP-sharding the vocab.
    """
    rank = len(shape) - (1 if stacked else 0)
    dims = shape[1:] if stacked else shape

    def fit(spec_dims):
        out = []
        for i in range(rank):
            ax = spec_dims[i] if i < len(spec_dims) else None
            if ax is not None and dims[i] % _axis_size(mesh, ax) != 0:
                ax = None
            out.append(ax)
        return P(*([None] + out)) if stacked else P(*out)

    for pats, spec in _PARAM_RULES:
        if any(path.endswith(p) or f"/{p}" in path for p in pats):
            return fit(list(spec))
    if rank >= 2:  # default: FSDP-shard the first unstacked dim
        return fit(["data"] + [None] * (rank - 1))
    return P(*([None] * len(shape)))


def tree_pspecs(params, mesh: Mesh | None = None) -> dict:
    """Pytree of PartitionSpecs matching a (possibly nested) param dict."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        stacked = "layers/" in name or name.startswith("layers")
        specs.append(param_pspec(name, tuple(leaf.shape), stacked, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(mesh: Mesh, params):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(params, mesh))
