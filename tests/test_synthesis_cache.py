"""PersistentSynthesisCache hardening (ISSUE 4 satellite): npz round-trip
across processes, corrupted/truncated file handling (raise or rebuild —
never garbage), and eviction-stat accounting under the row limit."""

import subprocess
import sys

import numpy as np
import pytest

from repro.core.accelerator import design_space_soa
from repro.core.confighash import config_digests
from repro.core.synthesis import (REPORT_COLUMNS, PersistentSynthesisCache,
                                  synthesize_soa)


def _small_soa(n: int | None = None):
    soa = next(design_space_soa())              # one SoA for the full grid
    if n is not None:
        soa = {k: v[:n] for k, v in soa.items()}
    return soa


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------

def test_save_load_round_trip_same_process(tmp_path):
    path = tmp_path / "synth.npz"
    cache = PersistentSynthesisCache(path)
    soa = _small_soa(64)
    cols = cache.synthesize(soa)
    assert cache.misses == 64 and cache.hits == 0
    assert cache.save() == 64

    warm = PersistentSynthesisCache(path)
    assert len(warm) == 64
    mask, cols2 = warm.lookup(config_digests(soa))
    assert mask.all()
    for c in REPORT_COLUMNS:
        assert np.array_equal(cols2[c], cols[c]), c


def test_round_trip_across_processes(tmp_path):
    """A cache written by another interpreter hydrates bit-identically —
    the npz format carries no in-process state."""
    import os
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    path = tmp_path / "synth.npz"
    writer = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.core.accelerator import design_space_soa\n"
        "from repro.core.synthesis import PersistentSynthesisCache\n"
        "soa = {{k: v[:48] for k, v in next(design_space_soa()).items()}}\n"
        "c = PersistentSynthesisCache({path!r})\n"
        "c.synthesize(soa)\n"
        "print(c.save())\n"
    ).format(src=str(root / "src"), path=str(path))
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", writer], cwd=str(root),
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip().endswith("48")

    soa = _small_soa(48)
    cache = PersistentSynthesisCache(path)
    assert len(cache) == 48
    mask, cols = cache.lookup(config_digests(soa))
    assert mask.all() and cache.hits == 48 and cache.misses == 0
    fresh = synthesize_soa(soa)
    for c in REPORT_COLUMNS:
        assert np.array_equal(cols[c], fresh[c]), c


# ---------------------------------------------------------------------------
# corrupted / truncated / structurally wrong files
# ---------------------------------------------------------------------------

def _saved_cache(tmp_path, n=32):
    path = tmp_path / "synth.npz"
    cache = PersistentSynthesisCache(path)
    cache.synthesize(_small_soa(n))
    cache.save()
    return path


def test_truncated_file_rebuilds_in_constructor(tmp_path):
    path = _saved_cache(tmp_path)
    raw = path.read_bytes()
    path.write_bytes(raw[: len(raw) // 2])
    with pytest.warns(RuntimeWarning, match="unreadable"):
        cache = PersistentSynthesisCache(path)
    assert len(cache) == 0                      # rebuilt, not garbage
    # and it still works: synthesize misses, then saves over the bad file
    cols = cache.synthesize(_small_soa(8))
    assert np.isfinite(cols["area_mm2"]).all()
    cache.save()
    assert len(PersistentSynthesisCache(path)) == 8


def test_garbage_bytes_rebuild_and_explicit_load_raises(tmp_path):
    path = tmp_path / "synth.npz"
    path.write_bytes(b"this is not an npz file at all")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        cache = PersistentSynthesisCache(path)
    assert len(cache) == 0
    with pytest.raises(Exception):
        cache.load(path)                        # explicit load surfaces it


def test_missing_columns_raise_not_merge(tmp_path):
    path = tmp_path / "synth.npz"
    np.savez(path, keys=np.zeros((4, 2), dtype=np.uint64))
    fresh = PersistentSynthesisCache()
    with pytest.raises(ValueError, match="missing array"):
        fresh.load(path)
    assert len(fresh) == 0


def test_wrong_key_shape_and_nonfinite_values_raise(tmp_path):
    path = tmp_path / "synth.npz"
    cols = {c: np.ones(4) for c in REPORT_COLUMNS}
    np.savez(path, keys=np.zeros((4, 3), dtype=np.uint64), **cols)
    with pytest.raises(ValueError, match="keys shape"):
        PersistentSynthesisCache().load(path)

    bad = dict(cols, area_mm2=np.array([1.0, np.nan, 1.0, 1.0]))
    np.savez(path, keys=np.zeros((4, 2), dtype=np.uint64), **bad)
    with pytest.raises(ValueError, match="non-finite"):
        PersistentSynthesisCache().load(path)

    ragged = dict(cols, power_mw=np.ones(3))
    np.savez(path, keys=np.zeros((4, 2), dtype=np.uint64), **ragged)
    with pytest.raises(ValueError):
        PersistentSynthesisCache().load(path)


# ---------------------------------------------------------------------------
# eviction accounting under the row limit
# ---------------------------------------------------------------------------

def test_eviction_stats_under_row_limit():
    cache = PersistentSynthesisCache(max_rows=40)
    soa = _small_soa(100)
    cache.synthesize(soa)
    # every insert overflow compacts down to max_rows // 2 newest rows
    assert len(cache) <= 40
    assert cache.evictions == 100 - len(cache)
    assert cache.misses == 100 and cache.hits == 0

    # the newest rows survive: re-synthesizing the tail hits, the head
    # misses and re-enters
    tail = {k: v[-len(cache):] for k, v in soa.items()}
    cache.synthesize(tail)
    assert cache.hits == len(tail["pe_rows"])

    head = {k: v[:20] for k, v in soa.items()}
    before = cache.evictions
    cache.synthesize(head)
    assert cache.misses == 120
    assert cache.evictions >= before            # may or may not compact

    # eviction never loses *correctness*: evicted rows re-synthesize to
    # the same values (pure function of the digest)
    fresh = synthesize_soa(head)
    _, cols = cache.lookup(config_digests(head))
    for c in REPORT_COLUMNS:
        assert np.array_equal(cols[c], fresh[c]), c


def test_clear_keeps_cap_and_path(tmp_path):
    path = tmp_path / "synth.npz"
    cache = PersistentSynthesisCache(path, max_rows=16)
    cache.synthesize(_small_soa(8))
    cache.clear()
    assert len(cache) == 0 and cache.hits == 0 and cache.misses == 0
    assert cache.max_rows == 16 and cache.path == path


# ---------------------------------------------------------------------------
# atomic persistence + state export/import (ISSUE 7 satellites)
# ---------------------------------------------------------------------------

def test_save_is_atomic_under_write_failure(tmp_path, monkeypatch):
    """A crash mid-save must leave the previous on-disk cache intact and
    no temp litter — save() writes a sibling temp file and renames."""
    path = tmp_path / "synth.npz"
    cache = PersistentSynthesisCache(path)
    soa = _small_soa(32)
    cache.synthesize(soa)
    assert cache.save() == 32

    cache.synthesize(_small_soa(64))            # 32 new rows pending

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez_compressed", boom)
    with pytest.raises(OSError):
        cache.save()
    monkeypatch.undo()

    leftovers = [p for p in tmp_path.iterdir() if p.name != "synth.npz"]
    assert leftovers == []                      # temp file cleaned up
    survivor = PersistentSynthesisCache(path)   # old file still valid
    assert len(survivor) == 32
    mask, cols = survivor.lookup(config_digests(soa))
    assert mask.all()


def test_export_import_state_roundtrip(tmp_path):
    src = PersistentSynthesisCache(tmp_path / "a.npz")
    soa = _small_soa(48)
    src.synthesize(soa)
    src.synthesize(soa)                         # 48 hits
    state = src.export_state()

    dst = PersistentSynthesisCache(tmp_path / "b.npz")
    dst.synthesize(_small_soa(8))               # overwritten by import
    dst.import_state(state)
    assert len(dst) == len(src) == 48
    assert (dst.hits, dst.misses, dst.evictions) == (48, 48, 0)
    mask, cols = dst.lookup(config_digests(soa))
    assert mask.all()
    fresh = synthesize_soa(soa)
    for c in REPORT_COLUMNS:
        assert np.array_equal(cols[c], fresh[c]), c

    # the exported dict is a snapshot: mutating the source afterwards
    # must not retroactively change an already-captured state
    src.synthesize(_small_soa(64))
    assert len(state["keys"]) == 48


def test_import_state_validates_shapes(tmp_path):
    cache = PersistentSynthesisCache(tmp_path / "c.npz")
    state = {"keys": np.zeros((4, 2), dtype=np.uint64),
             "vals": np.zeros((3, len(REPORT_COLUMNS))),
             "hits": 0, "misses": 0, "evictions": 0}
    with pytest.raises(ValueError):
        cache.import_state(state)
