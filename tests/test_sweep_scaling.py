"""Scaling paths of the sweep engine (ISSUE 2): explicit backend
resolution, the x64-free jax jit path (parity + no retraces + shard_map),
the chunked streamed driver, and the persisted synthesis cache."""

import numpy as np
import pytest

import repro.core.dse_batch as dse_batch
from repro.core.accelerator import (AcceleratorConfig, configs_to_soa,
                                    design_space, design_space_soa)
from repro.core.dse import explore, explore_chunked, pareto_front
from repro.core.dse_batch import (get_jax_kernel, resolve_backend,
                                  sweep_chunked, sweep_workload)
from repro.core.pe import PEType
from repro.core.synthesis import (PersistentSynthesisCache,
                                  clear_synthesis_cache, synthesize_soa)
from repro.core.workloads import ConvLayer, Workload

SMALL_SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in PEType
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (32, 32, 512, 25.6)]
]

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
))


# ---------------------------------------------------------------------------
# backend resolution (satellite: no silent jax fallback)
# ---------------------------------------------------------------------------

def test_resolve_backend_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown sweep backend"):
        resolve_backend("quantum")
    with pytest.raises(ValueError):
        sweep_workload(TINY_WL, SMALL_SPACE, backend="quantum")


def test_explicit_jax_raises_when_unusable(monkeypatch):
    monkeypatch.setattr(dse_batch, "_JAX_PROBE",
                        (False, "simulated breakage"))
    with pytest.raises(RuntimeError, match="jax is unusable"):
        resolve_backend("jax")
    with pytest.raises(RuntimeError, match="simulated breakage"):
        explore(TINY_WL, SMALL_SPACE, backend="jax")
    # auto quietly falls back; numpy is unaffected
    assert resolve_backend("auto") == "numpy"
    assert resolve_backend("numpy") == "numpy"


def test_auto_resolves_by_platform():
    assert resolve_backend("auto") in ("numpy", "jax")
    usable, _ = dse_batch._jax_usable()
    assert usable  # this environment has jax
    # CPU-only hosts keep the bit-exact numpy engine on auto
    if not dse_batch._jax_has_accelerator():
        assert resolve_backend("auto") == "numpy"


# ---------------------------------------------------------------------------
# jax backend: works without x64, matches numpy, no retraces, shard_map
# ---------------------------------------------------------------------------

def _headline_rel_diff(a, b):
    ra, rb = a.headline_ratios(), b.headline_ratios()
    return max(abs(rb[k] - ra[k]) / abs(ra[k]) for k in ra)


def test_jax_backend_works_without_x64_and_matches_numpy():
    import jax
    assert not jax.config.read("jax_enable_x64")  # default config
    cfgs = list(design_space())
    for wl in ("vgg16", "resnet34", "resnet50"):
        rn = explore(wl, cfgs, backend="numpy")
        rj = explore(wl, cfgs, backend="jax")
        assert _headline_rel_diff(rn, rj) < 1e-6, wl
        # per-point agreement on the headline metrics too
        pn = np.array([p.perf_per_area for p in rn.points])
        pj = np.array([p.perf_per_area for p in rj.points])
        en = np.array([p.energy_j for p in rn.points])
        ej = np.array([p.energy_j for p in rj.points])
        assert np.max(np.abs(pj / pn - 1)) < 1e-5, wl
        assert np.max(np.abs(ej / en - 1)) < 1e-5, wl


def test_jax_kernel_does_not_retrace_same_shape_batches():
    cfgs = list(design_space())
    explore("vgg16", cfgs, backend="jax")           # compile
    fn, exact = get_jax_kernel()
    assert not exact                                # x64-free policy
    before = fn._cache_size()
    # different values, same shapes: must hit the jit cache
    shifted = [AcceleratorConfig(
        pe_type=c.pe_type, pe_rows=c.pe_rows, pe_cols=c.pe_cols,
        ifmap_spad=c.ifmap_spad + 1, filter_spad=c.filter_spad,
        psum_spad=c.psum_spad, glb_kb=c.glb_kb,
        dram_bw_gbps=c.dram_bw_gbps) for c in cfgs]
    explore("vgg16", shifted, backend="jax")
    explore("vgg16", cfgs, backend="jax", use_cache=False)
    assert fn._cache_size() == before


def test_jax_sweep_with_mesh_matches_unsharded():
    from repro.launch.mesh import make_sweep_mesh
    mesh = make_sweep_mesh()
    plain = explore(TINY_WL, SMALL_SPACE, backend="jax")
    sharded = explore(TINY_WL, SMALL_SPACE, backend="jax", mesh=mesh)
    for p, s in zip(plain.points, sharded.points):
        assert p.result.energy_j == pytest.approx(s.result.energy_j,
                                                  rel=1e-6)
        assert p.result.perf_per_area == pytest.approx(
            s.result.perf_per_area, rel=1e-6)


# ---------------------------------------------------------------------------
# chunked streamed driver
# ---------------------------------------------------------------------------

def test_chunked_front_matches_in_memory_front():
    cfgs = list(design_space())
    res = explore("vgg16", cfgs, backend="numpy")
    want = {p.config for p in pareto_front(res.points)}
    # stream the same space as SoA chunks of awkward size
    chunked = explore_chunked("vgg16", design_space_soa(chunk_size=97),
                              chunk_size=97, backend="numpy")
    assert chunked.n_configs == len(cfgs)
    assert chunked.n_chunks == -(-len(cfgs) // 97)
    got = set(chunked.front_configs())
    assert got == want
    # metrics agree with the in-memory sweep
    by_cfg = {p.config: p for p in res.points}
    for pt in chunked.front_points():
        ref = by_cfg[pt["config"]]
        assert pt["energy_j"] == ref.energy_j
        assert pt["perf_per_area"] == ref.perf_per_area


def test_chunked_accepts_config_generator_and_sequences():
    gen = (c for c in SMALL_SPACE)                  # flat generator
    a = sweep_chunked(TINY_WL, gen, chunk_size=5, backend="numpy")
    b = sweep_chunked(TINY_WL, [SMALL_SPACE], chunk_size=5,
                      backend="numpy")              # sequence-of-sequences
    assert a.n_configs == b.n_configs == len(SMALL_SPACE)
    assert set(a.front_configs()) == set(b.front_configs())
    res = explore(TINY_WL, SMALL_SPACE, backend="numpy")
    assert set(a.front_configs()) == \
        {p.config for p in pareto_front(res.points)}


def test_chunked_empty_feed():
    res = sweep_chunked(TINY_WL, [], backend="numpy")
    assert res.n_configs == 0 and res.front_size == 0
    assert res.front_configs() == []


def test_chunked_jax_pads_tail_chunk():
    # 14 configs with chunk_size 8 -> tail of 6 is padded to 8 under jax;
    # results must still match numpy exactly per point
    space = SMALL_SPACE + [AcceleratorConfig(glb_kb=192),
                           AcceleratorConfig(glb_kb=320)]
    rn = sweep_chunked(TINY_WL, [space], chunk_size=8, backend="numpy")
    rj = sweep_chunked(TINY_WL, [space], chunk_size=8, backend="jax")
    assert rn.n_configs == rj.n_configs == len(space)
    assert set(rn.front_configs()) == set(rj.front_configs())


# ---------------------------------------------------------------------------
# persisted synthesis cache
# ---------------------------------------------------------------------------

def test_persistent_cache_roundtrip(tmp_path):
    path = tmp_path / "synth.npz"
    soa = configs_to_soa(SMALL_SPACE)
    ref = synthesize_soa(soa)

    cache = PersistentSynthesisCache(path)
    cols = cache.synthesize(soa)
    assert cache.misses == len(SMALL_SPACE) and cache.hits == 0
    for k in ref:
        assert np.array_equal(cols[k], ref[k])
    assert cache.save() == len(SMALL_SPACE)

    # a fresh process-equivalent: loads from disk, does zero synthesis
    cache2 = PersistentSynthesisCache(path)
    assert len(cache2) == len(SMALL_SPACE)
    cols2 = cache2.synthesize(soa)
    assert cache2.misses == 0 and cache2.hits == len(SMALL_SPACE)
    for k in ref:
        assert np.array_equal(cols2[k], ref[k])


def test_chunked_sweep_persists_and_reuses_cache(tmp_path):
    path = tmp_path / "sweep_synth.npz"
    r1 = sweep_chunked(TINY_WL, [SMALL_SPACE], chunk_size=5,
                       backend="numpy", cache=str(path))
    assert path.exists()
    assert r1.synthesis_cache.misses == len(SMALL_SPACE)

    r2 = sweep_chunked(TINY_WL, [SMALL_SPACE], chunk_size=5,
                       backend="numpy", cache=str(path))
    assert r2.synthesis_cache.misses == 0          # fully hydrated
    assert r2.synthesis_cache.hits == len(SMALL_SPACE)
    assert set(r1.front_configs()) == set(r2.front_configs())


def test_persistent_cache_clear_keeps_path_and_cap(tmp_path):
    path = tmp_path / "c.npz"
    cache = PersistentSynthesisCache(path, max_rows=64)
    cache.synthesize(configs_to_soa(SMALL_SPACE))
    cache.clear()
    assert len(cache) == 0
    assert cache.path == path and cache.max_rows == 64
    cache.synthesize(configs_to_soa(SMALL_SPACE[:2]))
    assert cache.save() == 2                       # path survived clear()


def test_cache_limit_also_bounds_sweep_array_store():
    from repro.core.synthesis import (set_synthesis_cache_limit,
                                      sweep_synthesis_cache)
    clear_synthesis_cache()
    old = set_synthesis_cache_limit(4)
    try:
        explore(TINY_WL, SMALL_SPACE)              # 12 distinct configs
        store = sweep_synthesis_cache()
        assert store.max_rows == 4
        assert len(store) <= 4 and store.evictions > 0
    finally:
        set_synthesis_cache_limit(old)
        clear_synthesis_cache()


def test_persistent_cache_bounded_compaction():
    cache = PersistentSynthesisCache(max_rows=8)
    soa = configs_to_soa(SMALL_SPACE)               # 12 distinct configs
    cache.synthesize(soa)
    assert len(cache) <= 8
    assert cache.evictions > 0
    # surviving rows still hit
    cache.hits = cache.misses = 0
    cache.synthesize(soa)
    assert cache.hits > 0


def test_incremental_sweep_cache_is_bounded():
    """Satellite: the in-process sweep cache must not grow without limit
    across IncrementalSweep.extend calls."""
    from repro.core.dse import IncrementalSweep
    from repro.core.synthesis import (sweep_synthesis_cache,
                                      synthesis_cache_stats)
    clear_synthesis_cache()
    store = sweep_synthesis_cache()
    old_cap = store.max_rows
    store.max_rows = 16
    try:
        sweep = IncrementalSweep(TINY_WL)
        for glb in (32, 64, 96, 128, 160):
            sweep.extend(AcceleratorConfig(pe_type=t, glb_kb=glb)
                         for t in PEType)
        assert len(sweep) == 20                     # results all kept...
        assert len(store) <= 16                     # ...the cache bounded
        stats = synthesis_cache_stats()
        assert stats["array_evictions"] > 0
        assert stats["array_size"] <= 16
    finally:
        store.max_rows = old_cap
        clear_synthesis_cache()
