"""Epsilon-dominance archive (ISSUE 7): grid semantics, deterministic
replay, and the bounded-memory / hypervolume-preservation contract on a
long NSGA-II run."""

import numpy as np
import pytest

from repro.core.workloads import ConvLayer, Workload
from repro.explore import CoExploreSpace, hypervolume, nsga2
from repro.explore.pareto import (EpsilonDominanceArchive,
                                  epsilon_from_reference)


# ---------------------------------------------------------------------------
# grid semantics
# ---------------------------------------------------------------------------

def test_box_dominated_candidate_rejected():
    a = EpsilonDominanceArchive(1.0, n_objectives=2)
    a.add(np.array([[0]]), np.array([[0.5, 0.5]]))      # box (0, 0)
    # box (1, 1) is dominated by (0, 0) -> rejected even though the point
    # itself is non-dominated at full resolution in neither objective
    n = a.add(np.array([[1]]), np.array([[1.5, 1.5]]))
    assert n == 1
    assert a.objectives.tolist() == [[0.5, 0.5]]


def test_accepted_candidate_evicts_dominated_boxes():
    a = EpsilonDominanceArchive(1.0, n_objectives=2)
    a.add(np.array([[0], [1]]),
          np.array([[2.5, 0.5], [0.5, 2.5]]))           # boxes (2,0), (0,2)
    assert len(a) == 2
    a.add(np.array([[2]]), np.array([[0.2, 0.2]]))      # box (0,0) beats both
    assert len(a) == 1
    assert a.objectives.tolist() == [[0.2, 0.2]]
    assert a.genomes.tolist() == [[2]]


def test_same_box_keeps_point_nearest_lower_corner():
    a = EpsilonDominanceArchive(1.0, n_objectives=2)
    a.add(np.array([[0]]), np.array([[0.9, 0.9]]))
    a.add(np.array([[1]]), np.array([[0.1, 0.1]]))      # closer to corner
    assert len(a) == 1
    assert a.genomes.tolist() == [[1]]
    # incumbent keeps ties and farther points
    a.add(np.array([[2]]), np.array([[0.1, 0.1]]))
    a.add(np.array([[3]]), np.array([[0.5, 0.5]]))
    assert a.genomes.tolist() == [[1]]


def test_incomparable_boxes_accumulate():
    a = EpsilonDominanceArchive(np.array([1.0, 2.0]))
    F = np.array([[0.5, 9.0], [1.5, 5.0], [2.5, 1.0]])
    a.add(np.arange(3)[:, None], F)
    assert len(a) == 3
    g, f = a.front()
    assert len(g) == 3                          # mutually non-dominated


def test_replay_reproduces_archive_exactly():
    """Re-offering the archived representatives in stored order rebuilds
    the grid bit for bit — the checkpoint/resume reconstruction path."""
    rng = np.random.default_rng(7)
    a = EpsilonDominanceArchive(0.05, n_objectives=3)
    for _ in range(20):
        a.add(rng.integers(0, 100, size=(16, 4)), rng.random((16, 3)))
    b = EpsilonDominanceArchive(0.05, n_objectives=3)
    b.add(a.genomes, a.objectives)
    assert np.array_equal(a.genomes, b.genomes)
    assert np.array_equal(a.objectives, b.objectives)


def test_archive_validation():
    with pytest.raises(ValueError, match="positive"):
        EpsilonDominanceArchive(0.0, n_objectives=2)
    with pytest.raises(ValueError, match="positive"):
        EpsilonDominanceArchive([0.1, -0.1])
    a = EpsilonDominanceArchive(0.1, n_objectives=2)
    with pytest.raises(ValueError, match="does not match epsilon"):
        a.add(np.zeros((1, 2)), np.zeros((1, 3)))
    with pytest.raises(ValueError, match="genomes vs"):
        a.add(np.zeros((2, 2)), np.zeros((1, 2)))
    assert a.genomes.shape == (0, 0)            # still empty, still usable


def test_epsilon_from_reference():
    eps = epsilon_from_reference(np.array([10.0, 1.0]),
                                 np.array([0.0, 1.0]), 0.1)
    np.testing.assert_allclose(eps[0], 1.0)     # 10% of the span
    np.testing.assert_allclose(eps[1], 0.1)     # zero span -> |ref| floor
    with pytest.raises(ValueError, match=r"in \(0, 1\)"):
        epsilon_from_reference(np.ones(2), np.zeros(2), 1.5)


# ---------------------------------------------------------------------------
# bounded archive on a long search run (acceptance criterion)
# ---------------------------------------------------------------------------

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
))
SEARCH_SPACE = CoExploreSpace(n_layers=len(TINY_WL.layers))


def test_bounded_archive_memory_and_hypervolume():
    """3072 evaluations: the epsilon archive stays bounded (far below the
    unbounded archive) while its hypervolume stays within the grid
    resolution of the unbounded one."""
    rel_eps = 0.02
    kw = dict(pop_size=64, seed=11, backend="numpy")
    unbounded = nsga2(SEARCH_SPACE, TINY_WL, 3072, **kw)
    bounded = nsga2(SEARCH_SPACE, TINY_WL, 3072, archive_epsilon=rel_eps,
                    **kw)

    # the evolution itself is archive-independent: same trajectory
    assert np.array_equal(bounded.population, unbounded.population)
    assert np.array_equal(bounded.all_objectives, unbounded.all_objectives)

    nb, nu = bounded.stats["archive_size"], unbounded.stats["archive_size"]
    assert nb < nu / 3                          # genuinely bounded
    assert nb <= 64                             # constant-memory regime

    # hv(unbounded) - hv(bounded) <= sum_k eps_k * prod_{j != k} span_j:
    # each archived box representative is within one grid cell of a true
    # non-dominated point, so the lost hypervolume is at most a one-cell-
    # thick shell of the dominated region
    eps = np.asarray(bounded.stats["archive_epsilon"])
    ref = unbounded.ref_point
    span = ref - unbounded.all_objectives.min(axis=0)
    k = len(eps)
    shell = sum(eps[i] * np.prod([span[j] for j in range(k) if j != i])
                for i in range(k))
    hv_u = unbounded.history[-1][1]
    hv_b = bounded.history[-1][1]
    assert hv_u >= hv_b                         # bounding never adds hv
    assert hv_u - hv_b <= shell, (hv_u, hv_b, shell)

    # the bounded front is a genuine non-dominated set over its archive
    assert len(bounded.genomes) == len(bounded.front_objectives)
    recomputed = hypervolume(bounded.front_objectives, ref)
    np.testing.assert_allclose(recomputed, hv_b, rtol=1e-12)


def test_marathon_preset_carries_archive_epsilon():
    from repro.configs.coexplore_presets import get_preset
    p = get_preset("marathon")
    assert p.archive_epsilon == 0.01 and p.method == "nsga2"
    with pytest.raises(ValueError, match="archive_epsilon"):
        from repro.configs.coexplore_presets import CoExplorePreset
        CoExplorePreset(name="bad", method="random", archive_epsilon=0.1)
