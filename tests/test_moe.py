"""MoE dispatch correctness: sort-based buffer dispatch == dense loop."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.moe import moe_ffn, topk_route
from repro.quant.policy import QuantPolicy, ExecMode


def _params(key, d, E, ff):
    ks = jax.random.split(jax.random.key(key), 4)
    return {
        "router": jax.random.normal(ks[0], (d, E)) * 0.5,
        "w_experts_gate": jax.random.normal(ks[1], (E, d, ff)) * 0.1,
        "w_experts_in": jax.random.normal(ks[2], (E, d, ff)) * 0.1,
        "w_experts_out": jax.random.normal(ks[3], (E, ff, d)) * 0.1,
    }


def dense_reference(x, p, top_k):
    """Compute every expert for every token, combine with top-k gates."""
    T, d = x.shape
    E = p["router"].shape[1]
    probs = jax.nn.softmax(x @ p["router"], axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", x, p["w_experts_gate"])
    u = jnp.einsum("td,edf->tef", x, p["w_experts_in"])
    h = jax.nn.silu(g) * u
    all_out = jnp.einsum("tef,efd->ted", h, p["w_experts_out"])
    out = jnp.zeros((T, d))
    for kk in range(top_k):
        sel = jnp.take_along_axis(
            all_out, experts[:, kk][:, None, None], axis=1)[:, 0]
        out = out + gates[:, kk][:, None] * sel
    return out


def test_moe_matches_dense_reference():
    d, E, ff, b, s = 16, 4, 32, 2, 8
    cfg = reduced(get_config("moonshot-v1-16b-a3b"),
                  d_model=d, n_experts=E, top_k=2, d_ff=ff)
    p = _params(0, d, E, ff)
    x = jax.random.normal(jax.random.key(1), (b, s, d)) * 0.5
    policy = QuantPolicy(mode=ExecMode.FP32)
    # ample capacity so nothing drops
    out, aux = moe_ffn(x, p, cfg, policy=policy, train=False,
                       capacity_factor=4.0)
    ref = dense_reference(x.reshape(-1, d), p, 2).reshape(b, s, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_topk_route_properties():
    x = jax.random.normal(jax.random.key(0), (32, 8))
    w = jax.random.normal(jax.random.key(1), (8, 6))
    gates, experts, aux = topk_route(x, w, 6, 3)
    assert gates.shape == (32, 3) and experts.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), np.ones(32),
                               rtol=1e-5)
    assert int(experts.max()) < 6 and int(experts.min()) >= 0
    # top-1 gate >= later gates
    assert bool(jnp.all(gates[:, 0] >= gates[:, -1]))


def test_capacity_drops_are_bounded():
    """With tight capacity, output is a partial sum — never NaN, and
    dropped tokens fall back toward zero contribution."""
    d, E, ff = 8, 2, 16
    cfg = reduced(get_config("moonshot-v1-16b-a3b"),
                  d_model=d, n_experts=E, top_k=2, d_ff=ff)
    p = _params(2, d, E, ff)
    x = jax.random.normal(jax.random.key(3), (1, 64, d))
    policy = QuantPolicy(mode=ExecMode.FP32)
    out, _ = moe_ffn(x, p, cfg, policy=policy, train=False,
                     capacity_factor=0.25)
    assert not bool(jnp.any(jnp.isnan(out)))
