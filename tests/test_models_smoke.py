"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import SHAPES, reduced
from repro.models.model import Model
from repro.optim import adamw


def _batch(cfg, b=2, s=16, key=0):
    toks = jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family in ("vlm", "audio"):
        batch["ctx"] = jax.random.normal(
            jax.random.key(key + 1), (b, cfg.n_ctx_tokens, cfg.d_model),
            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_no_nans(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch["tokens"],
                                ctx=batch.get("ctx"), train=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw.init(params)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert not bool(jnp.isnan(loss)) and float(loss) > 0
    gnorm = adamw.global_norm(grads)
    assert float(gnorm) > 0 and not bool(jnp.isnan(gnorm))
    new_params, opt, metrics = adamw.update(adamw.AdamWConfig(), grads,
                                            opt, params)
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    caches = model.init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, caches2 = model.decode_step(params, caches, tok, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_configs_match_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned dims."""
    cfg = get_config(arch)
    expected = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_param_counts_plausible():
    cfg = get_config("moonshot-v1-16b-a3b")
    n = cfg.n_params()
    na = cfg.n_active_params()
    # total/active derived from the *assigned* config (64e x d_ff 1408):
    # experts alone are 64*3*2048*1408*48 ~ 26.5B
    assert 26e9 < n < 30e9, n
    assert 2e9 < na < 4.5e9, na        # ~3B active (top-6)
    d = get_config("deepseek-67b")
    assert 60e9 < d.n_params() < 72e9


def test_gemma3_window_pattern():
    cfg = get_config("gemma3-4b")
    m = Model(cfg)
    w = m._windows(4096)
    import numpy as np
    w = np.asarray(w)
    assert (w == cfg.window).sum() == cfg.n_layers - cfg.n_layers // 6
    assert (w > 1e8).sum() == cfg.n_layers // 6   # global layers
