"""Mamba-2 SSD correctness: chunked algorithm vs naive recurrence, and
decode-step consistency with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import ssm
from repro.models.model import Model


def naive_ssm(xh, dt, a_log, B, C):
    """Sequential reference: h_t = exp(dt*A) h_{t-1} + dt*B_t x_t."""
    b, s, h, p = xh.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    A = -np.exp(np.asarray(a_log, np.float64))
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    x = np.asarray(xh, np.float64)
    dtn = np.asarray(dt, np.float64)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(dtn[:, t] * A)                      # (b, h)
        xt = x[:, t] * dtn[:, t][..., None]             # (b, h, p)
        state = state * dA[..., None, None] + \
            np.einsum("bhp,bhn->bhpn", xt, Bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.key(0)
    b, s, h, p, g, n = 2, 16, 4, 8, 1, 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y, final = ssm.ssd_chunked(xh, dt, a_log, B, C, chunk=chunk)
    y_ref, final_ref = naive_ssm(xh, dt, a_log, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref,
                               rtol=1e-4, atol=1e-4)


def test_decode_matches_forward_last_position():
    """Running the mamba block token-by-token must equal the full
    (chunked) forward at every position."""
    cfg = reduced(get_config("mamba2-130m"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    s = 8
    toks = jax.random.randint(jax.random.key(1), (2, s), 0, cfg.vocab)
    full_logits, _ = model.forward(params, toks, train=False)
    caches = model.init_cache(2, s)
    outs = []
    for i in range(s):
        logits, caches = model.decode_step(params, caches, toks[:, i:i + 1],
                                           jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_conv_streaming_matches_full():
    b, s, c = 2, 10, 6
    x = jax.random.normal(jax.random.key(0), (b, s, c))
    w = jax.random.normal(jax.random.key(1), (ssm.D_CONV, c)) * 0.5
    full, _ = ssm.causal_conv1d(x, w)
    cache = jnp.zeros((b, ssm.D_CONV - 1, c))
    outs = []
    for t in range(s):
        y, cache = ssm.causal_conv1d(x[:, t:t + 1], w, cache=cache)
        outs.append(y[:, 0])
    stream = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_ssd_init_state_threading():
    """Chunked SSD with an initial state == concatenated sequence."""
    key = jax.random.key(7)
    b, s, h, p, g, n = 1, 8, 2, 4, 1, 4
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, 2 * s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, 2 * s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    B = jax.random.normal(ks[3], (b, 2 * s, g, n))
    C = jax.random.normal(ks[4], (b, 2 * s, g, n))
    y_all, f_all = ssm.ssd_chunked(xh, dt, a_log, B, C, chunk=4)
    y1, f1 = ssm.ssd_chunked(xh[:, :s], dt[:, :s], a_log, B[:, :s],
                             C[:, :s], chunk=4)
    y2, f2 = ssm.ssd_chunked(xh[:, s:], dt[:, s:], a_log, B[:, s:],
                             C[:, s:], chunk=4, init_state=f1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_all),
                               rtol=1e-4, atol=1e-4)
