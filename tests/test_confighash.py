"""Counter-based config hash: bit-identity across scalar / batched-numpy /
batched-jax paths (ISSUE 2 acceptance), distribution sanity, and key
stability for the persisted synthesis cache.

Property tests run over seeded random config batches (no hypothesis
dependency, so they run in every environment)."""

import numpy as np
import pytest

from repro.core import confighash as ch
from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.pe import PEType

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

TYPES = tuple(PEType)


def random_config(rng: np.random.Generator) -> AcceleratorConfig:
    return AcceleratorConfig(
        pe_type=TYPES[rng.integers(len(TYPES))],
        pe_rows=int(rng.integers(1, 257)),
        pe_cols=int(rng.integers(1, 257)),
        ifmap_spad=int(rng.integers(0, 4097)),
        filter_spad=int(rng.integers(0, 4097)),
        psum_spad=int(rng.integers(0, 4097)),
        glb_kb=int(rng.integers(1, 1 << 16)),
        dram_bw_gbps=float(np.round(rng.uniform(0.1, 1e4), 3)),
        clock_ghz=(None if rng.random() < 0.5
                   else float(np.round(rng.uniform(0.05, 10.0), 3))))


def random_batch(rng, n):
    return [random_config(rng) for _ in range(n)]


def test_digests_bit_identical_scalar_batched_jax():
    """Property: for random config batches, the scalar path (length-1
    batch), the batched numpy path, and the jax path (default config, no
    x64) produce bit-identical digest lanes."""
    rng = np.random.default_rng(42)
    for trial in range(25):
        cfgs = random_batch(rng, int(rng.integers(1, 16)))
        words = ch.pack_config_words(configs_to_soa(cfgs))
        batched = ch.digest_words(words, xp=np)
        for i in range(len(cfgs)):
            soa1 = configs_to_soa(cfgs[i:i + 1])
            single = ch.digest_words(ch.pack_config_words(soa1), xp=np)
            for lane_b, lane_s in zip(batched, single):
                assert lane_b[i] == lane_s[0], (trial, i)
        jbatched = ch.digest_words(words, xp=jnp)
        for lane_b, lane_j in zip(batched, jbatched):
            lane_j = np.asarray(lane_j)
            assert lane_j.dtype == np.uint32
            assert np.array_equal(lane_b, lane_j), trial


def test_jitter_variates_bit_identical_across_precisions():
    """float64 (numpy) and float32 (jax x64-free) jitter variates are the
    same real numbers: 24-bit integers scale exactly in both."""
    rng = np.random.default_rng(7)
    d = ch.config_digests(configs_to_soa(random_batch(rng, 64)))
    for lane in d[:3]:
        u64 = ch.uniform01(lane, xp=np, dtype=np.float64)
        u32 = np.asarray(ch.uniform01(jnp.asarray(lane), xp=jnp,
                                      dtype=np.float32))
        assert u32.dtype == np.float32
        assert np.array_equal(u64, u32.astype(np.float64))
        assert np.all((u64 >= 0.0) & (u64 < 1.0))


def test_scalar_and_batched_synthesis_jitter_agree():
    """End-to-end: synthesize (length-1 batch) == synthesize_many row for
    random configs — the jitter inherits the digest bit-identity."""
    from repro.core.synthesis import synthesize, synthesize_many
    rng = np.random.default_rng(11)
    cfgs = random_batch(rng, 32)
    reps = synthesize_many(cfgs, use_cache=False)
    for cfg, rep in zip(cfgs, reps):
        assert rep == synthesize(cfg), cfg.name()


def test_distinct_configs_get_distinct_digests():
    rng = np.random.default_rng(3)
    cfgs = random_batch(rng, 512)
    uniq_cfgs = len({(c.pe_type, c.pe_rows, c.pe_cols, c.ifmap_spad,
                      c.filter_spad, c.psum_spad, c.glb_kb,
                      c.dram_bw_gbps, c.clock_ghz) for c in cfgs})
    keys = ch.digest_keys(ch.config_digests(configs_to_soa(cfgs)))
    assert len(set(keys)) == uniq_cfgs


def test_digest_uniqueness_and_uniformity_on_grid():
    from repro.core.accelerator import design_space_soa
    (soa,) = design_space_soa(glb_kbs=tuple(range(16, 2064, 16)),
                              bws=(6.4, 12.8, 25.6))
    n = len(soa["pe_rows"])
    d = ch.config_digests(soa)
    u64 = ch.digests_to_u64(d)
    assert len(np.unique(u64.view([("a", "u8"), ("b", "u8")]))) == n
    for lane in range(4):
        u = ch.uniform01(d[lane])
        assert abs(u.mean() - 0.5) < 0.01
        assert abs(u.std() - np.sqrt(1 / 12)) < 0.01


def test_digest_golden_value_is_stable():
    """The digest keys npz caches on disk — a change here silently orphans
    every persisted cache, so pin one golden value."""
    soa = configs_to_soa([AcceleratorConfig()])
    key = ch.digest_keys(ch.config_digests(soa))[0]
    assert key.hex() == "85ec1d0bfd223cd6d7ac4de740b49172"


def test_f64_words_canonicalizes_nan_and_separates_values():
    lo, hi = ch.f64_words(np.array([np.nan, np.inf, 12.8]))
    lo2, hi2 = ch.f64_words(np.array([np.float64("nan"), np.inf, 12.8]))
    assert np.array_equal(lo, lo2) and np.array_equal(hi, hi2)
    assert (lo[1], hi[1]) != (lo[2], hi[2])


def test_config_hash_distinguishes_every_field():
    base = AcceleratorConfig()
    from repro.core.synthesis import config_hash
    variants = [
        AcceleratorConfig(pe_type=PEType.FP32),
        AcceleratorConfig(pe_rows=13),
        AcceleratorConfig(pe_cols=13),
        AcceleratorConfig(ifmap_spad=13),
        AcceleratorConfig(filter_spad=13),
        AcceleratorConfig(psum_spad=13),
        AcceleratorConfig(glb_kb=13),
        AcceleratorConfig(dram_bw_gbps=13.0),
        AcceleratorConfig(clock_ghz=0.5),
    ]
    h0 = config_hash(base)
    hashes = {config_hash(v) for v in variants}
    assert h0 not in hashes and len(hashes) == len(variants)
