"""Property-based tests for the quantization library (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (dev dependency)")
from hypothesis import given, settings, strategies as st
from hypothesis.extra.numpy import arrays

from repro.quant import quantizers as qz

SETTINGS = dict(max_examples=25, deadline=None)


def finite_arrays(shape):
    return arrays(np.float32, shape,
                  elements=st.floats(-100, 100, width=32,
                                     allow_nan=False, allow_infinity=False))


@given(x=finite_arrays((8, 16)))
@settings(**SETTINGS)
def test_int8_qdq_error_bound(x):
    """|x - qdq(x)| <= scale/2 elementwise (plus clip at the edges)."""
    out = qz.quantize_dequantize_int(jnp.asarray(x), 8)
    scale = np.asarray(qz.int_scale(jnp.asarray(x), 8))
    assert np.all(np.abs(np.asarray(out) - x) <= scale / 2 + 1e-6)


@given(x=finite_arrays((4, 8)))
@settings(**SETTINGS)
def test_int8_qdq_idempotent(x):
    once = qz.quantize_dequantize_int(jnp.asarray(x), 8)
    twice = qz.quantize_dequantize_int(once, 8)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-5, atol=1e-6)


@given(x=finite_arrays((8, 8)))
@settings(**SETTINGS)
def test_pow2_relative_error(x):
    """pow2 rounding: within range, relative error <= 2^0.5-1 ~ 41%."""
    xj = jnp.asarray(x)
    out = np.asarray(qz.quantize_dequantize_pow2(xj))
    scale = np.asarray(qz.pow2_scale(xj))
    lo = scale * 2.0 ** (-qz.POW2_EXP_BIAS)
    in_range = np.abs(x) >= lo
    rel = np.abs(out - x) / np.maximum(np.abs(x), 1e-12)
    assert np.all(rel[in_range] <= 0.5 + 1e-6)


@given(x=finite_arrays((8, 8)))
@settings(**SETTINGS)
def test_pow2_2term_never_worse(x):
    xj = jnp.asarray(x)
    one = np.asarray(qz.quantize_dequantize_pow2(xj))
    two = np.asarray(qz.quantize_dequantize_pow2_2term(xj))
    assert np.all(np.abs(two - x) <= np.abs(one - x) + 1e-6)


@given(codes=arrays(np.int8, (6, 8), elements=st.integers(0, 15)))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(codes):
    packed = qz.pack_int4(jnp.asarray(codes))
    assert packed.shape == (6, 4)
    out = np.asarray(qz.unpack_int4(packed))
    np.testing.assert_array_equal(out, codes)


def test_pow2_encode_decode_exact_powers():
    scale = jnp.float32(1.0)
    vals = jnp.array([1.0, 0.5, 0.25, -1.0, -0.125], jnp.float32)
    codes = qz.pow2_encode(vals, scale)
    out = qz.pow2_decode(codes, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals))


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(qz.fake_quant_int(x, 8)))(
        jnp.linspace(-1, 1, 16))
    np.testing.assert_allclose(np.asarray(g), np.ones(16), atol=1e-6)


def test_per_channel_scales_shape():
    w = jax.random.normal(jax.random.key(0), (32, 16))
    s = qz.int_scale(w, 8, axis=0)
    assert s.shape == (1, 16)
    q = qz.quantize_int(w, s, 8)
    assert q.dtype == jnp.int8
    back = qz.dequantize_int(q, s)
    assert float(jnp.max(jnp.abs(back - w))) <= float(jnp.max(s)) / 2 + 1e-6
