"""Cross-backend differential tests (ISSUE 4 satellite).

One fixture (`cross_backend_check`, see conftest) drives the same batch
through the scalar reference, the batched numpy kernel, and the jitted
jax kernel, asserting bit-exactness (scalar vs numpy) and 1e-6 relative
parity (jax) — applied here to `sweep_mixed`, the multi-workload
`sweep_mixed_many`, and `sweep_chunked` resume points (a stream stopped
and resumed through the persisted synthesis cache).
"""

import numpy as np

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dataflow import run_workload_mixed
from repro.core.dse_batch import (AGGREGATE_OUTPUTS, sweep_chunked,
                                  sweep_mixed, sweep_mixed_many)
from repro.core.pe import PEType, supported_modes
from repro.core.workloads import ConvLayer, Workload, get_workload

TYPES = tuple(PEType)

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
    ConvLayer("big", 226, 226, 3, 64),
))

TINY_B = Workload("tinyb", (
    ConvLayer("c1", 114, 114, 32, 64),
    ConvLayer("fc", 1, 1, 256, 100, 1, 1),
))

SMALL_SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in TYPES
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (32, 32, 512, 25.6)]
]

RATIO_KEYS = ("latency_s", "energy_j", "perf_per_area",
              "throughput_gmacs")


def _random_assignment(rng, configs, n_layers):
    assign = np.empty((len(configs), n_layers), dtype=np.int64)
    for i, c in enumerate(configs):
        modes = [TYPES.index(m) for m in supported_modes(c.pe_type)]
        assign[i] = rng.choice(modes, size=n_layers)
    return assign


def _scalar_mixed(wl, configs, assign):
    """The scalar reference, column-ized like the kernel output."""
    rows = [run_workload_mixed(wl, cfg, [TYPES[j] for j in a])
            for cfg, a in zip(configs, assign)]
    return {
        "latency_s": np.array([r.latency_s for r in rows]),
        "energy_j": np.array([r.energy_j for r in rows]),
        "perf_per_area": np.array([r.perf_per_area for r in rows]),
        "throughput_gmacs": np.array([r.throughput_gmacs for r in rows]),
        "total_cycles_sum": np.array([r.total_cycles for r in rows],
                                     dtype=np.int64),
    }


def test_sweep_mixed_three_way(cross_backend_check):
    rng = np.random.default_rng(11)
    configs = [SMALL_SPACE[i]
               for i in rng.integers(0, len(SMALL_SPACE), size=40)]
    soa = configs_to_soa(configs)
    assign = _random_assignment(rng, configs, len(TINY_WL.layers))
    scalar = _scalar_mixed(TINY_WL, configs, assign)

    out = cross_backend_check(
        run=lambda backend: sweep_mixed(
            TINY_WL, soa, assign, backend=backend,
            outputs="aggregates", use_cache=False),
        scalar=scalar,
        bit_keys=("latency_s", "energy_j", "perf_per_area",
                  "total_cycles_sum"),
        ratio_keys=RATIO_KEYS)
    assert set(AGGREGATE_OUTPUTS) <= set(out)


def test_sweep_mixed_many_three_way(cross_backend_check):
    wls = (TINY_WL, TINY_B, get_workload("vgg16"))
    rng = np.random.default_rng(23)
    configs = [SMALL_SPACE[i]
               for i in rng.integers(0, len(SMALL_SPACE), size=30)]
    soa = configs_to_soa(configs)
    assigns = [_random_assignment(rng, configs, len(w.layers))
               for w in wls]
    # scalar reference: each workload independently, stacked to (W, N)
    per_wl = [_scalar_mixed(w, configs, a) for w, a in zip(wls, assigns)]
    scalar = {k: np.stack([p[k] for p in per_wl]) for k in per_wl[0]}

    cross_backend_check(
        run=lambda backend: sweep_mixed_many(
            wls, soa, assigns, backend=backend, use_cache=False),
        scalar=scalar,
        bit_keys=("latency_s", "energy_j", "perf_per_area",
                  "total_cycles_sum"),
        ratio_keys=RATIO_KEYS)


def test_sweep_chunked_resume_points_three_way(tmp_path,
                                               cross_backend_check):
    """A stream stopped after the first chunks and *resumed* (second sweep
    over the remaining feed, persisted synthesis cache shared) must land
    on the same Pareto front as the unbroken stream — per backend, with
    numpy bit-exact against the scalar-equivalent one-shot front."""
    space = SMALL_SPACE + [AcceleratorConfig(glb_kb=192),
                           AcceleratorConfig(glb_kb=320)]
    cut = 7                                     # resume point mid-chunk

    def run(backend):
        path = tmp_path / f"resume_{backend}.npz"
        first = sweep_chunked(TINY_WL, [space[:cut]], chunk_size=5,
                              backend=backend, cache=str(path))
        second = sweep_chunked(TINY_WL, [space[cut:]], chunk_size=5,
                               backend=backend, cache=str(path))
        # the resumed half re-loads the persisted synthesis rows
        assert second.synthesis_cache.misses == len(space) - cut
        # merge the two running fronts exactly like the streamed reduction
        merged = sweep_chunked(
            TINY_WL,
            [configs_to_soa(first.front_configs()
                            + second.front_configs())],
            chunk_size=5, backend=backend, cache=str(path))
        one_shot = sweep_chunked(TINY_WL, [space], chunk_size=5,
                                 backend=backend, cache=str(path))
        assert set(merged.front_configs()) == set(one_shot.front_configs())
        order = np.argsort(one_shot.front_metrics["energy_j"],
                           kind="stable")
        return {m: one_shot.front_metrics[m][order]
                for m in one_shot.front_metrics}

    # the scalar-equivalent reference: the batched numpy path is already
    # proven bit-exact vs explore_scalar elsewhere; here the "scalar" leg
    # is the unchunked batched evaluation of the same space
    from repro.core.dse import explore, pareto_front
    pts = pareto_front(explore(TINY_WL, space, backend="numpy",
                               use_cache=False).points)
    scalar = {
        "energy_j": np.array([p.energy_j for p in pts]),
        "perf_per_area": np.array([p.perf_per_area for p in pts]),
        "latency_s": np.array([p.result.latency_s for p in pts]),
        "throughput_gmacs": np.array([p.result.throughput_gmacs
                                      for p in pts]),
    }
    cross_backend_check(run, scalar=scalar,
                        bit_keys=("energy_j", "perf_per_area",
                                  "latency_s", "throughput_gmacs"),
                        ratio_keys=RATIO_KEYS)
