"""Correctness of the §Perf optimized paths: ring-buffer sliding-window
caches, int8 (W8A8) KV attention, and shard_map expert-parallel MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.models.moe import moe_ffn, moe_ffn_ep
from repro.parallel.sharding import (activation_sharding,
                                     default_activation_rules)
from repro.quant.policy import ExecMode, QuantPolicy


def _decode_equals_forward(arch, kv_quant, s=20, tol=5e-2):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, s), 0, cfg.vocab)
    full, _ = m.forward(params, toks, train=False)
    caches = m.init_cache(2, s, kv_quant=kv_quant)
    outs = []
    for i in range(s):
        lg, caches = m.decode_step(params, caches, toks[:, i:i + 1],
                                   jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    rel = err / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < tol, (arch, kv_quant, rel)


def test_ring_buffer_decode_past_wrap():
    """gemma3 ring caches wrap (s=20 > window=8) and still match."""
    _decode_equals_forward("gemma3-4b", kv_quant=False)


def test_ring_buffer_decode_int8_kv():
    _decode_equals_forward("gemma3-4b", kv_quant=True)


def test_int8_kv_dense_decode():
    _decode_equals_forward("starcoder2-7b", kv_quant=True)


def test_int8_kv_moe_decode():
    _decode_equals_forward("moonshot-v1-16b-a3b", kv_quant=True, tol=8e-2)


def test_ring_cache_memory_is_window_sized():
    cfg = reduced(get_config("gemma3-4b"))   # window=8, global_every=2
    m = Model(cfg)
    c = m.init_cache(2, 64)
    assert c["k_local"].shape[2] == cfg.window
    assert c["k"].shape[2] == 64
    n_glob = cfg.n_layers // cfg.global_every
    assert c["k"].shape[0] == n_glob
    assert c["k_local"].shape[0] == cfg.n_layers - n_glob


def test_moe_ep_matches_global_dispatch():
    cfg = reduced(get_config("moonshot-v1-16b-a3b"),
                  d_model=16, n_experts=4, top_k=2, d_ff=32)
    ks = jax.random.split(jax.random.key(0), 4)
    p = {"router": jax.random.normal(ks[0], (16, 4)) * 0.5,
         "w_experts_gate": jax.random.normal(ks[1], (4, 16, 32)) * 0.1,
         "w_experts_in": jax.random.normal(ks[2], (4, 16, 32)) * 0.1,
         "w_experts_out": jax.random.normal(ks[3], (4, 32, 16)) * 0.1}
    x = jax.random.normal(jax.random.key(9), (2, 8, 16)) * 0.5
    policy = QuantPolicy(mode=ExecMode.FP32)
    ref, aux_ref = moe_ffn(x, p, cfg, policy=policy, train=False,
                           capacity_factor=4.0)
    mesh = make_host_mesh()
    rules = default_activation_rules(mesh, seq_sharded=False)
    with mesh, activation_sharding(mesh, rules):
        out, aux = jax.jit(lambda x, p: moe_ffn_ep(
            x, p, cfg, policy=policy, train=False,
            capacity_factor=4.0))(x, p)
        grads = jax.grad(lambda p: moe_ffn_ep(
            x, p, cfg, policy=policy, train=True,
            capacity_factor=4.0)[0].sum())(p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert abs(float(aux) - float(aux_ref)) < 1e-4
    assert float(jnp.sum(jnp.abs(grads["w_experts_in"]))) > 0


def test_moe_ep_falls_back_without_mesh():
    """Outside a mesh context the EP path must degrade gracefully."""
    cfg = reduced(get_config("moonshot-v1-16b-a3b"),
                  d_model=8, n_experts=2, top_k=1, d_ff=16)
    ks = jax.random.split(jax.random.key(0), 4)
    p = {"router": jax.random.normal(ks[0], (8, 2)),
         "w_experts_gate": jax.random.normal(ks[1], (2, 8, 16)) * 0.1,
         "w_experts_in": jax.random.normal(ks[2], (2, 8, 16)) * 0.1,
         "w_experts_out": jax.random.normal(ks[3], (2, 16, 8)) * 0.1}
    x = jax.random.normal(jax.random.key(1), (1, 4, 8))
    policy = QuantPolicy(mode=ExecMode.FP32)
    out, _ = moe_ffn_ep(x, p, cfg, policy=policy, train=False)
    ref, _ = moe_ffn(x, p, cfg, policy=policy, train=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_weight_only_qat_policy():
    import dataclasses
    policy = dataclasses.replace(QuantPolicy(mode=ExecMode.W8A8),
                                 qat_acts=False)
    from repro.quant.qlinear import qat_act
    x = jnp.linspace(-1, 1, 32)
    np.testing.assert_array_equal(np.asarray(qat_act(x, policy)),
                                  np.asarray(x))
