"""Continuous batching == isolated serving, slot reuse, quantized modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model
from repro.serving.scheduler import ContinuousBatcher, Request


def _single(model, params, prompt, max_new, max_seq, kv_quant=False):
    """Reference: run one request alone through scalar-pos decode."""
    caches = model.init_cache(1, max_seq, kv_quant=kv_quant)
    toks = list(prompt)
    logits = None
    for i, t in enumerate(toks):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([[t]], jnp.int32), jnp.int32(i))
    out = []
    pos = len(toks)
    tok = int(jnp.argmax(logits[0, 0]))
    for _ in range(max_new):
        out.append(tok)
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([[tok]], jnp.int32),
            jnp.int32(pos))
        pos += 1
        tok = int(jnp.argmax(logits[0, 0]))
    return out


@pytest.mark.parametrize("arch", ["starcoder2-7b", "gemma3-4b"])
def test_batched_equals_isolated(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    max_seq = 24
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab, size=n)),
                    max_new=g)
            for i, (n, g) in enumerate([(3, 4), (5, 3), (2, 5)])]
    # 2 slots, 3 requests -> queuing + slot reuse exercised
    bat = ContinuousBatcher(model, params, n_slots=2, max_seq=max_seq)
    for r in reqs:
        bat.submit(r)
    done = bat.run()
    assert len(done) == 3 and all(r.done for r in done)
    for r in reqs:
        ref = _single(model, params, r.prompt, r.max_new, max_seq)
        assert r.generated == ref, (r.rid, r.generated, ref)


def test_batcher_with_int8_kv():
    cfg = reduced(get_config("starcoder2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, size=4)),
                    max_new=3) for i in range(2)]
    bat = ContinuousBatcher(model, params, n_slots=2, max_seq=16,
                            kv_quant=True)
    for r in reqs:
        bat.submit(r)
    done = bat.run()
    assert len(done) == 2
    for r in done:
        ref = _single(model, params, r.prompt, r.max_new, 16,
                      kv_quant=True)
        # int8 KV: allow small divergence on near-tie logits
        agree = np.mean(np.asarray(r.generated) == np.asarray(ref))
        assert agree >= 0.6, (r.generated, ref)


def test_mid_flight_admission():
    """A request admitted while another is mid-generation."""
    cfg = reduced(get_config("phi4-mini-3.8b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    r1 = Request(rid=1, prompt=[5, 6, 7, 8, 9], max_new=4)
    r2 = Request(rid=2, prompt=[1, 2], max_new=2)
    bat = ContinuousBatcher(model, params, n_slots=1, max_seq=24)
    bat.submit(r1)
    bat.submit(r2)                      # must wait for the single slot
    done = bat.run()
    assert [r.rid for r in done] == [1, 2]
    ref2 = _single(model, params, r2.prompt, r2.max_new, 24)
    assert r2.generated == ref2         # slot reuse is clean
