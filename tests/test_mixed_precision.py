"""Mixed-precision kernel extension (ISSUE 3 tentpole) + satellites:
aggregates-only sweep outputs and the loud PE<->mode mapping errors.

The contract: per-layer execution-mode columns through the batched kernel
are bit-exact vs the extended scalar reference (``run_workload_mixed``) on
the numpy backend, within the 1e-6 ratio gate on jax, and a homogeneous
assignment reduces exactly to the original per-config-scalar path.
"""

import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dataflow import map_layer, run_workload, run_workload_mixed
from repro.core.dse_batch import (AGGREGATE_OUTPUTS, check_assignment,
                                  sweep_mixed, sweep_workload)
from repro.core.pe import (PEType, mode_compat_matrix, pe_spec,
                           supported_modes, supports_mode)
from repro.core.synthesis import synthesize
from repro.core.workloads import ConvLayer, Workload

TYPES = tuple(PEType)

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
    ConvLayer("big", 226, 226, 3, 64),
))

SMALL_SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in TYPES
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (32, 32, 512, 25.6)]
]


def _random_assignment(rng, configs, n_layers):
    assign = np.empty((len(configs), n_layers), dtype=np.int64)
    for i, c in enumerate(configs):
        modes = [TYPES.index(m) for m in supported_modes(c.pe_type)]
        assign[i] = rng.choice(modes, size=n_layers)
    return assign


# ---------------------------------------------------------------------------
# mode-compatibility model
# ---------------------------------------------------------------------------

def test_supported_modes_follow_operand_widths():
    assert set(supported_modes(PEType.FP32)) == set(TYPES)
    assert supported_modes(PEType.LIGHTPE1) == (PEType.LIGHTPE1,)
    assert set(supported_modes(PEType.INT16)) == {
        PEType.INT16, PEType.LIGHTPE1, PEType.LIGHTPE2}
    # lightpe2 (8b x 8b) covers lightpe1 (8b x 4b) but not vice versa
    assert supports_mode(PEType.LIGHTPE2, PEType.LIGHTPE1)
    assert not supports_mode(PEType.LIGHTPE1, PEType.LIGHTPE2)
    compat = mode_compat_matrix()
    for i, h in enumerate(TYPES):
        for j, m in enumerate(TYPES):
            assert compat[i, j] == supports_mode(h, m)
        assert compat[i, i]                        # native mode always runs


# ---------------------------------------------------------------------------
# scalar reference: map_layer mode override + run_workload_mixed
# ---------------------------------------------------------------------------

def test_map_layer_native_mode_is_identity():
    cfg = AcceleratorConfig(pe_type=PEType.INT16)
    rep = synthesize(cfg)
    from repro.core.dataflow import leakage_mw
    leak = leakage_mw(cfg)
    for layer in TINY_WL.layers:
        a = map_layer(layer, cfg, rep.clock_ghz, rep.area_mm2, leak)
        b = map_layer(layer, cfg, rep.clock_ghz, rep.area_mm2, leak,
                      mode=PEType.INT16)
        assert a == b


def test_map_layer_narrow_mode_cuts_bytes_and_mac_energy():
    cfg = AcceleratorConfig(pe_type=PEType.FP32)
    rep = synthesize(cfg)
    from repro.core.dataflow import leakage_mw
    leak = leakage_mw(cfg)
    layer = TINY_WL.layers[0]
    wide = map_layer(layer, cfg, rep.clock_ghz, rep.area_mm2, leak)
    narrow = map_layer(layer, cfg, rep.clock_ghz, rep.area_mm2, leak,
                       mode=PEType.LIGHTPE1)
    assert narrow.dram_bytes < wide.dram_bytes
    assert narrow.energy_pj < wide.energy_pj
    # mapping is precision-independent on a fixed array
    assert narrow.compute_cycles == wide.compute_cycles


def test_run_workload_mixed_homogeneous_matches_run_workload():
    for cfg in SMALL_SPACE[:4]:
        ref = run_workload(TINY_WL, cfg)
        mixed = run_workload_mixed(
            TINY_WL, cfg, [cfg.pe_type] * len(TINY_WL.layers))
        assert ref.layers == mixed.layers
        assert ref.energy_j == mixed.energy_j
        assert ref.perf_per_area == mixed.perf_per_area


def test_run_workload_mixed_validates_inputs():
    cfg = AcceleratorConfig(pe_type=PEType.LIGHTPE1)
    with pytest.raises(ValueError, match="assignment length"):
        run_workload_mixed(TINY_WL, cfg, [PEType.LIGHTPE1])
    with pytest.raises(ValueError, match="not executable"):
        run_workload_mixed(TINY_WL, cfg,
                           [PEType.FP32] * len(TINY_WL.layers))


# ---------------------------------------------------------------------------
# batched kernel: bit-exact vs the scalar reference (acceptance criterion:
# >= 200 random genomes on numpy)
# ---------------------------------------------------------------------------

def test_mixed_batched_bitmatches_scalar_on_200_genomes():
    rng = np.random.default_rng(42)
    n = 200
    configs = [SMALL_SPACE[i] for i in
               rng.integers(0, len(SMALL_SPACE), size=n)]
    soa = configs_to_soa(configs)
    assign = _random_assignment(rng, configs, len(TINY_WL.layers))
    out = sweep_mixed(TINY_WL, soa, assign, backend="numpy",
                      outputs="full", use_cache=False)
    for i in rng.permutation(n)[:40]:       # full layer check on a sample
        ref = run_workload_mixed(TINY_WL, configs[i],
                                 [TYPES[j] for j in assign[i]])
        assert ref.energy_j == float(out["energy_j"][i])
        assert ref.perf_per_area == float(out["perf_per_area"][i])
        assert ref.total_cycles == int(out["total_cycles_sum"][i])
        for j, lr in enumerate(ref.layers):
            assert lr.energy_pj == float(out["energy_pj"][i, j])
            assert lr.dram_bytes == int(out["dram_bytes"][i, j])
            assert lr.total_cycles == int(out["total_cycles"][i, j])
    # aggregate columns checked exhaustively
    ref_energy = np.array([
        run_workload_mixed(TINY_WL, configs[i],
                           [TYPES[j] for j in assign[i]]).energy_j
        for i in range(n)])
    assert np.array_equal(ref_energy, out["energy_j"])


def test_mixed_homogeneous_assignment_reduces_to_scalar_path():
    soa = configs_to_soa(SMALL_SPACE)
    hom = np.repeat(soa["pe_type_idx"][:, None], len(TINY_WL.layers),
                    axis=1)
    out = sweep_mixed(TINY_WL, soa, hom, backend="numpy", outputs="full",
                      use_cache=False)
    sw = sweep_workload(TINY_WL, SMALL_SPACE, use_cache=False,
                        backend="numpy")
    for k in ("energy_j", "perf_per_area", "total_cycles",
              "dram_bytes", "energy_pj"):
        assert np.array_equal(out[k], sw.arrays[k]), k


def test_mixed_jax_within_ratio_gate():
    from repro.core.dse_batch import resolve_backend
    try:
        resolve_backend("jax")
    except RuntimeError:
        pytest.skip("jax unusable")
    rng = np.random.default_rng(7)
    soa = configs_to_soa(SMALL_SPACE)
    assign = _random_assignment(rng, SMALL_SPACE, len(TINY_WL.layers))
    a = sweep_mixed(TINY_WL, soa, assign, backend="numpy",
                    outputs="aggregates", use_cache=False)
    b = sweep_mixed(TINY_WL, soa, assign, backend="jax",
                    outputs="aggregates", use_cache=False)
    for k in ("energy_j", "perf_per_area", "latency_s"):
        assert np.max(np.abs(np.asarray(b[k]) / a[k] - 1)) < 1e-6, k


def test_mixed_rejects_bad_assignments():
    soa = configs_to_soa(SMALL_SPACE)
    L = len(TINY_WL.layers)
    with pytest.raises(ValueError, match="shape"):
        sweep_mixed(TINY_WL, soa, np.zeros((2, L), dtype=np.int64))
    bad = np.repeat(soa["pe_type_idx"][:, None], L, axis=1)
    bad[:] = TYPES.index(PEType.FP32)       # fp32 mode on lightpe hardware
    with pytest.raises(ValueError, match="not executable"):
        sweep_mixed(TINY_WL, soa, bad)
    oob = np.zeros((len(SMALL_SPACE), L), dtype=np.int64)
    oob[0, 0] = len(TYPES)
    with pytest.raises(ValueError, match="outside"):
        check_assignment(soa, oob)


# ---------------------------------------------------------------------------
# satellite: aggregates-only sweep outputs
# ---------------------------------------------------------------------------

def test_aggregates_output_parity_numpy():
    full = sweep_workload(TINY_WL, SMALL_SPACE, use_cache=False,
                          backend="numpy")
    agg = sweep_workload(TINY_WL, SMALL_SPACE, use_cache=False,
                         backend="numpy", outputs="aggregates")
    assert set(agg.arrays) == set(AGGREGATE_OUTPUTS)
    for k in AGGREGATE_OUTPUTS:
        assert np.array_equal(agg.arrays[k], full.arrays[k]), k
    # aggregate views still work without layer columns
    assert agg.result_view(0).energy_j == full.result_view(0).energy_j


def test_aggregates_output_parity_jax():
    from repro.core.dse_batch import resolve_backend
    try:
        resolve_backend("jax")
    except RuntimeError:
        pytest.skip("jax unusable")
    full = sweep_workload(TINY_WL, SMALL_SPACE, use_cache=False,
                          backend="jax")
    agg = sweep_workload(TINY_WL, SMALL_SPACE, use_cache=False,
                         backend="jax", outputs="aggregates")
    for k in AGGREGATE_OUTPUTS:
        a, f = np.asarray(agg.arrays[k]), np.asarray(full.arrays[k])
        assert np.max(np.abs(a / np.where(f == 0, 1, f) - 1)) < 1e-6, k


def test_unknown_outputs_mode_rejected():
    with pytest.raises(ValueError, match="unknown sweep outputs"):
        sweep_workload(TINY_WL, SMALL_SPACE[:2], use_cache=False,
                       backend="numpy", outputs="everything")


# ---------------------------------------------------------------------------
# satellite: PE<->mode mapping fails loudly, covers every type
# ---------------------------------------------------------------------------

def test_pe_mode_mapping_round_trips_every_type():
    from repro.quant.policy import (ExecMode, mode_for_pe, pe_for_mode)
    for t in PEType:
        assert pe_for_mode(mode_for_pe(t)) is t
    for m in ExecMode:
        assert mode_for_pe(pe_for_mode(m)) is m


def test_pe_mode_mapping_raises_clear_error_not_keyerror():
    from repro.quant.policy import mode_for_pe, pe_for_mode
    with pytest.raises(ValueError, match="no execution-mode mapping"):
        mode_for_pe("int3")
    with pytest.raises(ValueError, match="no PE-type mapping"):
        pe_for_mode("w2a2")
    # never a bare KeyError, even for arbitrary junk
    for junk in (None, 42, object()):
        with pytest.raises(ValueError):
            mode_for_pe(junk)
        with pytest.raises(ValueError):
            pe_for_mode(junk)
