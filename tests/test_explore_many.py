"""Multi-workload co-exploration (ISSUE 4 tentpole): shared hardware +
per-workload precision genomes, the fused W-workload kernel, suite
objectives with accuracy floors, the search engines in multi mode, the
NSGA-II external archive, and the coexplore_many() wiring."""

import numpy as np
import pytest

from repro.core.dse import coexplore_many
from repro.core.dse_batch import sweep_mixed, sweep_mixed_many
from repro.core.pe import PEType
from repro.core.workloads import ConvLayer, Workload
from repro.explore import (CoExploreManySpace, Evaluator,
                           accuracy_floor_violation,
                           multi_objective_matrix, nsga2, pareto_mask_k,
                           quant_noise, random_search, space_for_workloads,
                           successive_halving)
from repro.explore.objectives import (DEFAULT_MULTI_OBJECTIVES,
                                      MULTI_OBJECTIVES)
from repro.explore.space import N_HW_GENES

TYPES = tuple(PEType)

WL_A = Workload("wlA", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
))
WL_B = Workload("wlB", (
    ConvLayer("c1", 114, 114, 32, 64),
    ConvLayer("fc", 1, 1, 256, 100, 1, 1),
))
WL_C = Workload("wlC", (
    ConvLayer("c1", 226, 226, 3, 64),
    ConvLayer("c2", 56, 56, 64, 64),
    ConvLayer("c3", 28, 28, 64, 128),
    ConvLayer("fc", 1, 1, 128, 10, 1, 1),
))
SUITE = (WL_A, WL_B, WL_C)
SPACE = space_for_workloads(SUITE)


# ---------------------------------------------------------------------------
# many-space layout
# ---------------------------------------------------------------------------

def test_space_for_workloads_layout():
    assert SPACE.layer_counts == (3, 2, 4)
    assert SPACE.n_layers == 9
    assert SPACE.genome_width == N_HW_GENES + 9
    assert SPACE.segment_bounds == ((0, 3), (3, 5), (5, 9))
    assert SPACE.workload_names == ("wlA", "wlB", "wlC")
    assert SPACE.n_workloads == 3


def test_many_space_validation():
    with pytest.raises(ValueError, match="layer_counts"):
        CoExploreManySpace(n_layers=0, layer_counts=())
    with pytest.raises(ValueError, match="sum"):
        CoExploreManySpace(n_layers=4, layer_counts=(2, 3))
    with pytest.raises(ValueError, match="workload names"):
        CoExploreManySpace(n_layers=5, layer_counts=(2, 3),
                           workload_names=("only-one",))
    with pytest.raises(ValueError):
        space_for_workloads([])


def test_split_assign_views():
    g = SPACE.random_population(10, np.random.default_rng(0))
    _, assign = SPACE.decode(g)
    parts = SPACE.split_assign(assign)
    assert [p.shape for p in parts] == [(10, 3), (10, 2), (10, 4)]
    assert np.array_equal(np.concatenate(parts, axis=1), assign)
    with pytest.raises(ValueError, match="assignment shape"):
        SPACE.split_assign(assign[:, :-1])


# ---------------------------------------------------------------------------
# fused multi-workload kernel
# ---------------------------------------------------------------------------

def test_sweep_mixed_many_matches_per_workload_sweeps():
    g = SPACE.random_population(32, np.random.default_rng(7))
    soa, assign = SPACE.decode(g)
    assigns = SPACE.split_assign(assign)
    many = sweep_mixed_many(SUITE, soa, assigns, backend="numpy",
                            use_cache=False)
    for w, (wl, a) in enumerate(zip(SUITE, assigns)):
        one = sweep_mixed(wl, soa, a, backend="numpy", use_cache=False)
        for k in ("total_cycles_sum", "energy_pj_sum", "latency_s",
                  "energy_j", "throughput_gmacs", "perf_per_area"):
            assert np.array_equal(many[k][w], one[k]), (wl.name, k)
    # hardware columns are per-config, shared across workloads
    assert many["clock_ghz"].shape == (32,)
    assert many["area_mm2"].shape == (32,)


def test_sweep_mixed_many_validates_inputs():
    g = SPACE.random_population(4, np.random.default_rng(1))
    soa, assign = SPACE.decode(g)
    assigns = SPACE.split_assign(assign)
    with pytest.raises(ValueError, match="at least one workload"):
        sweep_mixed_many((), soa, [])
    with pytest.raises(ValueError, match="assignment matrices"):
        sweep_mixed_many(SUITE, soa, assigns[:2])
    with pytest.raises(ValueError, match="assignment shape"):
        sweep_mixed_many(SUITE, soa, [assigns[0], assigns[0], assigns[2]])


def test_sweep_mixed_many_shares_synthesis_across_workloads():
    from repro.core.synthesis import (clear_synthesis_cache,
                                      synthesis_cache_stats)
    clear_synthesis_cache()
    g = SPACE.random_population(24, np.random.default_rng(3))
    soa, assign = SPACE.decode(g)
    assigns = SPACE.split_assign(assign)
    sweep_mixed_many(SUITE, soa, assigns, backend="numpy")
    stats = synthesis_cache_stats()
    # one synthesis pass for 3 workloads: misses == unique hardware rows,
    # and nothing was synthesized per-workload
    assert stats["array_misses"] <= 24
    sweep_mixed_many(SUITE, soa, assigns, backend="numpy")
    stats2 = synthesis_cache_stats()
    assert stats2["array_hits"] >= 24           # full reuse on re-sweep
    clear_synthesis_cache()


# ---------------------------------------------------------------------------
# suite objectives
# ---------------------------------------------------------------------------

def _agg_for(g):
    soa, assign = SPACE.decode(g)
    assigns = SPACE.split_assign(assign)
    agg = sweep_mixed_many(SUITE, soa, assigns, backend="numpy")
    agg = {k: v for k, v in agg.items() if np.ndim(v) == 2}
    macs = [np.array([l.macs for l in w.layers], dtype=np.float64)
            for w in SUITE]
    return agg, assigns, macs


def test_multi_objective_semantics():
    g = SPACE.random_population(40, np.random.default_rng(5))
    agg, assigns, macs = _agg_for(g)
    F = multi_objective_matrix(agg, assigns, macs, MULTI_OBJECTIVES)
    cols = {n: F[:, i] for i, n in enumerate(MULTI_OBJECTIVES)}
    lat = agg["latency_s"]
    # worst-case == max over the suite; the energy-weighted mean lies
    # inside the per-workload envelope
    assert np.array_equal(cols["worst_latency_s"], lat.max(axis=0))
    assert (cols["mean_latency_s"] <= lat.max(axis=0) + 1e-300).all()
    assert (cols["mean_latency_s"] >= lat.min(axis=0) - 1e-300).all()
    assert np.array_equal(cols["total_energy_j"],
                          agg["energy_j"].sum(axis=0))
    assert np.array_equal(cols["neg_worst_perf_per_area"],
                          -agg["perf_per_area"].min(axis=0))
    noise = np.stack([quant_noise(a, m) for a, m in zip(assigns, macs)])
    assert np.array_equal(cols["worst_accuracy_noise"], noise.max(axis=0))
    edp = agg["energy_j"] * lat
    assert np.array_equal(cols["worst_edp"], edp.max(axis=0))

    # fixed importance weights replace the energy weighting
    Fw = multi_objective_matrix(agg, assigns, macs, ("mean_latency_s",),
                                weights=(1.0, 0.0, 0.0))
    assert np.array_equal(Fw[:, 0], lat[0])

    with pytest.raises(ValueError, match="unknown objective"):
        multi_objective_matrix(agg, assigns, macs, ("speed",))
    with pytest.raises(ValueError, match="weights"):
        multi_objective_matrix(agg, assigns, macs, ("mean_latency_s",),
                               weights=(1.0,))


def test_sqnr_floor_constraints_penalize_noisy_genomes():
    g = SPACE.random_population(64, np.random.default_rng(9))
    # an fp32-capable all-fp32 genome is feasible under any floor
    g[0, 0] = SPACE.pe_types.index(PEType.FP32)
    g[0, N_HW_GENES:] = TYPES.index(PEType.FP32)
    agg, assigns, macs = _agg_for(g)
    v = accuracy_floor_violation(assigns, macs, 20.0)
    assert v.shape == (64,)
    assert v[0] == 0.0
    assert (v >= 0).all()

    F_free = multi_objective_matrix(agg, assigns, macs,
                                    DEFAULT_MULTI_OBJECTIVES)
    F_floor = multi_objective_matrix(agg, assigns, macs,
                                     DEFAULT_MULTI_OBJECTIVES,
                                     sqnr_floor_db=20.0)
    feasible = v == 0
    assert np.array_equal(F_free[feasible], F_floor[feasible])
    assert (F_floor[~feasible] > F_free[~feasible]).all()
    # per-workload floors broadcast
    v3 = accuracy_floor_violation(assigns, macs, (20.0, 25.0, 30.0))
    assert (v3 >= v).all()


# ---------------------------------------------------------------------------
# evaluator in multi mode
# ---------------------------------------------------------------------------

def test_evaluator_multi_requires_many_space_and_matching_counts():
    from repro.explore.space import CoExploreSpace
    with pytest.raises(ValueError, match="CoExploreManySpace"):
        Evaluator(CoExploreSpace(n_layers=9), SUITE)
    bad = space_for_workloads([WL_A, WL_B])
    with pytest.raises(ValueError, match="layer_counts"):
        Evaluator(bad, SUITE)


def test_evaluator_multi_memoizes_and_matches_manual():
    ev = Evaluator(SPACE, SUITE, backend="numpy")
    assert ev.objectives == DEFAULT_MULTI_OBJECTIVES
    assert ev.name == "wlA+wlB+wlC"
    g = SPACE.random_population(32, np.random.default_rng(2))
    F1 = ev.evaluate(g)
    assert F1.shape == (32, len(DEFAULT_MULTI_OBJECTIVES))
    agg, assigns, macs = _agg_for(g)
    F_manual = multi_objective_matrix(agg, assigns, macs,
                                      DEFAULT_MULTI_OBJECTIVES)
    assert np.array_equal(F1, F_manual)
    F2 = ev.evaluate(g)
    assert np.array_equal(F1, F2)
    assert ev.n_memo_hits >= 32
    assert ev.stats()["n_workloads"] == 3


def test_evaluator_multi_subset_prefixes_every_workload():
    ev = Evaluator(SPACE, SUITE, backend="numpy")
    g = SPACE.random_population(8, np.random.default_rng(4))
    F_sub = ev.evaluate(g, subset=2)
    # manual: first min(2, L_w) layers of each workload
    wls, macs = ev._subset(2)
    assert [len(w.layers) for w in wls] == [2, 2, 2]
    soa, assign = SPACE.decode(g)
    assigns = [a[:, :2] for a in SPACE.split_assign(assign)]
    agg = sweep_mixed_many(wls, soa, assigns, backend="numpy")
    agg = {k: v for k, v in agg.items() if np.ndim(v) == 2}
    F_manual = multi_objective_matrix(agg, assigns, list(macs),
                                      ev.objectives)
    assert np.array_equal(F_sub, F_manual)


# ---------------------------------------------------------------------------
# engines in multi mode + the external archive
# ---------------------------------------------------------------------------

def test_random_search_multi_deterministic():
    a = random_search(SPACE, SUITE, 96, seed=3, backend="numpy")
    b = random_search(SPACE, SUITE, 96, seed=3, backend="numpy")
    assert a.workload == "wlA+wlB+wlC"
    assert np.array_equal(a.genomes, b.genomes)
    assert pareto_mask_k(a.front_objectives).all()


def test_successive_halving_multi_runs():
    res = successive_halving(SPACE, SUITE, 150, seed=1, backend="numpy")
    assert res.front_size >= 1
    ev = Evaluator(SPACE, SUITE, backend="numpy")
    assert np.array_equal(ev.evaluate(res.genomes), res.front_objectives)


def test_nsga2_external_archive_supersets_population_front():
    res = nsga2(SPACE, SUITE, 192, pop_size=16, seed=6, backend="numpy")
    assert res.population is not None and len(res.population) == 16
    # acceptance: the archive (returned front) is a superset of the final
    # population's non-dominated set — dominance judged over archive ∪
    # population, so a pop member beaten by an earlier-generation archive
    # genome counts as dominated
    comb_g = np.concatenate([res.genomes, res.population])
    comb_F = np.concatenate([res.front_objectives,
                             res.population_objectives])
    for row in comb_g[pareto_mask_k(comb_F)]:
        assert (res.genomes == row).all(axis=1).any()
    # equivalently: every within-population front member is either in the
    # archive or strictly dominated by an archive genome
    keep = pareto_mask_k(res.population_objectives)
    for g_row, f_row in zip(res.population[keep],
                            res.population_objectives[keep]):
        in_arch = (res.genomes == g_row).all(axis=1).any()
        dominated = ((res.front_objectives <= f_row).all(axis=1)
                     & (res.front_objectives < f_row).any(axis=1)).any()
        assert in_arch or dominated
    # archive is itself mutually non-dominated, duplicate-free, and its
    # hypervolume history is monotone
    assert pareto_mask_k(res.front_objectives).all()
    assert len(np.unique(res.genomes, axis=0)) == res.front_size
    hvs = [h for _, h in res.history]
    assert all(b >= a - 1e-12 for a, b in zip(hvs, hvs[1:]))


def test_nsga2_archive_absorbs_all_evaluations():
    """The archive equals the non-dominated set of every objective row
    the search ever produced — nothing non-dominated is dropped."""
    res = nsga2(SPACE, SUITE, 128, pop_size=16, seed=8, backend="numpy")
    allF = res.all_objectives
    global_front = allF[pareto_mask_k(allF)]
    # every global-front row appears in the archive objectives
    arch = res.front_objectives
    for row in np.unique(global_front, axis=0):
        assert (arch == row).all(axis=1).any()


# ---------------------------------------------------------------------------
# coexplore_many wiring
# ---------------------------------------------------------------------------

def test_coexplore_many_runs_and_decodes_front():
    res = coexplore_many(SUITE, preset="many-quick", budget=96, seed=3,
                         backend="numpy", pop_size=12)
    assert res.method == "nsga2"
    assert res.workload == "wlA+wlB+wlC"
    assert res.n_evals == 96
    pts = res.front_points()
    assert len(pts) == res.front_size
    from repro.core.pe import mode_compat_matrix
    compat = mode_compat_matrix()
    for pt in pts:
        modes = pt["modes"]
        assert set(modes) == {"wlA", "wlB", "wlC"}
        assert [len(m) for m in modes.values()] == [3, 2, 4]
        hw = TYPES.index(pt["config"].pe_type)
        for ms in modes.values():
            for m in ms:
                assert compat[hw, TYPES.index(PEType(m))]


def test_coexplore_many_backends_bit_identical_fronts(jax_usable):
    """Acceptance: >= 3 QAPPA workloads, numpy and jax produce the same
    Pareto-front genomes."""
    if not jax_usable:
        pytest.skip("jax unusable")
    wls = ("vgg16", "resnet34", "resnet50")
    n = coexplore_many(wls, preset="many-quick", budget=128, seed=0,
                       backend="numpy", pop_size=16)
    j = coexplore_many(wls, preset="many-quick", budget=128, seed=0,
                       backend="jax", pop_size=16)
    assert n.space.n_workloads == 3
    assert np.array_equal(n.genomes, j.genomes)
    assert np.array_equal(n.population, j.population)


def test_coexplore_many_rejects_unknowns():
    with pytest.raises(ValueError, match="unknown co-exploration method"):
        coexplore_many(SUITE, preset="many-quick", method="hill-climb")
    with pytest.raises(ValueError, match="at least one workload"):
        coexplore_many([])


def test_many_presets_registered():
    from repro.configs.coexplore_presets import PRESETS, get_preset
    assert {"many-quick", "many-default", "many-thorough"} <= set(PRESETS)
    assert set(get_preset("many-default").objectives) <= \
        set(MULTI_OBJECTIVES)
    # the floor now rides on the accuracy spec (sqnr_floor_db folded)
    thorough = get_preset("many-thorough")
    assert thorough.sqnr_floor_db is None
    assert thorough.accuracy.floor_db == 20.0
