"""Serving-fleet simulator: scalar/numpy/jax equivalence, traffic traces,
overload behavior, and the ContinuousBatcher as the golden latency
reference (ISSUE 6 tentpole)."""

import numpy as np
import pytest

from repro.serving.fleet_sim import (FleetResult, simulate_fleet,
                                     simulate_fleet_scalar)
from repro.serving.traffic import (TRAFFIC_PRESETS, TrafficPreset,
                                   TrafficTrace, get_traffic, make_trace,
                                   resolve_traffic)

# a latency spread matching the paper design space (~0.02-0.9 s/iter)
STEPS = np.array([0.02, 0.05, 0.11, 0.23, 0.45, 0.88])
ETOK = np.array([0.4, 0.55, 0.8, 1.1, 1.9, 3.2])


# ---------------------------------------------------------------------------
# traffic traces
# ---------------------------------------------------------------------------

def test_presets_materialize_and_are_deterministic():
    for name, preset in TRAFFIC_PRESETS.items():
        t1 = make_trace(preset)
        t2 = make_trace(name)
        assert t1.n_requests == preset.n_requests
        assert np.array_equal(t1.arrival_s, t2.arrival_s)
        assert np.array_equal(t1.prompt_tokens, t2.prompt_tokens)
        assert np.array_equal(t1.decode_tokens, t2.decode_tokens)
        assert (np.diff(t1.arrival_s) >= 0).all()
        assert (t1.prompt_tokens >= 1).all() and (t1.decode_tokens >= 1).all()
        # seed actually matters
        t3 = make_trace(preset, seed=preset.seed + 1)
        assert not np.array_equal(t1.arrival_s, t3.arrival_s)
        assert t3.name != t1.name          # derived name records override


def test_trace_validation():
    with pytest.raises(ValueError, match="sorted"):
        TrafficTrace("bad", np.array([1.0, 0.5]), np.array([2, 2]),
                     np.array([2, 2]))
    with pytest.raises(ValueError, match=">= 1"):
        TrafficTrace("bad", np.array([0.0]), np.array([0]), np.array([2]))
    with pytest.raises(ValueError, match="lengths disagree"):
        TrafficTrace("bad", np.array([0.0]), np.array([1, 2]),
                     np.array([2]))
    with pytest.raises(ValueError, match="slo_s"):
        TrafficTrace("bad", np.array([0.0]), np.array([1]), np.array([2]),
                     slo_s=0.0)
    with pytest.raises(ValueError, match="unknown traffic kind"):
        TrafficPreset(name="x", kind="weird")
    with pytest.raises(ValueError, match="unknown traffic preset"):
        get_traffic("nope")


def test_resolve_traffic_accepts_all_spellings():
    t = resolve_traffic("quick")
    assert resolve_traffic(t) is t
    assert np.array_equal(
        resolve_traffic(get_traffic("quick")).arrival_s, t.arrival_s)
    with pytest.raises(TypeError, match="TrafficTrace"):
        resolve_traffic(42)


# ---------------------------------------------------------------------------
# scalar event-driven reference == vectorized fixed-step sim (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(TRAFFIC_PRESETS))
@pytest.mark.parametrize("n_slots", [1, 3, 8])
def test_scalar_reference_bit_exact(preset, n_slots):
    res = simulate_fleet(STEPS, ETOK, preset, n_slots=n_slots,
                         backend="numpy")
    for i, (s, e) in enumerate(zip(STEPS, ETOK)):
        ref = simulate_fleet_scalar(s, e, preset, n_slots=n_slots)
        assert np.array_equal(res.submit_iter[i], ref.submit_iter[0])
        assert np.array_equal(res.comp_iter[i], ref.comp_iter[0])
        assert res.active_iters[i] == ref.active_iters[0]


@pytest.mark.parametrize("max_iters", [1, 7, 30, 100])
def test_scalar_reference_bit_exact_truncated(max_iters):
    res = simulate_fleet(STEPS, ETOK, "steady", n_slots=2,
                         max_iters=max_iters, backend="numpy")
    for i, (s, e) in enumerate(zip(STEPS, ETOK)):
        ref = simulate_fleet_scalar(s, e, "steady", n_slots=2,
                                    max_iters=max_iters)
        assert np.array_equal(res.comp_iter[i], ref.comp_iter[0])
        assert res.active_iters[i] == ref.active_iters[0]


def test_jax_parity_bit_exact(jax_usable):
    """The sim core is pure integer arithmetic — jax must match numpy
    *bit-exactly*, stronger than the 1e-6 backend contract."""
    if not jax_usable:
        pytest.skip("jax backend unusable")
    for preset in sorted(TRAFFIC_PRESETS):
        a = simulate_fleet(STEPS, ETOK, preset, n_slots=4,
                           backend="numpy")
        b = simulate_fleet(STEPS, ETOK, preset, n_slots=4, backend="jax")
        assert np.array_equal(a.submit_iter, b.submit_iter)
        assert np.array_equal(a.comp_iter, b.comp_iter)
        assert np.array_equal(a.active_iters, b.active_iters)
        ma, mb = a.metrics(), b.metrics()
        for k in ma:
            assert np.array_equal(ma[k], mb[k]), k


# ---------------------------------------------------------------------------
# edge cases: empty, ragged, overload
# ---------------------------------------------------------------------------

def test_empty_trace_and_no_candidates():
    empty = TrafficTrace("empty", np.zeros(0), np.zeros(0, np.int64),
                         np.zeros(0, np.int64))
    res = simulate_fleet(STEPS, ETOK, empty, backend="numpy")
    m = res.metrics()
    assert (m["slo_attainment"] == 1.0).all()
    assert (m["throughput_tps"] == 0.0).all()
    assert (m["p99_latency_s"] == 0.0).all()
    none = simulate_fleet(np.zeros(0), np.zeros(0), "quick",
                          backend="numpy")
    assert none.n_candidates == 0 and none.submit_iter.shape == (0, 16)


def test_ragged_trace_bit_exact():
    rng = np.random.default_rng(11)
    n = 20
    trace = TrafficTrace(
        "ragged",
        np.sort(rng.uniform(0, 3.0, n)),
        np.concatenate([rng.integers(1, 3, n // 2),
                        rng.integers(40, 90, n - n // 2)]).astype(np.int64),
        np.concatenate([rng.integers(1, 2, n // 2),
                        rng.integers(30, 60, n - n // 2)]).astype(np.int64))
    res = simulate_fleet(STEPS, ETOK, trace, n_slots=3, backend="numpy")
    for i, (s, e) in enumerate(zip(STEPS, ETOK)):
        ref = simulate_fleet_scalar(s, e, trace, n_slots=3)
        assert np.array_equal(res.comp_iter[i], ref.comp_iter[0])
        assert np.array_equal(res.submit_iter[i], ref.submit_iter[0])
        assert res.active_iters[i] == ref.active_iters[0]


def test_overload_poisons_percentiles():
    """A hard serving window leaves stragglers unserved: latency
    percentiles go to +inf and attainment drops — overload is penalized,
    never silently excused."""
    res = simulate_fleet(np.array([0.5]), np.array([1.0]), "interactive",
                         n_slots=1, max_iters=10, backend="numpy")
    m = res.metrics()
    assert m["served_frac"][0] < 1.0
    assert np.isinf(m["p99_latency_s"][0])
    assert m["slo_attainment"][0] < 1.0
    assert np.isfinite(m["throughput_tps"][0])
    # scalar reference agrees on the truncated horizon too
    ref = simulate_fleet_scalar(0.5, 1.0, "interactive", n_slots=1,
                                max_iters=10)
    assert np.array_equal(res.comp_iter, ref.comp_iter)


def test_drain_horizon_serves_everything():
    res = simulate_fleet(STEPS, ETOK, "steady", n_slots=8,
                         backend="numpy")
    assert res.served.all()
    m = res.metrics()
    assert np.isfinite(m["p99_latency_s"]).all()
    # slower steps mean strictly more wall-clock latency at equal stamps
    assert (np.diff(m["p50_latency_s"]) >= 0).any()


def test_hand_computed_tiny_example():
    """2 requests, 2 slots, step=1s: stamps and metrics by hand."""
    trace = TrafficTrace("tiny", np.array([0.0, 0.0]),
                         np.array([1, 2], np.int64),
                         np.array([2, 2], np.int64), slo_s=2.5)
    res = simulate_fleet(np.array([1.0]), np.array([2.0]), trace,
                         n_slots=2, backend="numpy")
    # svc = P+G-1 = [2, 3]; both admitted at k=0
    assert np.array_equal(res.submit_iter[0], [0, 0])
    assert np.array_equal(res.comp_iter[0], [2, 3])
    assert res.active_iters[0] == 3
    m = res.metrics()
    assert np.array_equal(res.latency_s[0], [2.0, 3.0])
    assert m["slo_attainment"][0] == 0.5
    assert m["throughput_tps"][0] == pytest.approx(5 / 3)
    # 3 active iters x 2 slots x 2 J / 5 served tokens
    assert m["energy_per_token_j"][0] == pytest.approx(12 / 5)


def test_input_validation():
    with pytest.raises(ValueError, match="matching 1-D"):
        simulate_fleet(np.array([0.1, 0.2]), np.array([1.0]), "quick")
    with pytest.raises(ValueError, match="finite and > 0"):
        simulate_fleet(np.array([0.0]), np.array([1.0]), "quick")
    with pytest.raises(ValueError, match="n_slots"):
        simulate_fleet(np.array([0.1]), np.array([1.0]), "quick",
                       n_slots=0)


# ---------------------------------------------------------------------------
# ContinuousBatcher is the golden reference for the iteration contract
# ---------------------------------------------------------------------------

def test_batcher_reproduces_fleet_sim_stamps():
    """Pace real batcher submissions by arrival iteration: its per-request
    submit/complete stamps must equal the fleet sim's bit-exactly."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models.model import Model
    from repro.serving.scheduler import ContinuousBatcher, Request

    rng = np.random.default_rng(5)
    n_req, n_slots, step_s = 7, 2, 1.0
    trace = TrafficTrace(
        "golden",
        np.sort(rng.uniform(0, 6.0, n_req)),
        rng.integers(1, 4, n_req).astype(np.int64),
        rng.integers(1, 4, n_req).astype(np.int64))
    sim = simulate_fleet(np.array([step_s]), np.array([1.0]), trace,
                         n_slots=n_slots, backend="numpy")

    cfg = reduced(get_config("starcoder2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    bat = ContinuousBatcher(model, params, n_slots=n_slots, max_seq=16)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab,
                                             trace.prompt_tokens[i])),
                    max_new=int(trace.decode_tokens[i]))
            for i in range(n_req)]
    arrive = np.ceil(trace.arrival_s / step_s).astype(int)
    submitted = 0
    for _ in range(10000):
        while submitted < n_req and arrive[submitted] <= bat.it:
            bat.submit(reqs[submitted])
            submitted += 1
        if submitted == n_req and not bat.busy:
            break
        bat.step()
    assert len(bat.completed) == n_req
    got_submit = np.array([r.submit_iter for r in reqs])
    got_comp = np.array([r.complete_iter for r in reqs])
    assert np.array_equal(got_submit, sim.submit_iter[0])
    assert np.array_equal(got_comp, sim.comp_iter[0])


def test_batcher_run_raises_when_cut_short():
    """run() must not silently drop in-flight/queued work at max_iters."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models.model import Model
    from repro.serving.scheduler import ContinuousBatcher, Request

    cfg = reduced(get_config("starcoder2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    bat = ContinuousBatcher(model, params, n_slots=1, max_seq=16)
    bat.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
    bat.submit(Request(rid=1, prompt=[4, 5], max_new=3))
    with pytest.raises(RuntimeError, match="max_iters=2.*queued"):
        bat.run(max_iters=2)
