"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import quantizers as qz


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32) \
        .astype(dtype)


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 96, 48),
                                   (33, 70, 17), (128, 64, 96)])
def test_w8a8_matches_ref(m, k, n):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n))
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ws = qz.int_scale(w, 8, axis=0)
    wq = qz.quantize_int(w, ws, 8)
    o_ref = ref.w8a8_matmul_ref(xq, wq, xs, ws)
    o_pal = ops.w8a8_matmul(xq, wq, xs, ws, impl="interpret",
                            bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_w8a8_out_dtypes(out_dtype):
    x, w = _rand(0, (32, 64)), _rand(1, (64, 32))
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ws = qz.int_scale(w, 8, axis=0)
    wq = qz.quantize_int(w, ws, 8)
    o = ops.w8a8_matmul(xq, wq, xs, ws, impl="interpret", bm=32, bn=32,
                        bk=32, out_dtype=out_dtype)
    assert o.dtype == out_dtype
    o_ref = ref.w8a8_matmul_ref(xq, wq, xs, ws, out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 128, 48), (16, 64, 96)])
def test_w4a8_matches_ref(m, k, n):
    x = _rand(2, (m, k))
    w = _rand(3, (k, n))
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ws = qz.pow2_scale(w, axis=0)
    packed = qz.pack_int4(qz.pow2_encode(w, ws).T).T
    o_ref = ref.w4a8_matmul_ref(xq, packed, xs, ws)
    o_pal = ops.w4a8_matmul(xq, packed, xs, ws, impl="interpret",
                            bm=16, bn=16, bk=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_w4a8_pow2_decode_consistency():
    """Packed kernel semantics == explicit pow2 dequant matmul."""
    x, w = _rand(4, (16, 32)), _rand(5, (32, 16))
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ws = qz.pow2_scale(w, axis=0)
    codes = qz.pow2_encode(w, ws)
    packed = qz.pack_int4(codes.T).T
    direct = (xq.astype(jnp.float32) * xs) @ qz.pow2_decode(codes, ws)
    o = ref.w4a8_matmul_ref(xq, packed, xs, ws)
    np.testing.assert_allclose(np.asarray(o), np.asarray(direct),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,h,s,d", [(1, 2, 64, 16), (2, 3, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, h, s, d, dtype):
    q, k, v = (_rand(i, (b, h, s, d), dtype) for i in range(3))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o_pal = ops.flash_attention(q, k, v, causal=True, impl="interpret",
                                bq=32, bk=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_window(window):
    b, h, s, d = 2, 2, 128, 16
    q, k, v = (_rand(i + 10, (b, h, s, d)) for i in range(3))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    o_pal = ops.flash_attention(q, k, v, causal=True, window=window,
                                impl="interpret", bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_noncausal():
    b, h, s, d = 1, 2, 64, 16
    q, k, v = (_rand(i + 20, (b, h, s, d)) for i in range(3))
    o_ref = ref.flash_attention_ref(q, k, v, causal=False)
    o_pal = ops.flash_attention(q, k, v, causal=False, impl="interpret",
                                bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_combine_matches_full():
    b, s, h, d = 2, 64, 4, 16
    q = _rand(30, (b, h, d))
    k = _rand(31, (b, s, h, d))
    v = _rand(32, (b, s, h, d))
    full = ref.decode_attention_ref(q, k, v)
    n_shards = 4
    parts = [ref.decode_attention_partial_ref(
        q, k[:, i * 16:(i + 1) * 16], v[:, i * 16:(i + 1) * 16])
        for i in range(n_shards)]
    comb = ref.decode_attention_combine_ref(parts)
    np.testing.assert_allclose(np.asarray(comb), np.asarray(full),
                               rtol=1e-5, atol=1e-6)
