"""Checkpointing: roundtrip, checksum validation, rotation, fallback."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


def _tree(key=0):
    k = jax.random.key(key)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    out = ck.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_rotation(tmp_path):
    t = _tree()
    for s in range(6):
        ck.save(str(tmp_path), s, t, keep=3)
    kept = sorted(os.listdir(tmp_path))
    assert len(kept) == 3
    assert ck.latest_step(str(tmp_path)) == 5


def test_corruption_detected_and_skipped(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, t)
    # corrupt the newest checkpoint
    with open(os.path.join(tmp_path, "step_00000002", "arrays.npz"),
              "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    assert ck.latest_step(str(tmp_path)) == 1      # falls back
    with pytest.raises(IOError):
        ck.restore(str(tmp_path), 2, t)
    step, out = ck.restore_latest(str(tmp_path), t)
    assert step == 1 and out is not None


def test_restore_latest_empty(tmp_path):
    step, out = ck.restore_latest(str(tmp_path / "nope"), _tree())
    assert step is None and out is None
