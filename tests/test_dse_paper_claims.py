"""Validates the reproduction against the paper's own claims (Sec. 4).

Paper: LightPE-1 achieves 4.9x perf/area and 4.9x energy improvement,
LightPE-2 4.1x / 4.2x, both vs the best INT16 config; INT16 achieves
1.7x / 1.4x vs the best FP32 config — averaged over VGG-16 / ResNet-34 /
ResNet-50.  The synthesis oracle is calibrated (DESIGN.md §2), so we
assert the *averages* land within ±20% of the paper's numbers and the
orderings/Pareto statements hold exactly.
"""

import numpy as np
import pytest

from repro.core.dse import DSEResult, explore, pareto_front
from repro.core.pe import PEType

PAPER = {
    "lightpe1_perf_per_area_vs_int16": 4.9,
    "lightpe1_energy_vs_int16": 4.9,
    "lightpe2_perf_per_area_vs_int16": 4.1,
    "lightpe2_energy_vs_int16": 4.2,
    "int16_perf_per_area_vs_fp32": 1.7,
    "int16_energy_vs_fp32": 1.4,
}


@pytest.fixture(scope="module")
def results() -> dict[str, DSEResult]:
    return {wl: explore(wl) for wl in ("vgg16", "resnet34", "resnet50")}


def test_headline_ratios_match_paper(results):
    mean = {}
    for wl, res in results.items():
        for k, v in res.headline_ratios().items():
            mean.setdefault(k, []).append(v)
    for k, target in PAPER.items():
        got = float(np.mean(mean[k]))
        assert abs(got - target) / target < 0.20, (k, got, target)


def test_ratios_hold_per_model(results):
    """'These conclusions hold for all models considered in this work.'"""
    for wl, res in results.items():
        r = res.headline_ratios()
        assert r["lightpe1_perf_per_area_vs_int16"] > 3.5, (wl, r)
        assert r["lightpe2_perf_per_area_vs_int16"] > 3.0, (wl, r)
        assert r["int16_perf_per_area_vs_fp32"] > 1.2, (wl, r)


def test_lightpes_dominate_pareto(results):
    """Figs. 3-5: LightPEs consistently outperform INT16/FP32 — the
    non-dominated frontier is entirely LightPE points."""
    for wl, res in results.items():
        front = pareto_front(res.points)
        kinds = {p.config.pe_type for p in front}
        assert kinds <= {PEType.LIGHTPE1, PEType.LIGHTPE2}, (wl, kinds)


def test_normalization_anchor(results):
    """Normalized charts anchor at the best-perf/area INT16 config = 1.0."""
    for res in results.values():
        norm = res.normalized()
        int16 = [p for p in norm if p["pe_type"] == "int16"]
        assert abs(max(p["norm_perf_per_area"] for p in int16) - 1.0) < 1e-9


def test_fp32_highest_power_and_area_per_pe():
    """Fig. 2 discussion: FP32 has the highest area and power cost; the
    LightPEs the lowest, per PE."""
    from repro.core.accelerator import AcceleratorConfig
    from repro.core.synthesis import synthesize
    reports = {t: synthesize(AcceleratorConfig(pe_type=t))
               for t in PEType}
    assert reports[PEType.FP32].area_mm2 > reports[PEType.INT16].area_mm2 \
        > reports[PEType.LIGHTPE2].area_mm2
    assert reports[PEType.FP32].power_mw > reports[PEType.INT16].power_mw \
        > reports[PEType.LIGHTPE2].power_mw > 0
