"""Validates the reproduction against the paper's own claims (Sec. 4).

Paper: LightPE-1 achieves 4.9x perf/area and 4.9x energy improvement,
LightPE-2 4.1x / 4.2x, both vs the best INT16 config; INT16 achieves
1.7x / 1.4x vs the best FP32 config — averaged over VGG-16 / ResNet-34 /
ResNet-50.  The synthesis oracle is calibrated (DESIGN.md §2), so we
assert the *averages* land within ±20% of the paper's numbers and the
orderings/Pareto statements hold exactly.
"""

import numpy as np
import pytest

from repro.core.dse import DSEResult, explore, explore_many, pareto_front
from repro.core.pe import PEType

PAPER = {
    "lightpe1_perf_per_area_vs_int16": 4.9,
    "lightpe1_energy_vs_int16": 4.9,
    "lightpe2_perf_per_area_vs_int16": 4.1,
    "lightpe2_energy_vs_int16": 4.2,
    "int16_perf_per_area_vs_fp32": 1.7,
    "int16_energy_vs_fp32": 1.4,
}


@pytest.fixture(scope="module")
def results() -> dict[str, DSEResult]:
    return {wl: explore(wl) for wl in ("vgg16", "resnet34", "resnet50")}


def test_headline_ratios_match_paper(results):
    mean = {}
    for wl, res in results.items():
        for k, v in res.headline_ratios().items():
            mean.setdefault(k, []).append(v)
    for k, target in PAPER.items():
        got = float(np.mean(mean[k]))
        assert abs(got - target) / target < 0.20, (k, got, target)


def test_ratios_hold_per_model(results):
    """'These conclusions hold for all models considered in this work.'"""
    for wl, res in results.items():
        r = res.headline_ratios()
        assert r["lightpe1_perf_per_area_vs_int16"] > 3.5, (wl, r)
        assert r["lightpe2_perf_per_area_vs_int16"] > 3.0, (wl, r)
        assert r["int16_perf_per_area_vs_fp32"] > 1.2, (wl, r)


def test_lightpes_dominate_pareto(results):
    """Figs. 3-5: LightPEs consistently outperform INT16/FP32 — the
    non-dominated frontier is entirely LightPE points."""
    for wl, res in results.items():
        front = pareto_front(res.points)
        kinds = {p.config.pe_type for p in front}
        assert kinds <= {PEType.LIGHTPE1, PEType.LIGHTPE2}, (wl, kinds)


def test_normalization_anchor(results):
    """Normalized charts anchor at the best-perf/area INT16 config = 1.0."""
    for res in results.values():
        norm = res.normalized()
        int16 = [p for p in norm if p["pe_type"] == "int16"]
        assert abs(max(p["norm_perf_per_area"] for p in int16) - 1.0) < 1e-9


def test_lightpe_advantage_holds_under_worst_case_across_workloads():
    """ISSUE 4 satellite: the paper's up-to-4.9x LightPE-1 perf/area
    advantage over INT16 is not an artifact of per-model cherry-picking —
    it survives the *worst-case-across-workloads* objective (each config
    scored by its weakest workload), the aggregation `coexplore_many`
    optimizes."""
    results = explore_many(("vgg16", "resnet34", "resnet50"))
    per_wl = np.array([[p.perf_per_area for p in res.points]
                       for res in results.values()])
    worst = per_wl.min(axis=0)
    types = [p.config.pe_type for p in next(iter(results.values())).points]
    best = {t: max(worst[i] for i, ty in enumerate(types) if ty is t)
            for t in PEType}
    r1 = best[PEType.LIGHTPE1] / best[PEType.INT16]
    r2 = best[PEType.LIGHTPE2] / best[PEType.INT16]
    assert 3.5 < r1 < 4.9 * 1.25, r1            # "up to 4.9x" holds
    assert 3.0 < r2 < 4.2 * 1.25, r2
    assert best[PEType.INT16] > best[PEType.FP32]


def test_coexplore_many_reproduces_golden_front():
    """A fixed-seed multi-workload co-exploration run reproduces the
    checked-in golden Pareto front bit-for-bit (numpy backend): genomes
    identical after the uint16 pack round-trip, objectives to 1e-9."""
    import json
    import pathlib

    from repro.core.dse import coexplore_many

    golden = json.loads(
        (pathlib.Path(__file__).parent / "golden_coexplore_many.json")
        .read_text())
    res = coexplore_many(golden["workloads"], preset=golden["preset"],
                         budget=golden["budget"], seed=golden["seed"],
                         backend="numpy", pop_size=golden["pop_size"])
    assert list(res.objectives) == golden["objectives"]
    want_g = res.space.unpack_genomes(
        np.array(golden["front_genomes_u16"], dtype=np.uint16))
    assert np.array_equal(res.genomes, want_g)
    want_F = np.array(golden["front_objectives"], dtype=np.float64)
    np.testing.assert_allclose(res.front_objectives, want_F, rtol=1e-9)
    # the golden front respects the paper's dominance claim on its own
    # terms: under the 3-objective set FP32 may survive by winning the
    # accuracy axis, but the best *worst-case perf/area* point is
    # lightweight-PE hardware
    pts = res.front_points()
    best = min(pts, key=lambda p: p["neg_worst_perf_per_area"])
    assert best["config"].pe_type in (PEType.LIGHTPE1, PEType.LIGHTPE2)


def test_fp32_highest_power_and_area_per_pe():
    """Fig. 2 discussion: FP32 has the highest area and power cost; the
    LightPEs the lowest, per PE."""
    from repro.core.accelerator import AcceleratorConfig
    from repro.core.synthesis import synthesize
    reports = {t: synthesize(AcceleratorConfig(pe_type=t))
               for t in PEType}
    assert reports[PEType.FP32].area_mm2 > reports[PEType.INT16].area_mm2 \
        > reports[PEType.LIGHTPE2].area_mm2
    assert reports[PEType.FP32].power_mw > reports[PEType.INT16].power_mw \
        > reports[PEType.LIGHTPE2].power_mw > 0
