"""Generated-RTL structural invariants (the paper's RTL-output feature)."""

import re

import pytest

from repro.core.accelerator import AcceleratorConfig
from repro.core.pe import PEType
from repro.core.rtl import generate_rtl, rtl_stats


@pytest.mark.parametrize("pe_type", list(PEType))
def test_module_set_complete(pe_type):
    rtl = generate_rtl(AcceleratorConfig(pe_type=pe_type))
    for mod in ("mac_unit", "ifmap_spad", "filter_spad", "psum_spad",
                "pe", "pe_array"):
        assert re.search(rf"module {mod}\b", rtl), mod
    st = rtl_stats(rtl)
    assert st["endmodules"] == 6


def test_lightpe_is_multiplier_free():
    """LightPEs replace the multiplier with shifts (paper Sec. 3.2)."""
    for t in (PEType.LIGHTPE1, PEType.LIGHTPE2):
        rtl = generate_rtl(AcceleratorConfig(pe_type=t))
        st = rtl_stats(rtl)
        assert st["has_shift"], t
        assert not st["has_multiplier"], t
    rtl16 = generate_rtl(AcceleratorConfig(pe_type=PEType.INT16))
    assert rtl_stats(rtl16)["has_multiplier"]
    assert not rtl_stats(rtl16)["has_shift"]


def test_quantization_aware_widths():
    rtl = generate_rtl(AcceleratorConfig(pe_type=PEType.LIGHTPE1))
    assert "AW=8, WW=4, PW=24" in rtl
    rtl = generate_rtl(AcceleratorConfig(pe_type=PEType.FP32))
    assert "AW=32, WW=32, PW=32" in rtl


def test_spad_depths_match_config():
    cfg = AcceleratorConfig(ifmap_spad=16, filter_spad=128, psum_spad=32)
    rtl = generate_rtl(cfg)
    assert "W=16, D=16" in rtl         # ifmap: 16b x 16 entries
    assert "D=128" in rtl
    assert "D=32" in rtl


def test_array_dims_in_generate_loop():
    cfg = AcceleratorConfig(pe_rows=8, pe_cols=10)
    rtl = generate_rtl(cfg)
    assert "gj < 10" in rtl and "gi < 8" in rtl
    # psum chain spans rows+1 per column
    assert "psum_chain [0:8][0:9]" in rtl


def test_balanced_structure():
    for t in PEType:
        rtl = generate_rtl(AcceleratorConfig(pe_type=t))
        assert rtl.count("module ") - rtl.count("endmodule") == 0
        assert rtl.count("begin") <= rtl.count("end")
        # every declared wire bus is well-formed [hi:lo]
        for m in re.finditer(r"\[(\-?\d+):0\]", rtl):
            assert int(m.group(1)) >= 0, m.group(0)


def test_rtl_differs_across_design_points():
    a = generate_rtl(AcceleratorConfig(pe_rows=8, pe_cols=8))
    b = generate_rtl(AcceleratorConfig(pe_rows=16, pe_cols=16))
    assert a != b
