"""Quantized serving path: QuantizedTensor weights + integer contractions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model
from repro.quant.policy import ExecMode, QuantPolicy, policy_for
from repro.quant.qlinear import (QuantizedTensor, dequantize_weight, qdot,
                                 quantize_weight, serve_dot)


@pytest.mark.parametrize("mode", [ExecMode.W8A8, ExecMode.W4A8_POW2])
def test_quantize_dequantize_weight(mode):
    policy = QuantPolicy(mode=mode)
    w = jax.random.normal(jax.random.key(0), (32, 16))
    qw = quantize_weight(w, policy)
    assert isinstance(qw, QuantizedTensor)
    back = dequantize_weight(qw)
    # error bounded by the format's step size
    err = float(jnp.max(jnp.abs(back - w)))
    assert err < float(jnp.max(jnp.abs(w))) * (0.35 if mode ==
                                               ExecMode.W4A8_POW2 else 0.01)


@pytest.mark.parametrize("mode", [ExecMode.W8A8, ExecMode.W4A8_POW2])
def test_serve_dot_equals_dequant_matmul(mode):
    policy = QuantPolicy(mode=mode)
    w = jax.random.normal(jax.random.key(1), (24, 12))
    x = jax.random.normal(jax.random.key(2), (5, 24))
    qw = quantize_weight(w, policy)
    got = serve_dot(x, qw)
    # reference: quantize acts the same way, matmul against dequant weight
    from repro.quant import quantizers as qz
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ref = (xq.astype(jnp.float32) * xs) @ dequantize_weight(qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_qdot_dispatch():
    policy = policy_for("w8a8")
    w = jax.random.normal(jax.random.key(3), (16, 8))
    x = jax.random.normal(jax.random.key(4), (2, 3, 16))
    # raw weight + train -> QAT fake quant path, close to plain matmul
    out_t = qdot(x, w, policy, train=True)
    plain = x @ w
    assert float(jnp.max(jnp.abs(out_t.astype(jnp.float32) - plain))) < 0.25
    # quantized weight -> integer path
    out_s = qdot(x, quantize_weight(w, policy), policy, train=False)
    assert out_s.shape == (2, 3, 8)
    assert float(jnp.max(jnp.abs(out_s.astype(jnp.float32) - plain))) < 0.25


def test_quantize_params_structure_and_loss():
    cfg = reduced(get_config("gemma3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    qparams = model.quantize_params(params)
    leaves = jax.tree.leaves(
        qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    assert any(isinstance(l, QuantizedTensor) for l in leaves)
    # forward with quantized weights stays close to the float forward
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    lf, _ = model.forward(params, toks, train=False)
    lq, _ = model.forward(qparams, toks, train=False)
    rel = float(jnp.mean(jnp.abs(lq - lf)) / (jnp.mean(jnp.abs(lf)) + 1e-9))
    assert rel < 0.35, rel


def test_qat_train_step_quantized_mode():
    """Gradients flow through fake-quant (STE) for every arch family."""
    for arch in ("starcoder2-7b", "mamba2-130m"):
        cfg = reduced(get_config(arch))
        assert cfg.quant == "w8a8"
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
        loss, grads = jax.value_and_grad(model.loss)(
            params, {"tokens": toks, "labels": toks})
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert gn > 0 and not np.isnan(gn)
