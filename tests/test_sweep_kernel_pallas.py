"""Pallas sweep kernel parity + routing (ISSUE 9 tentpole).

The hand-tiled Pallas kernel (``repro.kernels.sweep_kernel``) must be an
invisible substitution for the jitted XLA aggregate path: interpret-mode
results match the exact numpy kernel at ≤1e-6 relative on every
aggregate column — across ragged config tails, multi-tile accumulation
on both grid axes, mixed-precision ``(N, L)`` columns, and multi-segment
(multi-workload) reductions — and the ``use_pallas`` routing flag
threads from the public engines down to ``_run_kernel`` with strict
validation.
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dse_batch import (AGGREGATE_OUTPUTS, _make_cfg_lay,
                                  _sweep_chunked, _sweep_kernel,
                                  _sweep_mixed, _workload_batch,
                                  mixed_assign_cfg, resolve_use_pallas)
from repro.core.pe import PEType
from repro.core.synthesis import synthesize_soa
from repro.core.workloads import get_workload
from repro.kernels.sweep_kernel import (CFG_FIELDS, resolve_pallas_donate,
                                        resolve_pallas_interpret,
                                        sweep_aggregates_pallas)

RTOL = 1e-6


def _configs(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    types = tuple(PEType)
    return tuple(
        AcceleratorConfig(
            pe_type=types[int(rng.integers(len(types)))],
            pe_rows=int(rng.integers(4, 33)),
            pe_cols=int(rng.integers(4, 33)),
            glb_kb=int(rng.choice([64, 128, 256, 512])),
            dram_bw_gbps=float(rng.choice([6.4, 12.8, 25.6])))
        for _ in range(n))


def _cfg_lay(n: int, workloads=("vgg16",), seed: int = 0):
    """(cfg, lay, bounds) over the concatenated layer axis."""
    soa = configs_to_soa(_configs(n, seed))
    cols = synthesize_soa(soa)
    wbs = [_workload_batch(get_workload(w)) for w in workloads]
    cfg, _ = _make_cfg_lay(soa, cols, wbs[0])
    lay = {k: np.concatenate([wb.arrays[k][None, :] for wb in wbs],
                             axis=1) for k in wbs[0].arrays}
    bounds, s = [], 0
    for wb in wbs:
        L = len(wb.arrays["macs"])
        bounds.append((s, s + L))
        s += L
    return cfg, lay, tuple(bounds)


def _numpy_segments(cfg, lay, bounds):
    """Exact reference: the numpy kernel per workload segment -> (W, N)."""
    out = {k: [] for k in AGGREGATE_OUTPUTS}
    for s, e in bounds:
        sub_lay = {k: v[:, s:e] for k, v in lay.items()}
        sub_cfg = {k: (v[:, s:e] if v.shape[1] > 1 else v)
                   for k, v in cfg.items()}
        agg = _sweep_kernel(np, sub_cfg, sub_lay, outputs="aggregates")
        for k in AGGREGATE_OUTPUTS:
            out[k].append(np.asarray(agg[k], dtype=np.float64))
    return {k: np.stack(v) for k, v in out.items()}


def _assert_close(got: dict, want: dict):
    for k in AGGREGATE_OUTPUTS:
        g = np.asarray(got[k], dtype=np.float64)
        w = np.asarray(want[k], dtype=np.float64)
        assert g.shape == w.shape, k
        rel = np.max(np.abs(g - w) / np.maximum(np.abs(w), 1e-30))
        assert rel <= RTOL, (k, rel)


# ---------------------------------------------------------------------------
# interpret-mode parity vs the exact numpy kernel
# ---------------------------------------------------------------------------

def test_interpret_parity_single_workload():
    cfg, lay, _ = _cfg_lay(83)
    got = sweep_aggregates_pallas(cfg, lay, interpret=True)
    want = {k: v[0] for k, v in
            _numpy_segments(cfg, lay, ((0, lay["r"].shape[1]),)).items()}
    assert all(np.shape(got[k]) == (83,) for k in AGGREGATE_OUTPUTS)
    _assert_close(got, want)


def test_multi_tile_ragged_tail():
    """block_n/block_l far smaller than (N, L): the scratch accumulators
    must carry segment sums across layer tiles and the padded ragged
    tail rows/columns must never contaminate real outputs."""
    cfg, lay, _ = _cfg_lay(53, seed=1)
    L = lay["r"].shape[1]
    got = sweep_aggregates_pallas(cfg, lay, block_n=16, block_l=5,
                                  interpret=True)
    want = {k: v[0] for k, v in
            _numpy_segments(cfg, lay, ((0, L),)).items()}
    _assert_close(got, want)


def test_mixed_precision_columns():
    """(N, L) per-layer act/weight-bit + mac-energy columns (the
    co-exploration genome layout) ride the wide BlockSpec path."""
    rng = np.random.default_rng(7)
    cfg, lay, _ = _cfg_lay(40, seed=2)
    L = lay["r"].shape[1]
    assign = rng.integers(0, len(tuple(PEType)), size=(40, L))
    cfg = mixed_assign_cfg(cfg, assign)
    got = sweep_aggregates_pallas(cfg, lay, block_n=16, block_l=4,
                                  interpret=True)
    want = {k: v[0] for k, v in
            _numpy_segments(cfg, lay, ((0, L),)).items()}
    _assert_close(got, want)


def test_multi_segment_bounds():
    """Two workloads on one concatenated layer axis: per-segment masks
    must gate the Kahan updates even when a layer tile straddles the
    segment boundary."""
    cfg, lay, bounds = _cfg_lay(21, workloads=("vgg16", "resnet34"),
                                seed=3)
    got = sweep_aggregates_pallas(cfg, lay, bounds=bounds, block_n=8,
                                  block_l=8, interpret=True)
    want = _numpy_segments(cfg, lay, bounds)
    assert all(np.shape(got[k]) == (2, 21) for k in AGGREGATE_OUTPUTS)
    _assert_close(got, want)


def test_committed_stream_slice_parity():
    """Rows drawn from the committed benchmark stream (the widened
    chunked-scaling grid of dse_sweep_bench) match at ≤1e-6."""
    from repro.core.accelerator import design_space_soa
    soa = next(iter(design_space_soa(
        chunk_size=2048, glb_kbs=(4, 64, 1024, 4096),
        bws=tuple(np.linspace(2.0, 64.0, 156)))))
    cols = synthesize_soa(soa)
    wb = _workload_batch(get_workload("vgg16"))
    cfg, lay = _make_cfg_lay(soa, cols, wb)
    got = sweep_aggregates_pallas(cfg, lay, interpret=True)
    want = {k: np.asarray(v, dtype=np.float64) for k, v in
            _sweep_kernel(np, cfg, lay, outputs="aggregates").items()}
    _assert_close(got, want)


# ---------------------------------------------------------------------------
# guards + mode resolution
# ---------------------------------------------------------------------------

def test_validation_guards():
    cfg, lay, _ = _cfg_lay(8)
    bad = dict(cfg)
    del bad["pe_rows"]
    with pytest.raises(ValueError, match="missing field"):
        sweep_aggregates_pallas(bad, lay)
    bad = dict(cfg, pe_rows=cfg["pe_rows"][:, 0])    # (N,) not (N, 1)
    with pytest.raises(ValueError, match="shape"):
        sweep_aggregates_pallas(bad, lay)
    with pytest.raises(ValueError, match="bounds"):
        sweep_aggregates_pallas(cfg, lay, bounds=((0, 0),))
    with pytest.raises(ValueError, match="bounds"):
        sweep_aggregates_pallas(
            cfg, lay, bounds=((0, lay["r"].shape[1] + 1),))
    with pytest.raises(ValueError, match="block sizes"):
        sweep_aggregates_pallas(cfg, lay, block_n=0)


def test_mode_resolution_cpu():
    """On the CPU-only CI host: interpret auto-resolves on, donation
    auto-resolves off (CPU jax can't consume donations)."""
    from repro.core.dse_batch import _jax_has_accelerator
    if _jax_has_accelerator():          # pragma: no cover - device CI
        pytest.skip("accelerator attached")
    assert resolve_pallas_interpret(None) is True
    assert resolve_pallas_donate(None) is False
    assert resolve_pallas_interpret(False) is False
    assert resolve_pallas_donate(True) is True


def test_resolve_use_pallas_routing():
    assert resolve_use_pallas(False, "numpy") is False
    assert resolve_use_pallas(None, "numpy") is False
    assert resolve_use_pallas(True, "jax") is True
    with pytest.raises(ValueError, match="numpy"):
        resolve_use_pallas(True, "numpy")
    with pytest.raises(ValueError, match="mesh"):
        resolve_use_pallas(True, "jax", mesh=object())


# ---------------------------------------------------------------------------
# routing through the public engines
# ---------------------------------------------------------------------------

def test_sweep_mixed_use_pallas_matches_xla(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    from repro.core.pe import mode_compat_matrix
    rng = np.random.default_rng(11)
    wl = get_workload("vgg16")
    soa = configs_to_soa(_configs(24, seed=4))
    # per-layer modes drawn from each config's *compatible* mode set
    compat = mode_compat_matrix()[soa["pe_type_idx"]]     # (N, T)
    assign = np.stack([
        rng.choice(np.nonzero(row)[0], size=len(wl.layers))
        for row in compat])
    base = _sweep_mixed(wl, soa, assign, backend="jax",
                        outputs="aggregates", use_pallas=False)
    pal = _sweep_mixed(wl, soa, assign, backend="jax",
                       outputs="aggregates", use_pallas=True)
    _assert_close({k: pal[k] for k in AGGREGATE_OUTPUTS},
                  {k: np.asarray(base[k], dtype=np.float64)
                   for k in AGGREGATE_OUTPUTS})


def test_chunked_stream_use_pallas(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    wl = get_workload("vgg16")
    feed = list(_configs(36, seed=5))
    res = _sweep_chunked(wl, [feed], chunk_size=16, backend="jax",
                         use_pallas=True, use_cache=False)
    assert res.timings["use_pallas"] is True
    ref = _sweep_chunked(wl, [feed], chunk_size=16, backend="numpy",
                         overlap=False, use_cache=False)
    assert res.front_size == ref.front_size
    for m in ref.front_metrics:
        np.testing.assert_allclose(
            np.sort(res.front_metrics[m]), np.sort(ref.front_metrics[m]),
            rtol=1e-5)


def test_evaluator_use_pallas_parity(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    from repro.explore import CoExploreSpace
    from repro.explore.search import random_search
    wl = get_workload("vgg16")
    space = CoExploreSpace(n_layers=len(wl.layers))
    base = random_search(space, wl, 48, seed=9, backend="jax",
                         use_pallas=False)
    pal = random_search(space, wl, 48, seed=9, backend="jax",
                        use_pallas=True)
    assert pal.stats["use_pallas"] is True
    np.testing.assert_allclose(pal.front_objectives,
                               base.front_objectives, rtol=1e-5)


def test_explore_spec_use_pallas_validation():
    from repro.core.dse import ExploreSpec
    with pytest.raises(ValueError, match="numpy"):
        ExploreSpec.single("vgg16", backend="numpy", use_pallas=True)
    with pytest.raises(ValueError, match="prefetch_depth"):
        ExploreSpec.single("vgg16", prefetch_depth=0, chunk_size=8)
    with pytest.raises(ValueError, match="chunk_size"):
        ExploreSpec.single("vgg16", prefetch_depth=4)
    spec = ExploreSpec.single("vgg16", chunk_size=8, prefetch_depth=4)
    assert spec.prefetch_depth == 4 and spec.use_pallas is None
