"""W8A8 flash-decode Pallas kernel vs oracle and float attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import w8a8_decode_attention_ref
from repro.kernels.w8a8_decode import w8a8_decode_attention


def _setup(key, b, kvh, rep, hd, S):
    rng = np.random.default_rng(key)
    q = jnp.asarray(rng.standard_normal((b, kvh, rep, hd)), jnp.float32)
    kf = rng.standard_normal((b, S, kvh, hd)).astype(np.float32)
    vf = rng.standard_normal((b, S, kvh, hd)).astype(np.float32)
    ks = np.abs(kf).max(-1) / 127.0
    vs = np.abs(vf).max(-1) / 127.0
    kq = jnp.asarray(np.round(kf / ks[..., None]), jnp.int8)
    vq = jnp.asarray(np.round(vf / vs[..., None]), jnp.int8)
    return q, kq, vq, jnp.asarray(ks), jnp.asarray(vs), kf, vf


@pytest.mark.parametrize("b,kvh,rep,hd,S,bs", [
    (2, 2, 4, 32, 128, 32),
    (1, 4, 2, 16, 64, 16),
    (2, 1, 8, 64, 96, 32),
])
def test_kernel_matches_oracle(b, kvh, rep, hd, S, bs):
    q, kq, vq, ks, vs, _, _ = _setup(b * 7, b, kvh, rep, hd, S)
    for pos in (0, S // 2, S - 1):
        ref = w8a8_decode_attention_ref(q, kq, vq, ks, vs,
                                        jnp.int32(pos), bs=bs)
        pal = w8a8_decode_attention(q, kq, vq, ks, vs, jnp.int32(pos),
                                    bs=bs, interpret=True)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_kernel_close_to_float_attention():
    b, kvh, rep, hd, S = 2, 2, 4, 32, 128
    q, kq, vq, ks, vs, kf, vf = _setup(3, b, kvh, rep, hd, S)
    pos = jnp.int32(100)
    pal = w8a8_decode_attention(q, kq, vq, ks, vs, pos, bs=32,
                                interpret=True)
    logits = jnp.einsum("bgrd,bsgd->bgrs", q, jnp.asarray(kf)) \
        * (hd ** -0.5)
    ki = jnp.arange(S)[None, None, None, :]
    logits = jnp.where(ki <= pos, logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    outf = jnp.einsum("bgrs,bsgd->bgrd", p, jnp.asarray(vf))
    rel = float(jnp.max(jnp.abs(pal - outf))
                / (jnp.max(jnp.abs(outf)) + 1e-9))
    assert rel < 0.03, rel     # int8 rounding only


def test_ops_dispatch_ref_on_cpu():
    b, kvh, rep, hd, S = 1, 2, 2, 16, 64
    q, kq, vq, ks, vs, _, _ = _setup(5, b, kvh, rep, hd, S)
    out = ops.w8a8_decode_attention(q, kq, vq, ks, vs, jnp.int32(10),
                                    bs=16)
    assert out.shape == (b, kvh, rep, hd)
    assert not bool(jnp.any(jnp.isnan(out)))
