"""Row-stationary dataflow model invariants (property tests)."""

import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (dev dependency)")
from hypothesis import given, settings, strategies as st

from repro.core.accelerator import AcceleratorConfig
from repro.core.dataflow import map_layer, run_workload
from repro.core.pe import PEType
from repro.core.synthesis import synthesize
from repro.core.workloads import ConvLayer, get_workload

SETTINGS = dict(max_examples=30, deadline=None)

layer_st = st.builds(
    ConvLayer,
    name=st.just("l"),
    h=st.integers(8, 64), w=st.integers(8, 64),
    c=st.integers(1, 64), k=st.integers(1, 64),
    r=st.sampled_from([1, 3, 5, 7]), s=st.sampled_from([1, 3, 5, 7]),
    stride=st.sampled_from([1, 2]),
)

cfg_st = st.builds(
    AcceleratorConfig,
    pe_type=st.sampled_from(list(PEType)),
    pe_rows=st.sampled_from([8, 12, 16, 32]),
    pe_cols=st.sampled_from([8, 14, 16, 32]),
    glb_kb=st.sampled_from([64, 128, 512]),
    dram_bw_gbps=st.sampled_from([6.4, 25.6]),
)


def _res(layer, cfg):
    rep = synthesize(cfg)
    from repro.core.pe import _P_PE_LEAK_UW
    leak = cfg.num_pes * _P_PE_LEAK_UW[cfg.pe_type] * 1e-3
    return map_layer(layer, cfg, rep.clock_ghz, rep.area_mm2, leak)


@given(layer=layer_st, cfg=cfg_st)
@settings(**SETTINGS)
def test_utilization_bounded(layer, cfg):
    if layer.h < layer.r or layer.w < layer.s:
        return
    r = _res(layer, cfg)
    assert 0 < r.utilization <= 1.0 + 1e-9
    assert r.compute_cycles >= math.ceil(layer.macs / cfg.num_pes)
    assert r.total_cycles >= max(r.compute_cycles, r.mem_cycles)


@given(layer=layer_st, cfg=cfg_st)
@settings(**SETTINGS)
def test_dram_traffic_floor(layer, cfg):
    if layer.h < layer.r or layer.w < layer.s:
        return
    r = _res(layer, cfg)
    s = cfg.spec
    floor = (layer.c * layer.h * layer.w * s.act_bits
             + layer.k * layer.c * layer.r * layer.s * s.weight_bits
             + layer.k * layer.e * layer.f * s.act_bits) // 8
    assert r.dram_bytes >= floor
    assert r.energy_pj > 0


def test_bigger_glb_never_more_dram():
    layer = ConvLayer("c", 56, 56, 128, 256)
    prev = None
    for glb in (64, 128, 256, 512, 1024):
        r = _res(layer, AcceleratorConfig(glb_kb=glb))
        if prev is not None:
            assert r.dram_bytes <= prev
        prev = r.dram_bytes


def test_quantization_reduces_traffic():
    layer = ConvLayer("c", 28, 28, 256, 512)
    r16 = _res(layer, AcceleratorConfig(pe_type=PEType.INT16))
    r4 = _res(layer, AcceleratorConfig(pe_type=PEType.LIGHTPE1))
    assert r4.dram_bytes < r16.dram_bytes
    rf = _res(layer, AcceleratorConfig(pe_type=PEType.FP32))
    assert r16.dram_bytes < rf.dram_bytes


def test_workload_aggregation():
    wl = get_workload("vgg16")
    res = run_workload(wl, AcceleratorConfig())
    assert res.total_macs == wl.total_macs
    assert res.latency_s > 0 and res.energy_j > 0
    assert len(res.layers) == len(wl.layers)
    assert res.perf_per_area > 0


def test_eyeriss_like_full_utilization_case():
    """12x14 array, R=3, E=56: the canonical mapping should be ~100%."""
    layer = ConvLayer("c", 58, 58, 64, 64)   # E=F=56
    r = _res(layer, AcceleratorConfig(pe_rows=12, pe_cols=14))
    assert r.utilization > 0.95
