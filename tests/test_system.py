"""End-to-end behaviour tests for the full system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


def test_training_improves_loss():
    losses = train("gemma3-4b", steps=12, smoke=True, seq_len=32, batch=4)
    first = np.mean([l for _, l in losses[:3]])
    last = np.mean([l for _, l in losses[-3:]])
    assert last < first, (first, last)


def test_training_with_restart_resumes(tmp_path):
    losses = train("starcoder2-7b", steps=10, smoke=True, seq_len=16,
                   batch=2, ckpt_dir=str(tmp_path), ckpt_every=3,
                   fail_at={5: 1})
    steps = [s for s, _ in losses]
    assert steps[-1] == 9
    # step 5 ran twice (once failed before executing, once after restart)
    assert len([s for s in steps if s == 4]) >= 1


def test_training_with_grad_compression():
    losses = train("phi4-mini-3.8b", steps=8, smoke=True, seq_len=16,
                   batch=2, grad_compression=True)
    assert losses[-1][1] < losses[0][1] * 1.5   # stable, no blowup
    assert not np.isnan(losses[-1][1])


def test_serving_generates_batched_tokens():
    res = serve("gemma3-4b", batch=3, prompt_len=8, gen=6, smoke=True)
    assert res["tokens"].shape == (3, 6)
    assert res["tok_per_s"] > 0


def test_quantized_serving_matches_float_mostly():
    """LightPE-2 deployment: int8 weights generate the same continuation
    as float weights for a strong-signal prompt (greedy decode)."""
    a = serve("starcoder2-7b", batch=2, prompt_len=6, gen=5, smoke=True,
              quantize=False, seed=3)
    b = serve("starcoder2-7b", batch=2, prompt_len=6, gen=5, smoke=True,
              quantize=True, seed=3)
    agree = float(np.mean(np.asarray(a["tokens"]) == np.asarray(b["tokens"])))
    assert agree >= 0.5, agree   # random-init logits are nearly flat


def test_moe_serving():
    res = serve("moonshot-v1-16b-a3b", batch=2, prompt_len=4, gen=4,
                smoke=True)
    assert res["tokens"].shape == (2, 4)


def test_vlm_serving_with_ctx():
    res = serve("llama-3.2-vision-90b", batch=2, prompt_len=4, gen=3,
                smoke=True)
    assert res["tokens"].shape == (2, 3)


def test_data_pipeline_determinism():
    from repro.data.pipeline import DataConfig, SyntheticLM
    d1 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=4))
    d2 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=4))
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
