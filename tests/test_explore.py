"""Co-exploration subsystem (ISSUE 3): genome space, objectives, search
engines, determinism, and the coexplore() wiring."""

import numpy as np
import pytest

from repro.core.dse import coexplore
from repro.core.pe import PEType, mode_compat_matrix
from repro.core.workloads import ConvLayer, Workload
from repro.explore import (CoExploreSpace, Evaluator, hypervolume, nsga2,
                           random_search, reference_point,
                           space_for_workload, successive_halving)
from repro.explore.objectives import (mode_noise_table, objective_matrix,
                                      quant_noise)
from repro.explore.space import N_HW_GENES

TYPES = tuple(PEType)

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
    ConvLayer("big", 226, 226, 3, 64),
))

SPACE = CoExploreSpace(n_layers=len(TINY_WL.layers))


# ---------------------------------------------------------------------------
# genome space
# ---------------------------------------------------------------------------

def test_space_sizing_and_layout():
    assert SPACE.genome_width == N_HW_GENES + 4
    assert space_for_workload(TINY_WL).genome_width == SPACE.genome_width
    assert space_for_workload("vgg16").n_layers == 16
    # the joint space dwarfs the 720-point homogeneous grid
    assert SPACE.size() > 720


def test_random_population_valid_and_seeded():
    rng = np.random.default_rng(5)
    g = SPACE.random_population(300, rng)
    assert g.shape == (300, SPACE.genome_width)
    assert SPACE.valid_mask(g).all()
    g2 = SPACE.random_population(300, np.random.default_rng(5))
    assert np.array_equal(g, g2)
    # every hardware type and several modes get sampled
    assert len(np.unique(g[:, 0])) == len(TYPES)
    assert len(np.unique(g[:, N_HW_GENES:])) >= 3


def test_decode_round_trip_and_synthesis_cache_keying():
    from repro.core.confighash import config_digests
    rng = np.random.default_rng(9)
    g = SPACE.random_population(64, rng)
    soa, assign = SPACE.decode(g)
    assert assign.shape == (64, SPACE.n_layers)
    # hardware half digests through confighash -> same digest as an
    # equivalent homogeneous sweep config (the synthesis-cache key)
    from repro.core.accelerator import configs_to_soa, soa_to_configs
    cfgs = soa_to_configs(soa)
    d_genome = np.stack(config_digests(soa), axis=-1)
    d_config = np.stack(config_digests(configs_to_soa(cfgs)), axis=-1)
    assert np.array_equal(d_genome, d_config)


def test_valid_mask_flags_bad_levels_and_modes():
    g = SPACE.random_population(8, np.random.default_rng(1))
    g[0, 0] = len(SPACE.pe_types)           # hw level out of range
    g[1, 1] = -1
    g[2, N_HW_GENES] = len(TYPES)           # mode index out of range
    # force an incompatible mode: fp32 mode on lightpe1 hardware
    g[3, 0] = SPACE.pe_types.index(PEType.LIGHTPE1)
    g[3, N_HW_GENES] = TYPES.index(PEType.FP32)
    mask = SPACE.valid_mask(g)
    assert mask.tolist()[:4] == [False, False, False, False]
    assert mask[4:].all()
    with pytest.raises(ValueError, match="invalid genome"):
        SPACE.decode(g)
    with pytest.raises(ValueError, match="genome matrix shape"):
        SPACE.validate(g[:, :3])


def test_mutation_and_crossover_preserve_validity():
    rng = np.random.default_rng(13)
    a = SPACE.random_population(200, rng)
    b = SPACE.random_population(200, rng)
    child = SPACE.crossover(a, b, rng)
    assert SPACE.valid_mask(child).all()
    mut = SPACE.mutate(child, rng, rate=0.5)
    assert SPACE.valid_mask(mut).all()
    assert (mut != child).any()             # rate 0.5 must change something
    # repair clamps an incompatible mode to the hardware's own type
    g = a[:1].copy()
    g[0, 0] = SPACE.pe_types.index(PEType.LIGHTPE1)
    g[0, N_HW_GENES:] = TYPES.index(PEType.FP32)
    fixed = SPACE.repair(g)
    assert SPACE.valid_mask(fixed).all()
    assert (fixed[0, N_HW_GENES:] == TYPES.index(PEType.LIGHTPE1)).all()


def test_genome_keys_distinct_and_stable():
    rng = np.random.default_rng(21)
    g = SPACE.random_population(500, rng)
    keys = SPACE.genome_keys(g)
    uniq_rows = len(np.unique(g, axis=0))
    assert len(set(keys)) == uniq_rows
    assert keys == SPACE.genome_keys(g)     # pure function of the genome


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

def test_noise_table_orders_precisions_sensibly():
    t = mode_noise_table()
    i = {pt: TYPES.index(pt) for pt in TYPES}
    assert t[i[PEType.FP32]] == 0.0
    assert t[i[PEType.FP32]] < t[i[PEType.INT16]]
    assert t[i[PEType.INT16]] < t[i[PEType.LIGHTPE2]]
    assert t[i[PEType.LIGHTPE2]] < t[i[PEType.LIGHTPE1]]


def test_quant_noise_is_mac_weighted():
    macs = np.array([l.macs for l in TINY_WL.layers], dtype=np.float64)
    fp32 = np.full((1, 4), TYPES.index(PEType.FP32))
    int4 = np.full((1, 4), TYPES.index(PEType.LIGHTPE1))
    assert quant_noise(fp32, macs)[0] == 0.0
    assert quant_noise(int4, macs)[0] > 0.0
    # quantizing only the biggest-MAC layer costs more than only the
    # smallest
    big = fp32.copy()
    big[0, int(np.argmax(macs))] = TYPES.index(PEType.LIGHTPE1)
    small = fp32.copy()
    small[0, int(np.argmin(macs))] = TYPES.index(PEType.LIGHTPE1)
    assert quant_noise(big, macs)[0] > quant_noise(small, macs)[0]


def test_objective_matrix_orientation_and_unknown_name():
    ev = Evaluator(SPACE, TINY_WL, backend="numpy")
    g = SPACE.random_population(16, np.random.default_rng(3))
    F = ev.evaluate(g)
    assert F.shape == (16, 3)
    assert (F[:, 0] < 0).all()              # neg perf/area
    assert (F[:, 1] > 0).all()              # energy
    with pytest.raises(ValueError, match="unknown objective"):
        objective_matrix({"perf_per_area": np.ones(1),
                          "energy_j": np.ones(1),
                          "latency_s": np.ones(1),
                          "area_mm2": np.ones(1)},
                         np.zeros((1, 4), dtype=np.int64),
                         np.ones(4), objectives=("speed",))


# ---------------------------------------------------------------------------
# evaluator: memoization + synthesis-cache reuse
# ---------------------------------------------------------------------------

def test_evaluator_memoizes_and_reuses_synthesis_cache():
    from repro.core.synthesis import (clear_synthesis_cache,
                                      synthesis_cache_stats)
    clear_synthesis_cache()
    ev = Evaluator(SPACE, TINY_WL, backend="numpy")
    g = SPACE.random_population(64, np.random.default_rng(2))
    F1 = ev.evaluate(g)
    assert ev.n_kernel == 64 - (64 - len(np.unique(g, axis=0)))  \
        or ev.n_kernel <= 64
    F2 = ev.evaluate(g)                     # full memo hit
    assert np.array_equal(F1, F2)
    assert ev.n_memo_hits >= 64
    assert ev.n_kernel <= 64
    # different assignments on the same hardware hit the synthesis cache
    g2 = g.copy()
    g2[:, N_HW_GENES:] = SPACE.repair(
        np.concatenate([g[:, :N_HW_GENES],
                        np.full((64, SPACE.n_layers),
                                TYPES.index(PEType.LIGHTPE1))],
                       axis=1))[:, N_HW_GENES:]
    stats_before = synthesis_cache_stats()
    ev.evaluate(g2)
    stats_after = synthesis_cache_stats()
    assert stats_after["array_hits"] > stats_before["array_hits"]
    clear_synthesis_cache()


def test_evaluator_rejects_mismatched_space():
    with pytest.raises(ValueError, match="layer genes"):
        Evaluator(CoExploreSpace(n_layers=3), TINY_WL)


# ---------------------------------------------------------------------------
# search engines
# ---------------------------------------------------------------------------

def test_random_search_budget_and_front():
    res = random_search(SPACE, TINY_WL, 128, seed=0, backend="numpy")
    assert res.n_evals == 128
    assert len(res.all_objectives) == 128
    assert res.front_size >= 1
    assert res.history[-1][0] == 128
    # front is mutually non-dominated
    from repro.explore.pareto import pareto_mask_k
    assert pareto_mask_k(res.front_objectives).all()
    # hypervolume history is monotone for an accumulating archive
    hvs = [h for _, h in res.history]
    assert all(b >= a - 1e-12 for a, b in zip(hvs, hvs[1:]))


def test_nsga2_deterministic_and_beats_or_ties_itself():
    a = nsga2(SPACE, TINY_WL, 192, pop_size=16, seed=4, backend="numpy")
    b = nsga2(SPACE, TINY_WL, 192, pop_size=16, seed=4, backend="numpy")
    assert a.n_evals == b.n_evals == 192
    assert np.array_equal(a.genomes, b.genomes)
    assert np.array_equal(a.front_objectives, b.front_objectives)
    assert a.history == b.history
    c = nsga2(SPACE, TINY_WL, 192, pop_size=16, seed=5, backend="numpy")
    assert not np.array_equal(a.genomes, c.genomes)  # seed matters


def test_successive_halving_runs_and_final_front_is_full_workload():
    res = successive_halving(SPACE, TINY_WL, 200, seed=1, backend="numpy")
    assert res.front_size >= 1
    assert res.n_evals <= 210               # approximate budget, bounded
    # final-front objectives match a fresh full-workload evaluation
    ev = Evaluator(SPACE, TINY_WL, backend="numpy")
    F = ev.evaluate(res.genomes)
    assert np.array_equal(F, res.front_objectives)


def test_nsga2_reaches_random_hypervolume_at_equal_budget():
    budget = 384
    rnd = random_search(SPACE, TINY_WL, budget, seed=0, backend="numpy")
    gud = nsga2(SPACE, TINY_WL, budget, pop_size=24, seed=0,
                backend="numpy")
    ref = reference_point(np.concatenate([rnd.all_objectives,
                                          gud.all_objectives]))
    assert hypervolume(gud.front_objectives, ref) >= \
        hypervolume(rnd.front_objectives, ref) * 0.98


def test_search_determinism_across_backends():
    """Satellite: same seed => bit-identical final front on numpy and
    jax (the jax kernel's ~1e-7 parity never flips a search decision at
    these scales)."""
    from repro.core.dse_batch import resolve_backend
    try:
        resolve_backend("jax")
    except RuntimeError:
        pytest.skip("jax unusable")
    n = nsga2(SPACE, TINY_WL, 192, pop_size=16, seed=11, backend="numpy")
    j = nsga2(SPACE, TINY_WL, 192, pop_size=16, seed=11, backend="jax")
    assert np.array_equal(n.genomes, j.genomes)
    rn = random_search(SPACE, TINY_WL, 128, seed=11, backend="numpy")
    rj = random_search(SPACE, TINY_WL, 128, seed=11, backend="jax")
    assert np.array_equal(rn.genomes, rj.genomes)


# ---------------------------------------------------------------------------
# coexplore() wiring + presets
# ---------------------------------------------------------------------------

def test_coexplore_presets_registry():
    from repro.configs.coexplore_presets import (CoExplorePreset, PRESETS,
                                                 get_preset)
    assert {"quick", "default", "thorough"} <= set(PRESETS)
    assert get_preset("quick").budget < get_preset("default").budget
    with pytest.raises(ValueError, match="unknown co-exploration preset"):
        get_preset("warp-speed")
    with pytest.raises(ValueError, match="unknown objective"):
        CoExplorePreset(name="bad", objectives=("speed",))


def test_coexplore_runs_and_decodes_front():
    res = coexplore(TINY_WL, preset="quick", budget=96, seed=3,
                    backend="numpy", pop_size=12)
    assert res.method == "nsga2"
    assert res.workload == "tiny"
    assert res.n_evals == 96
    pts = res.front_points()
    assert len(pts) == res.front_size
    for pt in pts:
        cfg = pt["config"]
        assert len(pt["modes"]) == len(TINY_WL.layers)
        # every decoded mode is executable on its hardware
        compat = mode_compat_matrix()
        hw = TYPES.index(cfg.pe_type)
        for m in pt["modes"]:
            assert compat[hw, TYPES.index(PEType(m))]


def test_coexplore_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown co-exploration method"):
        coexplore(TINY_WL, preset="quick", method="simulated-annealing")
