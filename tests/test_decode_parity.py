"""Decode == forward parity for the remaining arch families (MoE, hybrid)
— complements test_attention / test_ssm / test_perf_paths coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model


@pytest.mark.parametrize("arch,tol", [
    ("phi3.5-moe-42b-a6.6b", 8e-2),
    ("zamba2-1.2b", 8e-2),
    ("phi4-mini-3.8b", 5e-2),
])
def test_decode_matches_forward(arch, tol):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    s = 10
    toks = jax.random.randint(jax.random.key(1), (2, s), 0, cfg.vocab)
    full, _ = m.forward(params, toks, train=False)
    caches = m.init_cache(2, s)
    outs = []
    for i in range(s):
        lg, caches = m.decode_step(params, caches, toks[:, i:i + 1],
                                   jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    rel = err / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < tol, (arch, rel)


def test_core_public_api():
    import repro.core as core
    assert callable(core.explore) and callable(core.generate_rtl)
    assert callable(core.synthesize) and callable(core.fit_ppa_suite)
