"""Batched DSE sweep engine vs the scalar reference path.

The contract (ISSUE: tentpole) is that the vectorized engine is a drop-in
replacement: per-layer results, per-config aggregates, headline ratios, and
Pareto fronts all *bit-match* the original Python loop.
"""

import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, design_space
from repro.core.dse import (IncrementalSweep, explore, explore_many,
                            explore_scalar, pareto_front, pareto_front_scalar)
from repro.core.dse_batch import pareto_mask, sweep_workload
from repro.core.pe import PEType
from repro.core.synthesis import (clear_synthesis_cache, config_hash,
                                  synthesis_cache_stats, synthesize,
                                  synthesize_cached, synthesize_many)
from repro.core.workloads import ConvLayer, Workload, get_workload

# a small but heterogeneous design space: every PE type, varied array /
# GLB / bandwidth, including non-default spads and a clock-capped point
SMALL_SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in PEType
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (32, 32, 512, 25.6)]
] + [
    AcceleratorConfig(pe_type=PEType.INT16, ifmap_spad=6, filter_spad=112,
                      psum_spad=12, glb_kb=256),
    AcceleratorConfig(pe_type=PEType.FP32, clock_ghz=0.5),
    # zero-size scratchpads: exercises the sram_area_um2 zero guard, which
    # the batched synthesis path must honor too
    AcceleratorConfig(pe_type=PEType.LIGHTPE1, ifmap_spad=0, filter_spad=0,
                      psum_spad=0),
]

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
    ConvLayer("big", 226, 226, 3, 64),
))


def test_batched_explore_bitmatches_scalar():
    scalar = explore_scalar(TINY_WL, SMALL_SPACE)
    batched = explore(TINY_WL, SMALL_SPACE, use_cache=False,
                      backend="numpy")
    assert len(scalar.points) == len(batched.points)
    for ps, pb in zip(scalar.points, batched.points):
        assert ps.config == pb.config
        rs, rb = ps.result, pb.result
        assert rs.area_mm2 == rb.area_mm2
        assert rs.clock_ghz == rb.clock_ghz
        assert rs.total_cycles == rb.total_cycles
        assert rs.energy_j == rb.energy_j
        assert rs.perf_per_area == rb.perf_per_area
        assert rs.latency_s == rb.latency_s
        for ls, lb in zip(rs.layers, rb.layers):
            assert ls == lb  # LayerResult is a frozen dataclass: exact


def test_batched_headline_ratios_identical_on_full_space():
    cfgs = list(design_space())
    wl = get_workload("vgg16")
    scalar = explore_scalar(wl, cfgs)
    batched = explore(wl, cfgs, backend="numpy")
    assert scalar.headline_ratios() == batched.headline_ratios()
    assert scalar.normalized() == batched.normalized()


def test_pareto_mask_matches_dominance_loop():
    rng = np.random.default_rng(7)
    perf = rng.uniform(1.0, 100.0, size=300)
    energy = rng.uniform(0.1, 10.0, size=300)
    # inject ties/duplicates to exercise the strict-dominance edge cases
    perf[10] = perf[20]
    energy[10] = energy[20]
    perf[30] = perf[40]
    mask = pareto_mask(perf, energy, chunk=64)
    for i in range(len(perf)):
        dominated = any(
            perf[q] >= perf[i] and energy[q] <= energy[i]
            and (perf[q] > perf[i] or energy[q] < energy[i])
            for q in range(len(perf)))
        assert mask[i] == (not dominated), i


def test_pareto_front_matches_scalar_reference():
    res = explore(TINY_WL, SMALL_SPACE)
    fv = pareto_front(res.points)
    fs = pareto_front_scalar(res.points)
    assert [p.config for p in fv] == [p.config for p in fs]


# ---------------------------------------------------------------------------
# pareto_mask / pareto_front edge cases (ISSUE 2 satellite)
# ---------------------------------------------------------------------------

def _brute_mask(perf, energy):
    return np.array([
        not any(perf[q] >= perf[i] and energy[q] <= energy[i]
                and (perf[q] > perf[i] or energy[q] < energy[i])
                for q in range(len(perf)))
        for i in range(len(perf))])


def test_pareto_mask_empty_and_single_point():
    assert pareto_mask(np.array([]), np.array([])).shape == (0,)
    assert pareto_mask(np.array([]), np.array([])).dtype == bool
    assert pareto_mask(np.array([3.0]), np.array([2.0])).tolist() == [True]


def test_pareto_mask_exact_duplicates_all_survive():
    # duplicate points do not strictly dominate each other: both stay
    perf = np.array([5.0, 5.0, 5.0, 1.0])
    energy = np.array([2.0, 2.0, 2.0, 1.0])
    got = pareto_mask(perf, energy)
    assert got.tolist() == [True, True, True, True]
    assert np.array_equal(got, _brute_mask(perf, energy))


def test_pareto_mask_ties_on_one_axis():
    # equal perf: only the lower-energy point survives; equal energy:
    # only the higher-perf point survives
    perf = np.array([4.0, 4.0, 2.0, 3.0])
    energy = np.array([1.0, 2.0, 3.0, 3.0])
    got = pareto_mask(perf, energy)
    assert got.tolist() == [True, False, False, False]
    assert np.array_equal(got, _brute_mask(perf, energy))


def test_pareto_mask_sorted_and_bcast_agree_under_heavy_ties():
    from repro.core.dse_batch import _pareto_mask_bcast, _pareto_mask_sorted
    rng = np.random.default_rng(19)
    for trial in range(20):
        n = int(rng.integers(1, 500))
        # coarse quantization forces many exact ties and duplicates
        perf = np.round(rng.uniform(0, 5, n), 1)
        energy = np.round(rng.uniform(0, 5, n), 1)
        a = _pareto_mask_bcast(perf, energy, chunk=64)
        b = _pareto_mask_sorted(perf, energy)
        assert np.array_equal(a, b), trial
        assert np.array_equal(a, _brute_mask(perf, energy)), trial


def test_pareto_mask_large_batch_uses_sorted_path():
    rng = np.random.default_rng(23)
    n = 5000                                   # above the dispatch cutoff
    perf = np.round(rng.uniform(0, 100, n), 0)
    energy = np.round(rng.uniform(0, 100, n), 0)
    from repro.core.dse_batch import _pareto_mask_bcast
    assert np.array_equal(pareto_mask(perf, energy),
                          _pareto_mask_bcast(perf, energy, chunk=1024))


def test_pareto_front_scalar_vs_vectorized_under_ties():
    # duplicate DSE points: scalar and vectorized fronts agree exactly
    res = explore(TINY_WL, SMALL_SPACE)
    doubled = res.points + res.points
    fv = pareto_front(doubled)
    fs = pareto_front_scalar(doubled)
    assert [p.config for p in fv] == [p.config for p in fs]
    assert len(fv) == 2 * len(pareto_front(res.points))
    assert pareto_front([]) == []
    assert pareto_front(res.points[:1]) == res.points[:1]


def test_synthesis_cache_hit_returns_identical_report():
    clear_synthesis_cache()
    cfg = AcceleratorConfig(pe_type=PEType.LIGHTPE1, glb_kb=256)
    first = synthesize_cached(cfg)
    again = synthesize_cached(cfg)
    assert again is first
    assert first == synthesize(cfg)
    stats = synthesis_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    # the batched path hits the same cache
    reps = synthesize_many([cfg, AcceleratorConfig()])
    assert reps[0] is first
    assert synthesis_cache_stats()["hits"] == 2


def test_synthesize_many_bitmatches_scalar():
    reps = synthesize_many(SMALL_SPACE, use_cache=False)
    for cfg, rep in zip(SMALL_SPACE, reps):
        assert rep == synthesize(cfg), cfg.name()


def test_synthesis_cache_lru_cap_and_eviction_counter():
    """Satellite: the in-process report cache is a bounded LRU with an
    eviction counter in synthesis_cache_stats()."""
    from repro.core.synthesis import set_synthesis_cache_limit
    clear_synthesis_cache()
    old = set_synthesis_cache_limit(8)
    try:
        cfgs = [AcceleratorConfig(glb_kb=16 * (i + 1)) for i in range(12)]
        synthesize_many(cfgs)
        stats = synthesis_cache_stats()
        assert stats["size"] == 8 and stats["limit"] == 8
        assert stats["evictions"] == 4
        # LRU: the 4 oldest were evicted, the newest 8 still hit
        first = synthesize_cached(cfgs[-1])
        assert synthesis_cache_stats()["hits"] == 1
        assert synthesize_cached(cfgs[0]) is not None     # miss, re-runs
        assert synthesis_cache_stats()["misses"] == 12 + 1
        assert synthesize_cached(cfgs[-1]) is first       # still resident
        # shrinking the cap evicts immediately
        set_synthesis_cache_limit(2)
        assert synthesis_cache_stats()["size"] == 2
    finally:
        set_synthesis_cache_limit(old)
        clear_synthesis_cache()


def test_config_hash_distinguishes_clock_cap():
    a = AcceleratorConfig()
    b = AcceleratorConfig(clock_ghz=0.5)
    assert a.name() == b.name()          # name ignores the clock cap...
    assert config_hash(a) != config_hash(b)  # ...the cache key must not


def test_explore_many_matches_individual_explores():
    wls = ("vgg16", "resnet34")
    many = explore_many(wls, SMALL_SPACE, backend="numpy")
    assert set(many) == set(wls)
    for wl in wls:
        single = explore(wl, SMALL_SPACE, backend="numpy")
        assert many[wl].headline_ratios() == single.headline_ratios()


def test_incremental_sweep_matches_oneshot():
    half = len(SMALL_SPACE) // 2
    inc = IncrementalSweep(TINY_WL, SMALL_SPACE[:half],
                           backend="numpy")
    assert len(inc) == half
    added = inc.extend(SMALL_SPACE)       # overlap: only the rest is new
    assert added == len(SMALL_SPACE) - half
    assert inc.extend(SMALL_SPACE) == 0   # fully deduped re-extend
    got = inc.result()
    ref = explore(TINY_WL, SMALL_SPACE, backend="numpy")
    assert len(got.points) == len(ref.points)
    by_cfg = {p.config: p for p in ref.points}
    for p in got.points:
        q = by_cfg[p.config]
        assert p.perf_per_area == q.perf_per_area
        assert p.energy_j == q.energy_j


def test_batched_view_aggregates_consistent_with_layers():
    res = explore(TINY_WL, SMALL_SPACE[:3], use_cache=False,
                  backend="numpy")
    for p in res.points:
        r = p.result
        assert r.total_macs == sum(l.macs for l in r.layers)
        assert r.total_cycles == sum(l.total_cycles for l in r.layers)
        assert r.energy_j == sum(l.energy_pj for l in r.layers) / 1e12
        assert len(r.layers) == len(TINY_WL.layers)


def test_explore_rejects_unknown_engine():
    with pytest.raises(ValueError):
        explore(TINY_WL, SMALL_SPACE, engine="quantum")
