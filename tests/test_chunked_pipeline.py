"""The double-buffered ``sweep_chunked`` pipeline (ISSUE 5 tentpole).

The two-stage overlap (synthesize chunk i+1 on the host while the kernel
maps chunk i) must be an invisible optimization: identical fronts,
identical chunk/config counts, identical resume points through the
persisted synthesis cache, and identical cache hit/miss accounting vs
the serial per-chunk loop — on every backend.
"""

import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dse_batch import sweep_chunked
from repro.core.pe import PEType
from repro.core.synthesis import PersistentSynthesisCache
from repro.core.workloads import get_workload

WL = get_workload("vgg16")
SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in tuple(PEType)
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (16, 16, 256, 12.8), (32, 32, 512, 25.6)]
]


def _backends(jax_usable):
    return ("numpy", "jax") if jax_usable else ("numpy",)


def _assert_same_sweep(a, b):
    assert a.n_configs == b.n_configs
    assert a.n_chunks == b.n_chunks
    assert a.front_size == b.front_size
    for m in a.front_metrics:
        assert np.array_equal(a.front_metrics[m], b.front_metrics[m]), m
    for k in a.front_soa:
        assert np.array_equal(a.front_soa[k], b.front_soa[k]), k


def test_overlap_matches_serial_all_backends(jax_usable):
    feed = SPACE * 7                              # several chunks + tail
    for backend in _backends(jax_usable):
        serial = sweep_chunked(WL, [feed], chunk_size=11, backend=backend,
                               overlap=False)
        pipe = sweep_chunked(WL, [feed], chunk_size=11, backend=backend,
                             overlap=True)
        _assert_same_sweep(serial, pipe)
        assert serial.timings["overlap"] is False
        assert pipe.timings["overlap"] is True
        for t in ("wall_s", "synth_s", "kernel_wait_s"):
            assert pipe.timings[t] >= 0.0


def test_overlap_with_generator_feed():
    """A lazy flat-config generator is pulled one chunk ahead at most —
    results must still match the serial eager evaluation."""
    def feed():
        for cfg in SPACE * 5:
            yield cfg
    serial = sweep_chunked(WL, [SPACE * 5], chunk_size=8, overlap=False,
                           backend="numpy")
    pipe = sweep_chunked(WL, feed(), chunk_size=8, overlap=True,
                         backend="numpy")
    _assert_same_sweep(serial, pipe)


def test_persistent_cache_accounting_identical(tmp_path, jax_usable):
    """Hit/miss accounting through the persisted cache is stream-ordered
    and must not depend on the overlap."""
    for backend in _backends(jax_usable):
        caches = {}
        for overlap in (False, True):
            cache = PersistentSynthesisCache(
                tmp_path / f"c_{backend}_{overlap}.npz")
            res = sweep_chunked(WL, [SPACE * 3], chunk_size=7,
                                backend=backend, overlap=overlap,
                                cache=cache)
            caches[overlap] = res.synthesis_cache
        for attr in ("hits", "misses"):
            assert getattr(caches[False], attr) \
                == getattr(caches[True], attr), (backend, attr)
        assert len(caches[False]) == len(caches[True])
        # a second pipelined sweep over the same space hits every row
        cache = caches[True]
        h0, n = cache.hits, len(SPACE) * 3
        sweep_chunked(WL, [SPACE * 3], chunk_size=7, backend=backend,
                      overlap=True, cache=cache)
        assert cache.hits == h0 + n


class _Boom(RuntimeError):
    pass


def test_midstream_interruption_and_resume(tmp_path):
    """A feed that dies mid-stream propagates the error (no hung worker
    thread), keeps the synthesized rows it already processed, and a
    resumed sweep over the remaining feed lands on the same front as the
    unbroken stream — identical resume-point semantics to the serial
    driver."""
    path = tmp_path / "resume.npz"
    chunks = [configs_to_soa(tuple(SPACE[i::4])) for i in range(4)]
    survived = 2

    def broken_feed():
        for i, ch in enumerate(chunks):
            if i == survived:
                raise _Boom("feed died")
            yield ch

    cache = PersistentSynthesisCache(path)
    with pytest.raises(_Boom):
        sweep_chunked(WL, broken_feed(), chunk_size=4, overlap=True,
                      cache=cache)
    n_seen = sum(len(c["pe_rows"]) for c in chunks[:survived])
    assert cache.misses == n_seen and cache.hits == 0
    assert len(cache) == len({  # unique digests actually synthesized
        k for i in range(survived)
        for k in _digests(chunks[i])})

    # resume: the interrupted run never reached save(), so persist now
    # (mirrors a driver checkpointing before retrying) and sweep the
    # remaining chunks through the on-disk rows
    cache.save()
    resumed = sweep_chunked(WL, chunks[survived:], chunk_size=4,
                            overlap=True, cache=str(path))
    assert resumed.synthesis_cache.hits == 0   # all-new configs
    # merged front of (interrupted + resumed halves) == unbroken stream
    first = sweep_chunked(WL, chunks[:survived], chunk_size=4,
                          overlap=True, cache=str(path))
    assert first.synthesis_cache.hits == n_seen   # re-run is all hits
    merged = sweep_chunked(
        WL, [configs_to_soa(tuple(first.front_configs()
                                  + resumed.front_configs()))],
        chunk_size=4, overlap=True, cache=str(path))
    one_shot = sweep_chunked(WL, chunks, chunk_size=4, overlap=False)
    assert set(merged.front_configs()) == set(one_shot.front_configs())


def _digests(soa):
    from repro.core.confighash import config_digests, digest_keys
    return digest_keys(config_digests(soa))


def test_jax_rejects_int_mesh(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    with pytest.raises(ValueError, match="jax.sharding.Mesh"):
        sweep_chunked(WL, [SPACE], backend="jax", mesh=2)


def test_empty_feed_still_returns_empty_front():
    res = sweep_chunked(WL, [], overlap=True, backend="numpy")
    assert res.n_configs == 0 and res.front_size == 0
    assert res.timings["overlap"] is True


# ---------------------------------------------------------------------------
# depth-k prefetch queue (ISSUE 9): the generalized pipeline must stay an
# invisible optimization at every depth, exactly like overlap=True at
# depth 2 — identical fronts and identical stream-ordered cache accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", (1, 2, 4))
def test_prefetch_depth_front_identity(depth, jax_usable):
    feed = SPACE * 7
    for backend in _backends(jax_usable):
        serial = sweep_chunked(WL, [feed], chunk_size=11, backend=backend,
                               overlap=False)
        pipe = sweep_chunked(WL, [feed], chunk_size=11, backend=backend,
                             overlap=True, prefetch_depth=depth)
        _assert_same_sweep(serial, pipe)
        assert pipe.timings["prefetch_depth"] == depth
        # overlap=False pins the effective depth to 1 regardless of the
        # requested prefetch_depth
        assert serial.timings["prefetch_depth"] == 1


@pytest.mark.parametrize("depth", (1, 2, 4))
def test_prefetch_depth_cache_accounting(tmp_path, depth, jax_usable):
    """Synthesis cache hit/miss counters are stream-ordered state; a
    deeper prefetch queue must not reorder or double-count them."""
    for backend in _backends(jax_usable):
        ref_cache = PersistentSynthesisCache(
            tmp_path / f"ref_{backend}_{depth}.npz")
        ref = sweep_chunked(WL, [SPACE * 3], chunk_size=7, backend=backend,
                            overlap=False, cache=ref_cache)
        cache = PersistentSynthesisCache(
            tmp_path / f"d_{backend}_{depth}.npz")
        res = sweep_chunked(WL, [SPACE * 3], chunk_size=7, backend=backend,
                            overlap=True, prefetch_depth=depth,
                            cache=cache)
        _assert_same_sweep(ref, res)
        for attr in ("hits", "misses"):
            assert getattr(cache, attr) == getattr(ref_cache, attr), \
                (backend, depth, attr)
        assert len(cache) == len(ref_cache)


def test_prefetch_depth_validation():
    with pytest.raises(ValueError, match="prefetch_depth"):
        sweep_chunked(WL, [SPACE], chunk_size=8, backend="numpy",
                      prefetch_depth=0)
