"""k-objective Pareto + hypervolume (ISSUE 3 satellite): duplicates,
ties, degenerate fronts, and property tests against the 2-D kernel."""

import numpy as np
import pytest

from repro.core.dse_batch import pareto_mask
from repro.explore.pareto import (crowding_distance, hypervolume,
                                  nondominated_sort, pareto_mask_k,
                                  reference_point)


def _brute_mask_k(F):
    n = len(F)
    return np.array([
        not any((F[q] <= F[i]).all() and (F[q] < F[i]).any()
                for q in range(n))
        for i in range(n)])


def test_pareto_mask_k_matches_brute_force_random():
    rng = np.random.default_rng(3)
    for k in (2, 3, 4, 5):
        for _ in range(5):
            n = int(rng.integers(1, 120))
            F = np.round(rng.uniform(0, 3, size=(n, k)), 1)  # force ties
            got = pareto_mask_k(F, chunk=16)
            assert np.array_equal(got, _brute_mask_k(F)), (k, n)


def test_pareto_mask_k_duplicates_all_survive():
    F = np.array([[1.0, 2.0, 3.0]] * 4 + [[2.0, 3.0, 4.0]])
    got = pareto_mask_k(F)
    assert got.tolist() == [True] * 4 + [False]


def test_pareto_mask_k_ties_on_some_axes():
    # equal in two objectives, strictly better in the third: dominates
    F = np.array([[1.0, 1.0, 1.0],
                  [1.0, 1.0, 2.0],
                  [0.5, 2.0, 2.0]])
    assert pareto_mask_k(F).tolist() == [True, False, True]


def test_pareto_mask_k_degenerate_fronts():
    # single point
    assert pareto_mask_k(np.array([[1.0, 2.0, 3.0]])).tolist() == [True]
    # empty
    assert pareto_mask_k(np.empty((0, 3))).shape == (0,)
    # all-dominated-but-one (a strictly dominating corner point)
    rng = np.random.default_rng(5)
    F = rng.uniform(1, 2, size=(50, 3))
    F = np.vstack([F, [[0.0, 0.0, 0.0]]])
    got = pareto_mask_k(F)
    assert got[-1] and got[:-1].sum() == 0
    # one objective: all minima survive (ties included)
    F1 = np.array([[2.0], [1.0], [1.0], [3.0]])
    assert pareto_mask_k(F1).tolist() == [False, True, True, False]


def test_pareto_mask_k2_delegates_bit_identical_to_2d_kernel():
    rng = np.random.default_rng(11)
    perf = np.round(rng.uniform(1, 50, 400), 0)
    energy = np.round(rng.uniform(0.1, 5, 400), 1)
    # 2-D minimization of (-perf, energy) == (max perf, min energy)
    got = pareto_mask_k(np.stack([-perf, energy], axis=-1))
    assert np.array_equal(got, pareto_mask(perf, energy))


def test_3obj_front_superset_of_2d_front():
    """Dropping an objective can only shrink the front: every point on the
    2-D front stays non-dominated when a third objective is added
    (distinct values; exact ties in both shared objectives can demote a
    2-D-front point in 3-D under strict-dominance semantics)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=60, deadline=None)
    @given(hnp.arrays(np.float64, (37, 3),
                      elements=hypothesis.strategies.floats(
                          0, 1e6, allow_nan=False),
                      unique=True))
    def check(F):
        mask2 = pareto_mask_k(F[:, :2])
        mask3 = pareto_mask_k(F)
        assert (mask3 | ~mask2).all()       # mask2 => mask3
        # and the 2-D restriction agrees with the production 2-D kernel
        assert np.array_equal(mask2, pareto_mask(-F[:, 0], F[:, 1]))

    check()


def test_nondominated_sort_ranks():
    F = np.array([[0.0, 0.0],       # front 0
                  [1.0, 1.0],       # front 1
                  [0.5, 2.0],       # dominated by [0,0] only -> front 1
                  [2.0, 2.0]])      # front 2
    assert nondominated_sort(F).tolist() == [0, 1, 1, 2]


def test_crowding_distance_boundaries_and_interior():
    F = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(F)
    assert np.isinf(d[0]) and np.isinf(d[-1])
    assert np.isfinite(d[1]) and np.isfinite(d[2])
    assert crowding_distance(F[:2]).tolist() == [np.inf, np.inf]


# ---------------------------------------------------------------------------
# hypervolume
# ---------------------------------------------------------------------------

def test_hypervolume_single_point_is_box_volume():
    ref = np.array([4.0, 5.0, 6.0])
    F = np.array([[1.0, 2.0, 3.0]])
    assert hypervolume(F, ref) == pytest.approx(3.0 * 3.0 * 3.0)


def test_hypervolume_clips_points_beyond_reference():
    ref = np.array([1.0, 1.0])
    F = np.array([[0.5, 0.5], [2.0, -1.0], [0.5, 0.5]])  # dup + outside
    assert hypervolume(F, ref) == pytest.approx(0.25)
    assert hypervolume(np.array([[2.0, 2.0]]), ref) == 0.0
    assert hypervolume(np.empty((0, 2)), ref) == 0.0


def test_hypervolume_union_of_two_boxes_2d_and_3d():
    ref2 = np.array([2.0, 2.0])
    F2 = np.array([[0.0, 1.0], [1.0, 0.0]])
    # union = 2*2 area of two 2x1 boxes overlapping in 1x1
    assert hypervolume(F2, ref2) == pytest.approx(2.0 + 2.0 - 1.0)
    ref3 = np.array([2.0, 2.0, 2.0])
    F3 = np.array([[0.0, 1.0, 0.0], [1.0, 0.0, 0.0]])
    assert hypervolume(F3, ref3) == pytest.approx((2 + 2 - 1) * 2.0)


def test_hypervolume_monotone_under_added_points():
    rng = np.random.default_rng(17)
    F = rng.uniform(0, 1, size=(30, 3))
    ref = np.full(3, 1.2)
    hv = hypervolume(F, ref)
    for _ in range(5):
        extra = rng.uniform(0, 1, size=(5, 3))
        hv2 = hypervolume(np.vstack([F, extra]), ref)
        assert hv2 >= hv - 1e-12
        F, hv = np.vstack([F, extra]), hv2


def test_hypervolume_3d_matches_monte_carlo():
    rng = np.random.default_rng(23)
    F = rng.uniform(0, 1, size=(12, 3))
    ref = np.full(3, 1.0)
    hv = hypervolume(F, ref)
    pts = rng.uniform(0, 1, size=(200_000, 3))
    dominated = ((pts[:, None, :] >= F[None, :, :]).all(-1)).any(1)
    mc = dominated.mean()
    assert hv == pytest.approx(mc, abs=5e-3)


def test_hypervolume_dimension_mismatch_raises():
    with pytest.raises(ValueError, match="reference point"):
        hypervolume(np.zeros((3, 2)), np.zeros(3))


def test_reference_point_bounds_all_points():
    rng = np.random.default_rng(29)
    F = rng.normal(size=(40, 4))
    ref = reference_point(F)
    assert (F < ref[None, :]).all()
    assert hypervolume(F, ref) > 0
