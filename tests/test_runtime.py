"""Fault tolerance: restart-equivalence, failure injection, stragglers,
elastic re-meshing, gradient compression error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import compression
from repro.runtime.elastic import reshard, survivable_mesh
from repro.runtime.fault_tolerance import (StragglerDetector,
                                           run_with_restarts)


def _toy_problem():
    """Tiny quadratic 'training': state = {'w', 'step'}."""
    target = jnp.arange(4.0)

    def init_state():
        return {"w": jnp.zeros(4), "step": jnp.int32(0)}

    def train_step(state, batch):
        w = state["w"]
        grad = 2 * (w - target) + batch["noise"]
        w = w - 0.1 * grad
        loss = jnp.sum((w - target) ** 2)
        return {"w": w, "step": state["step"] + 1}, loss

    def data_batch(step):
        return {"noise": 0.01 * jnp.sin(jnp.float32(step))}

    return init_state, train_step, data_batch


def test_restart_bitwise_equals_uninterrupted(tmp_path):
    init_state, step_fn, data = _toy_problem()
    clean = run_with_restarts(
        init_state=init_state, train_step=step_fn, data_batch=data,
        total_steps=30, ckpt_dir=str(tmp_path / "clean"), ckpt_every=5)
    failed = run_with_restarts(
        init_state=init_state, train_step=step_fn, data_batch=data,
        total_steps=30, ckpt_dir=str(tmp_path / "faulty"), ckpt_every=5,
        fail_at={12: 1, 23: 2})
    assert failed.restarts == 3
    # the final losses agree exactly (deterministic replay from ckpt)
    assert clean.losses[-1][0] == failed.losses[-1][0] == 29
    assert np.isclose(clean.losses[-1][1], failed.losses[-1][1],
                      rtol=0, atol=0)


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(alpha=0.3, threshold=3.0)
    for _ in range(20):
        det.observe(0.10 + np.random.default_rng(0).normal() * 0.0)
    assert det.observe(1.5) is True
    assert det.flagged >= 1


def test_elastic_reshard_roundtrip():
    devs = jax.devices()
    mesh = survivable_mesh(devs, prefer_model=1)
    tree = {"layers": {"wq": jnp.ones((8, 16))}, "embed": jnp.ones((4, 8))}
    out = reshard(tree, mesh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_error_feedback_unbiased():
    """Accumulated compressed grads converge to accumulated raw grads."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)}
    err = compression.init_error_state(grads)
    total_c = jnp.zeros((16, 16))
    steps = 40
    for _ in range(steps):
        dq, err = compression.compress_roundtrip(grads, err)
        total_c = total_c + dq["w"]
    total_raw = grads["w"] * steps
    rel = float(jnp.linalg.norm(total_c - total_raw)
                / jnp.linalg.norm(total_raw))
    # error feedback keeps the *cumulative* bias bounded by one step's
    # quantization error -> relative error shrinks like 1/steps
    assert rel < 0.02, rel


def test_grad_compression_single_step_error_bounded():
    g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
    err = compression.init_error_state(g)
    dq, err2 = compression.compress_roundtrip(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(dq["w"] - g["w"]))) <= scale / 2 + 1e-6
    # residual == what was lost
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - dq["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
# straggler re-baselining + generic restart loop (ISSUE 7 satellites)
# ---------------------------------------------------------------------------

def test_straggler_rebaseline_adopts_permanent_shift():
    """A permanent slowdown (e.g. migrated to slower hardware after a
    resume) is flagged only rebaseline_after times, then adopted as the
    new normal instead of flagging every step forever."""
    det = StragglerDetector(alpha=0.3, threshold=3.0, rebaseline_after=8)
    for _ in range(20):
        det.observe(0.10)
    flags = sum(det.observe(1.5) for _ in range(30))
    assert det.rebaselines == 1
    assert flags == det.rebaseline_after        # then silence
    assert det.consecutive_flags == 0
    # and the *new* regime's outliers are flagged again after warm-up
    for _ in range(10):
        det.observe(1.5)
    assert det.observe(15.0) is True


def test_restart_loop_retryable_set_and_counts():
    from repro.runtime.fault_tolerance import restart_loop

    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] <= 2:
            raise TimeoutError("transient")
        return "done"

    restarts, out = restart_loop(flaky, retryable=(TimeoutError,))
    assert (restarts, out) == (2, "done")

    def poisoned():
        raise ValueError("permanent")

    with pytest.raises(ValueError):             # outside the retryable set
        restart_loop(poisoned, retryable=(TimeoutError,))
    with pytest.raises(TimeoutError):           # budget exhausted
        restart_loop(lambda: (_ for _ in ()).throw(TimeoutError()),
                     max_restarts=3, retryable=(TimeoutError,))


def test_restart_loop_exponential_backoff(monkeypatch):
    from repro.runtime import fault_tolerance as ft

    sleeps = []
    monkeypatch.setattr(ft.time, "sleep", sleeps.append)
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] <= 4:
            raise TimeoutError
        return attempts["n"]

    restarts, _ = ft.restart_loop(flaky, retryable=(TimeoutError,),
                                  backoff_s=0.1, backoff_factor=2.0,
                                  max_backoff_s=0.3)
    assert restarts == 4
    np.testing.assert_allclose(sleeps, [0.1, 0.2, 0.3, 0.3])  # capped


def test_run_with_restarts_custom_retryable(tmp_path):
    """The training driver restarts from checkpoint on a user-chosen
    exception class, not just InjectedFailure."""
    init_state, step_fn, data = _toy_problem()
    tripped = {"done": False}

    def step_with_io_error(state, batch):
        if int(state["step"]) == 12 and not tripped["done"]:
            tripped["done"] = True
            raise OSError("nfs hiccup")
        return step_fn(state, batch)

    clean = run_with_restarts(
        init_state=init_state, train_step=step_fn, data_batch=data,
        total_steps=30, ckpt_dir=str(tmp_path / "clean"), ckpt_every=5)
    faulty = run_with_restarts(
        init_state=init_state, train_step=step_with_io_error,
        data_batch=data, total_steps=30,
        ckpt_dir=str(tmp_path / "faulty"), ckpt_every=5,
        retryable=(OSError,))
    assert faulty.restarts == 1
    assert np.isclose(clean.losses[-1][1], faulty.losses[-1][1],
                      rtol=0, atol=0)
