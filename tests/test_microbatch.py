"""Gradient-accumulation microbatching == full-batch gradients.

The dry-run's --microbatch path (HBM fit for 95/100-layer train cells,
EXPERIMENTS.md §Perf cell E) relies on the loss being a per-token mean:
mean of micro-gradients == full-batch gradient.  Verified here at smoke
scale with the same accumulation structure the launcher lowers.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model


def test_microbatch_grads_match_full_batch():
    cfg = reduced(get_config("phi4-mini-3.8b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, s, mb = 8, 16, 4
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    loss_full, g_full = jax.value_and_grad(model.loss)(params, batch)

    def split(x):
        return x.reshape(mb, b // mb, *x.shape[1:])
    mbatch = jax.tree.map(split, batch)

    def acc_step(carry, micro):
        gsum, lsum = carry
        l, g = jax.value_and_grad(model.loss)(params, micro)
        return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gacc, lacc), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), mbatch)
    gacc = jax.tree.map(lambda g: g / mb, gacc)
    lacc = lacc / mb

    assert abs(float(lacc) - float(loss_full)) < 2e-3
    errs = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a - b_.astype(jnp.float32)))),
        gacc, g_full)
    gmax = max(float(jnp.max(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree.leaves(g_full))
    assert max(jax.tree.leaves(errs)) < 2e-2 * max(gmax, 1.0), \
        sorted(jax.tree.leaves(errs))[-3:]
