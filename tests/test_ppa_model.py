"""The paper's PPA models (Fig. 2): polynomial + k-fold CV fits."""

import numpy as np
import pytest

from repro.core.accelerator import design_space
from repro.core.pe import PEType
from repro.core.ppa_model import (TARGETS, fit_poly_model, fit_ppa_suite,
                                  kfold_indices, poly_expand)
from repro.core.synthesis import synthesize


@pytest.fixture(scope="module")
def suite_stats():
    cfgs_by = {t: [c for c in design_space() if c.pe_type == t]
               for t in PEType}
    return fit_ppa_suite(cfgs_by)


def test_fig2_high_correlation(suite_stats):
    """Fig. 2: 'the proposed polynomial model agrees closely with the
    actual values extracted from the synthesis tools'."""
    _, stats = suite_stats
    for key, s in stats.items():
        assert s["r2"] > 0.97, (key, s)
        assert s["mape"] < 0.10, (key, s)


def test_model_selection_picks_valid_degree(suite_stats):
    suite, stats = suite_stats
    for key, s in stats.items():
        assert s["degree"] in (1, 2, 3)


def test_predict_unseen_config(suite_stats):
    suite, _ = suite_stats
    from repro.core.accelerator import AcceleratorConfig
    # interpolation (inside the sweep's hull); extrapolating num_pes far
    # outside the grid degrades throughput accuracy (documented limit)
    cfg = AcceleratorConfig(pe_type=PEType.LIGHTPE1, pe_rows=12, pe_cols=16,
                            glb_kb=192, dram_bw_gbps=10.0)
    pred = suite.predict(cfg)
    true = synthesize(cfg).as_dict()
    for t in TARGETS:
        rel = abs(pred[t] - true[t]) / true[t]
        assert rel < 0.25, (t, pred[t], true[t])


def test_predict_batch_matches_per_config(suite_stats):
    suite, _ = suite_stats
    from repro.core.accelerator import AcceleratorConfig
    mixed = [AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=r)
             for r in (8, 16) for t in PEType]
    batch = suite.predict_batch(mixed)
    for i, cfg in enumerate(mixed):
        single = suite.predict(cfg)
        for t in TARGETS:
            assert batch[t][i] == pytest.approx(single[t], rel=1e-12), (i, t)


def test_poly_expand_shapes():
    x = np.random.default_rng(0).standard_normal((10, 3))
    phi1 = poly_expand(x, 1)
    assert phi1.shape == (10, 4)
    phi2 = poly_expand(x, 2)
    assert phi2.shape == (10, 1 + 3 + 6)


def test_kfold_covers_everything():
    seen = set()
    for tr, va in kfold_indices(23, 5):
        assert set(tr) & set(va) == set()
        seen |= set(va)
    assert seen == set(range(23))


def test_fit_poly_model_recovers_polynomial():
    rng = np.random.default_rng(1)
    from repro.core.accelerator import AcceleratorConfig
    cfgs = [AcceleratorConfig(pe_rows=r, pe_cols=c, glb_kb=g)
            for r in (8, 12, 16, 24) for c in (8, 14, 16) for g in (64, 256)]
    y = np.array([c.num_pes ** 2 * 1e-4 + c.glb_kb for c in cfgs])
    m = fit_poly_model(cfgs, y, log_target=False)
    pred = m.predict(cfgs)
    assert np.corrcoef(pred, y)[0, 1] > 0.999
