"""Attention paths: chunked == dense, GQA decode == full recompute."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import attention as attn
from repro.models.model import Model


def _qkv(key, b, s, h, d):
    ks = jax.random.split(jax.random.key(key), 3)
    return tuple(jax.random.normal(k, (b, s, h, d)) for k in ks)


@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_dense(causal):
    q, k, v = _qkv(0, 2, 64, 4, 16)
    dense = attn.dense_attention(q, k, v, causal=causal)
    chunk = attn.chunked_attention(q, k, v, causal=causal, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_chunked_window_matches_dense():
    q, k, v = _qkv(1, 2, 64, 4, 16)
    dense = attn.dense_attention(q, k, v, causal=True, window=24)
    chunk = attn.chunked_attention(q, k, v, causal=True, window=24,
                                   bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_chunked_nondivisible_ctx():
    """Cross-attn shapes (e.g. 1601 image tokens) must not need padding."""
    q, _, _ = _qkv(2, 1, 64, 2, 16)
    _, k, v = _qkv(3, 1, 37, 2, 16)   # 37 is prime
    dense = attn.dense_attention(q, k, v, causal=False)
    chunk = attn.chunked_attention(q, k, v, causal=False, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["starcoder2-7b", "gemma3-4b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode equals the full forward at every position —
    covers GQA, RoPE positions, KV caching, and window masks."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    s = 12
    toks = jax.random.randint(jax.random.key(1), (2, s), 0, cfg.vocab)
    full_logits, _ = model.forward(params, toks, train=False)
    caches = model.init_cache(2, s)
    outs = []
    for i in range(s):
        logits, caches = model.decode_step(params, caches, toks[:, i:i + 1],
                                           jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_gqa_broadcast():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    out = attn._broadcast_kv(k, 6)
    assert out.shape == (2, 4, 6, 3)
    np.testing.assert_array_equal(np.asarray(out[:, :, 0]),
                                  np.asarray(out[:, :, 2]))
    np.testing.assert_array_equal(np.asarray(out[:, :, 3]),
                                  np.asarray(out[:, :, 5]))


def test_block_size_divisors():
    assert attn._block_size(4096, 512) == 512
    assert attn._block_size(1601, 512) == 1601   # prime -> single block
    assert attn._block_size(96, 512) == 96
    assert attn._block_size(1500, 512) == 500
