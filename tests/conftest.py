import os

import numpy as np
import pytest

# Tests run on the single real CPU device; the 512-device XLA flag is set
# ONLY inside launch/dryrun.py (see system design).  Guard against leakage.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dry-run XLA_FLAGS must not leak into the test environment"

os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# Cross-backend differential harness (ISSUE 4 satellite)
#
# The sweep/explore stack's contract is layered: the batched *numpy* path
# is bit-exact against the scalar reference, and the *jax* path agrees
# with numpy to 1e-6 relative.  `cross_backend_check` packages that
# three-way comparison so every kernel entry point (sweep_mixed,
# sweep_mixed_many, sweep_chunked, ...) asserts the same contract through
# one fixture instead of hand-rolled copies.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def jax_usable() -> bool:
    from repro.core.dse_batch import resolve_backend
    try:
        resolve_backend("jax")
        return True
    except RuntimeError:
        return False


@pytest.fixture
def cross_backend_check(jax_usable):
    """Run one batch through scalar / numpy / jax and assert the parity
    contract.

    Usage::

        out = cross_backend_check(
            run=lambda backend: <dict of column -> array>,
            scalar=<dict of column -> array from the scalar reference>,
            bit_keys=(...),     # scalar vs numpy: np.array_equal
            ratio_keys=(...),   # numpy vs jax: |b/a - 1| < rtol
        )

    ``run`` is called with ``backend="numpy"`` and (when jax is usable)
    ``backend="jax"``.  ``scalar`` / ``bit_keys`` may be omitted for
    paths with no scalar reference.  Returns the numpy outputs so callers
    can make extra assertions.  If jax is unusable the jax leg is skipped
    (CI always runs it).
    """
    def check(run, scalar=None, bit_keys=(), ratio_keys=None,
              rtol=1e-6):
        out_np = run("numpy")
        if scalar is not None:
            for k in bit_keys:
                a = np.asarray(scalar[k])
                b = np.asarray(out_np[k])
                assert a.shape == b.shape, \
                    f"scalar vs numpy shape mismatch for {k!r}"
                assert np.array_equal(a, b), \
                    f"scalar vs numpy not bit-identical for {k!r}"
        if jax_usable:
            out_j = run("jax")
            for k in (bit_keys if ratio_keys is None else ratio_keys):
                a = np.asarray(out_np[k], dtype=np.float64)
                b = np.asarray(out_j[k], dtype=np.float64)
                assert a.shape == b.shape, \
                    f"numpy vs jax shape mismatch for {k!r}"
                # where both backends agree on exactly 0, parity holds;
                # |b/denom - 1| would spuriously report 1.0 there
                both_zero = (a == 0) & (b == 0)
                denom = np.where(a == 0, 1.0, a)
                rel = (np.max(np.where(both_zero, 0.0,
                                       np.abs(b / denom - 1.0)))
                       if a.size else 0.0)
                assert rel < rtol, \
                    f"numpy vs jax relative error {rel:.3g} >= {rtol} " \
                    f"for {k!r}"
        return out_np
    return check
