import os

# Tests run on the single real CPU device; the 512-device XLA flag is set
# ONLY inside launch/dryrun.py (see system design).  Guard against leakage.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dry-run XLA_FLAGS must not leak into the test environment"

os.environ.setdefault("JAX_PLATFORMS", "cpu")
