"""Logical sharding rules: divisibility fallbacks + tree construction."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import compat_make_mesh
from repro.parallel.sharding import (activation_sharding,
                                     default_activation_rules, param_pspec,
                                     shard, tree_pspecs)


class FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


MESH = FakeMesh({"data": 16, "model": 16})


def test_tp_spec_for_attention_proj():
    spec = param_pspec("layers/wq", (48, 8192, 8192), True, MESH)
    assert spec == P(None, "data", "model")


def test_vocab_divisibility_fallback():
    # mamba2 vocab 50280 is not divisible by 16 -> fsdp-shard d instead
    spec = param_pspec("embed", (50280, 768), False, MESH)
    assert spec == P(None, "data")
    spec2 = param_pspec("embed", (163840, 2048), False, MESH)
    assert spec2 == P("model", "data")


def test_expert_parallel_spec():
    spec = param_pspec("layers/w_experts_in", (48, 64, 2048, 1408), True,
                       MESH)
    assert spec == P(None, "model", "data", None)


def test_small_params_replicated():
    assert param_pspec("layers/ln1", (48, 2048), True, MESH) == P(None, None)
    assert param_pspec("final_norm", (2048,), False, MESH) == P(None)


def test_nondivisible_inner_dim_dropped():
    # in_proj inner dim 3352 % 16 != 0 -> only fsdp axis survives
    spec = param_pspec("layers/in_proj", (24, 768, 3352), True, MESH)
    assert spec == P(None, "data", None)


def test_tree_pspecs_structure():
    params = {"embed": jnp.zeros((256, 64)),
              "layers": {"wq": jnp.zeros((2, 64, 64)),
                         "ln1": jnp.zeros((2, 64))}}
    specs = tree_pspecs(params, None)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(params)


def test_activation_sharding_context_noop_outside():
    x = jnp.ones((4, 4))
    # outside the context: identity
    np.testing.assert_array_equal(np.asarray(shard(x, "residual")),
                                  np.asarray(x))


def test_activation_sharding_applies_inside():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rules = default_activation_rules(mesh, seq_sharded=True)

    def f(x):
        with activation_sharding(mesh, rules):
            return shard(x, "residual") * 2
    with mesh:
        out = jax.jit(f)(jnp.ones((2, 4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((2, 4, 8)))


def test_default_rules_shapes():
    mesh = compat_make_mesh((1,), ("data",))
    rules = default_activation_rules(mesh, seq_sharded=False)
    assert "residual" in rules and "moe_buffer" in rules
