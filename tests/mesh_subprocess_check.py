"""Subprocess body for the forced-multi-device sharding tests.

Run as ``python mesh_subprocess_check.py <n_configs>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` in the
environment (the parent test sets it; the flag must be in place before
jax imports, which is why this is a subprocess and not a fixture — see
the no-leak assertion in ``tests/conftest.py``).  Prints one JSON object
with the device count, the numpy sharded-vs-unsharded bit-equality, and
the jax sharded-vs-(unsharded numpy / unsharded jax) max relative
errors.  Not collected by pytest (no ``test_`` prefix).
"""

import json
import os
import sys


def main() -> None:
    assert "xla_force_host_platform_device_count" in \
        os.environ.get("XLA_FLAGS", ""), "caller must force host devices"
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 30

    import jax
    import numpy as np

    from repro.core.accelerator import AcceleratorConfig, configs_to_soa
    from repro.core.dse_batch import sweep_mixed_many
    from repro.core.pe import PEType, supported_modes
    from repro.core.workloads import get_workload
    from repro.launch.mesh import make_sweep_mesh

    types = tuple(PEType)
    wls = (get_workload("vgg16"), get_workload("resnet34"))
    rng = np.random.default_rng(1234)
    space = [AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                               dram_bw_gbps=bw)
             for t in types
             for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                                   (32, 32, 512, 25.6)]]
    configs = [space[i] for i in rng.integers(0, len(space), size=n)]
    soa = configs_to_soa(configs)
    assigns = []
    for w in wls:
        a = np.empty((n, len(w.layers)), dtype=np.int64)
        for i, c in enumerate(configs):
            modes = [types.index(m) for m in supported_modes(c.pe_type)]
            a[i] = rng.choice(modes, size=len(w.layers))
        assigns.append(a)

    keys = ("latency_s", "energy_j", "perf_per_area", "throughput_gmacs")

    def max_rel(a: dict, b: dict) -> float:
        worst = 0.0
        for k in keys:
            x = np.asarray(a[k], dtype=np.float64)
            y = np.asarray(b[k], dtype=np.float64)
            both_zero = (x == 0) & (y == 0)
            denom = np.where(x == 0, 1.0, x)
            worst = max(worst, float(np.max(np.where(
                both_zero, 0.0, np.abs(y / denom - 1.0)))))
        return worst

    un_np = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                             use_cache=False)
    sh_np = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                             use_cache=False, mesh=jax.device_count())
    mesh = make_sweep_mesh()
    un_j = sweep_mixed_many(wls, soa, assigns, backend="jax",
                            use_cache=False)
    sh_j = sweep_mixed_many(wls, soa, assigns, backend="jax",
                            use_cache=False, mesh=mesh)

    print(json.dumps({
        "n_configs": n,
        "device_count": jax.device_count(),
        "numpy_sharded_bit_exact": bool(all(
            np.array_equal(un_np[k], sh_np[k]) for k in un_np)),
        "jax_sharded_vs_numpy_max_rel": max_rel(un_np, sh_j),
        "jax_sharded_vs_unsharded_max_rel": max_rel(un_j, sh_j),
    }))


if __name__ == "__main__":
    main()
