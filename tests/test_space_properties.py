"""Property-based closure tests for the co-exploration genome spaces
(ISSUE 4 satellite).

Any sequence of sample / mutate / crossover / repair operations must stay
inside the space: every produced genome decodes to compatible (hardware,
mode) pairs, and every genome round-trips through pack/unpack
bit-identically — for single-workload `CoExploreSpace` and the ragged
multi-workload `CoExploreManySpace` alike.  Requires `hypothesis`
(skipped when absent; CI installs it).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pe import mode_compat_matrix  # noqa: E402
from repro.explore.space import (CoExploreManySpace,  # noqa: E402
                                 CoExploreSpace, N_HW_GENES)

MAX_EXAMPLES = 60


def _space(layer_counts):
    if len(layer_counts) == 1:
        return CoExploreSpace(n_layers=layer_counts[0])
    return CoExploreManySpace(n_layers=sum(layer_counts),
                              layer_counts=tuple(layer_counts))


spaces = st.lists(st.integers(min_value=1, max_value=9),
                  min_size=1, max_size=4).map(_space)
# an op sequence: (op, seed) pairs applied in order
ops = st.lists(st.tuples(st.sampled_from(["mutate", "crossover",
                                          "repair", "resample"]),
                         st.integers(0, 2 ** 31 - 1)),
               min_size=0, max_size=6)


def _assert_closed(space, g):
    """The closure invariant: valid levels, executable modes, decode
    consistency."""
    assert space.valid_mask(g).all()
    soa, assign = space.decode(g)
    assert assign.shape == (len(g), space.n_layers)
    compat = mode_compat_matrix()
    hw = soa["pe_type_idx"]
    assert compat[hw[:, None], assign].all()
    if isinstance(space, CoExploreManySpace):
        parts = space.split_assign(assign)
        assert [p.shape[1] for p in parts] == list(space.layer_counts)
        assert np.array_equal(np.concatenate(parts, axis=1), assign)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(space=spaces, seed=st.integers(0, 2 ** 31 - 1), ops=ops,
       n=st.integers(2, 24), rate=st.floats(0.0, 1.0))
def test_op_sequences_stay_closed(space, seed, ops, n, rate):
    rng = np.random.default_rng(seed)
    g = space.random_population(n, rng)
    _assert_closed(space, g)
    for op, op_seed in ops:
        op_rng = np.random.default_rng(op_seed)
        if op == "mutate":
            g = space.mutate(g, op_rng, rate=rate)
        elif op == "crossover":
            other = space.random_population(len(g), op_rng)
            g = space.crossover(g, other, op_rng)
        elif op == "repair":
            g = space.repair(g)
        else:                                   # resample a fresh batch
            g = space.random_population(len(g), op_rng)
        _assert_closed(space, g)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(space=spaces, seed=st.integers(0, 2 ** 31 - 1),
       n=st.integers(1, 32))
def test_pack_unpack_round_trips_bit_identically(space, seed, n):
    g = space.random_population(n, np.random.default_rng(seed))
    packed = space.pack_genomes(g)
    assert packed.dtype == np.uint16
    assert packed.shape == g.shape
    back = space.unpack_genomes(packed)
    assert back.dtype == g.dtype == np.int64
    assert np.array_equal(back, g)
    # digests (the memo identity) survive the round trip too
    assert space.genome_keys(back) == space.genome_keys(g)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(space=spaces, seed=st.integers(0, 2 ** 31 - 1),
       rate=st.floats(0.0, 1.0))
def test_repair_is_idempotent_and_preserves_valid_genomes(space, seed,
                                                          rate):
    rng = np.random.default_rng(seed)
    g = space.random_population(8, rng)
    assert np.array_equal(space.repair(g), g)   # valid input untouched
    mut = space.mutate(g, rng, rate=rate)
    assert np.array_equal(space.repair(mut), mut)  # mutate ends repaired


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       counts=st.lists(st.integers(1, 6), min_size=2, max_size=4))
def test_many_space_digests_fold_segment_boundaries(seed, counts):
    """Same flat genome, different workload boundaries => different
    digests (the memo must never alias two packings)."""
    hypothesis.assume(tuple(counts) != tuple(reversed(counts)))
    a = CoExploreManySpace(n_layers=sum(counts),
                           layer_counts=tuple(counts))
    b = CoExploreManySpace(n_layers=sum(counts),
                           layer_counts=tuple(reversed(counts)))
    g = a.random_population(4, np.random.default_rng(seed))
    assert a.genome_keys(g) != b.genome_keys(g)


def test_unpack_rejects_corrupted_archives():
    space = CoExploreManySpace(n_layers=5, layer_counts=(2, 3))
    g = space.random_population(4, np.random.default_rng(0))
    packed = space.pack_genomes(g)
    bad = packed.copy()
    bad[0, 0] = 2 ** 15                         # absurd factor level
    with pytest.raises(ValueError, match="invalid genome"):
        space.unpack_genomes(bad)
    with pytest.raises(ValueError, match="genome matrix shape"):
        space.unpack_genomes(packed[:, :-1])
