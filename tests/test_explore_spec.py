"""ExploreSpec / dse.run() facade: parity against every legacy entry
point (bit-identical under numpy, <=1e-6 under jax), deprecation shims,
spec validation, and the serving-objective plumbing (ISSUE 6 satellites
1-3)."""

import warnings

import numpy as np
import pytest

from repro.core import dse
from repro.core.accelerator import design_space
from repro.core.dse import ExploreSpec, run
from repro.core.workloads import get_workload

CFGS = tuple(design_space())[:24]


def _silently(fn, *args, **kwargs):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kwargs)


def _points_equal(a, b):
    assert len(a.points) == len(b.points)
    for pa, pb in zip(a.points, b.points):
        assert pa.config == pb.config
        assert pa.result.energy_j == pb.result.energy_j
        assert pa.result.perf_per_area == pb.result.perf_per_area


# ---------------------------------------------------------------------------
# every legacy entry point warns, and its run() equivalent is bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("call", [
    lambda: dse.explore("vgg16", CFGS[:4], backend="numpy"),
    lambda: dse.explore_scalar("vgg16", CFGS[:2]),
    lambda: dse.explore_many(["vgg16"], CFGS[:4], backend="numpy"),
    lambda: dse.explore_chunked("vgg16", CFGS[:8], chunk_size=4,
                                backend="numpy"),
])
def test_legacy_dse_names_warn(call):
    with pytest.warns(DeprecationWarning, match="deprecated.*ExploreSpec"):
        call()


def test_legacy_sweep_names_warn():
    from repro.core import dse_batch
    wl = get_workload("vgg16")
    with pytest.warns(DeprecationWarning, match="deprecated"):
        dse_batch.sweep_workload(wl, CFGS[:4], backend="numpy")
    with pytest.warns(DeprecationWarning, match="deprecated"):
        dse_batch.sweep_chunked(wl, CFGS[:8], chunk_size=4,
                                backend="numpy")


def test_single_parity_with_explore():
    old = _silently(dse.explore, "vgg16", CFGS, backend="numpy")
    new = run(ExploreSpec.single("vgg16", CFGS, backend="numpy"))
    _points_equal(old, new)


def test_single_scalar_engine_parity():
    old = _silently(dse.explore_scalar, "vgg16", CFGS[:4])
    new = run(ExploreSpec.single("vgg16", CFGS[:4], engine="scalar",
                                 use_cache=False))
    _points_equal(old, new)


def test_single_outputs_modes():
    sw = run(ExploreSpec.single("vgg16", CFGS, backend="numpy",
                                outputs="sweep"))
    ag = run(ExploreSpec.single("vgg16", CFGS, backend="numpy",
                                outputs="aggregates"))
    pts = run(ExploreSpec.single("vgg16", CFGS, backend="numpy"))
    assert np.array_equal(sw.arrays["energy_j"], ag.arrays["energy_j"])
    assert ag.arrays["energy_j"][0] == pts.points[0].result.energy_j


def test_many_parity_with_explore_many():
    old = _silently(dse.explore_many, ["vgg16", "resnet34"], CFGS,
                    backend="numpy")
    new = run(ExploreSpec.many(["vgg16", "resnet34"], configs=CFGS,
                               backend="numpy"))
    assert sorted(old) == sorted(new)
    for k in old:
        _points_equal(old[k], new[k])


def test_chunked_parity_with_explore_chunked():
    old = _silently(dse.explore_chunked, "vgg16", CFGS, chunk_size=8,
                    backend="numpy")
    new = run(ExploreSpec.single("vgg16", CFGS, chunk_size=8,
                                 backend="numpy", use_cache=False))
    assert old.n_configs == new.n_configs
    assert np.array_equal(np.sort(old.front_metrics["energy_j"]),
                          np.sort(new.front_metrics["energy_j"]))


def test_mixed_parity_with_coexplore():
    old = _silently(dse.coexplore, "vgg16", preset="quick", seed=7,
                    backend="numpy", budget=64)
    new = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                                backend="numpy", budget=64))
    assert np.array_equal(old.front_objectives, new.front_objectives)
    assert np.array_equal(old.genomes, new.genomes)
    assert old.objectives == new.objectives


def test_many_mixed_parity_with_coexplore_many():
    old = _silently(dse.coexplore_many, ["vgg16", "resnet34"],
                    preset="many-quick", seed=3, backend="numpy",
                    budget=64)
    new = run(ExploreSpec.many(["vgg16", "resnet34"], precision="mixed",
                               preset="many-quick", seed=3,
                               backend="numpy", budget=64))
    assert np.array_equal(old.front_objectives, new.front_objectives)
    assert np.array_equal(old.genomes, new.genomes)


def test_jax_front_parity(jax_usable):
    """Facade under jax matches numpy to the backend contract (<=1e-6)."""
    if not jax_usable:
        pytest.skip("jax backend unusable")
    a = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                              backend="numpy", budget=64))
    b = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                              backend="jax", budget=64))
    # identical search trajectory -> same genome set; objectives to 1e-6
    assert np.array_equal(a.genomes, b.genomes)
    denom = np.where(a.front_objectives == 0, 1.0, a.front_objectives)
    rel = np.abs(b.front_objectives / denom - 1.0)
    assert rel.max() < 1e-6


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_spec_rejects_contradictions():
    with pytest.raises(ValueError, match="at least one workload"):
        ExploreSpec(workloads=())
    with pytest.raises(ValueError, match="precision"):
        ExploreSpec(workloads=("vgg16",), precision="both")
    with pytest.raises(ValueError, match="outputs"):
        ExploreSpec.single("vgg16", outputs="everything")
    with pytest.raises(ValueError, match="engine"):
        ExploreSpec.single("vgg16", engine="warp")
    with pytest.raises(ValueError, match="search knob"):
        ExploreSpec(workloads=("vgg16",), precision="uniform",
                    budget=128)
    with pytest.raises(ValueError, match="sweep knob"):
        ExploreSpec(workloads=("vgg16",), precision="mixed",
                    configs=CFGS[:2])
    with pytest.raises(ValueError, match="single"):
        ExploreSpec.many(["vgg16", "resnet34"], chunk_size=8)
    with pytest.raises(ValueError, match="scalar"):
        ExploreSpec.single("vgg16", engine="scalar", outputs="sweep")
    with pytest.raises(ValueError, match="search kwarg"):
        ExploreSpec.many(["vgg16", "resnet34"], pop_size=8)
    with pytest.raises(ValueError, match=">= 2 workloads"):
        ExploreSpec.mixed("vgg16").__class__(
            workloads=("vgg16",), precision="mixed", weights=(1.0,))
    with pytest.raises(TypeError, match="ExploreSpec"):
        run("vgg16")


def test_spec_chunked_needs_explicit_feed():
    with pytest.raises(ValueError, match="explicit config feed"):
        run(ExploreSpec.single("vgg16", chunk_size=8))


def test_spec_chunked_feed_stays_lazy():
    """A chunk-streamed generator feed must not be materialized at spec
    construction — bounded memory is the whole point."""
    pulled = []

    def feed():
        for c in CFGS:
            pulled.append(c)
            yield c

    spec = ExploreSpec.single("vgg16", feed(), chunk_size=8,
                              backend="numpy", use_cache=False)
    assert pulled == []                    # untouched until run()
    res = run(spec)
    assert res.n_configs == len(CFGS) and len(pulled) == len(CFGS)


# ---------------------------------------------------------------------------
# serving objectives plumbing (traffic=)
# ---------------------------------------------------------------------------

def test_serving_objectives_via_facade():
    res = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                                backend="numpy", budget=64,
                                traffic="quick"))
    from repro.explore.objectives import DEFAULT_SERVING_OBJECTIVES
    assert res.objectives == DEFAULT_SERVING_OBJECTIVES
    assert res.stats["traffic"] == "quick"
    assert res.stats["n_slots"] == 8
    assert np.isfinite(res.front_objectives).all()


def test_serving_preset_equals_explicit_traffic():
    a = run(ExploreSpec.mixed("vgg16", preset="serving-quick", seed=2,
                              backend="numpy", budget=64))
    b = run(ExploreSpec.mixed("vgg16", preset="quick", seed=2,
                              backend="numpy", budget=64,
                              traffic="quick"))
    assert a.objectives == b.objectives
    assert np.array_equal(a.front_objectives, b.front_objectives)


def test_serving_front_differs_from_edp_front():
    """The acceptance claim in miniature: traffic-aware objectives select
    a different front than per-inference EDP objectives."""
    base = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                                 backend="numpy", budget=96))
    serv = run(ExploreSpec.mixed("vgg16", preset="quick", seed=7,
                                 backend="numpy", budget=96,
                                 traffic="steady"))
    ga = {g.tobytes() for g in base.genomes}
    gb = {g.tobytes() for g in serv.genomes}
    assert ga != gb


def test_evaluator_serving_validation():
    from repro.explore.search import Evaluator
    from repro.explore.space import space_for_workload, space_for_workloads
    wl = get_workload("vgg16")
    space = space_for_workload(wl)
    with pytest.raises(ValueError, match="need traffic="):
        Evaluator(space, wl, objectives=("p99_latency_s",))
    with pytest.raises(ValueError, match="no serving objective"):
        Evaluator(space, wl, objectives=("edp",), traffic="quick")
    wls = (wl, get_workload("resnet34"))
    mspace = space_for_workloads(wls)
    with pytest.raises(ValueError, match="single-workload only"):
        Evaluator(mspace, wls, objectives=("p99_latency_s",),
                  traffic="quick")


def test_objective_matrix_serving_floor_penalty():
    """Overloaded candidates land on the finite floor penalty, keeping
    hypervolume/nsga2 arithmetic finite."""
    from repro.explore.objectives import FLOOR_PENALTY, objective_matrix
    agg = {"latency_s": np.array([0.5]), "energy_j": np.array([1.0]),
           "perf_per_area": np.array([1.0]), "area_mm2": np.array([1.0]),
           "accuracy_noise": np.array([0.0])}
    from repro.serving.traffic import resolve_traffic
    f = objective_matrix(
        agg, None, None,
        objectives=("p99_latency_s", "energy_per_token_j"),
        traffic=resolve_traffic("interactive"), n_slots=1)
    assert np.isfinite(f).all()
    assert (f <= FLOOR_PENALTY).all()
    with pytest.raises(ValueError, match="traffic"):
        objective_matrix(agg, None, None, objectives=("p99_latency_s",))


def test_random_search_batch_kwarg_deprecated():
    from repro.explore.search import random_search
    from repro.explore.space import space_for_workload
    wl = get_workload("vgg16")
    space = space_for_workload(wl)
    with pytest.warns(DeprecationWarning, match="batch_size"):
        a = random_search(space, wl, 32, batch=16, seed=1,
                          backend="numpy")
    b = random_search(space, wl, 32, batch_size=16, seed=1,
                      backend="numpy")
    assert np.array_equal(a.front_objectives, b.front_objectives)
