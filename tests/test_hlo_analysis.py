"""The HLO-text cost model vs XLA's cost_analysis and hand counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import (analyze_compiled, analyze_hlo_text,
                                     cost_analysis_dict)
from repro.core.tpu_roofline import (Roofline, dense_model_flops,
                                     roofline_from_stats)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_loop_free_matches_cost_analysis():
    def g(a, b):
        return (a @ b).sum()
    co = _compile(g, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                  jax.ShapeDtypeStruct((512, 128), jnp.float32))
    mc = analyze_hlo_text(co.as_text())
    xla = cost_analysis_dict(co)["flops"]
    expect = 2 * 256 * 512 * 128
    assert abs(mc.flops - expect) / expect < 0.02
    assert abs(mc.flops - xla) / xla < 0.02


def test_scan_trip_count_correction():
    L = 7

    def f(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y.sum()

    co = _compile(jax.grad(f),
                  jax.ShapeDtypeStruct((L, 64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((8, 64), jnp.float32))
    mc = analyze_hlo_text(co.as_text())
    # fwd dot + 2 bwd dots per layer
    expect = 2 * 8 * 64 * 64 * L * 3
    assert abs(mc.flops - expect) / expect < 0.10, mc.flops
    # XLA counts the body once -> must be way below our corrected count
    assert cost_analysis_dict(co)["flops"] < mc.flops / 2


def test_analyze_compiled_fields():
    def g(a):
        return jnp.tanh(a).sum()
    co = _compile(g, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    st = analyze_compiled(co)
    assert st.flops > 0 and st.bytes_accessed > 0
    assert st.transcendentals >= 128 * 128
    assert st.collectives.total_bytes == 0
    d = st.as_dict()
    assert "collective_bytes_by_kind" in d and "flops" in d


def test_collectives_parsed_under_sharding():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_roofline_terms():
    from repro.core.hlo_analysis import CollectiveStats, CompiledStats
    st = CompiledStats(
        flops=197e12, bytes_accessed=819e9, transcendentals=0,
        collectives=CollectiveStats({"all-reduce": 200e9}, {"all-reduce": 4}),
        xla_flops=0, xla_bytes=0, argument_bytes=0, output_bytes=0,
        temp_bytes=0, generated_code_bytes=0)
    r = roofline_from_stats(st, arch="a", shape="s", mesh="m", chips=256,
                            model_flops=197e12 * 256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory", "collective")
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9
    assert abs(r.roofline_fraction - 1.0) < 1e-9


def test_model_flops_helpers():
    assert dense_model_flops(1e9, 1e6) == 6e15
