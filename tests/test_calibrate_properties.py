"""Property-based invariants of the tier-1 accuracy calibration (ISSUE 10
satellite): permutation invariance of the noise measurement, bit-width
monotonicity of the int quantizer family, the one-term/two-term pow2
ordering, and the exact algebra of the MAC-weighted table reduction.
Requires `hypothesis` (skipped when absent; CI installs it).

The full calibrator runs a real zoo model, far too slow per hypothesis
example — these tests exercise the same noise measurement
(:func:`repro.quant.calibrate._rel_noise` over
:func:`repro.quant.quantizers.quantize_dequantize`) and table reduction
(:func:`repro.explore.accuracy._mac_weighted`) on generated tensors.
"""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.explore.accuracy import _mac_weighted  # noqa: E402
from repro.quant.calibrate import _per_channel, _rel_noise  # noqa: E402
from repro.quant.quantizers import (FakeQuantSpec,  # noqa: E402
                                    quantize_dequantize)

MAX_EXAMPLES = 40

# finite, non-degenerate calibration tensors: float32-representable
# magnitudes well inside the exponent range, never all-zero
finite = st.floats(min_value=-64.0, max_value=64.0, width=32,
                   allow_nan=False, allow_infinity=False)


def nonzero_arrays(min_size=4, max_size=64):
    # a guaranteed O(1)-magnitude first element keeps absmax away from 0
    # without a rejection filter (hypothesis loves all-zero lists)
    return st.tuples(
        st.floats(min_value=0.5, max_value=64.0, allow_nan=False),
        st.lists(finite, min_size=min_size - 1, max_size=max_size - 1),
    ).map(lambda t: np.asarray([t[0], *t[1]], dtype=np.float64))


def _noise(x64: np.ndarray, spec: FakeQuantSpec) -> float:
    x32 = np.asarray(x64, dtype=np.float32)
    return _rel_noise(x64, quantize_dequantize(x32, spec))


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(x=nonzero_arrays(), bits=st.integers(2, 12), seed=st.integers(0, 99))
def test_noise_invariant_under_tensor_permutation(x, bits, seed):
    """Per-tensor calibration noise is a set function of the tensor: the
    absmax scale and the element-wise quantizer cannot see element order,
    so any permutation of the calibration tensor measures the same noise
    (up to float64 summation order in the mean)."""
    perm = np.random.default_rng(seed).permutation(len(x))
    spec = FakeQuantSpec("int", bits)
    assert math.isclose(_noise(x, spec), _noise(x[perm], spec),
                        rel_tol=1e-9, abs_tol=0.0)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(x=nonzero_arrays(min_size=8, max_size=64), rows=st.integers(2, 8),
       seed=st.integers(0, 99))
def test_per_channel_noise_invariant_under_row_permutation(x, rows, seed):
    """Per-output-channel calibration (scale per column of a
    (d_in, d_out) weight) is invariant under permutation of the *input*
    rows — the column-wise absmax scales don't move."""
    w = np.resize(x, (rows, max(2, len(x) // rows)))
    perm = np.random.default_rng(seed).permutation(rows)
    spec = _per_channel(FakeQuantSpec("int", 4))
    assert math.isclose(_noise(w, spec), _noise(w[perm], spec),
                        rel_tol=1e-9, abs_tol=0.0)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(x=nonzero_arrays(), bits=st.integers(2, 11))
def test_int_noise_nonnegative_and_monotone_in_bits(x, bits):
    """Relative noise is >= 0 and monotone non-increasing in bit-width,
    up to the finer grid's worst-case floor: one extra bit at least
    halves the step, so noise(b+1) can only exceed noise(b) when both
    already sit below the (b+1)-bit worst-case bound (step^2/4 plus the
    float32 measurement noise) — e.g. a tensor exactly on the coarse
    grid.  Above that floor, more bits strictly help."""
    n_b = _noise(x, FakeQuantSpec("int", bits))
    n_b1 = _noise(x, FakeQuantSpec("int", bits + 1))
    assert n_b >= 0.0 and n_b1 >= 0.0
    absmax = float(np.abs(x).max())
    step = absmax / (2 ** bits - 1)               # (b+1)-bit step
    worst = (step / 2 + 4e-7 * absmax) ** 2 / float(np.mean(x ** 2))
    assert n_b1 <= max(n_b, worst)
    if n_b > worst:
        assert n_b1 < n_b


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(x=nonzero_arrays())
def test_two_term_pow2_never_noisier_than_one_term(x):
    """The LightPE-2 datapath's second shift term is applied per element
    only where it reduces error, so the two-term mode family is noise-
    monotone against one-term by construction (the mode-family analogue
    of bit-width monotonicity)."""
    one = _noise(x, FakeQuantSpec("pow2"))
    two = _noise(x, FakeQuantSpec("pow2_2term"))
    assert 0.0 <= two <= one * (1 + 1e-6) + 1e-12


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 999), layers=st.integers(2, 12),
       n=st.integers(1, 6))
def test_mac_weighted_table_reduction_algebra(seed, layers, n):
    """The (L, T) table reduction behind CalibratedAccuracy.score: joint
    permutation of (layers, macs, assignments) leaves scores unchanged,
    scores are non-negative, zero-noise rows score zero, and raising one
    layer's table entry never lowers a genome's score."""
    rng = np.random.default_rng(seed)
    table = rng.uniform(0.0, 1.0, size=(layers, 4))
    table[:, 0] = 0.0                              # fp32 column
    macs = rng.uniform(1.0, 100.0, size=layers)
    assign = rng.integers(0, 4, size=(n, layers))
    s = _mac_weighted(table, assign, macs)
    assert s.shape == (n,) and (s >= 0).all()
    assert np.allclose(
        _mac_weighted(table, np.zeros_like(assign), macs), 0.0)
    perm = rng.permutation(layers)
    s_p = _mac_weighted(table[perm], assign[:, perm], macs[perm])
    np.testing.assert_allclose(s_p, s, rtol=1e-12)
    # monotone in the table: a noisier layer entry cannot help
    l, t = int(rng.integers(layers)), int(rng.integers(1, 4))
    worse = table.copy()
    worse[l, t] += 1.0
    assert (_mac_weighted(worse, assign, macs) >= s - 1e-15).all()
