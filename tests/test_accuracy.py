"""Tiered accuracy subsystem (ISSUE 10): the AccuracyModel protocol and
its three tiers, tier-1 calibration + npz cache, tier-2 quantized-forward
elite validation, the objective registry's deprecation shims, and
checkpoint pinning of calibration tables.

Every calibration in this module runs against the smallest zoo config
(mamba2-130m) with a module-scoped cache directory, so the table is
measured once and every later use is a cache hit.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core.dse import ExploreSpec, run
from repro.core.pe import PEType
from repro.core.workloads import get_workload
from repro.explore.accuracy import (AccuracyModel, AccuracySpec,
                                    CalibratedAccuracy, ProxyAccuracy,
                                    resolve_accuracy, validate_elites)
from repro.explore.objectives import (FLOOR_PENALTY,
                                      LEGACY_OBJECTIVE_ALIASES,
                                      MULTI_OBJECTIVES, OBJECTIVE_REGISTRY,
                                      OBJECTIVES, accuracy_floor_violation,
                                      mode_noise_table, quant_noise,
                                      reset_sqnr_table, resolve_objectives,
                                      sqnr_floor_violation)
from repro.explore.search import Evaluator, nsga2, random_search
from repro.explore.space import space_for_workload, space_for_workloads
from repro.quant.calibrate import (calibrate_model, calibration_cache_stats,
                                   calibration_key,
                                   reset_calibration_cache_stats)

TYPES = tuple(PEType)
MODEL = "mamba2-130m"                  # smallest zoo config

WL = get_workload("vgg16")
SPACE = space_for_workload(WL)
MACS = np.array([l.macs for l in WL.layers], dtype=np.float64)


def _assigns(n=16, seed=0):
    _, assign = SPACE.decode(SPACE.random_population(
        n, np.random.default_rng(seed)))
    return assign


@pytest.fixture(scope="module")
def calib_dir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("calib"))


@pytest.fixture(scope="module")
def cal(calib_dir) -> CalibratedAccuracy:
    return CalibratedAccuracy(AccuracySpec(tier=1, model=MODEL,
                                           cache_dir=calib_dir))


# ---------------------------------------------------------------------------
# AccuracySpec
# ---------------------------------------------------------------------------

def test_spec_parse():
    assert AccuracySpec.parse("proxy") == AccuracySpec()
    c = AccuracySpec.parse(f"calibrated:{MODEL}")
    assert (c.tier, c.model) == (1, MODEL)
    m = AccuracySpec.parse(f"measured:{MODEL}")
    assert (m.tier, m.model) == (2, MODEL)
    for bad in ("", "proxy:x", "calibrated", "calibrated:", "exact:x"):
        with pytest.raises(ValueError, match="bad accuracy spec|expected"):
            AccuracySpec.parse(bad)


def test_spec_validation():
    with pytest.raises(ValueError, match="tier must be"):
        AccuracySpec(tier=3)
    with pytest.raises(ValueError, match="takes no model"):
        AccuracySpec(tier=0, model=MODEL)
    with pytest.raises(ValueError, match="pass\\s+model="):
        AccuracySpec(tier=1)
    with pytest.raises(ValueError, match="floor_db must be > 0"):
        AccuracySpec(floor_db=0.0)
    with pytest.raises(ValueError, match="floor_db must be > 0"):
        AccuracySpec(floor_db=(20.0, -1.0))
    with pytest.raises(ValueError, match="max_elites"):
        AccuracySpec(tier=2, model=MODEL, max_elites=0)
    # scalar and per-workload tuple floors both normalize
    assert AccuracySpec(floor_db=np.float32(20)).floor_db == 20.0
    assert AccuracySpec(floor_db=[20, 25]).floor_db == (20.0, 25.0)


def test_resolve_accuracy_coercions(cal):
    assert isinstance(resolve_accuracy(None), ProxyAccuracy)
    assert isinstance(resolve_accuracy("proxy"), ProxyAccuracy)
    assert resolve_accuracy(cal) is cal           # model instances pass through
    with pytest.raises(TypeError, match="accuracy must be"):
        resolve_accuracy(42)


def test_models_satisfy_protocol(cal):
    assert isinstance(ProxyAccuracy(), AccuracyModel)
    assert isinstance(cal, AccuracyModel)


# ---------------------------------------------------------------------------
# tier 0: ProxyAccuracy
# ---------------------------------------------------------------------------

def test_proxy_matches_quant_noise_bitwise():
    assign = _assigns()
    p = ProxyAccuracy()
    assert np.array_equal(p.score(assign, MACS), quant_noise(assign, MACS))


def test_proxy_state_restore_pins_table():
    assign = _assigns()
    p = ProxyAccuracy()
    t = p.state()["mode_table"]
    assert np.array_equal(t, mode_noise_table())
    d0 = p.digest()
    p.restore_state({"mode_table": t * 2.0})      # pin a different table
    assert p.digest() != d0
    assert np.array_equal(p.score(assign, MACS),
                          2.0 * quant_noise(assign, MACS))
    # pinning the real table reproduces the live scores exactly
    p.restore_state({"mode_table": t})
    assert p.digest() == d0
    assert np.array_equal(p.score(assign, MACS), quant_noise(assign, MACS))


def test_reset_sqnr_table_remeasures_identically():
    t0 = mode_noise_table().copy()
    reset_sqnr_table()
    assert np.array_equal(mode_noise_table(), t0)
    assert np.array_equal(mode_noise_table(refresh=True), t0)


# ---------------------------------------------------------------------------
# tier 1: CalibratedAccuracy + cache
# ---------------------------------------------------------------------------

def test_calibration_table_shape_and_sanity(cal):
    tab = cal.calibration
    L, T = tab.table.shape
    assert T == len(TYPES) and L == tab.n_layers >= 2
    assert (tab.table >= 0).all()
    fp32 = TYPES.index(PEType.FP32)
    assert (tab.table[:, fp32] == 0).all()        # fp32 pays no noise
    # real tensors produce per-layer variation the tier-0 proxy cannot
    lp1 = TYPES.index(PEType.LIGHTPE1)
    assert np.ptp(tab.table[:, lp1]) > 0
    assert (tab.absmax > 0).all() and (tab.std > 0).all()


def test_layer_table_proportional_mapping(cal):
    tab = cal.calibration.table
    lm = cal.calibration.n_layers
    n = SPACE.n_layers
    t = cal.layer_table(n)
    idx = (np.arange(n) * lm) // n
    assert np.array_equal(t, tab[idx])
    assert cal.layer_table(n) is t                # memoized
    assert np.array_equal(cal.layer_table(lm), tab)


def test_calibrated_score_semantics(cal):
    assign = _assigns()
    s = cal.score(assign, MACS)
    assert s.shape == (len(assign),) and (s >= 0).all()
    assert not np.array_equal(s, quant_noise(assign, MACS))
    # fp32-everywhere is the zero of the scale, as in the proxy
    fp32 = np.full((1, SPACE.n_layers), TYPES.index(PEType.FP32))
    assert cal.score(fp32, MACS)[0] == 0.0


def test_calibrated_state_restore_digest_roundtrip(cal, calib_dir):
    assign = _assigns()
    other = CalibratedAccuracy(AccuracySpec(tier=1, model=MODEL,
                                            cache_dir=calib_dir))
    other.restore_state({k: v.copy() for k, v in cal.state().items()})
    assert other.digest() == cal.digest()
    assert np.array_equal(other.score(assign, MACS), cal.score(assign, MACS))
    # a perturbed table is a different calibration
    s = {k: v.copy() for k, v in cal.state().items()}
    s["table"] = s["table"] * 1.5
    other.restore_state(s)
    assert other.digest() != cal.digest()


def test_calibration_cache_hit_on_rerun(cal, calib_dir):
    reset_calibration_cache_stats()
    t2 = calibrate_model(MODEL, cache_dir=calib_dir)
    stats = calibration_cache_stats()
    assert stats == {"hits": 1, "misses": 0}
    assert np.array_equal(t2.table, cal.calibration.table)
    assert t2.digest() == cal.digest()
    # refresh bypasses the entry and re-measures the same table
    t3 = calibrate_model(MODEL, cache_dir=calib_dir, refresh=True)
    assert calibration_cache_stats()["misses"] == 1
    assert np.array_equal(t3.table, t2.table)


def test_calibration_key_separates_specs():
    keys = {calibration_key(MODEL),
            calibration_key(MODEL, seed=1),
            calibration_key(MODEL, percentile=50.0),
            calibration_key(MODEL, per_channel=False),
            calibration_key("gemma3-4b")}
    assert len(keys) == 5


# ---------------------------------------------------------------------------
# objective registry + deprecation shims
# ---------------------------------------------------------------------------

def test_registry_canonical_names():
    assert "accuracy_noise" in OBJECTIVES
    assert "worst_accuracy_noise" in MULTI_OBJECTIVES
    assert "mean_accuracy_noise" in MULTI_OBJECTIVES
    assert set(LEGACY_OBJECTIVE_ALIASES) == {
        "quant_noise", "worst_quant_noise", "mean_quant_noise"}
    assert not set(LEGACY_OBJECTIVE_ALIASES) & set(OBJECTIVE_REGISTRY)
    with pytest.raises(ValueError, match="unknown objective"):
        resolve_objectives(("speed",))


def test_legacy_objective_names_warn_and_resolve():
    for old, new in LEGACY_OBJECTIVE_ALIASES.items():
        with pytest.warns(DeprecationWarning, match="deprecated"):
            assert resolve_objectives((old,)) == (new,)


def test_sqnr_floor_violation_shim_parity():
    assign = _assigns()
    want = accuracy_floor_violation([assign], [MACS], 20.0)
    with pytest.warns(DeprecationWarning, match="accuracy_floor_violation"):
        got = sqnr_floor_violation([assign], [MACS], 20.0)
    assert np.array_equal(got, want)
    assert (want >= 0).all() and want.shape == (len(assign),)


def test_engine_sqnr_floor_kwarg_folds_into_accuracy():
    with pytest.warns(DeprecationWarning, match="sqnr_floor_db"):
        a = random_search(SPACE, WL, 32, seed=1, backend="numpy",
                          sqnr_floor_db=20.0)
    b = random_search(SPACE, WL, 32, seed=1, backend="numpy",
                      accuracy=AccuracySpec(floor_db=20.0))
    assert np.array_equal(a.genomes, b.genomes)
    assert np.array_equal(a.front_objectives, b.front_objectives)


def test_both_floor_spellings_rejected():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="not\\s+both"):
            Evaluator(SPACE, WL, backend="numpy", sqnr_floor_db=20.0,
                      accuracy=AccuracySpec(floor_db=20.0))


def test_preset_floor_folds_with_warning():
    from repro.configs.coexplore_presets import CoExplorePreset
    with pytest.warns(DeprecationWarning, match="sqnr_floor_db"):
        p = CoExplorePreset(name="x", sqnr_floor_db=21.0)
    assert p.sqnr_floor_db is None
    assert p.accuracy == AccuracySpec(floor_db=21.0)
    with pytest.warns(DeprecationWarning):
        q = CoExplorePreset(name="y", objectives=(
            "neg_perf_per_area", "energy_j", "quant_noise"))
    assert q.objectives == ("neg_perf_per_area", "energy_j",
                            "accuracy_noise")


def test_floor_turns_into_static_penalty():
    g = SPACE.random_population(32, np.random.default_rng(4))
    free = Evaluator(SPACE, WL, backend="numpy").evaluate(g)
    # a 200 dB floor is unattainable for any quantized layer
    hard = Evaluator(SPACE, WL, backend="numpy",
                     accuracy=AccuracySpec(floor_db=200.0)).evaluate(g)
    _, assign = SPACE.decode(g)
    quantized = (assign != TYPES.index(PEType.FP32)).any(axis=1)
    assert quantized.any()
    assert (hard[quantized] > FLOOR_PENALTY / 2).all()
    assert np.array_equal(hard[~quantized], free[~quantized])


def test_explore_spec_validates_accuracy_string():
    s = ExploreSpec.mixed("vgg16", accuracy="proxy")
    assert s.accuracy == AccuracySpec()
    with pytest.raises(ValueError, match="bad accuracy spec"):
        ExploreSpec.mixed("vgg16", accuracy="calibrated:")


# ---------------------------------------------------------------------------
# checkpoint pinning
# ---------------------------------------------------------------------------

def test_resume_bit_identical_with_calibrated_accuracy(cal, tmp_path):
    from repro.runtime.dse_checkpoint import resume_search
    base = nsga2(SPACE, WL, 48, pop_size=8, seed=5, backend="numpy",
                 accuracy=cal)
    res = resume_search(SPACE, WL, 48, checkpoint_dir=str(tmp_path),
                        checkpoint_every=2, pop_size=8, seed=5,
                        backend="numpy", accuracy=cal,
                        fail_at_generation={2: 1})
    assert res.stats.get("restarts") == 1
    assert np.array_equal(base.genomes, res.genomes)
    assert np.array_equal(base.front_objectives, res.front_objectives)


def test_resume_refuses_different_calibration(cal, calib_dir, tmp_path):
    nsga2(SPACE, WL, 32, pop_size=8, seed=5, backend="numpy",
          accuracy=cal, checkpoint_dir=str(tmp_path), checkpoint_every=1)
    other = CalibratedAccuracy(AccuracySpec(tier=1, model=MODEL,
                                            percentile=50.0,
                                            cache_dir=calib_dir))
    assert other.digest() != cal.digest()
    with pytest.raises(ValueError, match="refusing to resume"):
        nsga2(SPACE, WL, 32, pop_size=8, seed=5, backend="numpy",
              accuracy=other, checkpoint_dir=str(tmp_path),
              checkpoint_every=1)


# ---------------------------------------------------------------------------
# tier 2: quantized-forward elite validation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tier2(calib_dir):
    acc = CalibratedAccuracy(AccuracySpec(tier=2, model=MODEL,
                                          cache_dir=calib_dir,
                                          max_elites=3))
    res = nsga2(SPACE, WL, 48, pop_size=8, seed=7, backend="numpy",
                accuracy=acc)
    return res, acc


def test_validate_elites_measures_loss_deltas(tier2):
    res, acc = tier2
    v = validate_elites(res, acc)
    n = len(v.elite_indices)
    assert 1 <= n <= 3
    assert v.baseline_loss > 0
    assert np.isfinite(v.loss_delta).all()
    assert v.quant_loss.shape == (n,)
    assert v.measured_objectives.shape == (n, len(res.objectives))
    assert v.accuracy_column == list(res.objectives).index("accuracy_noise")
    assert np.array_equal(v.measured_objectives[:, v.accuracy_column],
                          v.loss_delta)
    assert v.pareto_mask.dtype == bool and v.pareto_mask.sum() >= 1
    s = v.summary()
    assert s["model"] == MODEL and s["n_elites"] == n
    # deterministic end to end: fixed init seed, fixed eval batch
    v2 = validate_elites(res, acc)
    assert np.array_equal(v2.loss_delta, v.loss_delta)
    assert v2.baseline_loss == v.baseline_loss


def test_validate_elites_rejects_proxy(tier2):
    res, _ = tier2
    with pytest.raises(ValueError, match="tier-0 proxy"):
        validate_elites(res, "proxy")


def test_validate_elites_rejects_multi_workload(cal):
    wls = (get_workload("vgg16"), get_workload("resnet34"))
    msp = space_for_workloads(wls)
    res = nsga2(msp, wls, 24, pop_size=8, seed=3, backend="numpy")
    with pytest.raises(ValueError, match="single-workload only"):
        validate_elites(res, cal)


def test_run_attaches_tier2_validation(calib_dir):
    spec = AccuracySpec(tier=2, model=MODEL, cache_dir=calib_dir,
                        max_elites=2)
    res = run(ExploreSpec.mixed("vgg16", preset="quick", budget=32,
                                pop_size=8, seed=2, backend="numpy",
                                accuracy=spec))
    assert res.validation is not None
    assert res.validation.summary()["n_elites"] <= 2
    # tier 1 attaches nothing
    t1 = AccuracySpec(tier=1, model=MODEL, cache_dir=calib_dir)
    res1 = run(ExploreSpec.mixed("vgg16", preset="quick", budget=32,
                                 pop_size=8, seed=2, backend="numpy",
                                 accuracy=t1))
    assert res1.validation is None


def test_many_facade_rejects_tier2(calib_dir):
    spec = AccuracySpec(tier=2, model=MODEL, cache_dir=calib_dir)
    with pytest.raises(ValueError, match="single-workload only"):
        run(ExploreSpec.many(("vgg16", "resnet34"), precision="mixed",
                             preset="many-quick", budget=16,
                             backend="numpy", accuracy=spec))


# ---------------------------------------------------------------------------
# golden calibrated front (the committed calibrated-quick preset)
# ---------------------------------------------------------------------------

def test_calibrated_quick_reproduces_golden_front():
    """The committed tier-1 preset reproduces its checked-in golden front
    bit-for-bit, and that front's *membership* differs from the proxy's —
    the calibrated signal changes which genomes survive, not just their
    scores.  Regenerate with
    ``python benchmarks/accuracy_bench.py --regen-golden``."""
    golden = json.loads(
        (pathlib.Path(__file__).parent / "golden_calibrated_front.json")
        .read_text())
    res = run(ExploreSpec.mixed(golden["workload"], preset=golden["preset"],
                                seed=golden["seed"],
                                backend=golden["backend"]))
    assert list(res.objectives) == golden["objectives"]
    acc = resolve_accuracy(f"calibrated:{MODEL}")
    assert acc.digest() == golden["calibration_digest"]
    want_g = res.space.unpack_genomes(
        np.array(golden["front_genomes_u16"], dtype=np.uint16))
    assert np.array_equal(res.genomes, want_g)
    np.testing.assert_allclose(
        res.front_objectives,
        np.array(golden["front_objectives"], dtype=np.float64), rtol=1e-9)

    prox = run(ExploreSpec.mixed(golden["workload"], preset="quick",
                                 seed=golden["seed"],
                                 backend=golden["backend"]))
    assert set(res.space.genome_keys(res.genomes)) != \
        set(prox.space.genome_keys(prox.genomes))
