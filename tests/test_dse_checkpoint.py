"""Preemption-safe exploration runtime (ISSUE 7 tentpole): checkpoint/
resume for chunked sweeps and NSGA-II searches, deterministic fault
injection, the chunk watchdog, and jax->numpy degradation.

The contract under test: a run killed at *any* chunk / generation
boundary and resumed from its newest valid snapshot produces a Pareto
front **bit-identical** to the uninterrupted run on the numpy backend —
including synthesis-cache hit/miss accounting — and within 1e-6 on jax.
"""

import os

import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig
from repro.core.dse import ExploreSpec, run
from repro.core import dse_batch
from repro.core.dse_batch import ChunkDeadlineExceeded, _sweep_chunked
from repro.core.pe import PEType
from repro.core.synthesis import PersistentSynthesisCache
from repro.core.workloads import ConvLayer, Workload, get_workload
from repro.explore import CoExploreSpace, nsga2
from repro.runtime.dse_checkpoint import (SearchCheckpointer,
                                          SweepCheckpointer, resume_search,
                                          resume_sweep)
from repro.runtime.fault_tolerance import InjectedFailure

WL = get_workload("vgg16")
SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in tuple(PEType)
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (16, 16, 256, 12.8), (32, 32, 512, 25.6)]
]
FEED = SPACE * 7                 # 112 configs; chunk_size=11 -> 11 chunks
CHUNK = 11
N_CHUNKS = 11

TINY_WL = Workload("tiny", (
    ConvLayer("c1", 58, 58, 64, 64),
    ConvLayer("c2", 30, 30, 64, 128, 3, 3, 2),
    ConvLayer("fc", 1, 1, 512, 1000, 1, 1),
))
SEARCH_SPACE = CoExploreSpace(n_layers=len(TINY_WL.layers))


def _assert_same_sweep(a, b):
    assert a.n_configs == b.n_configs
    assert a.n_chunks == b.n_chunks
    assert a.front_size == b.front_size
    for m in a.front_metrics:
        assert np.array_equal(a.front_metrics[m], b.front_metrics[m]), m
    for k in a.front_soa:
        assert np.array_equal(a.front_soa[k], b.front_soa[k]), k


def _assert_same_search(a, b, *, exact=True):
    eq = np.array_equal if exact else \
        lambda x, y: np.allclose(x, y, rtol=1e-6, atol=0)
    assert np.array_equal(a.genomes, b.genomes)
    assert eq(a.front_objectives, b.front_objectives)
    assert np.array_equal(a.population, b.population)
    assert eq(a.population_objectives, b.population_objectives)
    assert eq(a.all_objectives, b.all_objectives)
    assert a.n_evals == b.n_evals
    assert [e for e, _ in a.history] == [e for e, _ in b.history]
    np.testing.assert_allclose([h for _, h in a.history],
                               [h for _, h in b.history],
                               rtol=0 if exact else 1e-6, atol=0)


# ---------------------------------------------------------------------------
# sweep checkpoint/resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ref_sweep():
    return _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="numpy")


@pytest.mark.parametrize("overlap", [False, True])
@pytest.mark.parametrize("boundary", range(N_CHUNKS))
def test_sweep_resume_bit_identical_at_every_boundary(
        tmp_path, ref_sweep, overlap, boundary):
    """Kill the stream once at each chunk boundary: the resumed front is
    byte-for-byte the uninterrupted one, under both pipeline modes."""
    res = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                       checkpoint_every=2, fail_at={boundary: 1},
                       chunk_size=CHUNK, backend="numpy", overlap=overlap)
    assert res.timings["restarts"] == 1
    _assert_same_sweep(res, ref_sweep)


def test_sweep_resume_repeated_failures(tmp_path, ref_sweep):
    res = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                       checkpoint_every=2, fail_at={3: 1, 6: 2},
                       chunk_size=CHUNK, backend="numpy")
    assert res.timings["restarts"] == 3
    _assert_same_sweep(res, ref_sweep)


def test_sweep_resume_cache_accounting_identical(tmp_path, ref_sweep):
    """Hit/miss/eviction counters of the persisted synthesis cache replay
    exactly through a preempted-and-resumed stream."""
    clean_cache = PersistentSynthesisCache(tmp_path / "clean.npz")
    clean = _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="numpy",
                           cache=clean_cache)
    faulty_cache = PersistentSynthesisCache(tmp_path / "faulty.npz")
    res = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path / "ck"),
                       checkpoint_every=2, fail_at={2: 1, 7: 1},
                       cache=faulty_cache, chunk_size=CHUNK,
                       backend="numpy")
    assert res.timings["restarts"] == 2
    _assert_same_sweep(res, clean)
    for stat in ("hits", "misses", "evictions"):
        assert getattr(faulty_cache, stat) == getattr(clean_cache, stat), \
            stat
    assert len(faulty_cache) == len(clean_cache)


def test_sweep_resume_after_completion_is_idempotent(tmp_path, ref_sweep):
    """Resuming a finished run restores the terminal snapshot and skips
    the whole feed — no re-synthesis, identical front."""
    first = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                         checkpoint_every=4, chunk_size=CHUNK,
                         backend="numpy")
    cache = PersistentSynthesisCache(tmp_path / "c.npz")
    again = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                         checkpoint_every=4, cache=cache,
                         chunk_size=CHUNK, backend="numpy")
    assert again.timings["restarts"] == 0
    _assert_same_sweep(first, again)
    _assert_same_sweep(again, ref_sweep)
    # every chunk was skipped: the cache never synthesized a row
    assert cache.misses == 0 and cache.hits == 0


def test_sweep_corrupt_snapshot_falls_back_to_older(tmp_path, ref_sweep):
    ck = SweepCheckpointer(str(tmp_path), every=2)
    _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="numpy",
                   checkpoint=ck)
    steps = sorted(d for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps, "expected snapshots on disk"
    with open(tmp_path / steps[-1] / "arrays.npz", "r+b") as f:
        f.seek(8)
        f.write(b"\xde\xad\xbe\xef")           # corrupt the newest one
    res = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                       checkpoint_every=2, chunk_size=CHUNK,
                       backend="numpy")
    assert res.timings["restarts"] == 0
    _assert_same_sweep(res, ref_sweep)          # replayed the tail


def test_sweep_resume_exhausts_max_restarts(tmp_path):
    with pytest.raises(InjectedFailure):
        resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                     fail_at={0: 5}, max_restarts=2, chunk_size=CHUNK,
                     backend="numpy")


def test_sweep_non_retryable_propagates(tmp_path):
    calls = {"n": 0}

    def feed():
        calls["n"] += 1
        raise KeyError("feed exploded")

    with pytest.raises(KeyError):
        resume_sweep(WL, feed, checkpoint_dir=str(tmp_path),
                     chunk_size=CHUNK, backend="numpy")
    assert calls["n"] == 1                      # no blind retry


def test_sweep_resume_jax_backend(tmp_path, jax_usable):
    if not jax_usable:
        pytest.skip("jax not usable on this host")
    clean = _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="jax")
    res = resume_sweep(WL, [FEED], checkpoint_dir=str(tmp_path),
                       checkpoint_every=2, fail_at={4: 1},
                       chunk_size=CHUNK, backend="jax")
    assert res.timings["restarts"] == 1
    assert res.n_configs == clean.n_configs
    assert res.front_size == clean.front_size
    for m in clean.front_metrics:               # same kernel replayed on
        np.testing.assert_allclose(             # the same chunks
            res.front_metrics[m], clean.front_metrics[m],
            rtol=1e-6, atol=0, err_msg=m)


def test_sweep_checkpointer_ignores_foreign_snapshots(tmp_path):
    """A sweep restore refuses a search snapshot sharing the directory
    (and vice versa) instead of mis-restoring."""
    rng = np.random.default_rng(0)
    sck = SearchCheckpointer(str(tmp_path), every=1)
    sck.save(gen=0, evals=4, pop=np.zeros((4, 7), dtype=np.int64),
             F=np.zeros((4, 2)), arch_g=np.zeros((2, 7), dtype=np.int64),
             arch_F=np.zeros((2, 2)), ref=np.ones(2),
             history=[(4, 0.0)], all_F=[np.zeros((4, 2))],
             rng_state=rng.bit_generator.state, eps_vec=None)
    assert SweepCheckpointer(str(tmp_path)).restore() is None
    wck = SweepCheckpointer(str(tmp_path / "s"), every=1)
    wck.save(cursor=1, n_total=8, front_soa={}, front_metrics={},
             cache_state=None)
    assert SearchCheckpointer(str(tmp_path / "s")).restore() is None


# ---------------------------------------------------------------------------
# watchdog + degradation
# ---------------------------------------------------------------------------

def test_watchdog_redispatches_stuck_chunk(tmp_path, monkeypatch,
                                           ref_sweep):
    """A chunk kernel exceeding the deadline is cancelled and recomputed
    serially: the stream finishes with the exact front."""
    real_kernel = dse_batch._sweep_kernel
    state = {"calls": 0}

    def slow_once(xp, cfg, lay, **kw):
        state["calls"] += 1
        if state["calls"] == 3:                 # one mid-stream chunk
            import time
            time.sleep(0.5)
        return real_kernel(xp, cfg, lay, **kw)

    monkeypatch.setattr(dse_batch, "_sweep_kernel", slow_once)
    with pytest.warns(RuntimeWarning, match="watchdog deadline"):
        res = _sweep_chunked(WL, [FEED], chunk_size=CHUNK,
                             backend="numpy", overlap=True,
                             chunk_deadline_s=0.1)
    assert res.timings["watchdog_redispatches"] >= 1
    _assert_same_sweep(res, ref_sweep)


def test_watchdog_zombie_worker_does_not_cascade(monkeypatch, ref_sweep):
    """Regression (ISSUE 9): ``fut.cancel()`` cannot interrupt a running
    kernel, so before the executor-replacement fix the zombie worker kept
    occupying the 1-worker pool and every later chunk queued behind it
    into its own deadline.  A deliberately slow *first* chunk must now
    fire the watchdog exactly once, replace the executor, and let the
    rest of the stream (including chunks already queued on the torn-down
    executor) finish cleanly on the exact front."""
    real_kernel = dse_batch._sweep_kernel
    state = {"calls": 0}

    def slow_first(xp, cfg, lay, **kw):
        state["calls"] += 1
        if state["calls"] == 1:
            import time
            time.sleep(0.9)
        return real_kernel(xp, cfg, lay, **kw)

    monkeypatch.setattr(dse_batch, "_sweep_kernel", slow_first)
    with pytest.warns(RuntimeWarning) as rec:
        res = _sweep_chunked(WL, [FEED], chunk_size=CHUNK,
                             backend="numpy", overlap=True,
                             prefetch_depth=4, chunk_deadline_s=0.3)
    deadline_warns = [w for w in rec
                     if "watchdog deadline" in str(w.message)]
    assert len(deadline_warns) == 1          # no cascading deadlines
    t = res.timings
    assert t["watchdog_redispatches"] == 1
    assert t["executor_replacements"] == 1
    # chunks queued behind the zombie surface as cancellations and are
    # recomputed serially, never as their own watchdog fires
    assert 0 < t["cancelled_recomputes"] < N_CHUNKS
    _assert_same_sweep(res, ref_sweep)


class _SlowBuf:
    """Array-like whose materialization blocks — a wedged device buffer."""

    def __init__(self, arr, delay):
        self.arr, self.delay = arr, delay

    def __array__(self, dtype=None):
        import time
        time.sleep(self.delay)
        return np.asarray(self.arr, dtype=dtype)


def test_jax_watchdog_drops_abandoned_buffers(monkeypatch):
    """Regression (ISSUE 9): the daemon materialize thread the watchdog
    abandons used to park the chunk's host+device buffers in its result
    box for the life of the process.  The orphan must now discard its
    result on completion and the ledger must return to zero live."""
    import time
    from repro.core.dse_batch import abandoned_finalizers

    n = 4
    out = {"latency_s": _SlowBuf(np.ones(n), 0.8),
           "energy_j": _SlowBuf(np.ones(n), 0.0)}
    monkeypatch.setattr(dse_batch, "get_jax_kernel",
                        lambda mesh, outputs: (lambda c, l: out, False))
    monkeypatch.setattr(dse_batch, "_to_jax_inputs",
                        lambda cfg, lay, exact: (cfg, lay))
    a0 = abandoned_finalizers.abandoned
    c0 = abandoned_finalizers.completed
    finalize = dse_batch._dispatch_chunk(
        {"pe_rows": np.ones(n)}, {}, "jax", None, n, n, None)
    with pytest.raises(ChunkDeadlineExceeded):
        finalize(timeout=0.1)
    assert abandoned_finalizers.abandoned == a0 + 1
    deadline = time.time() + 5.0
    while abandoned_finalizers.completed < c0 + 1:
        if time.time() > deadline:            # pragma: no cover
            pytest.fail("orphaned finalizer never completed")
        time.sleep(0.05)
    assert abandoned_finalizers.live == (a0 - c0)   # back to baseline


def test_jax_watchdog_stream_counts_abandoned_finalizers(monkeypatch,
                                                         ref_sweep):
    """Stream-level: a jax chunk that never materializes within the
    deadline is recomputed on numpy, counted in
    ``timings['abandoned_finalizers']``, and the stream finishes with
    the exact front (no cascade, no unbounded orphan growth)."""
    from repro.core.dse_batch import abandoned_finalizers
    real_kernel = dse_batch._sweep_kernel
    state = {"calls": 0}

    def jax_fn(cfg, lay):
        state["calls"] += 1
        out = real_kernel(np, cfg, lay, outputs="aggregates")
        if state["calls"] == 1:
            return {k: _SlowBuf(v, 0.9) for k, v in out.items()}
        return out

    monkeypatch.setattr(dse_batch, "resolve_backend",
                        lambda b="auto": "jax")
    monkeypatch.setattr(dse_batch, "_require_jax_mesh", lambda mesh: None)
    monkeypatch.setattr(dse_batch, "get_jax_kernel",
                        lambda mesh, outputs: (jax_fn, False))
    monkeypatch.setattr(dse_batch, "_to_jax_inputs",
                        lambda cfg, lay, exact: (cfg, lay))
    a0 = abandoned_finalizers.abandoned
    with pytest.warns(RuntimeWarning) as rec:
        res = _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="jax",
                             overlap=True, prefetch_depth=3,
                             chunk_deadline_s=0.3)
    assert len([w for w in rec
                if "watchdog deadline" in str(w.message)]) == 1
    assert res.timings["watchdog_redispatches"] == 1
    assert res.timings["abandoned_finalizers"] == 1
    assert abandoned_finalizers.abandoned == a0 + 1
    _assert_same_sweep(res, ref_sweep)


def test_jax_failure_degrades_stream_to_numpy(monkeypatch, ref_sweep):
    """A jax failure mid-stream falls back to the numpy kernel with a
    warning instead of losing the accumulated front."""
    monkeypatch.setattr(dse_batch, "resolve_backend", lambda b="auto": "jax")
    monkeypatch.setattr(dse_batch, "_require_jax_mesh", lambda mesh: None)

    def boom(mesh=None, outputs="full"):
        raise RuntimeError("device wedged")

    monkeypatch.setattr(dse_batch, "get_jax_kernel", boom)
    with pytest.warns(RuntimeWarning, match="degrading stream to numpy"):
        res = _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="jax")
    assert res.backend == "numpy"
    assert res.timings["degraded"] is True
    _assert_same_sweep(res, ref_sweep)


def test_jax_failure_raises_when_degradation_disabled(monkeypatch):
    monkeypatch.setattr(dse_batch, "resolve_backend", lambda b="auto": "jax")
    monkeypatch.setattr(dse_batch, "_require_jax_mesh", lambda mesh: None)

    def boom(mesh=None, outputs="full"):
        raise RuntimeError("device wedged")

    monkeypatch.setattr(dse_batch, "get_jax_kernel", boom)
    with pytest.raises(RuntimeError, match="device wedged"):
        _sweep_chunked(WL, [FEED], chunk_size=CHUNK, backend="jax",
                       degrade_on_failure=False)


# ---------------------------------------------------------------------------
# nsga2 checkpoint/resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ref_search():
    return nsga2(SEARCH_SPACE, TINY_WL, 120, pop_size=16, seed=3,
                 backend="numpy")


@pytest.mark.parametrize("boundary", range(8))   # init + 7 generations
def test_search_resume_bit_identical_at_every_generation(
        tmp_path, ref_search, boundary):
    """Kill the search once at each generation boundary (including before
    the initial population): resumed result is bit-identical — front,
    population, RNG-threaded history, and the full objective trail."""
    res = resume_search(SEARCH_SPACE, TINY_WL, 120,
                        checkpoint_dir=str(tmp_path), checkpoint_every=1,
                        fail_at_generation={boundary: 1},
                        pop_size=16, seed=3, backend="numpy")
    assert res.stats["restarts"] == 1
    _assert_same_search(res, ref_search)


def test_search_resume_repeated_failures(tmp_path, ref_search):
    res = resume_search(SEARCH_SPACE, TINY_WL, 120,
                        checkpoint_dir=str(tmp_path), checkpoint_every=2,
                        fail_at_generation={1: 1, 5: 2, 7: 1},
                        pop_size=16, seed=3, backend="numpy")
    assert res.stats["restarts"] == 4
    _assert_same_search(res, ref_search)


def test_search_resume_with_epsilon_archive(tmp_path):
    clean = nsga2(SEARCH_SPACE, TINY_WL, 120, pop_size=16, seed=3,
                  backend="numpy", archive_epsilon=0.05)
    res = resume_search(SEARCH_SPACE, TINY_WL, 120,
                        checkpoint_dir=str(tmp_path), checkpoint_every=1,
                        fail_at_generation={2: 1, 5: 1},
                        pop_size=16, seed=3, backend="numpy",
                        archive_epsilon=0.05)
    assert res.stats["restarts"] == 2
    _assert_same_search(res, clean)
    assert res.stats["archive_epsilon"] == clean.stats["archive_epsilon"]
    assert res.stats["archive_size"] == clean.stats["archive_size"]


def test_resume_search_rejects_non_nsga2(tmp_path):
    with pytest.raises(ValueError, match="nsga2"):
        resume_search(SEARCH_SPACE, TINY_WL, 64,
                      checkpoint_dir=str(tmp_path), method="random")


# ---------------------------------------------------------------------------
# ExploreSpec / run() facade wiring
# ---------------------------------------------------------------------------

def test_explore_spec_checkpoint_validation():
    with pytest.raises(ValueError, match="checkpoint_every needs"):
        ExploreSpec.single(WL, [FEED], chunk_size=CHUNK,
                           checkpoint_every=4)
    with pytest.raises(ValueError, match="no resumable stream"):
        ExploreSpec.single(WL, [FEED],
                           checkpoint_dir="/tmp/nope")
    with pytest.raises(ValueError, match="checkpoint_every must be >= 1"):
        ExploreSpec.single(WL, [FEED], chunk_size=CHUNK,
                           checkpoint_dir="/tmp/nope", checkpoint_every=0)


def test_run_checkpointed_chunked_sweep(tmp_path, ref_sweep):
    spec = ExploreSpec.single(WL, [FEED],
                              chunk_size=CHUNK, backend="numpy",
                              use_cache=False,
                              checkpoint_dir=str(tmp_path),
                              checkpoint_every=2)
    first = run(spec)
    _assert_same_sweep(first, ref_sweep)
    assert first.timings["restarts"] == 0
    again = run(spec)                   # resumes the terminal snapshot
    _assert_same_sweep(again, ref_sweep)


def test_run_checkpointed_search_requires_nsga2(tmp_path):
    spec = ExploreSpec.mixed("vgg16", method="random", budget=32,
                             checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="nsga2"):
        run(spec)


# ---------------------------------------------------------------------------
# property test: resume from an arbitrary failure schedule (hypothesis)
# ---------------------------------------------------------------------------

def test_sweep_resume_any_failure_schedule(ref_sweep):
    """Property: *any* schedule of kills at chunk boundaries, any
    snapshot cadence, either pipeline mode — the resumed front is
    bit-identical (the deterministic boundary sweep above is the
    always-on baseline; this widens it when hypothesis is available)."""
    pytest.importorskip("hypothesis")
    import tempfile

    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(st.dictionaries(st.integers(0, N_CHUNKS - 1),
                           st.integers(1, 2), max_size=3),
           st.integers(1, 5), st.booleans())
    def check(fail_at, every, overlap):
        with tempfile.TemporaryDirectory() as d:
            res = resume_sweep(WL, [FEED], checkpoint_dir=d,
                               checkpoint_every=every,
                               fail_at=dict(fail_at), max_restarts=16,
                               chunk_size=CHUNK, backend="numpy",
                               overlap=overlap)
        assert res.timings["restarts"] == sum(fail_at.values())
        _assert_same_sweep(res, ref_sweep)

    check()
