"""Mesh sharding of the multi-workload fused kernel (ISSUE 5 tentpole).

Two layers of coverage:

* in-process — the numpy backend's simulated sharding (``mesh=<int>`` or
  a real mesh) must be **bit-identical** to the unsharded path for any
  shard count, including shard counts that don't divide the batch size,
  and the whole explore stack must accept ``mesh=`` without changing the
  search trajectory;
* subprocess — real multi-device ``shard_map`` sharding under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must
  precede the jax import, so the check runs in a fresh interpreter; same
  pattern as the device-count skip in ``tests/test_hlo_analysis.py``),
  asserting ≤1e-6 relative parity vs the unsharded numpy front for both
  a divisible and a non-divisible batch-size-vs-device-count case.
"""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core.accelerator import AcceleratorConfig, configs_to_soa
from repro.core.dse_batch import sweep_mixed_many
from repro.core.pe import PEType, supported_modes
from repro.core.workloads import get_workload

TYPES = tuple(PEType)
SMALL_SPACE = [
    AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                      dram_bw_gbps=bw)
    for t in TYPES
    for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                          (32, 32, 512, 25.6)]
]
WLS = ("vgg16", "resnet34")


def _batch(n: int, seed: int = 7):
    wls = tuple(get_workload(w) for w in WLS)
    rng = np.random.default_rng(seed)
    configs = [SMALL_SPACE[i]
               for i in rng.integers(0, len(SMALL_SPACE), size=n)]
    soa = configs_to_soa(configs)
    assigns = []
    for w in wls:
        a = np.empty((n, len(w.layers)), dtype=np.int64)
        for i, c in enumerate(configs):
            modes = [TYPES.index(m) for m in supported_modes(c.pe_type)]
            a[i] = rng.choice(modes, size=len(w.layers))
        assigns.append(a)
    return wls, soa, assigns


@pytest.mark.parametrize("n,shards", [(24, 4),   # divisible
                                      (29, 4),   # non-divisible
                                      (3, 8)])   # more shards than rows
def test_numpy_sharded_bit_identical(n, shards):
    wls, soa, assigns = _batch(n)
    un = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                          use_cache=False)
    sh = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                          use_cache=False, mesh=shards)
    assert set(un) == set(sh)
    for k in un:
        assert np.array_equal(un[k], sh[k]), k


def test_numpy_mesh_object_taken_by_device_count(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    from repro.launch.mesh import make_sweep_mesh
    wls, soa, assigns = _batch(17)
    un = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                          use_cache=False)
    sh = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                          use_cache=False, mesh=make_sweep_mesh())
    for k in un:
        assert np.array_equal(un[k], sh[k]), k


def test_invalid_mesh_args():
    wls, soa, assigns = _batch(6)
    with pytest.raises(ValueError, match="shard count"):
        sweep_mixed_many(wls, soa, assigns, backend="numpy",
                         use_cache=False, mesh=0)


def test_jax_rejects_int_mesh(jax_usable):
    if not jax_usable:
        pytest.skip("jax unusable")
    wls, soa, assigns = _batch(6)
    with pytest.raises(ValueError, match="jax.sharding.Mesh"):
        sweep_mixed_many(wls, soa, assigns, backend="jax",
                         use_cache=False, mesh=2)


def test_jax_single_device_mesh_parity(jax_usable):
    """Even a 1-device mesh goes through the shard_map code path and must
    match the unsharded jit kernel (multi-device runs live in the
    subprocess test below and the multi-device-smoke CI job)."""
    if not jax_usable:
        pytest.skip("jax unusable")
    from repro.launch.mesh import make_sweep_mesh
    wls, soa, assigns = _batch(21)
    un = sweep_mixed_many(wls, soa, assigns, backend="jax",
                          use_cache=False)
    sh = sweep_mixed_many(wls, soa, assigns, backend="jax",
                          use_cache=False, mesh=make_sweep_mesh())
    for k in ("latency_s", "energy_j", "perf_per_area",
              "throughput_gmacs"):
        a = np.asarray(un[k], dtype=np.float64)
        b = np.asarray(sh[k], dtype=np.float64)
        both_zero = (a == 0) & (b == 0)
        denom = np.where(a == 0, 1.0, a)
        rel = np.max(np.where(both_zero, 0.0, np.abs(b / denom - 1.0)))
        assert rel < 1e-6, (k, rel)


def test_evaluator_mesh_threads_through_search():
    """coexplore_many(mesh=...) must not change the numpy search
    trajectory (simulated shards are bit-identical), and the shard count
    must land in the run stats."""
    from repro.core.dse import coexplore_many
    base = coexplore_many(WLS, preset="many-quick", budget=48, seed=5,
                          backend="numpy")
    sharded = coexplore_many(WLS, preset="many-quick", budget=48, seed=5,
                             backend="numpy", mesh=3)
    assert np.array_equal(base.genomes, sharded.genomes)
    assert np.array_equal(base.front_objectives,
                          sharded.front_objectives)
    assert sharded.stats["mesh_shards"] == 3
    assert base.stats["mesh_shards"] is None


@pytest.mark.parametrize("n", [32, 30])   # divisible / non-divisible by 4
def test_forced_four_device_shard_map_parity(n, jax_usable):
    """Real shard_map over 4 forced host devices (fresh interpreter so
    XLA_FLAGS precedes the jax import)."""
    if not jax_usable:
        pytest.skip("jax unusable")
    script = pathlib.Path(__file__).parent / "mesh_subprocess_check.py"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(script), str(n)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    r = json.loads(proc.stdout.strip().splitlines()[-1])
    assert r["device_count"] == 4
    assert r["n_configs"] == n
    assert r["numpy_sharded_bit_exact"]
    assert r["jax_sharded_vs_numpy_max_rel"] < 1e-6
    assert r["jax_sharded_vs_unsharded_max_rel"] < 1e-6
