"""Unified telemetry layer (`repro.obs`): span tracing, metrics registry,
exporters, and the no-behavior-change contract.

Layers under test:

* `trace` — nesting/ordering invariants, the async start/stop handle
  path, Chrome trace_event schema validity, JSONL round-trip including
  the torn-final-line tolerance a SIGKILL leaves, and the configure /
  configured scoping (the disabled path returns shared no-op objects).
* `metrics` — counter/gauge/histogram semantics and the flat snapshot.
* instrumentation — enabling telemetry changes **nothing**: chunked-sweep
  fronts and synthesis-cache accounting are bit-identical with tracing
  on and off (both backends), Evaluator stats attribute per search via
  `reset_stats`, and a failed sweep attempt still flushes `wall_s` and
  the registry totals (the satellite bugfixes of ISSUE 8).
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.core.accelerator import design_space_soa
from repro.core.dse import ExploreSpec, run
from repro.core.dse_batch import _sweep_chunked
from repro.core.synthesis import PersistentSynthesisCache
from repro.core.workloads import get_workload

CHUNK = 16
GRID = dict(glb_kbs=(64, 256), bws=(8.0, 16.0, 32.0, 64.0))


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with tracing off and a fresh ring +
    registry — telemetry state is process-global."""
    obs.disable()
    obs.configure(enabled=False, reset=True)
    obs.reset_metrics()
    yield
    obs.disable()
    obs.configure(enabled=False, reset=True)
    obs.reset_metrics()


def _space():
    return design_space_soa(chunk_size=CHUNK, **GRID)


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    obs.configure(enabled=True)
    with obs.span("outer", a=1) as outer:
        with obs.span("inner"):
            pass
        with obs.span("inner2") as sp:
            sp.set(extra="x")
    spans = obs.get_tracer().spans()
    by_name = {s.name: s for s in spans}
    assert set(by_name) == {"outer", "inner", "inner2"}
    # children closed before the parent, parent/depth recorded
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    assert by_name["outer"].parent_id is None
    assert by_name["outer"].depth == 0
    for child in ("inner", "inner2"):
        assert by_name[child].parent_id == by_name["outer"].span_id
        assert by_name[child].depth == 1
    assert by_name["inner2"].attrs["extra"] == "x"
    assert by_name["outer"].attrs["a"] == 1
    # durations are non-negative and children start within the parent
    for s in spans:
        assert s.dur_s >= 0.0
        assert s.cpu_dur_s >= 0.0
    assert by_name["inner"].t0_s >= by_name["outer"].t0_s


def test_span_status_on_exception():
    obs.configure(enabled=True)
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (sp,) = obs.get_tracer().spans("boom")
    assert sp.status == "error"


def test_async_start_end_handles():
    obs.configure(enabled=True)
    h1 = obs.span_start("kernel", chunk=0)
    h2 = obs.span_start("kernel", chunk=1)
    obs.span_end(h2, status="ok", n=5)
    obs.span_end(h1)
    spans = obs.get_tracer().spans("kernel")
    assert [s.attrs["chunk"] for s in spans] == [1, 0]   # end order
    assert spans[0].attrs["n"] == 5
    # async spans are not pushed on the nesting stack
    assert all(s.depth == 0 for s in spans)


def test_disabled_path_is_noop():
    assert not obs.is_enabled()
    a = obs.span("x")
    b = obs.span("y", attr=1)
    assert a is b                      # shared singleton, no allocation
    with a as sp:
        sp.set(ignored=True)           # full Span surface, does nothing
    assert obs.span_start("x") is None
    obs.span_end(None)                 # ignores the disabled handle
    assert obs.get_tracer().spans() == []


def test_ring_bound_evicts_oldest():
    obs.configure(enabled=True, ring_size=4)
    for i in range(10):
        with obs.span("s", i=i):
            pass
    tr = obs.get_tracer()
    spans = tr.spans()
    assert len(spans) == 4
    assert [s.attrs["i"] for s in spans] == [6, 7, 8, 9]
    assert tr.n_recorded == 10 and tr.n_evicted == 6
    obs.configure(enabled=False, ring_size=65536)


def test_timed_span_populates_sink_always():
    sink = {}
    with obs.timed_span("stage", sink=sink, key="synth_s"):
        pass
    assert sink["synth_s"] >= 0.0      # timed even while disabled
    assert obs.get_tracer().spans() == []
    obs.configure(enabled=True)
    with obs.timed_span("stage", sink=sink, key="synth_s"):
        pass
    assert len(obs.get_tracer().spans("stage")) == 1


def test_configured_scoping_restores_prior_state(tmp_path):
    with obs.configured(None):
        assert not obs.is_enabled()    # None leaves the switch alone
    with obs.configured(True):
        assert obs.is_enabled()
    assert not obs.is_enabled()
    with obs.configured({"jsonl_path": tmp_path / "t.jsonl"}):
        assert obs.is_enabled()
        with obs.span("inside"):
            pass
    assert not obs.is_enabled()
    assert len(obs.load_jsonl(tmp_path / "t.jsonl")) == 1


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_and_content(tmp_path):
    obs.configure(enabled=True)
    with obs.span("parent", k="v"):
        with obs.span("child"):
            pass
    path = tmp_path / "trace.json"
    doc = obs.export_chrome_trace(path)
    assert obs.validate_chrome_trace(doc) == []
    reloaded = json.loads(path.read_text())
    assert obs.validate_chrome_trace(reloaded) == []
    events = {e["name"]: e for e in reloaded["traceEvents"]}
    assert set(events) == {"parent", "child"}
    for e in events.values():
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0
    assert events["parent"]["args"]["k"] == "v"
    assert (events["child"]["args"]["parent_id"]
            == events["parent"]["args"]["span_id"])
    # child nests inside the parent on the trace timeline
    assert events["child"]["ts"] >= events["parent"]["ts"]
    assert (events["child"]["ts"] + events["child"]["dur"]
            <= events["parent"]["ts"] + events["parent"]["dur"] + 1e-3)


def test_validate_chrome_trace_flags_problems():
    assert obs.validate_chrome_trace({}) != []
    assert obs.validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": -1.0,
                            "pid": 1, "tid": 0}]}
    problems = obs.validate_chrome_trace(bad)
    assert any("dur" in p for p in problems)
    assert any("negative" in p for p in problems)


def test_jsonl_roundtrip_and_truncation_tolerance(tmp_path):
    path = tmp_path / "events.jsonl"
    obs.configure(enabled=True, jsonl_path=path)
    for i in range(3):
        with obs.span("chunk", i=i):
            pass
    obs.disable()
    rows = obs.load_jsonl(path)
    assert [r["attrs"]["i"] for r in rows] == [0, 1, 2]
    assert all(r["name"] == "chunk" and r["dur_s"] >= 0 for r in rows)
    # a SIGKILL mid-write leaves a torn final line: replay drops it only
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"name": "torn", "attrs": {"i": 3')
    rows2 = obs.load_jsonl(path)
    assert [r["attrs"]["i"] for r in rows2] == [0, 1, 2]


def test_jsonl_nonserializable_attrs_degrade(tmp_path):
    path = tmp_path / "events.jsonl"
    obs.configure(enabled=True, jsonl_path=path)
    with obs.span("np_attrs", n=np.int64(7), f=np.float64(0.5),
                  arr=np.arange(2)):
        pass
    obs.disable()
    (row,) = obs.load_jsonl(path)
    assert row["attrs"]["n"] == 7
    assert row["attrs"]["f"] == 0.5      # numpy scalars -> JSON numbers


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_instruments_and_snapshot():
    reg = obs.get_registry()
    reg.inc("a.count")
    reg.inc("a.count", 4)
    reg.set("a.gauge", 2.5)
    for v in (1.0, 3.0):
        reg.observe("a.hist", v)
    snap = obs.snapshot()
    assert snap["a.count"] == 5
    assert snap["a.gauge"] == 2.5
    assert snap["a.hist.count"] == 2
    assert snap["a.hist.sum"] == 4.0
    assert snap["a.hist.min"] == 1.0
    assert snap["a.hist.max"] == 3.0
    assert snap["a.hist.mean"] == 2.0
    assert list(snap) == sorted(snap)
    json.dumps(snap)                     # provenance-block serializable
    # get-or-create returns the same instrument
    assert reg.counter("a.count") is reg.counter("a.count")
    obs.reset_metrics()
    assert obs.snapshot() == {}


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def test_summarize_and_render():
    obs.configure(enabled=True)
    with obs.span("sweep.synthesize"):
        pass
    reg = obs.get_registry()
    reg.inc("synth_cache.hits", 30)
    reg.inc("synth_cache.misses", 10)
    reg.inc("sweep.configs", 1000)
    reg.inc("sweep.wall_s", 2.0)
    reg.inc("explore.requested_evals", 50)
    reg.inc("explore.eval_seconds", 0.5)
    s = obs.summarize()
    assert s["spans"]["sweep.synthesize"]["count"] == 1
    assert s["derived"]["synth_cache_hit_rate"] == pytest.approx(0.75)
    assert s["derived"]["sweep_configs_per_s"] == pytest.approx(500.0)
    assert s["derived"]["explore_evals_per_s"] == pytest.approx(100.0)
    text = obs.render_text(s)
    assert "sweep.synthesize" in text
    assert "synth_cache_hit_rate" in text


# ---------------------------------------------------------------------------
# instrumentation: no behavior change, consistent totals
# ---------------------------------------------------------------------------

def _sweep_once(backend: str):
    cache = PersistentSynthesisCache()
    res = _sweep_chunked(get_workload("vgg16"), _space(),
                         backend=backend, chunk_size=CHUNK, cache=cache,
                         save_cache=False)
    return res, {"hits": cache.hits, "misses": cache.misses}


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_bit_identity_telemetry_on_vs_off(backend, jax_usable):
    if backend == "jax" and not jax_usable:
        pytest.skip("jax unusable on this host")
    ref, ref_acct = _sweep_once(backend)
    obs.configure(enabled=True, reset=True)
    try:
        on, on_acct = _sweep_once(backend)
    finally:
        obs.disable()
    assert on_acct == ref_acct
    assert on.n_configs == ref.n_configs
    assert on.n_chunks == ref.n_chunks
    for m in ref.front_metrics:
        assert np.array_equal(on.front_metrics[m], ref.front_metrics[m])
    for k in ref.front_soa:
        assert np.array_equal(on.front_soa[k], ref.front_soa[k])
    # the instrumented run actually recorded the stage spans
    names = {s.name for s in obs.get_tracer().spans()}
    assert {"sweep_chunked", "sweep.synthesize", "sweep.kernel",
            "sweep.reduce"} <= names


def test_sweep_metrics_always_on():
    res, acct = _sweep_once("numpy")
    snap = obs.snapshot()
    assert snap["sweep.chunks"] == res.n_chunks
    assert snap["sweep.configs"] == res.n_configs
    assert snap["sweep.wall_s"] == pytest.approx(res.timings["wall_s"])
    assert snap["synth_cache.hits"] == acct["hits"]
    assert snap["synth_cache.misses"] == acct["misses"]
    assert obs.get_tracer().spans() == []      # tracing stayed off


def test_wall_s_flushed_on_injected_failure():
    """Satellite bugfix: a failed attempt still reports its wall time —
    both into the (discarded) timings dict and the metrics registry —
    and resumed runs report consistent totals."""
    from repro.runtime.fault_tolerance import InjectedFailure
    wl = get_workload("vgg16")
    with pytest.raises(InjectedFailure):
        _sweep_chunked(wl, _space(), backend="numpy", chunk_size=CHUNK,
                       fail_at={2: 1})
    snap = obs.snapshot()
    assert snap["sweep.failures"] == 1
    assert snap["sweep.wall_s"] > 0.0
    assert snap["sweep.chunks"] == 2           # chunks 0..1 before the boom


def test_resumed_run_totals_consistent(tmp_path):
    """Across restarts the registry counts work actually performed:
    chunks replayed from a snapshot are not re-counted, while the
    in-flight chunk the failed attempt synthesized but never
    checkpointed *is* (it genuinely runs twice — that is the cost of
    the preemption)."""
    from repro.runtime.dse_checkpoint import resume_sweep
    wl = get_workload("vgg16")
    ref = _sweep_chunked(wl, _space(), backend="numpy", chunk_size=CHUNK)
    obs.reset_metrics()
    res = resume_sweep(wl, _space, checkpoint_dir=str(tmp_path),
                       checkpoint_every=1, chunk_size=CHUNK,
                       backend="numpy", fail_at={2: 1})
    assert res.timings["restarts"] == 1
    snap = obs.snapshot()
    assert snap["sweep.restarts"] == 1
    assert snap["sweep.failures"] == 1
    assert snap["checkpoint.saves"] >= 2
    assert snap["checkpoint.restores"] >= 1
    # every chunk counted at least once, and the redo is bounded by the
    # pipeline depth (at most one dispatched-but-undrained chunk)
    assert ref.n_chunks <= snap["sweep.chunks"] <= ref.n_chunks + 1
    assert (ref.n_configs <= snap["sweep.configs"]
            <= ref.n_configs + CHUNK)
    # the result itself reports the de-duplicated totals
    assert res.n_chunks == ref.n_chunks
    assert res.n_configs == ref.n_configs


def test_root_span_error_status_on_failure():
    from repro.runtime.fault_tolerance import InjectedFailure
    obs.configure(enabled=True, reset=True)
    try:
        with pytest.raises(InjectedFailure):
            _sweep_chunked(get_workload("vgg16"), _space(),
                           backend="numpy", chunk_size=CHUNK,
                           fail_at={1: 1})
    finally:
        obs.disable()
    (root,) = obs.get_tracer().spans("sweep_chunked")
    assert root.status == "error"
    assert root.attrs["wall_s"] > 0.0


def test_evaluator_reset_stats():
    """Satellite bugfix: eval counters can be reset so a reused evaluator
    attributes stats per search instead of accumulating forever."""
    from repro.explore.search import Evaluator
    from repro.explore.space import space_for_workload
    space = space_for_workload("vgg16")
    ev = Evaluator(space, "vgg16", backend="numpy")
    rng = np.random.default_rng(0)
    g = space.random_population(8, rng)
    ev.evaluate(g)
    first = ev.stats()
    assert first["requested_evals"] == 8
    assert first["eval_seconds"] > 0.0
    ev.reset_stats()
    zeroed = ev.stats()
    assert zeroed["requested_evals"] == 0
    assert zeroed["kernel_evals"] == 0
    assert zeroed["memo_hits"] == 0
    assert zeroed["eval_seconds"] == 0.0
    # the memo survives the reset: re-evaluating the same genomes is all
    # memo hits, and the rows are identical
    F1 = ev.evaluate(g)
    assert ev.stats()["memo_hits"] == 8
    assert ev.stats()["kernel_evals"] == 0
    ev2 = Evaluator(space, "vgg16", backend="numpy")
    assert np.array_equal(F1, ev2.evaluate(g))
    # registry mirror counted both rounds
    snap = obs.snapshot()
    assert snap["explore.requested_evals"] == 24
    assert snap["explore.memo_hits"] == 8


def test_explore_spec_telemetry_field(tmp_path):
    with pytest.raises(ValueError, match="telemetry"):
        ExploreSpec.single("vgg16", chunk_size=None, telemetry="yes")
    spec = ExploreSpec.mixed("vgg16", method="random", budget=8,
                             seed=3, backend="numpy",
                             telemetry={"jsonl_path":
                                        tmp_path / "run.jsonl"})
    res = run(spec)
    assert not obs.is_enabled()            # scoped to the run
    rows = obs.load_jsonl(tmp_path / "run.jsonl")
    assert any(r["name"] == "explore.evaluate" for r in rows)
    assert res.stats["eval_seconds"] > 0.0
    # telemetry=None (default) leaves the global switch untouched and
    # changes nothing about the result
    res2 = run(ExploreSpec.mixed("vgg16", method="random", budget=8,
                                 seed=3, backend="numpy"))
    assert np.array_equal(res.genomes, res2.genomes)
    assert np.array_equal(res.front_objectives, res2.front_objectives)
