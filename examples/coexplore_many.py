"""Multi-workload co-exploration (the full QUIDAM setting): pick ONE
accelerator that serves a whole workload suite, with a per-layer
execution-precision assignment chosen *per workload*.

Runs the NSGA-II engine (with its unbounded external archive) against the
random baseline at equal budget over (shared hardware x per-workload
modes), scores genomes by worst-case-across-workloads objectives, prints
the final front with each design's per-workload precision strings, and
reports the synthesis-cache reuse that keeps W-workload evaluation ~O(1
synthesis) per hardware config.

  PYTHONPATH=src python examples/coexplore_many.py [--quick]
      [--workloads vgg16 resnet34 resnet50] [--seed 0] [--backend auto]
      [--floor-db 20]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.dse import ExploreSpec, run
from repro.explore.accuracy import AccuracySpec
from repro.core.synthesis import (clear_synthesis_cache,
                                  synthesis_cache_stats)
from repro.explore.pareto import hypervolume, reference_point

_MODE_CH = {"fp32": "F", "int16": "I", "lightpe1": "1", "lightpe2": "2"}


def _mode_string(modes) -> str:
    return "".join(_MODE_CH.get(m, m[0].upper()) for m in modes)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small budget/population")
    ap.add_argument("--workloads", nargs="+",
                    default=["vgg16", "resnet34", "resnet50"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--floor-db", type=float, default=None,
                    help="per-workload accuracy floor in dB (constraint, "
                         "rides on the accuracy spec)")
    args = ap.parse_args()

    accuracy = (None if args.floor_db is None
                else AccuracySpec(floor_db=args.floor_db))
    preset = "many-quick" if args.quick else "many-default"
    print(f"workloads={'+'.join(args.workloads)}  preset={preset}  "
          f"seed={args.seed}")

    clear_synthesis_cache()
    t0 = time.perf_counter()
    guided = run(ExploreSpec.many(args.workloads, precision="mixed",
                                  preset=preset, seed=args.seed,
                                  backend=args.backend,
                                  accuracy=accuracy))
    t_guided = time.perf_counter() - t0
    t0 = time.perf_counter()
    rand = run(ExploreSpec.many(args.workloads, precision="mixed",
                                preset=preset, method="random",
                                seed=args.seed, backend=args.backend,
                                accuracy=accuracy))
    t_rand = time.perf_counter() - t0

    ref = reference_point(np.concatenate([guided.all_objectives,
                                          rand.all_objectives]))
    hv_g = hypervolume(guided.front_objectives, ref)
    hv_r = hypervolume(rand.front_objectives, ref)
    print(f"\nnsga2 : {guided.n_evals} evals in {t_guided:.2f}s  "
          f"archive front={guided.front_size}  hypervolume={hv_g:.5g}")
    print(f"random: {rand.n_evals} evals in {t_rand:.2f}s  "
          f"front={rand.front_size}  hypervolume={hv_r:.5g}")
    print(f"guided/random hypervolume: {hv_g / max(hv_r, 1e-300):.3f}x")

    stats = synthesis_cache_stats()
    hits, misses = stats["array_hits"], stats["array_misses"]
    print(f"synthesis cache: {hits} hits / {misses} misses "
          f"({hits / max(1, hits + misses):.1%} hit rate — one synthesis "
          f"pass serves all {len(args.workloads)} workloads per hardware "
          f"config)")

    print("\nfront (per-workload modes: F=fp32 I=int16 1=lightpe1 "
          "2=lightpe2):")
    for pt in guided.front_points()[:8]:
        cfg = pt["config"]
        modes = " ".join(f"{nm}[{_mode_string(ms)}]"
                         for nm, ms in pt["modes"].items())
        print(f"  {cfg.pe_type.value:9s} {cfg.pe_rows}x{cfg.pe_cols:<3d}"
              f" glb{cfg.glb_kb:<4d}"
              f"  worst perf/area={-pt['neg_worst_perf_per_area']:8.1f}"
              f"  suite energy={pt['total_energy_j'] * 1e3:8.3f} mJ"
              f"  worst noise={pt['worst_accuracy_noise']:.2e}")
        print(f"            {modes}")

    print("\narchive hypervolume vs evaluations (guided, own reference):")
    for evals, hv in guided.history[:: max(1, len(guided.history) // 8)]:
        print(f"  {evals:6d}  {hv:.5g}")


if __name__ == "__main__":
    main()
