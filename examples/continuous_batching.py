"""Continuous-batching serving demo: requests of different lengths share
slots, new requests are admitted mid-flight (Orca-style iteration-level
scheduling), over int8-KV quantized decode.

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model
from repro.serving.scheduler import ContinuousBatcher, Request


def main():
    cfg = reduced(get_config("gemma3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab,
                                             size=rng.integers(2, 8))),
                    max_new=int(rng.integers(3, 8)))
            for i in range(6)]

    bat = ContinuousBatcher(model, params, n_slots=3, max_seq=32,
                            kv_quant=True)
    for r in reqs:
        bat.submit(r)
    t0 = time.time()
    iters = 0
    while bat.busy:
        bat.step()
        iters += 1
    dt = time.time() - t0
    total = sum(len(r.generated) for r in bat.completed)
    print(f"served {len(bat.completed)} requests / {total} tokens in "
          f"{iters} iterations ({dt:.1f}s, 3 slots, int8 KV)")
    for r in sorted(bat.completed, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
